//! `cargo bench` entry for E3 (Fig. 4): a reduced overhead sweep.
//! The full paper sweep runs via `cf4rs bench overhead`.

use cf4rs::harness::overhead::{render, sweep, SweepOpts};

fn main() {
    println!("== Fig. 4 overhead sweep (reduced; full: `cf4rs bench overhead`) ==");
    let mut opts = SweepOpts::quick();
    opts.runs = 6;
    match sweep(&opts) {
        Ok(cells) => print!("{}", render(&cells)),
        Err(e) => {
            eprintln!("sweep failed: {e}");
            std::process::exit(1);
        }
    }
}
