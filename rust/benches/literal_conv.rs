//! Buffer ⇄ literal marshalling cost: the native device's per-launch
//! data-movement tax (bytes → Literal → PJRT → Literal → bytes).

use cf4rs::harness::microbench::bench;
use cf4rs::runtime::literal::{
    bytes_from_u64, literal_from_bytes, literal_to_bytes, u64_from_bytes, ElemType,
};

fn main() {
    println!("== literal conversion ==");
    for n in [4096usize, 65536, 1 << 20] {
        let v: Vec<u64> = (0..n as u64).collect();
        let bytes = bytes_from_u64(&v);
        bench(&format!("bytes->literal u64[{n}]"), 2, 9, || {
            let lit = literal_from_bytes(ElemType::U64, &bytes, false).unwrap();
            std::hint::black_box(lit.element_count());
        });
        let lit = literal_from_bytes(ElemType::U64, &bytes, false).unwrap();
        bench(&format!("literal->bytes u64[{n}]"), 2, 9, || {
            let b = literal_to_bytes(ElemType::U64, &lit).unwrap();
            std::hint::black_box(b.len());
        });
        bench(&format!("u64 vec encode+decode [{n}]"), 2, 9, || {
            let b = bytes_from_u64(&v);
            let w = u64_from_bytes(&b).unwrap();
            std::hint::black_box(w.len());
        });
    }
}
