//! Profiler analysis scaling: `calc()`-equivalent cost (aggregation +
//! sweep-line overlaps + union) on synthetic event sets of growing size.
//! This is the "computationally expensive" step the paper calls out in
//! §6.2 — the dominant framework overhead at large iteration counts.

use cf4rs::ccl::prof::info::ProfInfo;
use cf4rs::ccl::prof::overlap::{compute_overlaps, effective_total};
use cf4rs::harness::microbench::bench;
use cf4rs::rawcl::simexec::{init_seed, xorshift};

fn synthetic_infos(n: usize) -> Vec<ProfInfo> {
    let mut s = init_seed(7);
    let mut infos = Vec::with_capacity(n);
    let mut cursors = [0u64; 2];
    for i in 0..n {
        s = xorshift(s);
        let q = (i % 2) as usize;
        let start = cursors[q] + s % 40;
        s = xorshift(s);
        let end = start + 1 + s % 150;
        cursors[q] = end.saturating_sub(30); // force frequent overlaps
        infos.push(ProfInfo {
            name: if q == 0 { "RNG_KERNEL" } else { "READ_BUFFER" }.into(),
            queue: if q == 0 { "Main" } else { "Comms" }.into(),
            t_queued: start,
            t_submit: start,
            t_start: start,
            t_end: end,
        });
    }
    infos
}

fn main() {
    println!("== profiler calc scaling ==");
    for n in [1_000usize, 10_000, 100_000] {
        let infos = synthetic_infos(n);
        bench(&format!("overlaps+union over {n} events"), 1, 7, || {
            let ov = compute_overlaps(&infos);
            let eff = effective_total(&infos);
            std::hint::black_box((ov.len(), eff));
        });
    }
}
