//! Enqueue-path microbenchmarks: the per-command host cost of the raw
//! substrate vs the framework (the mechanism behind Fig. 4's small-n
//! regime), plus the cost of the framework's event tracking.

use cf4rs::ccl::{Arg, Buffer, Context, Program, Queue};
use cf4rs::harness::microbench::bench_per_op;
use cf4rs::rawcl::types::MemFlags;
use cf4rs::rawcl::{self, ArgValue, QueueProps};

const N: usize = 4096;
const OPS: u32 = 64;

fn main() {
    println!("== enqueue-path microbench (n={N}, {OPS} launches/sample) ==");

    // framework path
    {
        let ctx = Context::new_gpu().unwrap();
        let dev = ctx.device(0).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let prg = Program::new_from_artifacts(&ctx, &["rng_n4096"]).unwrap();
        prg.build().unwrap();
        let k = prg.kernel("prng_step").unwrap();
        let a = Buffer::new(&ctx, MemFlags::READ_WRITE, N * 8).unwrap();
        let b = Buffer::new(&ctx, MemFlags::READ_WRITE, N * 8).unwrap();
        bench_per_op("ccl: set_args_and_enqueue_ndrange", 2, 12, OPS, || {
            for _ in 0..OPS {
                k.set_args_and_enqueue_ndrange(
                    &q,
                    &[N],
                    None,
                    &[],
                    &[Arg::priv_u32(N as u32), Arg::buf(&a), Arg::buf(&b)],
                )
                .unwrap();
            }
            q.finish().unwrap();
            q.clear_events();
        });
    }

    // raw path
    {
        let mut st = 0;
        let ctx = rawcl::create_context(&[rawcl::DeviceId(1)], &mut st);
        let q = rawcl::create_command_queue(ctx, rawcl::DeviceId(1), QueueProps::PROFILING_ENABLE, &mut st);
        let src = cf4rs::runtime::hlogen::resolve_named_source("rng_n4096").unwrap();
        let prg = rawcl::create_program_with_source(ctx, &[src], &mut st);
        rawcl::build_program(prg, None, "");
        let k = rawcl::create_kernel(prg, "prng_step", &mut st);
        let a = rawcl::create_buffer(ctx, MemFlags::READ_WRITE, N * 8, None, &mut st);
        let b = rawcl::create_buffer(ctx, MemFlags::READ_WRITE, N * 8, None, &mut st);
        let narg = ArgValue::Scalar((N as u32).to_le_bytes().to_vec());
        bench_per_op("raw: set_kernel_arg x3 + enqueue", 2, 12, OPS, || {
            for _ in 0..OPS {
                rawcl::set_kernel_arg(k, 0, &narg);
                rawcl::set_kernel_arg(k, 1, &ArgValue::Buffer(a));
                rawcl::set_kernel_arg(k, 2, &ArgValue::Buffer(b));
                let mut evt = rawcl::EventH::NULL;
                rawcl::enqueue_ndrange_kernel(q, k, 1, &[N], None, &[], Some(&mut evt));
                rawcl::release_event(evt);
            }
            rawcl::finish(q);
        });
        rawcl::release_mem_object(a);
        rawcl::release_mem_object(b);
        rawcl::release_kernel(k);
        rawcl::release_program(prg);
        rawcl::release_command_queue(q);
        rawcl::release_context(ctx);
    }
}
