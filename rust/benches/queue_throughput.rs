//! End-to-end queue throughput on both backends: commands/second through
//! one in-order queue, and PRNG service MiB/s (the headline §5 metric).

use cf4rs::ccl::{Buffer, Context, Device, Queue};
use cf4rs::coordinator::{run_ccl, RngConfig, Sink};
use cf4rs::harness::microbench::{bench, bench_per_op};
use cf4rs::rawcl::types::{DeviceId, MemFlags};

fn main() {
    println!("== queue throughput ==");

    // fill-command round trips on the sim device (pure coordination)
    {
        let ctx = Context::new_gpu().unwrap();
        let dev = ctx.device(0).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let b = Buffer::new(&ctx, MemFlags::READ_WRITE, 4096).unwrap();
        bench_per_op("sim queue: enqueue_fill x64 + finish", 2, 10, 64, || {
            for _ in 0..64 {
                b.enqueue_fill(&q, &[0xA5], 0, 4096, &[]).unwrap();
            }
            q.finish().unwrap();
            q.clear_events();
        });
    }

    // native PJRT kernel dispatch
    {
        let dev = Device::from_id(DeviceId(0)).unwrap();
        let ctx = Context::new_from_devices(&[dev]).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let prg =
            cf4rs::ccl::Program::new_from_artifacts(&ctx, &["rng_n4096"]).unwrap();
        prg.build().unwrap();
        let k = prg.kernel("prng_step").unwrap();
        let a = Buffer::new(&ctx, MemFlags::READ_WRITE, 4096 * 8).unwrap();
        let b2 = Buffer::new(&ctx, MemFlags::READ_WRITE, 4096 * 8).unwrap();
        bench_per_op("native PJRT: rng_n4096 dispatch", 2, 10, 16, || {
            use cf4rs::ccl::Arg;
            for _ in 0..16 {
                k.set_args_and_enqueue_ndrange(
                    &q,
                    &[4096],
                    None,
                    &[],
                    &[Arg::priv_u32(4096), Arg::buf(&a), Arg::buf(&b2)],
                )
                .unwrap();
            }
            q.finish().unwrap();
            q.clear_events();
        });
    }

    // large-n sim service: stresses the sim kernel execution path
    {
        let mut cfg = RngConfig::new(1 << 20, 4);
        cfg.device_index = 1;
        cfg.profile = false;
        cfg.sink = Sink::Discard;
        bench("rng service n=2^20 i=4 (gtx1080sim)", 1, 5, || {
            run_ccl(&cfg).unwrap();
        });
    }

    // end-to-end service throughput (the paper's headline workload)
    for (dev, name) in [(1u32, "gtx1080sim"), (0u32, "native")] {
        let mut cfg = RngConfig::new(65536, 8);
        cfg.device_index = dev;
        cfg.profile = false;
        cfg.sink = Sink::Discard;
        let bytes = 8.0 * 65536.0 * 8.0;
        let r = bench(&format!("rng service n=65536 i=8 ({name})"), 1, 5, || {
            run_ccl(&cfg).unwrap();
        });
        let mibs = bytes / r.median().expect("5 samples").as_secs_f64() / (1 << 20) as f64;
        println!("    -> {mibs:.1} MiB/s");
    }
}
// (perf-pass addition) large-n sim service — stresses the sim kernel
// execution path whose copies the perf pass eliminates.
