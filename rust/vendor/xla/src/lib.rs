//! # `xla` facade — a deterministic PJRT stand-in for cf4rs
//!
//! This crate exposes the *exact* subset of the xla-rs binding surface
//! that cf4rs' [`runtime`] module consumes (`PjRtClient`, `Literal`,
//! `HloModuleProto`, `XlaComputation`, `PjRtLoadedExecutable`), but backs
//! it with a reference interpreter instead of `libxla_extension`:
//!
//! * "compiling" a module parses its `HloModule` header (name + entry
//!   signature) and `// cf4rs.*` metadata directives;
//! * "executing" it runs the scalar reference implementation of the
//!   recognised kernel family (`prng_init`, `prng_step`,
//!   `prng_multi_step`, `vecadd`, `saxpy`) — bit-compatible with the
//!   Pallas kernels and the python oracles in
//!   `python/compile/kernels/ref.py`.
//!
//! The point is hermeticity: `cargo build && cargo test` work on any
//! machine (CI included) with zero native dependencies, while every
//! byte that crosses the executable boundary is identical to what the
//! real AOT artifacts produce. To run on a real PJRT plugin, point the
//! `xla` path dependency in `rust/Cargo.toml` at the real bindings —
//! no cf4rs source change is needed.

use std::fmt;
use std::path::Path;

mod interp;
mod kernels;

pub use interp::{ParsedModule, TensorSig};

// ---------------------------------------------------------------------------
// Error type
// ---------------------------------------------------------------------------

/// Error type mirroring `xla::Error`: a message, nothing fancy.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(facade): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// Primitive types, shapes, literals
// ---------------------------------------------------------------------------

/// Element types the facade understands (what the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrimitiveType {
    U32,
    U64,
    F32,
}

impl PrimitiveType {
    pub fn size_bytes(self) -> usize {
        match self {
            Self::U32 | Self::F32 => 4,
            Self::U64 => 8,
        }
    }

    pub(crate) fn parse(s: &str) -> Result<Self> {
        match s {
            "u32" => Ok(Self::U32),
            "u64" => Ok(Self::U64),
            "f32" => Ok(Self::F32),
            other => Err(Error::msg(format!("unsupported element type {other:?}"))),
        }
    }
}

/// Minimal shape view: enough for `tuple_size()` queries.
#[derive(Debug, Clone)]
pub struct Shape {
    tuple_arity: Option<usize>,
}

impl Shape {
    /// `Some(n)` for tuple shapes, `None` for array/scalar shapes.
    pub fn tuple_size(&self) -> Option<usize> {
        self.tuple_arity
    }
}

/// Sealed marker for plain-old-data element views used by
/// `copy_raw_from`/`copy_raw_to`.
pub trait NativeType: Copy + 'static + private::Sealed {}

mod private {
    pub trait Sealed {}
    impl Sealed for u32 {}
    impl Sealed for u64 {}
    impl Sealed for f32 {}
}

impl NativeType for u32 {}
impl NativeType for u64 {}
impl NativeType for f32 {}

/// A typed host-side tensor (or tuple of tensors), stored as raw
/// native-endian bytes, mirroring `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    prim: PrimitiveType,
    /// Dimensions; empty = rank-0 scalar.
    dims: Vec<usize>,
    data: Vec<u8>,
    /// `Some` when this literal is a tuple; `prim`/`dims`/`data` are then
    /// unused.
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Zero-initialised literal of the given element type and dims
    /// (empty dims = scalar).
    pub fn create_from_shape(prim: PrimitiveType, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        Self {
            prim,
            dims: dims.to_vec(),
            data: vec![0u8; n * prim.size_bytes()],
            tuple: None,
        }
    }

    /// Build a tuple literal from element literals.
    pub fn tuple(elements: Vec<Literal>) -> Self {
        Self {
            prim: PrimitiveType::U32,
            dims: Vec::new(),
            data: Vec::new(),
            tuple: Some(elements),
        }
    }

    /// Number of elements (product of dims; 1 for scalars).
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// The element type of an array literal.
    pub fn primitive_type(&self) -> PrimitiveType {
        self.prim
    }

    /// Raw bytes of an array literal (native endian).
    pub fn raw_bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape {
            tuple_arity: self.tuple.as_ref().map(Vec::len),
        })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error::msg("literal is not a tuple"))
    }

    /// Copy typed host data into the literal (sizes must match).
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        let esz = std::mem::size_of::<T>();
        if esz != self.prim.size_bytes() {
            return Err(Error::msg(format!(
                "element size mismatch: literal {} B, source {} B",
                self.prim.size_bytes(),
                esz
            )));
        }
        if src.len() != self.element_count() {
            return Err(Error::msg(format!(
                "element count mismatch: literal {}, source {}",
                self.element_count(),
                src.len()
            )));
        }
        // SAFETY: T is a sealed POD numeric type; byte length checked.
        let bytes = unsafe {
            std::slice::from_raw_parts(src.as_ptr() as *const u8, src.len() * esz)
        };
        self.data.clear();
        self.data.extend_from_slice(bytes);
        Ok(())
    }

    /// Copy the literal's data out into a typed host slice.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let esz = std::mem::size_of::<T>();
        if esz != self.prim.size_bytes() {
            return Err(Error::msg(format!(
                "element size mismatch: literal {} B, destination {} B",
                self.prim.size_bytes(),
                esz
            )));
        }
        if dst.len() != self.element_count() {
            return Err(Error::msg(format!(
                "element count mismatch: literal {}, destination {}",
                self.element_count(),
                dst.len()
            )));
        }
        // SAFETY: as above; lengths checked.
        let out = unsafe {
            std::slice::from_raw_parts_mut(dst.as_mut_ptr() as *mut u8, dst.len() * esz)
        };
        out.copy_from_slice(&self.data);
        Ok(())
    }

    /// Internal constructor used by the interpreter.
    pub(crate) fn from_bytes(prim: PrimitiveType, dims: Vec<usize>, data: Vec<u8>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>() * prim.size_bytes());
        Self { prim, dims, data, tuple: None }
    }

    pub(crate) fn dims(&self) -> &[usize] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// Module / computation / client / executable
// ---------------------------------------------------------------------------

/// Parsed stand-in for `xla::HloModuleProto`: retains the module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load a module from an HLO text file.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading {}: {e}", path.display())))?;
        // Validate eagerly so errors surface at load time, like the
        // real proto parser.
        interp::ParsedModule::parse(&text)?;
        Ok(Self { text })
    }

    /// Parse a module from in-memory HLO text bytes.
    pub fn parse_and_return_unverified_module(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| Error::msg(format!("module text is not UTF-8: {e}")))?;
        interp::ParsedModule::parse(text)?;
        Ok(Self { text: text.to_string() })
    }
}

/// Stand-in for `xla::XlaComputation`.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    module: interp::ParsedModule,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        // Parse already validated by the proto constructors.
        let module = interp::ParsedModule::parse(&proto.text)
            .expect("proto text validated at construction");
        Self { module }
    }

    /// Full module name, `jit_` prefix included (callers strip it).
    pub fn name(&self) -> String {
        self.module.raw_name.clone()
    }
}

/// Stand-in for `xla::PjRtClient` (one in-process "CPU device").
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "cf4rs interpreter (cpu)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    /// "Compile": retain the parsed module for interpretation.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { module: comp.module.clone() })
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    module: interp::ParsedModule,
}

impl PjRtLoadedExecutable {
    /// Execute the module on literal inputs.
    ///
    /// Matches the xla-rs shape: one replica, one result buffer holding
    /// a tuple literal (the `return_tuple=True` lowering convention).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let inputs: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let outputs = interp::execute(&self.module, &inputs)?;
        Ok(vec![vec![PjRtBuffer { lit: Literal::tuple(outputs) }]])
    }
}

/// Stand-in for `xla::PjRtBuffer`: already host-resident.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RNG_N4: &str = "HloModule jit_prng_step, entry_computation_layout=\
                          {(u64[4]{0})->(u64[4]{0})}\n\
                          ENTRY main {\n  p0 = u64[4]{0} parameter(0)\n\
                          ROOT t = (u64[4]{0}) tuple(p0)\n}\n";

    #[test]
    fn literal_roundtrip_u64() {
        let mut lit = Literal::create_from_shape(PrimitiveType::U64, &[3]);
        lit.copy_raw_from(&[1u64, 2, 3]).unwrap();
        assert_eq!(lit.element_count(), 3);
        let mut out = [0u64; 3];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, [1, 2, 3]);
    }

    #[test]
    fn scalar_literal_shape() {
        let lit = Literal::create_from_shape(PrimitiveType::F32, &[]);
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.shape().unwrap().tuple_size(), None);
    }

    #[test]
    fn tuple_literal_decomposes() {
        let a = Literal::create_from_shape(PrimitiveType::U64, &[2]);
        let t = Literal::tuple(vec![a.clone(), a]);
        assert_eq!(t.shape().unwrap().tuple_size(), Some(2));
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn mismatched_copy_is_error() {
        let mut lit = Literal::create_from_shape(PrimitiveType::U64, &[3]);
        assert!(lit.copy_raw_from(&[1u64, 2]).is_err());
        assert!(lit.copy_raw_from(&[1u32, 2, 3]).is_err());
    }

    #[test]
    fn compile_and_execute_end_to_end() {
        let proto =
            HloModuleProto::parse_and_return_unverified_module(RNG_N4.as_bytes()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        assert_eq!(comp.name(), "jit_prng_step");
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();

        let mut input = Literal::create_from_shape(PrimitiveType::U64, &[4]);
        input.copy_raw_from(&[1u64, 2, 3, 4]).unwrap();
        let bufs = exe.execute::<Literal>(&[input]).unwrap();
        let result = bufs[0][0].to_literal_sync().unwrap();
        let parts = result.to_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        let mut out = [0u64; 4];
        parts[0].copy_raw_to(&mut out).unwrap();
        // prng_step == one xorshift(21, 35, 4) step.
        assert_eq!(out[0], crate::kernels::xorshift(1));
    }

    #[test]
    fn platform_is_cpuish() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().to_lowercase().contains("cpu"));
        assert_eq!(c.device_count(), 1);
    }

    #[test]
    fn bad_module_text_rejected() {
        assert!(HloModuleProto::parse_and_return_unverified_module(b"__kernel void f()").is_err());
    }
}
