//! The facade's HLO interpreter: header parsing + kernel dispatch.
//!
//! A module is recognised by its (`jit_`-stripped) name and executed by
//! the matching scalar reference kernel. Structured metadata the real
//! compiler would recover from the module body travels in comment
//! directives the cf4rs HLO generator emits:
//!
//! ```text
//! // cf4rs.k = 16           (fused step count of prng_multi_step)
//! // cf4rs.gid_offset = 4096 (first global index hashed by prng_init)
//! ```

use crate::kernels;
use crate::{Error, Literal, PrimitiveType, Result};

/// One tensor slot of the entry signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub prim: PrimitiveType,
    /// Empty = rank-0 scalar.
    pub dims: Vec<usize>,
}

impl TensorSig {
    fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// A parsed module: name, signature, metadata directives.
#[derive(Debug, Clone)]
pub struct ParsedModule {
    /// Module name as written (`jit_` prefix retained).
    pub raw_name: String,
    /// Name with any `jit_` prefix stripped (the kernel family key).
    pub name: String,
    pub params: Vec<TensorSig>,
    pub results: Vec<TensorSig>,
    /// Fused step count (`// cf4rs.k`); `None` when the module carries
    /// no directive. `prng_multi_step` REQUIRES it: a real lowered
    /// artifact bakes the unrolled steps into the body, which this
    /// interpreter never reads, so executing without the directive
    /// would silently run one step — refuse instead.
    pub k: Option<usize>,
    /// Global-index offset for init (`// cf4rs.gid_offset`), default 0.
    pub gid_offset: u64,
}

impl ParsedModule {
    /// Parse the `HloModule` header line and metadata directives.
    pub fn parse(text: &str) -> Result<Self> {
        let header = text
            .lines()
            .find(|l| !l.trim().is_empty())
            .ok_or_else(|| Error::msg("empty module text"))?;
        let rest = header.strip_prefix("HloModule ").ok_or_else(|| {
            Error::msg(format!("first line is not an HloModule header: {header:?}"))
        })?;
        let (raw_name, attrs) = match rest.find(',') {
            Some(i) => (rest[..i].trim(), &rest[i + 1..]),
            None => (rest.trim(), ""),
        };
        if raw_name.is_empty() {
            return Err(Error::msg("empty module name"));
        }
        let name = raw_name.strip_prefix("jit_").unwrap_or(raw_name).to_string();

        let (params, results) = match attrs.find("entry_computation_layout={") {
            Some(start) => {
                let sig = &attrs[start + "entry_computation_layout={".len()..];
                let end = matching_brace(sig)
                    .ok_or_else(|| Error::msg("unterminated entry_computation_layout"))?;
                let sig = &sig[..end];
                let arrow = sig
                    .find("->")
                    .ok_or_else(|| Error::msg("no -> in entry_computation_layout"))?;
                (parse_tensor_list(&sig[..arrow])?, parse_tensor_list(&sig[arrow + 2..])?)
            }
            None => (Vec::new(), Vec::new()),
        };

        let mut k = None;
        let mut gid_offset = 0u64;
        for line in text.lines() {
            let line = line.trim();
            let Some(directive) = line.strip_prefix("// cf4rs.") else {
                continue;
            };
            let Some((key, value)) = directive.split_once('=') else {
                continue;
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "k" => {
                    k = Some(value.parse().map_err(|_| {
                        Error::msg(format!("bad cf4rs.k directive {value:?}"))
                    })?);
                }
                "gid_offset" => {
                    gid_offset = value.parse().map_err(|_| {
                        Error::msg(format!("bad cf4rs.gid_offset directive {value:?}"))
                    })?;
                }
                _ => {} // unknown directives are forward-compatible no-ops
            }
        }

        Ok(Self { raw_name: raw_name.to_string(), name, params, results, k, gid_offset })
    }
}

/// Index of the `}` closing the layout (which itself contains `{0}`
/// layout annotations, so depth must be counted).
fn matching_brace(s: &str) -> Option<usize> {
    let mut depth = 1usize;
    for (i, c) in s.char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse `(u64[4096]{0}, f32[])` — a parenthesised tensor list.
fn parse_tensor_list(s: &str) -> Result<Vec<TensorSig>> {
    let s = s.trim();
    let s = s
        .strip_prefix('(')
        .and_then(|t| t.strip_suffix(')'))
        .ok_or_else(|| Error::msg(format!("tensor list not parenthesised: {s:?}")))?;
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let push = |part: &str, out: &mut Vec<TensorSig>| -> Result<()> {
        let part = part.trim();
        if !part.is_empty() {
            out.push(parse_tensor(part)?);
        }
        Ok(())
    };
    for (i, c) in s.char_indices() {
        match c {
            '[' | '{' | '(' => depth += 1,
            ']' | '}' | ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                push(&s[start..i], &mut out)?;
                start = i + 1;
            }
            _ => {}
        }
    }
    push(&s[start..], &mut out)?;
    Ok(out)
}

/// Parse one `u64[4096]{0}` / `f32[]` tensor.
fn parse_tensor(s: &str) -> Result<TensorSig> {
    let bracket = s
        .find('[')
        .ok_or_else(|| Error::msg(format!("no dims bracket in tensor {s:?}")))?;
    let prim = PrimitiveType::parse(&s[..bracket])?;
    let rest = &s[bracket + 1..];
    let close = rest
        .find(']')
        .ok_or_else(|| Error::msg(format!("unterminated dims in tensor {s:?}")))?;
    let dims_str = &rest[..close];
    let dims = if dims_str.is_empty() {
        Vec::new()
    } else {
        dims_str
            .split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::msg(format!("bad dim {d:?} in tensor {s:?}")))
            })
            .collect::<Result<Vec<_>>>()?
    };
    Ok(TensorSig { prim, dims })
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

fn check_inputs(module: &ParsedModule, inputs: &[&Literal]) -> Result<()> {
    if inputs.len() != module.params.len() {
        return Err(Error::msg(format!(
            "{}: expected {} inputs, got {}",
            module.name,
            module.params.len(),
            inputs.len()
        )));
    }
    for (i, (sig, lit)) in module.params.iter().zip(inputs).enumerate() {
        // Element type and count must match; a rank-1 literal feeding a
        // rank-2 parameter is accepted as an implicit (free) reshape —
        // hosts hand over flat byte buffers, the signature is
        // authoritative for geometry.
        if lit.primitive_type() != sig.prim || lit.element_count() != sig.element_count()
        {
            return Err(Error::msg(format!(
                "{}: input {i} shape mismatch (want {:?}{:?}, got {:?}{:?})",
                module.name,
                sig.prim,
                sig.dims,
                lit.primitive_type(),
                lit.dims()
            )));
        }
    }
    Ok(())
}

fn u64s(lit: &Literal) -> Vec<u64> {
    lit.raw_bytes()
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn f32s(lit: &Literal) -> Vec<f32> {
    lit.raw_bytes()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn u64_literal(dims: Vec<usize>, values: impl Iterator<Item = u64>) -> Literal {
    let mut data = Vec::new();
    for v in values {
        data.extend_from_slice(&v.to_le_bytes());
    }
    Literal::from_bytes(PrimitiveType::U64, dims, data)
}

fn f32_literal(dims: Vec<usize>, values: impl Iterator<Item = f32>) -> Literal {
    let mut data = Vec::new();
    for v in values {
        data.extend_from_slice(&v.to_le_bytes());
    }
    Literal::from_bytes(PrimitiveType::F32, dims, data)
}

/// Execute a parsed module on literal inputs; returns the result tensors
/// in signature order.
pub fn execute(module: &ParsedModule, inputs: &[&Literal]) -> Result<Vec<Literal>> {
    check_inputs(module, inputs)?;
    let result_sig = module
        .results
        .first()
        .ok_or_else(|| Error::msg(format!("{}: module has no result tensor", module.name)))?;
    let n = result_sig.element_count();
    // No explicit return-type annotation: closures pin elided reference
    // lifetimes too eagerly; inference ties it to `inputs` correctly.
    let input = |i: usize| {
        inputs.get(i).copied().ok_or_else(|| {
            Error::msg(format!("{}: module declares too few parameters", module.name))
        })
    };
    match module.name.as_str() {
        "prng_init" => {
            let off = module.gid_offset;
            Ok(vec![u64_literal(
                result_sig.dims.clone(),
                (0..n as u64).map(|i| kernels::init_seed((off + i) as u32)),
            )])
        }
        "prng_step" | "prng_multi_step" => {
            let k = if module.name == "prng_multi_step" {
                module.k.ok_or_else(|| {
                    Error::msg(
                        "prng_multi_step module has no // cf4rs.k directive: the \
                         facade interpreter cannot recover the fused step count \
                         from a lowered artifact body — use generated HLO \
                         (runtime::hlogen) or real PJRT bindings",
                    )
                })?
            } else {
                1
            };
            let state = u64s(input(0)?);
            Ok(vec![u64_literal(
                result_sig.dims.clone(),
                state.into_iter().map(|mut s| {
                    for _ in 0..k {
                        s = kernels::xorshift(s);
                    }
                    s
                }),
            )])
        }
        "vecadd" => {
            let (x, y) = (f32s(input(0)?), f32s(input(1)?));
            Ok(vec![f32_literal(
                result_sig.dims.clone(),
                x.iter().zip(&y).map(|(a, b)| a + b),
            )])
        }
        "saxpy" => {
            let a = *f32s(input(0)?)
                .first()
                .ok_or_else(|| Error::msg("saxpy: empty scalar input"))?;
            let (x, y) = (f32s(input(1)?), f32s(input(2)?));
            Ok(vec![f32_literal(
                result_sig.dims.clone(),
                x.iter().zip(&y).map(|(xi, yi)| a * xi + yi),
            )])
        }
        "reduce" => {
            let xs = u64s(input(0)?);
            Ok(vec![u64_literal(
                result_sig.dims.clone(),
                std::iter::once(kernels::reduce_tree(&xs)),
            )])
        }
        "stencil5" => {
            let dims = &result_sig.dims;
            if dims.len() != 2 {
                return Err(Error::msg(format!(
                    "stencil5: expected a rank-2 result, got {dims:?}"
                )));
            }
            let (h, w) = (dims[0], dims[1]);
            let g = f32s(input(0)?);
            if g.len() != h * w {
                return Err(Error::msg("stencil5: grid size mismatch"));
            }
            let mut out = vec![0f32; h * w];
            kernels::stencil5_grid(&g, &mut out, h, w);
            Ok(vec![f32_literal(result_sig.dims.clone(), out.into_iter())])
        }
        "matmul" => {
            let dims = &result_sig.dims;
            if dims.len() != 2 {
                return Err(Error::msg(format!(
                    "matmul: expected a rank-2 result, got {dims:?}"
                )));
            }
            let (rows, d) = (dims[0], dims[1]);
            let (a, b) = (f32s(input(0)?), f32s(input(1)?));
            if a.len() != rows * d || b.len() != d * d {
                return Err(Error::msg("matmul: operand size mismatch"));
            }
            let mut out = vec![0f32; rows * d];
            kernels::matmul_rows(&a, &b, &mut out, rows, d);
            Ok(vec![f32_literal(result_sig.dims.clone(), out.into_iter())])
        }
        other => Err(Error::msg(format!(
            "facade interpreter cannot execute kernel family {other:?} \
             (known: prng_init, prng_step, prng_multi_step, vecadd, saxpy, \
             reduce, stencil5, matmul)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit_u64(v: &[u64]) -> Literal {
        let mut l = Literal::create_from_shape(PrimitiveType::U64, &[v.len()]);
        l.copy_raw_from(v).unwrap();
        l
    }

    #[test]
    fn parses_header_and_directives() {
        let m = ParsedModule::parse(
            "HloModule jit_prng_multi_step, entry_computation_layout=\
             {(u64[8]{0})->(u64[8]{0})}\n// cf4rs.k = 5\nENTRY e {}\n",
        )
        .unwrap();
        assert_eq!(m.name, "prng_multi_step");
        assert_eq!(m.k, Some(5));
        assert_eq!(m.params.len(), 1);
        assert_eq!(m.results[0].element_count(), 8);
    }

    #[test]
    fn init_respects_gid_offset() {
        let m = ParsedModule::parse(
            "HloModule jit_prng_init, entry_computation_layout={()->(u64[4]{0})}\n\
             // cf4rs.gid_offset = 100\n",
        )
        .unwrap();
        let out = execute(&m, &[]).unwrap();
        let v = u64s(&out[0]);
        assert_eq!(v[0], kernels::init_seed(100));
        assert_eq!(v[3], kernels::init_seed(103));
    }

    #[test]
    fn multi_step_equals_repeated_single() {
        let step = ParsedModule::parse(
            "HloModule jit_prng_step, entry_computation_layout=\
             {(u64[3]{0})->(u64[3]{0})}\n",
        )
        .unwrap();
        let multi = ParsedModule::parse(
            "HloModule jit_prng_multi_step, entry_computation_layout=\
             {(u64[3]{0})->(u64[3]{0})}\n// cf4rs.k = 4\n",
        )
        .unwrap();
        let seed = [7u64, 11, 13];
        let fused = u64s(&execute(&multi, &[&lit_u64(&seed)]).unwrap()[0]);
        let mut state = seed.to_vec();
        for _ in 0..4 {
            state = u64s(&execute(&step, &[&lit_u64(&state)]).unwrap()[0]);
        }
        assert_eq!(fused, state);
    }

    #[test]
    fn multi_step_without_k_directive_is_refused() {
        // A real lowered artifact has the steps unrolled in its body and
        // no directive — executing it here must be an error, never a
        // silent single step.
        let m = ParsedModule::parse(
            "HloModule jit_prng_multi_step, entry_computation_layout=\
             {(u64[3]{0})->(u64[3]{0})}\n",
        )
        .unwrap();
        let err = execute(&m, &[&lit_u64(&[1, 2, 3])]).unwrap_err();
        assert!(err.to_string().contains("cf4rs.k"), "{err}");
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let m = ParsedModule::parse(
            "HloModule jit_prng_step, entry_computation_layout=\
             {(u64[4]{0})->(u64[4]{0})}\n",
        )
        .unwrap();
        assert!(execute(&m, &[&lit_u64(&[1, 2])]).is_err());
        assert!(execute(&m, &[]).is_err());
    }

    #[test]
    fn reduce_sums_with_wrapping_adds() {
        let m = ParsedModule::parse(
            "HloModule jit_reduce, entry_computation_layout=\
             {(u64[4]{0})->(u64[1]{0})}\n",
        )
        .unwrap();
        let out = execute(&m, &[&lit_u64(&[u64::MAX, 1, 2, 3])]).unwrap();
        assert_eq!(u64s(&out[0]), vec![5u64], "wrapping sum");
    }

    #[test]
    fn stencil_and_matmul_read_geometry_from_signature() {
        let st = ParsedModule::parse(
            "HloModule jit_stencil5, entry_computation_layout=\
             {(f32[2,2]{1,0})->(f32[2,2]{1,0})}\n",
        )
        .unwrap();
        let mut g = Literal::create_from_shape(PrimitiveType::F32, &[4]);
        g.copy_raw_from(&[1.0f32, 1.0, 1.0, 1.0]).unwrap();
        let out = execute(&st, &[&g]).unwrap();
        // Every cell of a 2×2 all-ones grid has exactly 2 neighbours.
        assert_eq!(f32s(&out[0]), vec![0.75f32; 4]);

        let mm = ParsedModule::parse(
            "HloModule jit_matmul, entry_computation_layout=\
             {(f32[1,2]{1,0}, f32[2,2]{1,0})->(f32[1,2]{1,0})}\n",
        )
        .unwrap();
        let mut a = Literal::create_from_shape(PrimitiveType::F32, &[2]);
        a.copy_raw_from(&[1.0f32, 2.0]).unwrap();
        let mut b = Literal::create_from_shape(PrimitiveType::F32, &[4]);
        b.copy_raw_from(&[1.0f32, 0.0, 0.0, 1.0]).unwrap();
        let out = execute(&mm, &[&a, &b]).unwrap();
        assert_eq!(f32s(&out[0]), vec![1.0f32, 2.0]);
    }

    #[test]
    fn unknown_family_rejected_at_execute() {
        let m = ParsedModule::parse(
            "HloModule jit_mystery, entry_computation_layout={()->(u64[4]{0})}\n",
        )
        .unwrap();
        assert!(execute(&m, &[]).is_err());
    }
}
