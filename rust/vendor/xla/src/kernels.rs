//! Scalar reference kernels — the facade's "device code".
//!
//! Bit-compatible with `rust/src/rawcl/simexec.rs` and the python
//! oracles in `python/compile/kernels/ref.py`. Duplicated here (rather
//! than imported) so the facade crate stays dependency-free in both
//! directions; the cross-crate equivalence is pinned by the
//! known-answer tests below and by the cf4rs backend cross-validation
//! suite.

/// Jenkins 6-shift integer hash (listing S4, low word).
#[inline]
pub fn jenkins6(mut a: u32) -> u32 {
    a = a.wrapping_add(0x7ED5_5D16).wrapping_add(a << 12);
    a = (a ^ 0xC761_C23C) ^ (a >> 19);
    a = a.wrapping_add(0x1656_67B1).wrapping_add(a << 5);
    a = a.wrapping_add(0xD3A2_646C) ^ (a << 9);
    a = a.wrapping_add(0xFD70_46C5).wrapping_add(a << 3);
    a = a.wrapping_sub(0xB55A_4F09).wrapping_sub(a >> 16);
    a
}

/// Thomas Wang 32-bit hash (listing S4, high word).
#[inline]
pub fn wang(mut a: u32) -> u32 {
    a = (a ^ 61) ^ (a >> 16);
    a = a.wrapping_add(a << 3);
    a ^= a >> 4;
    a = a.wrapping_mul(0x27D4_EB2D);
    a ^= a >> 15;
    a
}

/// The u64 seed for one global index (low = jenkins6, high = wang(low)).
#[inline]
pub fn init_seed(gid: u32) -> u64 {
    let low = jenkins6(gid);
    let high = wang(low);
    ((high as u64) << 32) | low as u64
}

/// One xorshift (21, 35, 4) step (listing S5).
#[inline]
pub fn xorshift(mut s: u64) -> u64 {
    s ^= s << 21;
    s ^= s >> 35;
    s ^= s << 4;
    s
}

/// Wrapping-u64 pairwise tree reduction (bit-compatible with
/// `simexec::reduce_tree`; wrapping adds make every schedule identical).
pub fn reduce_tree(xs: &[u64]) -> u64 {
    let mut v: Vec<u64> = xs.to_vec();
    while v.len() > 1 {
        let mut next = Vec::with_capacity(v.len().div_ceil(2));
        for pair in v.chunks(2) {
            next.push(if pair.len() == 2 {
                pair[0].wrapping_add(pair[1])
            } else {
                pair[0]
            });
        }
        v = next;
    }
    v.first().copied().unwrap_or(0)
}

/// One 5-point stencil output value — the summation order (up, down,
/// left, right) is fixed and must match `simexec::stencil5_point`.
#[inline]
pub fn stencil5_point(c: f32, up: f32, down: f32, left: f32, right: f32) -> f32 {
    let mut s = up;
    s += down;
    s += left;
    s += right;
    0.5f32 * c + 0.125f32 * s
}

/// 2-D 5-point stencil over an `h × w` row-major grid, zero boundary
/// (bit-compatible with `simexec::stencil5_grid`).
pub fn stencil5_grid(g: &[f32], out: &mut [f32], h: usize, w: usize) {
    let at = |r: isize, c: isize| -> f32 {
        if r < 0 || c < 0 || r as usize >= h || c as usize >= w {
            0.0
        } else {
            g[r as usize * w + c as usize]
        }
    };
    for r in 0..h as isize {
        for c in 0..w as isize {
            out[r as usize * w + c as usize] = stencil5_point(
                at(r, c),
                at(r - 1, c),
                at(r + 1, c),
                at(r, c - 1),
                at(r, c + 1),
            );
        }
    }
}

/// Row-band matmul with a fixed ascending-`k` accumulation order
/// (bit-compatible with `simexec::matmul_rows`).
pub fn matmul_rows(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, d: usize) {
    for r in 0..rows {
        for j in 0..d {
            let mut acc = 0f32;
            for k in 0..d {
                acc += a[r * d + k] * b[k * d + j];
            }
            out[r * d + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answers_match_simexec() {
        // Pinned values from rust/src/rawcl/simexec.rs — if these drift,
        // the two reference implementations have diverged.
        assert_eq!(xorshift(1), 0x0220_0011);
        assert_eq!(xorshift(0), 0);
        assert_eq!(init_seed(0), 0x1BB8_2F6B_28B9_1B1D);
    }

    #[test]
    fn reduce_is_order_independent() {
        let xs: Vec<u64> = (0..33).map(|i| init_seed(i) | (1 << 63)).collect();
        let seq = xs.iter().fold(0u64, |a, &b| a.wrapping_add(b));
        assert_eq!(reduce_tree(&xs), seq);
    }

    #[test]
    fn stencil_known_value() {
        // Pinned against simexec::stencil5_point.
        assert_eq!(stencil5_point(1.0, 1.0, 1.0, 1.0, 1.0), 1.0);
        assert_eq!(stencil5_point(2.0, 0.0, 0.0, 1.0, 0.0), 1.125);
    }

    #[test]
    fn matmul_identity() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let ident = [1.0f32, 0.0, 0.0, 1.0];
        let mut o = [0f32; 4];
        matmul_rows(&a, &ident, &mut o, 2, 2);
        assert_eq!(o, a);
    }
}
