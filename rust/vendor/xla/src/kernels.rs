//! Scalar reference kernels — the facade's "device code".
//!
//! Bit-compatible with `rust/src/rawcl/simexec.rs` and the python
//! oracles in `python/compile/kernels/ref.py`. Duplicated here (rather
//! than imported) so the facade crate stays dependency-free in both
//! directions; the cross-crate equivalence is pinned by the
//! known-answer tests below and by the cf4rs backend cross-validation
//! suite.

/// Jenkins 6-shift integer hash (listing S4, low word).
#[inline]
pub fn jenkins6(mut a: u32) -> u32 {
    a = a.wrapping_add(0x7ED5_5D16).wrapping_add(a << 12);
    a = (a ^ 0xC761_C23C) ^ (a >> 19);
    a = a.wrapping_add(0x1656_67B1).wrapping_add(a << 5);
    a = a.wrapping_add(0xD3A2_646C) ^ (a << 9);
    a = a.wrapping_add(0xFD70_46C5).wrapping_add(a << 3);
    a = a.wrapping_sub(0xB55A_4F09).wrapping_sub(a >> 16);
    a
}

/// Thomas Wang 32-bit hash (listing S4, high word).
#[inline]
pub fn wang(mut a: u32) -> u32 {
    a = (a ^ 61) ^ (a >> 16);
    a = a.wrapping_add(a << 3);
    a ^= a >> 4;
    a = a.wrapping_mul(0x27D4_EB2D);
    a ^= a >> 15;
    a
}

/// The u64 seed for one global index (low = jenkins6, high = wang(low)).
#[inline]
pub fn init_seed(gid: u32) -> u64 {
    let low = jenkins6(gid);
    let high = wang(low);
    ((high as u64) << 32) | low as u64
}

/// One xorshift (21, 35, 4) step (listing S5).
#[inline]
pub fn xorshift(mut s: u64) -> u64 {
    s ^= s << 21;
    s ^= s >> 35;
    s ^= s << 4;
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answers_match_simexec() {
        // Pinned values from rust/src/rawcl/simexec.rs — if these drift,
        // the two reference implementations have diverged.
        assert_eq!(xorshift(1), 0x0220_0011);
        assert_eq!(xorshift(0), 0);
        assert_eq!(init_seed(0), 0x1BB8_2F6B_28B9_1B1D);
    }
}
