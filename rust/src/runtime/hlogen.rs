//! HLO source generation — the artifact fallback path.
//!
//! The primary source of device programs is the AOT pipeline
//! (`python/compile/aot.py` → `artifacts/manifest.tsv`). When the
//! manifest is absent (fresh checkout, CI) or lacks a problem size, this
//! module *generates* an HLO text module for any of the five kernel
//! families at any size — so programs, the backend layer and the whole
//! test suite work hermetically.
//!
//! The generated text is structurally faithful: a real `HloModule`
//! header with an `entry_computation_layout` (which is all
//! [`crate::rawcl::hlometa`] needs) and a body whose ops sketch the
//! computation. Parameters the real compiler would recover from the
//! body (fused step count, global-index offset) are carried in
//! `// cf4rs.*` directives, which the `xla` facade interpreter honours.
//! When swapping in real PJRT bindings, route these kernels through the
//! AOT pipeline instead (the manifest is always preferred when present).

use super::artifacts::{ArtifactKind, Manifest};

/// Options for one generated module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GenSpec {
    pub kind: ArtifactKind,
    /// Problem size (elements of the principal vector/grid).
    pub n: usize,
    /// Fused step count (meaningful for `RngMulti`; 1 otherwise).
    pub k: usize,
    /// First global index hashed by `Init` (0 for whole-stream init;
    /// non-zero when a scheduler shards the stream across backends).
    pub gid_offset: u64,
    /// Secondary dimension: grid width for `Stencil5`, inner dimension
    /// for `Matmul` (1 for the 1-D families). Must divide `n`.
    pub m: usize,
}

impl GenSpec {
    pub fn new(kind: ArtifactKind, n: usize) -> Self {
        Self { kind, n, k: 1, gid_offset: 0, m: 1 }
    }

    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn with_gid_offset(mut self, off: u64) -> Self {
        self.gid_offset = off;
        self
    }

    pub fn with_m(mut self, m: usize) -> Self {
        self.m = m.max(1);
        self
    }
}

/// Generate the HLO text module for `spec`.
pub fn source(spec: &GenSpec) -> String {
    let n = spec.n;
    match spec.kind {
        ArtifactKind::Init => {
            let mut s = format!(
                "HloModule jit_prng_init, entry_computation_layout=\
                 {{()->(u64[{n}]{{0}})}}\n"
            );
            if spec.gid_offset != 0 {
                s.push_str(&format!("// cf4rs.gid_offset = {}\n", spec.gid_offset));
            }
            s.push_str(&format!(
                "\nENTRY main {{\n  \
                 gid = u32[{n}]{{0}} iota(), iota_dimension=0\n  \
                 off = u32[{n}]{{0}} broadcast(u32[] constant({off})), dimensions={{}}\n  \
                 idx = u32[{n}]{{0}} add(gid, off)\n  \
                 seed = u64[{n}]{{0}} custom-call(idx), \
                 custom_call_target=\"cf4rs_jenkins6_wang\"\n  \
                 ROOT out = (u64[{n}]{{0}}) tuple(seed)\n}}\n",
                off = spec.gid_offset,
            ));
            s
        }
        ArtifactKind::Rng => format!(
            "HloModule jit_prng_step, entry_computation_layout=\
             {{(u64[{n}]{{0}})->(u64[{n}]{{0}})}}\n\n\
             ENTRY main {{\n  \
             state = u64[{n}]{{0}} parameter(0)\n  \
             next = u64[{n}]{{0}} custom-call(state), \
             custom_call_target=\"cf4rs_xorshift_21_35_4\"\n  \
             ROOT out = (u64[{n}]{{0}}) tuple(next)\n}}\n"
        ),
        ArtifactKind::RngMulti => format!(
            "HloModule jit_prng_multi_step, entry_computation_layout=\
             {{(u64[{n}]{{0}})->(u64[{n}]{{0}})}}\n\
             // cf4rs.k = {k}\n\n\
             ENTRY main {{\n  \
             state = u64[{n}]{{0}} parameter(0)\n  \
             next = u64[{n}]{{0}} custom-call(state), \
             custom_call_target=\"cf4rs_xorshift_21_35_4_x{k}\"\n  \
             ROOT out = (u64[{n}]{{0}}) tuple(next)\n}}\n",
            k = spec.k,
        ),
        ArtifactKind::VecAdd => format!(
            "HloModule jit_vecadd, entry_computation_layout=\
             {{(f32[{n}]{{0}}, f32[{n}]{{0}})->(f32[{n}]{{0}})}}\n\n\
             ENTRY main {{\n  \
             x = f32[{n}]{{0}} parameter(0)\n  \
             y = f32[{n}]{{0}} parameter(1)\n  \
             sum = f32[{n}]{{0}} add(x, y)\n  \
             ROOT out = (f32[{n}]{{0}}) tuple(sum)\n}}\n"
        ),
        ArtifactKind::Saxpy => format!(
            "HloModule jit_saxpy, entry_computation_layout=\
             {{(f32[], f32[{n}]{{0}}, f32[{n}]{{0}})->(f32[{n}]{{0}})}}\n\n\
             ENTRY main {{\n  \
             a = f32[] parameter(0)\n  \
             x = f32[{n}]{{0}} parameter(1)\n  \
             y = f32[{n}]{{0}} parameter(2)\n  \
             ab = f32[{n}]{{0}} broadcast(a), dimensions={{}}\n  \
             ax = f32[{n}]{{0}} multiply(ab, x)\n  \
             sum = f32[{n}]{{0}} add(ax, y)\n  \
             ROOT out = (f32[{n}]{{0}}) tuple(sum)\n}}\n"
        ),
        ArtifactKind::Reduce => format!(
            "HloModule jit_reduce, entry_computation_layout=\
             {{(u64[{n}]{{0}})->(u64[1]{{0}})}}\n\n\
             add {{\n  \
             a = u64[] parameter(0)\n  \
             b = u64[] parameter(1)\n  \
             ROOT r = u64[] add(a, b)\n}}\n\n\
             ENTRY main {{\n  \
             x = u64[{n}]{{0}} parameter(0)\n  \
             zero = u64[] constant(0)\n  \
             sum = u64[] reduce(x, zero), dimensions={{0}}, to_apply=add\n  \
             out1 = u64[1]{{0}} reshape(sum)\n  \
             ROOT out = (u64[1]{{0}}) tuple(out1)\n}}\n"
        ),
        ArtifactKind::Stencil5 => {
            let (h, w) = grid_dims(spec);
            format!(
                "HloModule jit_stencil5, entry_computation_layout=\
                 {{(f32[{h},{w}]{{1,0}})->(f32[{h},{w}]{{1,0}})}}\n\n\
                 ENTRY main {{\n  \
                 g = f32[{h},{w}]{{1,0}} parameter(0)\n  \
                 s = f32[{h},{w}]{{1,0}} custom-call(g), \
                 custom_call_target=\"cf4rs_stencil5\"\n  \
                 ROOT out = (f32[{h},{w}]{{1,0}}) tuple(s)\n}}\n"
            )
        }
        ArtifactKind::Matmul => {
            let (r, d) = grid_dims(spec);
            format!(
                "HloModule jit_matmul, entry_computation_layout=\
                 {{(f32[{r},{d}]{{1,0}}, f32[{d},{d}]{{1,0}})->(f32[{r},{d}]{{1,0}})}}\n\n\
                 ENTRY main {{\n  \
                 a = f32[{r},{d}]{{1,0}} parameter(0)\n  \
                 b = f32[{d},{d}]{{1,0}} parameter(1)\n  \
                 c = f32[{r},{d}]{{1,0}} dot(a, b), lhs_contracting_dims={{1}}, \
                 rhs_contracting_dims={{0}}\n  \
                 ROOT out = (f32[{r},{d}]{{1,0}}) tuple(c)\n}}\n"
            )
        }
    }
}

/// `(rows, cols)` of a 2-D spec; degenerate `m` collapses to one row so
/// bare [`source`] never panics ([`resolve_source`] — every compile
/// path's entry point — rejects such specs up front instead).
fn grid_dims(spec: &GenSpec) -> (usize, usize) {
    let m = spec.m.max(1);
    if m > 0 && spec.n % m == 0 && spec.n > 0 {
        (spec.n / m, m)
    } else {
        (1, spec.n.max(1))
    }
}

/// Resolve the source text for `spec`: prefer a matching manifest
/// artifact (real AOT output), fall back to generation.
///
/// The manifest is only consulted for unsharded specs (`gid_offset == 0`
/// and, for `RngMulti`, matching `k`) — artifacts bake those parameters
/// in at lowering time.
pub fn resolve_source(spec: &GenSpec) -> std::io::Result<String> {
    if matches!(spec.kind, ArtifactKind::Stencil5 | ArtifactKind::Matmul)
        && (spec.n == 0 || spec.m == 0 || spec.n % spec.m != 0)
    {
        // Never hand out a module with silently-collapsed geometry: a
        // grid whose width does not divide its element count has no
        // faithful [rows, cols] signature.
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!(
                "degenerate 2-D spec for {}: n={} is not a multiple of m={}",
                spec.kind.kernel_name(),
                spec.n,
                spec.m
            ),
        ));
    }
    if spec.gid_offset == 0 && spec.m <= 1 {
        if let Some(man) = manifest_if_present()? {
            if let Some(art) = man.find(spec.kind, spec.n) {
                let k_matches = spec.kind != ArtifactKind::RngMulti || art.k == spec.k;
                if k_matches {
                    return std::fs::read_to_string(&art.path);
                }
            }
        }
    }
    Ok(source(spec))
}

/// The manifest when one exists; a *corrupt* manifest is an error, not
/// a fall-through to generation (the user built artifacts on purpose).
fn manifest_if_present() -> std::io::Result<Option<Manifest>> {
    Manifest::discover_if_present()
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, format!("{e:#}")))
}

/// Parse the conventional artifact name into a [`GenSpec`]: `init_n4096`,
/// `rng_n65536`, `rngk16_n4096`, `vecadd_n1024`, `saxpy_n1024`,
/// `reduce_n65536`, `stencil5_m128_n16384`, `matmul_m64_n4096` (the
/// `_m<cols>` segment carries the 2-D families' secondary dimension).
pub fn parse_artifact_name(name: &str) -> Option<GenSpec> {
    let (head, n_str) = name.rsplit_once("_n")?;
    let n: usize = n_str.parse().ok()?;
    if n == 0 {
        return None;
    }
    if let Some(rest) = head.strip_prefix("stencil5_m") {
        return grid_spec(ArtifactKind::Stencil5, n, rest);
    }
    if let Some(rest) = head.strip_prefix("matmul_m") {
        return grid_spec(ArtifactKind::Matmul, n, rest);
    }
    Some(match head {
        "init" => GenSpec::new(ArtifactKind::Init, n),
        "rng" => GenSpec::new(ArtifactKind::Rng, n),
        "vecadd" => GenSpec::new(ArtifactKind::VecAdd, n),
        "saxpy" => GenSpec::new(ArtifactKind::Saxpy, n),
        "reduce" => GenSpec::new(ArtifactKind::Reduce, n),
        other => {
            let k: usize = other.strip_prefix("rngk")?.parse().ok()?;
            if k == 0 {
                return None;
            }
            GenSpec::new(ArtifactKind::RngMulti, n).with_k(k)
        }
    })
}

fn grid_spec(kind: ArtifactKind, n: usize, m_str: &str) -> Option<GenSpec> {
    let m: usize = m_str.parse().ok()?;
    if m == 0 || n % m != 0 {
        return None;
    }
    Some(GenSpec::new(kind, n).with_m(m))
}

/// Resolve an artifact by conventional name: manifest text when the
/// manifest has it, generated HLO otherwise.
pub fn resolve_named_source(name: &str) -> std::io::Result<String> {
    if let Some(man) = manifest_if_present()? {
        if let Some(art) = man.get(name) {
            return std::fs::read_to_string(&art.path);
        }
    }
    match parse_artifact_name(name) {
        Some(spec) => Ok(source(&spec)),
        None => Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            format!("no artifact named {name:?}, and the name is not generatable"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rawcl::hlometa::parse_header;
    use crate::rawcl::kernelspec::{parse_build_options, spec_for};
    use crate::runtime::executable::count_instructions;

    #[test]
    fn generated_headers_parse_and_spec() {
        for (kind, params) in [
            (ArtifactKind::Init, 0),
            (ArtifactKind::Rng, 1),
            (ArtifactKind::VecAdd, 2),
            (ArtifactKind::Saxpy, 3),
        ] {
            let text = source(&GenSpec::new(kind, 4096));
            let meta = parse_header(&text).unwrap();
            assert_eq!(meta.problem_size(), 4096, "{kind}");
            assert_eq!(meta.params.len(), params, "{kind}");
            assert!(spec_for(&meta, &[]).is_ok(), "{kind}");
            assert!(count_instructions(&text) > 0, "{kind}");
        }
    }

    #[test]
    fn multi_step_carries_k_and_builds_with_define() {
        let text = source(&GenSpec::new(ArtifactKind::RngMulti, 1024).with_k(16));
        assert!(text.contains("// cf4rs.k = 16"));
        let meta = parse_header(&text).unwrap();
        let defines = parse_build_options("-Dk=16").unwrap();
        assert_eq!(spec_for(&meta, &defines).unwrap().k, 16);
    }

    #[test]
    fn init_offset_is_emitted() {
        let text = source(&GenSpec::new(ArtifactKind::Init, 64).with_gid_offset(4096));
        assert!(text.contains("// cf4rs.gid_offset = 4096"));
        // Offset 0 stays directive-free (matches real artifacts).
        let plain = source(&GenSpec::new(ArtifactKind::Init, 64));
        assert!(!plain.contains("gid_offset"));
    }

    #[test]
    fn generated_modules_compile_on_the_runtime() {
        for kind in [ArtifactKind::Init, ArtifactKind::Rng, ArtifactKind::VecAdd] {
            let text = source(&GenSpec::new(kind, 256));
            let module = crate::runtime::TextModule::compile(&text).unwrap();
            assert!(module.instruction_count > 0);
        }
    }

    #[test]
    fn workload_families_generate_and_spec() {
        // reduce: 1 HLO input, one-word result, n taken from the input.
        let text = source(&GenSpec::new(ArtifactKind::Reduce, 4096));
        let meta = parse_header(&text).unwrap();
        assert_eq!(meta.params.len(), 1);
        let s = spec_for(&meta, &[]).unwrap();
        assert_eq!(s.n, 4096);

        // stencil5: rank-2 signature carries the grid geometry.
        let text = source(&GenSpec::new(ArtifactKind::Stencil5, 48 * 32).with_m(32));
        let meta = parse_header(&text).unwrap();
        assert_eq!(meta.results[0].dims, vec![48, 32]);
        let s = spec_for(&meta, &[]).unwrap();
        assert_eq!((s.n, s.m), (48 * 32, 32));

        // matmul: B is the m×m operand.
        let text = source(&GenSpec::new(ArtifactKind::Matmul, 16 * 24).with_m(24));
        let meta = parse_header(&text).unwrap();
        assert_eq!(meta.params[1].dims, vec![24, 24]);
        let s = spec_for(&meta, &[]).unwrap();
        assert_eq!((s.n, s.m), (16 * 24, 24));
    }

    #[test]
    fn artifact_names_parse_to_specs() {
        let s = parse_artifact_name("init_n4096").unwrap();
        assert_eq!((s.kind, s.n, s.k), (ArtifactKind::Init, 4096, 1));
        let s = parse_artifact_name("rngk16_n65536").unwrap();
        assert_eq!((s.kind, s.n, s.k), (ArtifactKind::RngMulti, 65536, 16));
        assert!(parse_artifact_name("mystery_n4096").is_none());
        assert!(parse_artifact_name("init_nquux").is_none());
        assert!(parse_artifact_name("init").is_none());
        assert!(parse_artifact_name("rngk0_n16").is_none());
        let s = parse_artifact_name("reduce_n65536").unwrap();
        assert_eq!((s.kind, s.n), (ArtifactKind::Reduce, 65536));
        let s = parse_artifact_name("stencil5_m32_n1536").unwrap();
        assert_eq!((s.kind, s.n, s.m), (ArtifactKind::Stencil5, 1536, 32));
        let s = parse_artifact_name("matmul_m24_n384").unwrap();
        assert_eq!((s.kind, s.n, s.m), (ArtifactKind::Matmul, 384, 24));
        assert!(parse_artifact_name("matmul_m0_n384").is_none());
        assert!(parse_artifact_name("stencil5_m7_n16").is_none(), "m must divide n");
    }

    #[test]
    fn named_resolution_generates_without_a_manifest() {
        let text = resolve_named_source("rng_n4096").unwrap();
        assert!(text.contains("prng_step"));
        assert!(resolve_named_source("nonsense").is_err());
    }

    #[test]
    fn resolve_source_falls_back_to_generation() {
        // A size no artifact ladder will ever contain.
        let text =
            resolve_source(&GenSpec::new(ArtifactKind::Rng, 12345)).unwrap();
        assert!(text.contains("u64[12345]"));
    }

    #[test]
    fn degenerate_2d_specs_are_rejected_not_collapsed() {
        // n not a multiple of m must error at resolve time — never
        // silently generate a 1-row grid of the wrong geometry.
        let bad = GenSpec::new(ArtifactKind::Stencil5, 16).with_m(7);
        assert!(resolve_source(&bad).is_err());
        let bad = GenSpec::new(ArtifactKind::Matmul, 10).with_m(4);
        assert!(resolve_source(&bad).is_err());
        // A 2-D spec that forgot with_m entirely (m defaults to 1) is
        // legal-but-degenerate geometry: one column. n % 1 == 0, so it
        // resolves; callers wanting a real grid must set m.
        let ok = GenSpec::new(ArtifactKind::Stencil5, 48 * 32).with_m(32);
        assert!(resolve_source(&ok).is_ok());
    }
}
