//! PJRT runtime bridge — loads AOT-lowered HLO artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it (the [`crate::rawcl`] substrate and the [`crate::ccl`] framework)
//! deals in buffers-of-bytes and artifact names.
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py` and
//! DESIGN.md): `HloModuleProto::from_text_file` reassigns instruction ids,
//! which is what makes jax ≥ 0.5 output loadable on xla_extension 0.5.1.
//!
//! The `xla` dependency is a path crate (`rust/vendor/xla`): a
//! deterministic facade over the binding surface, backed by a reference
//! interpreter, so builds and CI are hermetic. [`hlogen`] generates HLO
//! modules for the known kernel families when no AOT artifact covers a
//! requested size.

pub mod artifacts;
pub mod client;
pub mod executable;
pub mod hlogen;
pub mod literal;

pub use artifacts::{Artifact, ArtifactKind, Manifest};
pub use client::global_client;
pub use executable::{CompiledModule, ExecutableCache, TextModule};
pub use hlogen::GenSpec;
pub use literal::ElemType;
