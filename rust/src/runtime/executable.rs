//! Compiled HLO modules and the process-wide executable cache.
//!
//! A [`CompiledModule`] owns one `PjRtLoadedExecutable` built from an HLO
//! text artifact. The [`ExecutableCache`] memoises compilation per
//! artifact name — OpenCL programs are built once per context and reused;
//! the cache gives the substrate the same cost profile.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context as _, Result};

use super::artifacts::Artifact;
use super::client;
use super::literal::{literal_to_bytes, ElemType};

/// `xla::PjRtLoadedExecutable` holds an `Rc` handle to the client and is
/// not `Send`/`Sync` by declaration. All operations that clone or drop
/// that handle (compile, execute, executable drop) run under the global
/// [`client::pjrt_lock`] — see the thread-safety notes in
/// [`super::client`].
struct SendExe(Option<xla::PjRtLoadedExecutable>);

// SAFETY: every use of the inner executable (execute, drop) happens while
// the global PJRT lock is held, so the non-atomic client refcount inside
// never experiences a racing update.
unsafe impl Send for SendExe {}
unsafe impl Sync for SendExe {}

impl Drop for SendExe {
    fn drop(&mut self) {
        // Dropping the executable decrements the client Rc — take the
        // lock so this cannot race a compile/execute on another thread.
        let _guard = client::pjrt_lock().lock().unwrap();
        self.0.take();
    }
}

/// One compiled device program (an HLO module on the PJRT CPU client).
pub struct CompiledModule {
    artifact: Artifact,
    exe: SendExe,
    /// Wall time spent in `client.compile` — surfaced by `cclc` and the
    /// program-build log.
    pub compile_time: std::time::Duration,
    /// HLO instruction count (crude program-complexity metric for cclc).
    pub instruction_count: usize,
}

impl CompiledModule {
    /// Load + compile an artifact on the global PJRT client.
    pub fn compile(artifact: &Artifact) -> Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&artifact.path)
            .with_context(|| format!("parsing {}", artifact.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client::with_client(|c| c.compile(&comp))
            .with_context(|| format!("compiling {}", artifact.name))?;
        let text = std::fs::read_to_string(&artifact.path)?;
        Ok(Self {
            artifact: artifact.clone(),
            exe: SendExe(Some(exe)),
            compile_time: t0.elapsed(),
            instruction_count: count_instructions(&text),
        })
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Execute with literal inputs; returns one byte vector per output.
    ///
    /// The AOT recipe lowers with `return_tuple=True`, so the executable
    /// yields a single tuple literal which is decomposed here.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<u8>>> {
        if inputs.len() != self.artifact.num_inputs {
            bail!(
                "{}: expected {} inputs, got {}",
                self.artifact.name,
                self.artifact.num_inputs,
                inputs.len()
            );
        }
        let result = {
            // Global PJRT lock: execute clones the client handle into the
            // output buffers and drops those clones before returning.
            let _guard = client::pjrt_lock().lock().unwrap();
            let exe = self.exe.0.as_ref().expect("executable present until drop");
            let bufs = exe.execute::<xla::Literal>(inputs)?;
            bufs[0][0].to_literal_sync()?
        };
        let parts = result
            .to_tuple()
            .context("expected tuple result (return_tuple=True lowering)")?;
        if parts.len() != self.artifact.num_outputs {
            bail!(
                "{}: expected {} outputs, got {}",
                self.artifact.name,
                self.artifact.num_outputs,
                parts.len()
            );
        }
        parts
            .iter()
            .map(|lit| literal_to_bytes(self.output_type(), lit))
            .collect()
    }

    /// Element type of the outputs (single-typed in all our artifacts).
    pub fn output_type(&self) -> ElemType {
        self.artifact.dtype
    }
}

/// A compiled HLO module built from in-memory text (no manifest entry).
///
/// This is the substrate's program-build path: `rawcl` programs are
/// created from source strings, so they compile through here rather than
/// through the artifact-keyed [`CompiledModule`].
pub struct TextModule {
    exe: SendExe,
    /// Stripped module name (what `rawcl` exposes as the kernel name).
    pub name: String,
    pub compile_time: std::time::Duration,
    pub instruction_count: usize,
}

impl TextModule {
    /// Parse + compile HLO text on the global PJRT client.
    pub fn compile(text: &str) -> Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(
            text.as_bytes(),
        )
        .context("parsing HLO text")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let name = comp.name();
        let exe = client::with_client(|c| c.compile(&comp))
            .with_context(|| format!("compiling module {name}"))?;
        Ok(Self {
            exe: SendExe(Some(exe)),
            name: name.strip_prefix("jit_").unwrap_or(&name).to_string(),
            compile_time: t0.elapsed(),
            instruction_count: count_instructions(text),
        })
    }

    /// Execute and return the raw output literals (callers decode them
    /// straight into their destinations — the no-staging path).
    pub fn execute_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = {
            let _guard = client::pjrt_lock().lock().unwrap();
            let exe = self.exe.0.as_ref().expect("executable present until drop");
            let bufs = exe.execute::<xla::Literal>(inputs)?;
            bufs[0][0].to_literal_sync()?
        };
        result
            .to_tuple()
            .context("expected tuple result (return_tuple=True lowering)")
    }

    /// Execute with literal inputs; returns one byte vector per output.
    /// `out_types` gives the element type of each tuple element.
    pub fn execute(
        &self,
        inputs: &[xla::Literal],
        out_types: &[ElemType],
    ) -> Result<Vec<Vec<u8>>> {
        let result = {
            let _guard = client::pjrt_lock().lock().unwrap();
            let exe = self.exe.0.as_ref().expect("executable present until drop");
            let bufs = exe.execute::<xla::Literal>(inputs)?;
            bufs[0][0].to_literal_sync()?
        };
        let parts = result
            .to_tuple()
            .context("expected tuple result (return_tuple=True lowering)")?;
        if parts.len() != out_types.len() {
            bail!("expected {} outputs, got {}", out_types.len(), parts.len());
        }
        parts
            .iter()
            .zip(out_types)
            .map(|(lit, ty)| literal_to_bytes(*ty, lit))
            .collect()
    }
}

/// Global compile cache for text modules, keyed by a content hash.
///
/// Real OpenCL drivers cache program binaries; without this, every
/// service run pays a full PJRT compilation (tens of ms) per kernel,
/// which dominated the native-device benchmarks (EXPERIMENTS.md §Perf).
/// Collisions are broken by comparing the stored source.
static TEXT_CACHE: Mutex<Vec<(u64, String, Arc<TextModule>)>> = Mutex::new(Vec::new());

/// FNV-1a 64 offset basis — shared with the harness stream fingerprints
/// (`crate::harness::backends::Fnv`) so the constants live in one place.
pub const FNV1A_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a 64 absorption step over raw bytes.
pub fn fnv1a_update(h: &mut u64, bytes: &[u8]) {
    for b in bytes {
        *h ^= *b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

fn fnv1a(text: &str) -> u64 {
    let mut h = FNV1A_OFFSET;
    fnv1a_update(&mut h, text.as_bytes());
    h
}

impl TextModule {
    /// Cached variant of [`TextModule::compile`]: returns the previously
    /// compiled module when the same source was built before (and is
    /// still alive somewhere).
    pub fn compile_cached(text: &str) -> Result<Arc<TextModule>> {
        let h = fnv1a(text);
        {
            let cache = TEXT_CACHE.lock().unwrap();
            for (hash, src, module) in cache.iter() {
                if *hash == h && src == text {
                    return Ok(module.clone());
                }
            }
        }
        let module = Arc::new(Self::compile(text)?);
        // Entries are kept for the process lifetime — the working set is
        // bounded by the artifact ladder (a handful of sources), exactly
        // like a driver's on-disk binary cache.
        TEXT_CACHE.lock().unwrap().push((h, text.to_string(), module.clone()));
        Ok(module)
    }
}

/// Count `=`-assignments in HLO text — a stable proxy for instruction
/// count that does not require a full parser.
pub fn count_instructions(hlo_text: &str) -> usize {
    hlo_text
        .lines()
        .map(str::trim_start)
        .filter(|l| {
            (l.starts_with("ROOT ") || l.split_whitespace().nth(1) == Some("="))
                && l.contains(" = ")
        })
        .count()
}

/// Process-wide compile cache, keyed by artifact name.
#[derive(Default)]
pub struct ExecutableCache {
    map: Mutex<HashMap<String, Arc<CompiledModule>>>,
}

impl ExecutableCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get the compiled module for `artifact`, compiling on first use.
    pub fn get_or_compile(&self, artifact: &Artifact) -> Result<Arc<CompiledModule>> {
        // Fast path under the lock; compile outside it would allow
        // duplicate work but never inconsistency — we keep it simple and
        // compile under the lock (compiles are rare, once per artifact).
        let mut map = self.map.lock().unwrap();
        if let Some(m) = map.get(&artifact.name) {
            return Ok(m.clone());
        }
        let module = Arc::new(CompiledModule::compile(artifact)?);
        map.insert(artifact.name.clone(), module.clone());
        Ok(module)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The global cache used by the `rawcl` native device.
pub fn global_cache() -> &'static ExecutableCache {
    static CACHE: std::sync::OnceLock<ExecutableCache> = std::sync::OnceLock::new();
    CACHE.get_or_init(ExecutableCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use crate::runtime::literal::{bytes_from_f32, f32_from_bytes, literal_from_bytes};

    fn manifest() -> Option<Manifest> {
        Manifest::discover().ok()
    }

    #[test]
    fn count_instructions_on_snippet() {
        let text = "HloModule m\n\nENTRY e {\n  a = f32[2] parameter(0)\n  \
                    b = f32[2] parameter(1)\n  ROOT c = f32[2] add(a, b)\n}\n";
        assert_eq!(count_instructions(text), 3);
    }

    #[test]
    fn compile_and_execute_vecadd() {
        let Some(m) = manifest() else { return };
        let art = m.get("vecadd_n1024").expect("vecadd artifact");
        let module = CompiledModule::compile(art).unwrap();
        assert!(module.instruction_count > 0);

        let x: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..1024).map(|i| 2.0 * i as f32).collect();
        let lx = literal_from_bytes(ElemType::F32, &bytes_from_f32(&x), false).unwrap();
        let ly = literal_from_bytes(ElemType::F32, &bytes_from_f32(&y), false).unwrap();
        let out = module.execute(&[lx, ly]).unwrap();
        assert_eq!(out.len(), 1);
        let sum = f32_from_bytes(&out[0]).unwrap();
        assert_eq!(sum[10], 30.0);
        assert_eq!(sum[1023], 3.0 * 1023.0);
    }

    #[test]
    fn execute_rejects_wrong_arity() {
        let Some(m) = manifest() else { return };
        let art = m.get("vecadd_n1024").unwrap();
        let module = global_cache().get_or_compile(art).unwrap();
        assert!(module.execute(&[]).is_err());
    }

    #[test]
    fn cache_memoises() {
        let Some(m) = manifest() else { return };
        let art = m.get("vecadd_n1024").unwrap();
        let cache = ExecutableCache::new();
        let a = cache.get_or_compile(art).unwrap();
        let b = cache.get_or_compile(art).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
    }
}
