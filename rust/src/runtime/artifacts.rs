//! Artifact manifest — the build-time contract between python and rust.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.tsv`; this module
//! parses it and locates artifact files. The manifest plays the role of
//! OpenCL kernel metadata queries: it tells the host each device program's
//! entry signature (element type, problem size, input/output counts).

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context as _, Result};

use super::literal::ElemType;

/// What a device program computes — decides both the kernel-argument ABI
/// (see [`crate::rawcl::kernelspec`]) and the simulated-device reference
/// implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Listing S4: hash the global index into the first seed batch.
    Init,
    /// Listing S5: one xorshift step over the state vector.
    Rng,
    /// Fused k-step xorshift (perf artifact).
    RngMulti,
    /// Quickstart: elementwise f32 add.
    VecAdd,
    /// Quickstart: `a*x + y`.
    Saxpy,
    /// Workload: wrapping-u64 tree reduction to one word.
    Reduce,
    /// Workload: 2-D 5-point stencil over an f32 grid.
    Stencil5,
    /// Workload: f32 row-band × square matrix multiply.
    Matmul,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "init" => Self::Init,
            "rng" => Self::Rng,
            "rng_multi" => Self::RngMulti,
            "vecadd" => Self::VecAdd,
            "saxpy" => Self::Saxpy,
            "reduce" => Self::Reduce,
            "stencil5" => Self::Stencil5,
            "matmul" => Self::Matmul,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }

    /// The kernel name exposed to hosts (what `clCreateKernel` takes).
    pub fn kernel_name(self) -> &'static str {
        match self {
            Self::Init => "init",
            Self::Rng => "rng",
            Self::RngMulti => "rng_multi",
            Self::VecAdd => "vecadd",
            Self::Saxpy => "saxpy",
            Self::Reduce => "reduce",
            Self::Stencil5 => "stencil5",
            Self::Matmul => "matmul",
        }
    }
}

impl fmt::Display for ArtifactKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.kernel_name())
    }
}

/// One row of the manifest: a lowered HLO module plus its signature.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Unique artifact name, e.g. `rng_n4096`.
    pub name: String,
    pub kind: ArtifactKind,
    /// Problem size (elements in the state/output vector).
    pub n: usize,
    /// Fused step count (0/1 when not applicable).
    pub k: usize,
    pub dtype: ElemType,
    pub num_inputs: usize,
    pub num_outputs: usize,
    /// Absolute path of the HLO text file.
    pub path: PathBuf,
}

impl Artifact {
    /// Bytes per element of the principal vector.
    pub fn elem_size(&self) -> usize {
        self.dtype.size_bytes()
    }

    /// Size in bytes of the principal input/output vector.
    pub fn vector_bytes(&self) -> usize {
        self.n * self.elem_size()
    }
}

/// Parsed `manifest.tsv`: the set of available device programs.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    by_name: HashMap<String, Artifact>,
    dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.tsv` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, &dir)
    }

    /// Locate the artifacts directory: `$CF4RS_ARTIFACTS`, then
    /// `./artifacts`, then `../artifacts` (for tests run from `rust/`).
    pub fn discover() -> Result<Self> {
        match Self::discover_if_present()? {
            Some(man) => Ok(man),
            None => bail!(
                "no artifacts/manifest.tsv found — run `make artifacts` \
                 (or set CF4RS_ARTIFACTS)"
            ),
        }
    }

    /// Like [`discover`](Self::discover), but distinguishes *absent*
    /// (`Ok(None)` — callers may fall back to generated kernels) from
    /// *present but unloadable* (`Err` — a corrupt manifest must never
    /// be silently papered over).
    pub fn discover_if_present() -> Result<Option<Self>> {
        if let Ok(dir) = std::env::var("CF4RS_ARTIFACTS") {
            return Self::load(dir).map(Some);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.tsv").exists() {
                return Self::load(cand).map(Some);
            }
        }
        Ok(None)
    }

    /// Parse manifest text; `dir` is prepended to the file column.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
        let expect = "name\tkind\tn\tk\tdtype\tnum_inputs\tnum_outputs\tfile";
        if header != expect {
            bail!("manifest header mismatch:\n got {header:?}\nwant {expect:?}");
        }
        let mut by_name = HashMap::new();
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 8 {
                bail!("manifest line {}: want 8 columns, got {}", lineno + 2, cols.len());
            }
            let art = Artifact {
                name: cols[0].to_string(),
                kind: ArtifactKind::parse(cols[1])?,
                n: cols[2].parse().context("n column")?,
                k: cols[3].parse().context("k column")?,
                dtype: ElemType::parse(cols[4])?,
                num_inputs: cols[5].parse().context("num_inputs column")?,
                num_outputs: cols[6].parse().context("num_outputs column")?,
                path: dir.join(cols[7]),
            };
            if by_name.insert(art.name.clone(), art).is_some() {
                bail!("duplicate artifact name {:?}", cols[0]);
            }
        }
        Ok(Self { by_name, dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.by_name.get(name)
    }

    /// Find the artifact of `kind` with problem size `n`.
    pub fn find(&self, kind: ArtifactKind, n: usize) -> Option<&Artifact> {
        self.by_name.values().find(|a| a.kind == kind && a.n == n)
    }

    /// All artifacts, name-sorted (stable output for devinfo/cclc).
    pub fn iter_sorted(&self) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> = self.by_name.values().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// The ladder of PRNG sizes present (sorted ascending).
    pub fn rng_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_name
            .values()
            .filter(|a| a.kind == ArtifactKind::Rng)
            .map(|a| a.n)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name\tkind\tn\tk\tdtype\tnum_inputs\tnum_outputs\tfile\n\
        init_n4096\tinit\t4096\t0\tu64\t0\t1\tinit_n4096.hlo.txt\n\
        rng_n4096\trng\t4096\t1\tu64\t1\t1\trng_n4096.hlo.txt\n\
        vecadd_n1024\tvecadd\t1024\t0\tf32\t2\t1\tvecadd_n1024.hlo.txt\n";

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.len(), 3);
        let rng = m.get("rng_n4096").unwrap();
        assert_eq!(rng.kind, ArtifactKind::Rng);
        assert_eq!(rng.n, 4096);
        assert_eq!(rng.num_inputs, 1);
        assert_eq!(rng.vector_bytes(), 4096 * 8);
        assert_eq!(rng.path, Path::new("/tmp/a/rng_n4096.hlo.txt"));
    }

    #[test]
    fn find_by_kind_and_size() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.find(ArtifactKind::Init, 4096).is_some());
        assert!(m.find(ArtifactKind::Init, 1024).is_none());
        assert_eq!(m.rng_sizes(), vec![4096]);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("nope\nx", Path::new(".")).is_err());
    }

    #[test]
    fn rejects_duplicate_name() {
        let dup = format!(
            "{}rng_n4096\trng\t4096\t1\tu64\t1\t1\tx.hlo.txt\n",
            SAMPLE
        );
        assert!(Manifest::parse(&dup, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = "name\tkind\tn\tk\tdtype\tnum_inputs\tnum_outputs\tfile\n\
            a\tmystery\t1\t0\tu64\t0\t1\ta.hlo.txt\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn discover_if_present_is_consistent_with_discover() {
        // Ok(None) = absent (the generated-kernel fallback signal);
        // Err = present but broken. Both must agree with discover().
        match Manifest::discover_if_present() {
            Ok(Some(_)) => assert!(Manifest::discover().is_ok()),
            Ok(None) | Err(_) => assert!(Manifest::discover().is_err()),
        }
    }

    #[test]
    fn discovers_real_artifacts_when_present() {
        // Only meaningful after `make artifacts`; skip silently otherwise.
        if let Ok(m) = Manifest::discover() {
            assert!(!m.is_empty());
            assert!(!m.rng_sizes().is_empty());
        }
    }
}
