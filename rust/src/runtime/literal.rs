//! Byte-buffer ⇄ `xla::Literal` conversion.
//!
//! The `rawcl` substrate stores device memory as plain byte vectors (like
//! OpenCL buffers); PJRT wants typed literals. These helpers convert in
//! both directions without interpreting element values.

use anyhow::{bail, Result};

/// Element types crossing the python→rust boundary.
///
/// Only what the artifacts actually use — extend as the model grows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElemType {
    U64,
    U32,
    F32,
}

impl ElemType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "u64" => Self::U64,
            "u32" => Self::U32,
            "f32" => Self::F32,
            other => bail!("unknown element type {other:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Self::U64 => 8,
            Self::U32 | Self::F32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::U64 => "u64",
            Self::U32 => "u32",
            Self::F32 => "f32",
        }
    }

    fn primitive(self) -> xla::PrimitiveType {
        match self {
            Self::U64 => xla::PrimitiveType::U64,
            Self::U32 => xla::PrimitiveType::U32,
            Self::F32 => xla::PrimitiveType::F32,
        }
    }
}

/// Build a rank-1 literal of `ty` from raw little-endian bytes.
///
/// A scalar (rank-0) literal is produced when `scalar` is true; the byte
/// slice must then hold exactly one element.
pub fn literal_from_bytes(ty: ElemType, bytes: &[u8], scalar: bool) -> Result<xla::Literal> {
    let esz = ty.size_bytes();
    if bytes.len() % esz != 0 {
        bail!(
            "byte length {} not a multiple of element size {esz}",
            bytes.len()
        );
    }
    let n = bytes.len() / esz;
    if scalar && n != 1 {
        bail!("scalar literal needs exactly 1 element, got {n}");
    }
    let dims: &[usize] = if scalar { &[] } else { &[n] };
    let mut lit = xla::Literal::create_from_shape(ty.primitive(), dims);
    // copy_raw_from is typed; go through the matching slice view.
    match ty {
        ElemType::U64 => lit.copy_raw_from(cast_slice::<u64>(bytes))?,
        ElemType::U32 => lit.copy_raw_from(cast_slice::<u32>(bytes))?,
        ElemType::F32 => lit.copy_raw_from(cast_slice::<f32>(bytes))?,
    }
    Ok(lit)
}

/// Extract raw little-endian bytes from a rank-≤1 literal of `ty`.
pub fn literal_to_bytes(ty: ElemType, lit: &xla::Literal) -> Result<Vec<u8>> {
    let count = lit.element_count();
    let mut out = vec![0u8; count * ty.size_bytes()];
    match ty {
        ElemType::U64 => lit.copy_raw_to(cast_slice_mut::<u64>(&mut out))?,
        ElemType::U32 => lit.copy_raw_to(cast_slice_mut::<u32>(&mut out))?,
        ElemType::F32 => lit.copy_raw_to(cast_slice_mut::<f32>(&mut out))?,
    }
    Ok(out)
}

/// Extract bytes from a rank-≤1 literal into a caller slice (no alloc).
pub fn literal_to_slice(ty: ElemType, lit: &xla::Literal, out: &mut [u8]) -> Result<()> {
    let need = lit.element_count() * ty.size_bytes();
    if out.len() != need {
        bail!("output slice is {} bytes, literal needs {need}", out.len());
    }
    match ty {
        ElemType::U64 => lit.copy_raw_to(cast_slice_mut::<u64>(out))?,
        ElemType::U32 => lit.copy_raw_to(cast_slice_mut::<u32>(out))?,
        ElemType::F32 => lit.copy_raw_to(cast_slice_mut::<f32>(out))?,
    }
    Ok(())
}

/// View a byte slice as a typed slice (alignment-checked).
fn cast_slice<T>(bytes: &[u8]) -> &[T] {
    let esz = std::mem::size_of::<T>();
    assert_eq!(bytes.len() % esz, 0);
    assert_eq!(bytes.as_ptr() as usize % std::mem::align_of::<T>(), 0,
        "buffer misaligned for element type");
    // SAFETY: length and alignment checked above; T is a plain-old-data
    // numeric type in all instantiations in this module.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const T, bytes.len() / esz) }
}

fn cast_slice_mut<T>(bytes: &mut [u8]) -> &mut [T] {
    let esz = std::mem::size_of::<T>();
    assert_eq!(bytes.len() % esz, 0);
    assert_eq!(bytes.as_ptr() as usize % std::mem::align_of::<T>(), 0,
        "buffer misaligned for element type");
    // SAFETY: as above.
    unsafe {
        std::slice::from_raw_parts_mut(bytes.as_mut_ptr() as *mut T, bytes.len() / esz)
    }
}

/// Convenience: encode a `u64` slice as little-endian bytes.
pub fn bytes_from_u64(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Convenience: decode little-endian bytes into `u64`s.
pub fn u64_from_bytes(b: &[u8]) -> Result<Vec<u64>> {
    if b.len() % 8 != 0 {
        bail!("length {} not a multiple of 8", b.len());
    }
    Ok(b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Convenience: encode an `f32` slice as little-endian bytes.
pub fn bytes_from_f32(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Convenience: decode little-endian bytes into `f32`s.
pub fn f32_from_bytes(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        bail!("length {} not a multiple of 4", b.len());
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Convenience: encode a `u32` scalar for kernel private args.
pub fn bytes_from_u32(x: u32) -> Vec<u8> {
    x.to_le_bytes().to_vec()
}

impl std::fmt::Display for ElemType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip() {
        let v = vec![0u64, 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D];
        let bytes = bytes_from_u64(&v);
        let lit = literal_from_bytes(ElemType::U64, &bytes, false).unwrap();
        assert_eq!(lit.element_count(), 4);
        let back = literal_to_bytes(ElemType::U64, &lit).unwrap();
        assert_eq!(u64_from_bytes(&back).unwrap(), v);
    }

    #[test]
    fn f32_roundtrip() {
        let v = vec![0.0f32, -1.5, f32::MAX, 1e-20];
        let bytes = bytes_from_f32(&v);
        let lit = literal_from_bytes(ElemType::F32, &bytes, false).unwrap();
        let back = literal_to_bytes(ElemType::F32, &lit).unwrap();
        assert_eq!(f32_from_bytes(&back).unwrap(), v);
    }

    #[test]
    fn scalar_literal() {
        let lit =
            literal_from_bytes(ElemType::F32, &2.5f32.to_le_bytes(), true).unwrap();
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.shape().unwrap().tuple_size(), None);
    }

    #[test]
    fn scalar_rejects_vector_input() {
        let bytes = bytes_from_f32(&[1.0, 2.0]);
        assert!(literal_from_bytes(ElemType::F32, &bytes, true).is_err());
    }

    #[test]
    fn rejects_ragged_length() {
        assert!(literal_from_bytes(ElemType::U64, &[0u8; 7], false).is_err());
        assert!(u64_from_bytes(&[0u8; 9]).is_err());
        assert!(f32_from_bytes(&[0u8; 5]).is_err());
    }

    #[test]
    fn elem_type_parse() {
        assert_eq!(ElemType::parse("u64").unwrap(), ElemType::U64);
        assert_eq!(ElemType::parse("f32").unwrap(), ElemType::F32);
        assert!(ElemType::parse("i8").is_err());
        assert_eq!(ElemType::U64.size_bytes(), 8);
    }
}
