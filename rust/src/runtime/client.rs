//! Shared PJRT CPU client.
//!
//! PJRT client construction is expensive (thread pools, allocator); the
//! whole process shares one lazily-initialised CPU client, mirroring how
//! an OpenCL ICD exposes one platform handle per driver.
//!
//! ## Thread-safety model
//!
//! The `xla` crate's `PjRtClient` is an `Rc`-backed handle and is not
//! `Send`: cloning it (which `compile`, `execute` and buffer creation do
//! internally) mutates a non-atomic refcount. The underlying PJRT C API
//! object *is* thread-compatible, so cf4rs makes cross-thread use sound by
//! funnelling **every client-touching operation** through one global lock,
//! [`pjrt_lock`]. Holders: [`super::executable`] (compile + execute).
//! Plain `Literal` byte conversions do not touch the client and stay
//! lock-free.
//!
//! Consequence (documented in DESIGN.md §Perf): the native CPU device
//! behaves like a single-compute-unit device — two command queues can
//! overlap a PJRT kernel with a host-side buffer read (the Fig. 5
//! pattern), but not two PJRT kernels with each other.

use std::sync::{Mutex, OnceLock};

use anyhow::{Context as _, Result};

/// See module docs: sound because all uses happen under [`pjrt_lock`].
struct SendClient(xla::PjRtClient);

// SAFETY: the inner Rc is only ever cloned/dropped while `pjrt_lock` is
// held (enforced by this module exposing the client solely through
// `with_client`), so refcount updates never race.
unsafe impl Send for SendClient {}
unsafe impl Sync for SendClient {}

static CLIENT: OnceLock<SendClient> = OnceLock::new();
static PJRT_LOCK: Mutex<()> = Mutex::new(());

/// The lock serialising all PJRT client operations. Exposed so the
/// executable module can hold it across compile/execute sequences.
pub(crate) fn pjrt_lock() -> &'static Mutex<()> {
    &PJRT_LOCK
}

fn init_client() -> &'static SendClient {
    CLIENT.get_or_init(|| {
        SendClient(xla::PjRtClient::cpu().expect(
            "failed to initialise PJRT CPU client \
             (is /opt/xla_extension/lib on the rpath?)",
        ))
    })
}

/// Run `f` with the global client while holding the PJRT lock.
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> R) -> R {
    let _guard = PJRT_LOCK.lock().unwrap();
    f(&init_client().0)
}

/// Legacy accessor used by single-threaded tools (devinfo, cclc).
///
/// Prefer [`with_client`]; this exists for read-only queries such as
/// `platform_name` where the caller provably stays on one thread.
pub fn global_client() -> &'static xla::PjRtClient {
    let _guard = PJRT_LOCK.lock().unwrap();
    &init_client().0
}

/// Fallible initialisation for diagnostics-friendly tools.
pub fn try_platform_summary() -> Result<String> {
    let _guard = PJRT_LOCK.lock().unwrap();
    if CLIENT.get().is_none() {
        // Probe construction separately so a broken environment produces
        // an error value instead of a panic.
        let c = xla::PjRtClient::cpu().context("initialising PJRT CPU client")?;
        let _ = CLIENT.set(SendClient(c));
    }
    let c = &CLIENT.get().unwrap().0;
    Ok(format!("{} ({} device(s))", c.platform_name(), c.device_count()))
}

/// Human-readable description of the PJRT platform (for devinfo).
pub fn platform_summary() -> String {
    with_client(|c| format!("{} ({} device(s))", c.platform_name(), c.device_count()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_is_cpu() {
        assert!(platform_summary().to_lowercase().contains("cpu"));
    }

    #[test]
    fn summary_is_ok() {
        assert!(try_platform_summary().unwrap().contains("device"));
    }

    #[test]
    fn with_client_reentrant_sequential() {
        let a = with_client(|c| c.device_count());
        let b = with_client(|c| c.device_count());
        assert_eq!(a, b);
    }
}
