//! `cf4rs cclc` — the `ccl_c` utility (paper §3.1): offline kernel
//! compiler, linker and analyzer.
//!
//! Modes:
//! * `build` — compile HLO sources for a device (native devices go
//!   through the PJRT compiler) and print the build log;
//! * `analyze` — parse + compile and report per-kernel statistics:
//!   signature, instruction count, buffer footprint, estimated op
//!   counts, and a roofline time estimate per device profile;
//! * `link` — combine several single-kernel sources into one program and
//!   verify they build together (the OpenCL "link" step's moral
//!   equivalent in an AOT world).

use crate::ccl::{Context, Program};
use crate::ccl::errors::{CclError, CclResult};
use crate::rawcl::hlometa;
use crate::rawcl::kernelspec;
use crate::rawcl::types::DeviceType;
use crate::runtime::executable::count_instructions;

#[derive(Debug, PartialEq)]
pub enum Mode {
    Build,
    Analyze,
    Link,
}

#[derive(Debug)]
pub struct CclcOpts {
    pub mode: Mode,
    pub sources: Vec<String>,
    pub options: String,
    /// Target device type (`--device-type cpu|gpu`), default GPU
    /// (mirrors ccl_c's default device selection).
    pub device_type: DeviceType,
}

impl CclcOpts {
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut it = args.iter();
        let mode = match it.next().map(|s| s.as_str()) {
            Some("build") => Mode::Build,
            Some("analyze") => Mode::Analyze,
            Some("link") => Mode::Link,
            other => return Err(format!("unknown cclc mode {other:?} (build|analyze|link)")),
        };
        let mut sources = Vec::new();
        let mut options = String::new();
        let mut device_type = DeviceType::GPU;
        while let Some(a) = it.next() {
            match a.as_str() {
                "-o" | "--options" => {
                    options = it.next().ok_or("--options needs a value")?.clone();
                }
                "-t" | "--device-type" => {
                    let v = it.next().ok_or("--device-type needs cpu|gpu")?;
                    device_type = match v.as_str() {
                        "cpu" => DeviceType::CPU,
                        "gpu" => DeviceType::GPU,
                        other => return Err(format!("bad device type {other:?}")),
                    };
                }
                path => sources.push(path.to_string()),
            }
        }
        if sources.is_empty() {
            return Err("no source files given".into());
        }
        Ok(Self { mode, sources, options, device_type })
    }
}

/// Run cclc and return the report text.
pub fn run(opts: &CclcOpts) -> CclResult<String> {
    let ctx = Context::new_from_type(opts.device_type)?;
    let mut out = String::new();
    match opts.mode {
        Mode::Build | Mode::Link => {
            let prg = Program::new_from_source_files(&ctx, &opts.sources)?;
            let res = prg.build_with_options(&opts.options);
            let log = prg.build_log()?;
            match res {
                Ok(()) => {
                    out.push_str(&format!(
                        "build OK ({} kernel(s)): {}\n",
                        prg.kernel_names()?.len(),
                        prg.kernel_names()?.join(", ")
                    ));
                    out.push_str(&log);
                }
                Err(e) => {
                    out.push_str(&format!("build FAILED: {e}\n"));
                    out.push_str(&log);
                    return Err(CclError::framework(out));
                }
            }
        }
        Mode::Analyze => {
            let defines = kernelspec::parse_build_options(&opts.options)
                .map_err(|bad| CclError::framework(format!("bad option {bad:?}")))?;
            for path in &opts.sources {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    CclError::artifacts(format!("reading {path}: {e}"))
                })?;
                let meta = hlometa::parse_header(&text)
                    .map_err(|e| CclError::framework(e.to_string()))?;
                out.push_str(&format!("== {} (kernel `{}`)\n", path, meta.name));
                out.push_str(&format!(
                    "   inputs : {}\n",
                    fmt_tensors(&meta.params)
                ));
                out.push_str(&format!(
                    "   outputs: {}\n",
                    fmt_tensors(&meta.results)
                ));
                out.push_str(&format!(
                    "   instructions: {}\n",
                    count_instructions(&text)
                ));
                match kernelspec::spec_for(&meta, &defines) {
                    Ok(spec) => {
                        out.push_str(&format!(
                            "   abi: {} args, n={}, {} ops/elem, {} B/elem\n",
                            spec.num_args(), spec.n, spec.ops_per_elem, spec.bytes_per_elem
                        ));
                        // Roofline estimates per device profile.
                        for dev in crate::rawcl::device::devices() {
                            let t = dev
                                .profile
                                .timing
                                .kernel_ns(spec.total_ops(), spec.bytes_touched());
                            out.push_str(&format!(
                                "   est. time on {:<18}: {:>10.1} us\n",
                                dev.profile.name,
                                t as f64 / 1e3
                            ));
                        }
                    }
                    Err(e) => out.push_str(&format!("   abi: <{e}>\n")),
                }
            }
        }
    }
    Ok(out)
}

fn fmt_tensors(ts: &[hlometa::TensorMeta]) -> String {
    if ts.is_empty() {
        return "(none)".into();
    }
    ts.iter()
        .map(|t| format!("{}{:?}", t.dtype.name(), t.dims))
        .collect::<Vec<_>>()
        .join(", ")
}

/// CLI entrypoint.
pub fn main(args: &[String]) -> i32 {
    let opts = match CclcOpts::parse(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("cclc: {e}");
            eprintln!(
                "usage: cf4rs cclc build|analyze|link [-o OPTS] [-t cpu|gpu] FILE..."
            );
            return 2;
        }
    };
    match run(&opts) {
        Ok(s) => {
            print!("{s}");
            0
        }
        Err(e) => {
            eprintln!("cclc: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn art_path(name: &str) -> Option<String> {
        Manifest::discover()
            .ok()?
            .get(name)
            .map(|a| a.path.to_string_lossy().into_owned())
    }

    #[test]
    fn parse_modes_and_options() {
        let o = CclcOpts::parse(&[
            "analyze".into(),
            "-o".into(),
            "-Dk=16".into(),
            "a.hlo.txt".into(),
        ])
        .unwrap();
        assert_eq!(o.mode, Mode::Analyze);
        assert_eq!(o.options, "-Dk=16");
        assert_eq!(o.sources, vec!["a.hlo.txt"]);
        assert!(CclcOpts::parse(&[]).is_err());
        assert!(CclcOpts::parse(&["build".into()]).is_err());
    }

    #[test]
    fn analyze_reports_signature_and_estimates() {
        let Some(p) = art_path("rng_n4096") else { return };
        let o = CclcOpts {
            mode: Mode::Analyze,
            sources: vec![p],
            options: String::new(),
            device_type: DeviceType::GPU,
        };
        let r = run(&o).unwrap();
        assert!(r.contains("kernel `prng_step`"), "{r}");
        assert!(r.contains("u64[4096]"));
        assert!(r.contains("est. time on SimCL GTX 1080"));
        assert!(r.contains("16 B/elem"));
    }

    #[test]
    fn build_gpu_succeeds_with_log() {
        let Some(p) = art_path("init_n4096") else { return };
        let o = CclcOpts {
            mode: Mode::Build,
            sources: vec![p],
            options: String::new(),
            device_type: DeviceType::GPU,
        };
        let r = run(&o).unwrap();
        assert!(r.contains("build OK"));
        assert!(r.contains("prng_init"));
    }

    #[test]
    fn link_two_kernels() {
        let (Some(a), Some(b)) = (art_path("init_n4096"), art_path("rng_n4096")) else {
            return;
        };
        let o = CclcOpts {
            mode: Mode::Link,
            sources: vec![a, b],
            options: String::new(),
            device_type: DeviceType::GPU,
        };
        let r = run(&o).unwrap();
        assert!(r.contains("2 kernel(s)"));
    }

    #[test]
    fn build_failure_is_error_with_log() {
        let dir = std::env::temp_dir().join("cf4rs_cclc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(
            &bad,
            "HloModule jit_mystery, entry_computation_layout={()->(f32[4]{0})}",
        )
        .unwrap();
        let o = CclcOpts {
            mode: Mode::Build,
            sources: vec![bad.to_string_lossy().into_owned()],
            options: String::new(),
            device_type: DeviceType::GPU,
        };
        let e = run(&o).unwrap_err();
        assert!(e.message.contains("unknown kernel"), "{e}");
    }
}
