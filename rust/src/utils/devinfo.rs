//! `cf4rs devinfo` — the `ccl_devinfo` utility (paper §3.1).
//!
//! Queries platforms and devices; supports custom parameter lists via
//! `--custom name[,name...]` (prefix-tolerant, like cf4ocl's
//! `ccl_devinfo -c`).

use crate::ccl::{devquery, platforms};
use crate::ccl::errors::CclResult;

/// Options parsed from the CLI.
#[derive(Default, Debug)]
pub struct DevInfoOpts {
    /// Show all known parameters (`-a`).
    pub all: bool,
    /// Restrict to one device index across the flattened device list.
    pub device: Option<usize>,
    /// Custom parameter names (`-c name,name`).
    pub custom: Vec<String>,
    /// List known parameter names (`--list`).
    pub list: bool,
}

impl DevInfoOpts {
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut o = Self::default();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "-a" | "--all" => o.all = true,
                "--list" => o.list = true,
                "-d" | "--device" => {
                    let v = it.next().ok_or("--device needs an index")?;
                    o.device = Some(v.parse().map_err(|_| format!("bad index {v:?}"))?);
                }
                "-c" | "--custom" => {
                    let v = it.next().ok_or("--custom needs a name list")?;
                    o.custom.extend(v.split(',').map(|s| s.trim().to_string()));
                }
                other => return Err(format!("unknown devinfo option {other:?}")),
            }
        }
        Ok(o)
    }
}

/// Default (non `--all`) parameter set — the quick overview.
const DEFAULT_PARAMS: &[&str] = &[
    "name",
    "vendor",
    "type",
    "max_compute_units",
    "max_work_group_size",
    "preferred_work_group_size_multiple",
    "global_mem_size",
    "backend",
];

/// Render the report to a string (testable; `main` prints it).
pub fn report(opts: &DevInfoOpts) -> CclResult<String> {
    let mut out = String::new();
    if opts.list {
        out.push_str("Known device parameters:\n");
        for p in devquery::known_params() {
            out.push_str(&format!("  {:<36} {}\n", p.name, p.description));
        }
        return Ok(out);
    }
    let params: Vec<String> = if !opts.custom.is_empty() {
        opts.custom.clone()
    } else if opts.all {
        devquery::known_params().iter().map(|p| p.name.to_string()).collect()
    } else {
        DEFAULT_PARAMS.iter().map(|s| s.to_string()).collect()
    };

    let mut flat_index = 0usize;
    for plat in platforms::all()? {
        out.push_str(&format!(
            "Platform #{}: {} ({}, {})\n",
            plat.id.0, plat.name, plat.vendor, plat.version
        ));
        for dev in &plat.devices {
            let selected = opts.device.map(|d| d == flat_index).unwrap_or(true);
            if selected {
                out.push_str(&format!(
                    "  Device #{flat_index}: {}\n",
                    dev.name().unwrap_or_else(|_| "?".into())
                ));
                for name in &params {
                    match devquery::query_by_name(dev, name) {
                        Ok(v) => out.push_str(&format!("    {:<36} {}\n", name, v)),
                        Err(e) => out.push_str(&format!("    {:<36} <{}>\n", name, e)),
                    }
                }
            }
            flat_index += 1;
        }
    }
    Ok(out)
}

/// CLI entrypoint.
pub fn main(args: &[String]) -> i32 {
    let opts = match DevInfoOpts::parse(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("devinfo: {e}");
            eprintln!(
                "usage: cf4rs devinfo [-a] [-d INDEX] [-c name,name...] [--list]"
            );
            return 2;
        }
    };
    match report(&opts) {
        Ok(s) => {
            print!("{s}");
            0
        }
        Err(e) => {
            eprintln!("devinfo: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_report_covers_all_devices() {
        let r = report(&DevInfoOpts::default()).unwrap();
        assert!(r.contains("SimCL GTX 1080"));
        assert!(r.contains("SimCL HD 7970"));
        assert!(r.contains("cf4rs PJRT CPU"));
        assert!(r.contains("preferred_work_group_size_multiple"));
    }

    #[test]
    fn device_filter() {
        let opts = DevInfoOpts { device: Some(1), ..Default::default() };
        let r = report(&opts).unwrap();
        assert!(r.contains("GTX 1080"));
        assert!(!r.contains("Device #2"));
    }

    #[test]
    fn custom_params() {
        let opts = DevInfoOpts {
            custom: vec!["max_clock_frequency".into(), "local_mem_size".into()],
            ..Default::default()
        };
        let r = report(&opts).unwrap();
        assert!(r.contains("max_clock_frequency"));
        assert!(r.contains("1607"));
        assert!(!r.contains("global_mem_size"));
    }

    #[test]
    fn list_mode() {
        let opts = DevInfoOpts { list: true, ..Default::default() };
        let r = report(&opts).unwrap();
        assert!(r.contains("Known device parameters"));
        assert!(r.contains("backend"));
    }

    #[test]
    fn parse_errors() {
        assert!(DevInfoOpts::parse(&["--bogus".into()]).is_err());
        assert!(DevInfoOpts::parse(&["-d".into()]).is_err());
        let o = DevInfoOpts::parse(&[
            "-a".into(),
            "-d".into(),
            "2".into(),
            "-c".into(),
            "name,vendor".into(),
        ])
        .unwrap();
        assert!(o.all);
        assert_eq!(o.device, Some(2));
        assert_eq!(o.custom, vec!["name", "vendor"]);
    }
}
