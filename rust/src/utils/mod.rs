//! The three command-line utilities (paper §3.1), exposed as subcommands
//! of the `cf4rs` binary:
//!
//! * [`devinfo`] — `ccl_devinfo`: query platforms and devices;
//! * [`cclc`] — `ccl_c`: offline kernel build / link / analyze;
//! * [`plot_events`] — `ccl_plot_events`: queue-utilization charts from
//!   profiler exports (Fig. 5).

pub mod cclc;
pub mod devinfo;
pub mod plot_events;
