//! `cf4rs plot-events` — the `ccl_plot_events` utility (paper §3.1).
//!
//! Reads a profile export table (written by `Prof::export_tsv`) and
//! renders the Fig. 5 queue-utilization chart, either as a unicode
//! terminal Gantt chart or as an SVG file.

use std::collections::BTreeMap;

use crate::ccl::errors::{CclError, CclResult};
use crate::ccl::prof::export::parse_tsv;
use crate::ccl::prof::info::ProfInfo;

#[derive(Debug)]
pub struct PlotOpts {
    pub input: String,
    /// Write an SVG here instead of/in addition to the terminal chart.
    pub svg: Option<String>,
    /// Terminal chart width in columns.
    pub width: usize,
}

impl PlotOpts {
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut input = None;
        let mut svg = None;
        let mut width = 100;
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--svg" => svg = Some(it.next().ok_or("--svg needs a path")?.clone()),
                "--width" => {
                    width = it
                        .next()
                        .ok_or("--width needs a number")?
                        .parse()
                        .map_err(|_| "bad width")?;
                }
                path => {
                    if input.is_some() {
                        return Err(format!("unexpected extra argument {path:?}"));
                    }
                    input = Some(path.to_string());
                }
            }
        }
        Ok(Self {
            input: input.ok_or("no input file given")?,
            svg,
            width: width.clamp(20, 400),
        })
    }
}

/// Group events per queue, preserving queue insertion order.
fn by_queue(infos: &[ProfInfo]) -> BTreeMap<&str, Vec<&ProfInfo>> {
    let mut map: BTreeMap<&str, Vec<&ProfInfo>> = BTreeMap::new();
    for i in infos {
        map.entry(&i.queue).or_default().push(i);
    }
    map
}

/// Stable colour/glyph per event name.
fn glyph_for(name: &str, palette: &mut BTreeMap<String, (char, &'static str)>) -> (char, &'static str) {
    const GLYPHS: &[char] = &['█', '▓', '▒', '░', '▞', '▚', '▛', '▜'];
    const COLORS: &[&str] = &[
        "#4C72B0", "#DD8452", "#55A868", "#C44E52", "#8172B3", "#937860",
    ];
    if let Some(g) = palette.get(name) {
        return *g;
    }
    let idx = palette.len();
    let g = (GLYPHS[idx % GLYPHS.len()], COLORS[idx % COLORS.len()]);
    palette.insert(name.to_string(), g);
    g
}

/// Render the terminal Gantt chart.
pub fn render_text(infos: &[ProfInfo], width: usize) -> CclResult<String> {
    if infos.is_empty() {
        return Err(CclError::framework("no events to plot"));
    }
    let t0 = infos.iter().map(|i| i.t_start).min().unwrap();
    let t1 = infos.iter().map(|i| i.t_end).max().unwrap();
    let span = (t1 - t0).max(1) as f64;
    let mut palette = BTreeMap::new();
    let mut out = String::new();
    out.push_str(&format!(
        "Queue utilization, {:.3} ms total ({} events)\n",
        span / 1e6,
        infos.len()
    ));
    for (queue, events) in by_queue(infos) {
        let mut row = vec![' '; width];
        for e in &events {
            let (g, _) = glyph_for(&e.name, &mut palette);
            let a = ((e.t_start - t0) as f64 / span * (width - 1) as f64) as usize;
            let b = ((e.t_end - t0) as f64 / span * (width - 1) as f64) as usize;
            for cell in row.iter_mut().take(b.max(a) + 1).skip(a) {
                *cell = g;
            }
        }
        out.push_str(&format!("{:>8} |{}|\n", queue, row.iter().collect::<String>()));
    }
    out.push_str("legend: ");
    for (name, (g, _)) in &palette {
        out.push_str(&format!("{g}={name}  "));
    }
    out.push('\n');
    Ok(out)
}

/// Render the SVG chart (Fig. 5 analogue).
pub fn render_svg(infos: &[ProfInfo]) -> CclResult<String> {
    if infos.is_empty() {
        return Err(CclError::framework("no events to plot"));
    }
    let t0 = infos.iter().map(|i| i.t_start).min().unwrap();
    let t1 = infos.iter().map(|i| i.t_end).max().unwrap();
    let span = (t1 - t0).max(1) as f64;
    const W: f64 = 900.0;
    const ROW_H: f64 = 42.0;
    const LEFT: f64 = 110.0;
    let queues = by_queue(infos);
    let h = 60.0 + queues.len() as f64 * ROW_H + 40.0;
    let mut palette = BTreeMap::new();
    let mut svg = String::new();
    svg.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{}\" height=\"{}\" \
         font-family=\"monospace\" font-size=\"12\">\n",
        W + LEFT + 20.0,
        h
    ));
    svg.push_str(&format!(
        "<text x=\"{LEFT}\" y=\"20\">Queue utilization ({:.3} ms, {} events)</text>\n",
        span / 1e6,
        infos.len()
    ));
    for (row, (queue, events)) in queues.iter().enumerate() {
        let y = 40.0 + row as f64 * ROW_H;
        svg.push_str(&format!(
            "<text x=\"4\" y=\"{:.1}\">{}</text>\n",
            y + ROW_H / 2.0,
            queue
        ));
        svg.push_str(&format!(
            "<line x1=\"{LEFT}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" \
             stroke=\"#ccc\"/>\n",
            y + ROW_H - 6.0,
            LEFT + W,
            y + ROW_H - 6.0
        ));
        for e in events {
            let (_, color) = glyph_for(&e.name, &mut palette);
            let x = LEFT + (e.t_start - t0) as f64 / span * W;
            let w = (((e.t_end - e.t_start) as f64 / span) * W).max(0.5);
            svg.push_str(&format!(
                "<rect x=\"{x:.2}\" y=\"{:.1}\" width=\"{w:.2}\" height=\"{:.1}\" \
                 fill=\"{color}\" opacity=\"0.9\"><title>{} [{} - {} ns]</title></rect>\n",
                y + 6.0,
                ROW_H - 16.0,
                e.name,
                e.t_start,
                e.t_end
            ));
        }
    }
    // legend
    let ly = 40.0 + queues.len() as f64 * ROW_H + 10.0;
    let mut lx = LEFT;
    for (name, (_, color)) in &palette {
        svg.push_str(&format!(
            "<rect x=\"{lx:.1}\" y=\"{ly:.1}\" width=\"12\" height=\"12\" fill=\"{color}\"/>\n\
             <text x=\"{:.1}\" y=\"{:.1}\">{name}</text>\n",
            lx + 16.0,
            ly + 11.0
        ));
        lx += 30.0 + name.len() as f64 * 8.0;
    }
    svg.push_str("</svg>\n");
    Ok(svg)
}

/// CLI entrypoint.
pub fn main(args: &[String]) -> i32 {
    let opts = match PlotOpts::parse(args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("plot-events: {e}");
            eprintln!("usage: cf4rs plot-events PROFILE.tsv [--svg OUT.svg] [--width N]");
            return 2;
        }
    };
    let text = match std::fs::read_to_string(&opts.input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("plot-events: reading {}: {e}", opts.input);
            return 1;
        }
    };
    let infos = match parse_tsv(&text) {
        Ok(i) => i,
        Err(e) => {
            eprintln!("plot-events: {e}");
            return 1;
        }
    };
    match render_text(&infos, opts.width) {
        Ok(chart) => print!("{chart}"),
        Err(e) => {
            eprintln!("plot-events: {e}");
            return 1;
        }
    }
    if let Some(svg_path) = &opts.svg {
        match render_svg(&infos) {
            Ok(svg) => {
                if let Err(e) = std::fs::write(svg_path, svg) {
                    eprintln!("plot-events: writing {svg_path}: {e}");
                    return 1;
                }
                eprintln!("wrote {svg_path}");
            }
            Err(e) => {
                eprintln!("plot-events: {e}");
                return 1;
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<ProfInfo> {
        let mk = |name: &str, queue: &str, s: u64, e: u64| ProfInfo {
            name: name.into(),
            queue: queue.into(),
            t_queued: s,
            t_submit: s,
            t_start: s,
            t_end: e,
        };
        vec![
            mk("INIT_KERNEL", "Main", 0, 100),
            mk("RNG_KERNEL", "Main", 150, 250),
            mk("READ_BUFFER", "Comms", 120, 400),
        ]
    }

    #[test]
    fn text_chart_has_rows_and_legend() {
        let c = render_text(&sample(), 80).unwrap();
        assert!(c.contains("Main |"));
        assert!(c.contains("Comms |"));
        assert!(c.contains("legend:"));
        assert!(c.contains("READ_BUFFER"));
    }

    #[test]
    fn svg_chart_has_rects_and_titles() {
        let s = render_svg(&sample()).unwrap();
        assert!(s.starts_with("<svg"));
        assert!(s.matches("<rect").count() >= 3 + 3); // bars + legend
        assert!(s.contains("RNG_KERNEL"));
        assert!(s.ends_with("</svg>\n"));
    }

    #[test]
    fn empty_input_is_error() {
        assert!(render_text(&[], 80).is_err());
        assert!(render_svg(&[]).is_err());
    }

    #[test]
    fn parse_opts() {
        let o = PlotOpts::parse(&[
            "prof.tsv".into(),
            "--svg".into(),
            "out.svg".into(),
            "--width".into(),
            "60".into(),
        ])
        .unwrap();
        assert_eq!(o.input, "prof.tsv");
        assert_eq!(o.svg.as_deref(), Some("out.svg"));
        assert_eq!(o.width, 60);
        assert!(PlotOpts::parse(&[]).is_err());
        assert!(PlotOpts::parse(&["a".into(), "b".into()]).is_err());
    }
}
