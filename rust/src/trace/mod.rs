//! End-to-end request tracing: span trees from edge to device event.
//!
//! A lock-light, process-global span sink mirroring the
//! [`crate::analysis::record`] recorder pattern: [`Tracing::start`]
//! arms it, dropping the guard disarms it, and while disarmed the only
//! cost at every hook site is one relaxed atomic load
//! ([`enabled`]). While armed, completed [`Span`]s land in a bounded
//! ring buffer under a single mutex; overflow drops the *oldest*
//! spans and counts them, so a runaway trace degrades instead of
//! allocating without bound.
//!
//! Timestamps are nanoseconds on the shared process profiling clock
//! ([`crate::rawcl::clock::now_ns`]) — the same clock every backend
//! stamps its `EventTimes` with — so host spans and grafted device
//! events share one timeline with no rebasing.
//!
//! Causality runs on two rails:
//!
//! * **Correlation ids** (`corr`): one per traced request, allocated
//!   at the edge (wire `trace` flag) or at service admission
//!   ([`new_corr`]). Every span a request touches carries its corr;
//!   the scheduler recovers it from the `svc.req-<id>.` shard tag via
//!   the [`register_req`] table. A window may also set an *ambient*
//!   corr ([`Tracing::set_ambient`]) which adopts corr-less spans —
//!   how the `cf4rs trace` CLI claims scheduler/device spans when it
//!   replays a cell outside the service.
//! * **Parent ids**: spans opened in the same scope link explicitly
//!   ([`SpanScope::child`]); everything else is attached by
//!   smallest-enclosing interval containment at assembly time
//!   ([`tree::Forest::build`]).
//!
//! Export: Chrome trace-event JSON ([`chrome::export_chrome`],
//! loadable in Perfetto / `chrome://tracing`), an indented human tree
//! and a TSV table ([`tree::Forest`]).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::ccl::prof::info::ProfInfo;
use crate::rawcl::clock;

pub mod chrome;
pub mod tree;

/// Default ring-buffer capacity, in spans.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// A typed span tag value.
#[derive(Clone, Debug, PartialEq)]
pub enum Tag {
    U64(u64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl From<u64> for Tag {
    fn from(v: u64) -> Tag {
        Tag::U64(v)
    }
}
impl From<usize> for Tag {
    fn from(v: usize) -> Tag {
        Tag::U64(v as u64)
    }
}
impl From<u32> for Tag {
    fn from(v: u32) -> Tag {
        Tag::U64(v as u64)
    }
}
impl From<f64> for Tag {
    fn from(v: f64) -> Tag {
        Tag::F64(v)
    }
}
impl From<bool> for Tag {
    fn from(v: bool) -> Tag {
        Tag::Bool(v)
    }
}
impl From<&str> for Tag {
    fn from(v: &str) -> Tag {
        Tag::Str(v.to_string())
    }
}
impl From<String> for Tag {
    fn from(v: String) -> Tag {
        Tag::Str(v)
    }
}

impl std::fmt::Display for Tag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Tag::U64(v) => write!(f, "{v}"),
            Tag::F64(v) => write!(f, "{v:.3}"),
            Tag::Bool(v) => write!(f, "{v}"),
            Tag::Str(v) => write!(f, "{v}"),
        }
    }
}

/// One completed span on the shared process profiling clock.
#[derive(Clone, Debug)]
pub struct Span {
    /// Process-unique span id (never 0).
    pub id: u64,
    /// Explicit parent span id, when the opener knew it.
    pub parent: Option<u64>,
    /// Correlation id of the request this span belongs to.
    pub corr: Option<u64>,
    /// Layer-prefixed name: `edge.*`, `svc.*`, `sched.*`, `dev.*`.
    pub name: String,
    /// Timeline track (queue/component) the span renders on.
    pub track: String,
    /// Interned host thread that recorded the span.
    pub thread: u32,
    /// Start, ns on the shared process profiling clock.
    pub t_start: u64,
    /// End, ns on the shared process profiling clock.
    pub t_end: u64,
    /// Typed key/value tags.
    pub tags: Vec<(&'static str, Tag)>,
}

impl Span {
    pub fn duration(&self) -> u64 {
        self.t_end.saturating_sub(self.t_start)
    }

    /// Value of a tag, if present.
    pub fn tag(&self, key: &str) -> Option<&Tag> {
        self.tags.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }
}

// ---------------------------------------------------------------------------
// Global sink
// ---------------------------------------------------------------------------

struct SinkState {
    ring: VecDeque<Span>,
    cap: usize,
    dropped: u64,
    ambient: Option<u64>,
    /// service req_id → corr, for the scheduler's shard-tag recovery.
    req_corr: HashMap<u64, u64>,
    threads: HashMap<std::thread::ThreadId, u32>,
}

impl SinkState {
    fn new(cap: usize) -> Self {
        Self {
            ring: VecDeque::with_capacity(cap.min(4096)),
            cap: cap.max(1),
            dropped: 0,
            ambient: None,
            req_corr: HashMap::new(),
            threads: HashMap::new(),
        }
    }

    fn thread(&mut self) -> u32 {
        let id = std::thread::current().id();
        if let Some(&t) = self.threads.get(&id) {
            return t;
        }
        let t = self.threads.len() as u32;
        self.threads.insert(id, t);
        t
    }

    fn push(&mut self, mut span: Span) {
        span.corr = span.corr.or(self.ambient);
        span.thread = self.thread();
        if self.ring.len() >= self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(span);
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<SinkState>> = Mutex::new(None);
/// Serializes tracing windows process-wide (parallel tests must not
/// interleave their spans).
static WINDOW: Mutex<()> = Mutex::new(());
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_CORR: AtomicU64 = AtomicU64::new(1);

fn lock_state() -> MutexGuard<'static, Option<SinkState>> {
    match STATE.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Cheap armed-check for every hook site: one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds on the shared process profiling clock (the span
/// timebase — identical to backend `EventTimes`).
#[inline]
pub fn now_ns() -> u64 {
    clock::now_ns()
}

/// Allocate a fresh process-unique correlation id.
pub fn new_corr() -> u64 {
    NEXT_CORR.fetch_add(1, Ordering::Relaxed)
}

fn next_span_id() -> u64 {
    NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed)
}

fn with_state<R>(f: impl FnOnce(&mut SinkState) -> R) -> Option<R> {
    if !enabled() {
        return None;
    }
    let mut st = lock_state();
    st.as_mut().map(f)
}

/// Map a service `req_id` to its correlation id for the duration of a
/// dispatch — the scheduler's shard tags carry the req id, not the
/// corr, so [`corr_for_req`] closes the loop.
pub fn register_req(req_id: u64, corr: u64) {
    with_state(|s| {
        s.req_corr.insert(req_id, corr);
    });
}

/// Drop a [`register_req`] mapping once the request is answered.
pub fn unregister_req(req_id: u64) {
    with_state(|s| {
        s.req_corr.remove(&req_id);
    });
}

/// Correlation id registered for a service `req_id`, if any.
pub fn corr_for_req(req_id: u64) -> Option<u64> {
    with_state(|s| s.req_corr.get(&req_id).copied()).flatten()
}

/// Recover the corr of a scheduler shard tag (`svc.req-<id>.`).
pub fn corr_from_tag(tag: &str) -> Option<u64> {
    let rest = tag.strip_prefix("svc.req-")?;
    let id: u64 = rest.strip_suffix('.')?.parse().ok()?;
    corr_for_req(id)
}

/// RAII tracing window. Arms the global sink on `start`, disarms on
/// drop. Windows are exclusive: a second `start` blocks until the
/// first guard drops.
pub struct Tracing {
    _window: MutexGuard<'static, ()>,
}

impl Tracing {
    pub fn start() -> Tracing {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// Arm with an explicit ring capacity (spans kept; overflow drops
    /// the oldest and counts them).
    pub fn with_capacity(cap: usize) -> Tracing {
        let window = match WINDOW.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *lock_state() = Some(SinkState::new(cap));
        ENABLED.store(true, Ordering::SeqCst);
        Tracing { _window: window }
    }

    /// Adopt corr-less spans into `corr` for the rest of the window
    /// (`None` clears). Used by replay drivers that trace a cell
    /// outside the service, where nothing else allocates a corr.
    pub fn set_ambient(&self, corr: Option<u64>) {
        if let Some(s) = lock_state().as_mut() {
            s.ambient = corr;
        }
    }

    /// Copy of the spans recorded so far, in record order.
    pub fn snapshot(&self) -> Vec<Span> {
        lock_state().as_ref().map(|s| s.ring.iter().cloned().collect()).unwrap_or_default()
    }

    /// Spans lost to ring overflow so far.
    pub fn dropped(&self) -> u64 {
        lock_state().as_ref().map(|s| s.dropped).unwrap_or(0)
    }

    /// Stop tracing and return the recorded spans.
    pub fn finish(self) -> Vec<Span> {
        let spans = self.snapshot();
        drop(self);
        spans
    }
}

impl Drop for Tracing {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        *lock_state() = None;
    }
}

// ---------------------------------------------------------------------------
// Recording
// ---------------------------------------------------------------------------

/// Record a completed span directly. Returns its id when the sink is
/// armed.
pub fn complete(
    name: &str,
    track: &str,
    corr: Option<u64>,
    parent: Option<u64>,
    t_start: u64,
    t_end: u64,
    tags: Vec<(&'static str, Tag)>,
) -> Option<u64> {
    if !enabled() {
        return None;
    }
    let id = next_span_id();
    with_state(|s| {
        s.push(Span {
            id,
            parent,
            corr,
            name: name.to_string(),
            track: track.to_string(),
            thread: 0,
            t_start,
            t_end: t_end.max(t_start),
            tags,
        });
        id
    })
}

/// Record a zero-duration event span (steal, retry, quarantine …).
pub fn instant(
    name: &str,
    track: &str,
    corr: Option<u64>,
    parent: Option<u64>,
    tags: Vec<(&'static str, Tag)>,
) -> Option<u64> {
    let t = if enabled() { now_ns() } else { 0 };
    complete(name, track, corr, parent, t, t, tags)
}

struct ScopeInner {
    id: u64,
    parent: Option<u64>,
    corr: Option<u64>,
    name: String,
    track: String,
    t_start: u64,
    tags: Vec<(&'static str, Tag)>,
}

/// RAII open span: captures the start time when opened, records the
/// completed span when dropped (or [`end`](SpanScope::end)ed). Inert —
/// no allocation, no clock read — when the sink is disarmed at open.
pub struct SpanScope(Option<ScopeInner>);

impl SpanScope {
    /// Open a span (top-level within its corr; parented later by
    /// interval containment).
    pub fn begin(name: &str, track: &str, corr: Option<u64>) -> SpanScope {
        Self::begin_child(name, track, corr, None)
    }

    /// An inert scope — for hook sites that pre-check [`enabled`] to
    /// avoid computing a track label on the disabled fast path.
    pub fn disabled() -> SpanScope {
        SpanScope(None)
    }

    /// Open a span with an explicit parent.
    pub fn begin_child(
        name: &str,
        track: &str,
        corr: Option<u64>,
        parent: Option<u64>,
    ) -> SpanScope {
        if !enabled() {
            return SpanScope(None);
        }
        SpanScope(Some(ScopeInner {
            id: next_span_id(),
            parent,
            corr,
            name: name.to_string(),
            track: track.to_string(),
            t_start: now_ns(),
            tags: Vec::new(),
        }))
    }

    /// Open a child of this span on the same corr and track.
    pub fn child(&self, name: &str) -> SpanScope {
        match &self.0 {
            Some(i) => Self::begin_child(name, &i.track, i.corr, Some(i.id)),
            None => SpanScope(None),
        }
    }

    /// Attach a typed tag.
    pub fn tag(&mut self, key: &'static str, value: impl Into<Tag>) {
        if let Some(i) = &mut self.0 {
            i.tags.push((key, value.into()));
        }
    }

    /// The open span's id, when armed.
    pub fn id(&self) -> Option<u64> {
        self.0.as_ref().map(|i| i.id)
    }

    /// Close and record now (Drop does the same).
    pub fn end(self) {}
}

impl Drop for SpanScope {
    fn drop(&mut self) {
        let Some(i) = self.0.take() else { return };
        let t_end = now_ns();
        with_state(|s| {
            s.push(Span {
                id: i.id,
                parent: i.parent,
                corr: i.corr,
                name: i.name,
                track: i.track,
                thread: 0,
                t_start: i.t_start,
                t_end: t_end.max(i.t_start),
                tags: i.tags,
            });
        });
    }
}

/// All recorded spans carrying `corr`, in record order (non-
/// destructive — the window keeps them).
pub fn collect_corr(corr: u64) -> Vec<Span> {
    with_state(|s| s.ring.iter().filter(|sp| sp.corr == Some(corr)).cloned().collect())
        .unwrap_or_default()
}

/// Graft a request's device-event Prof slice into the trace: each
/// [`ProfInfo`] becomes a `dev.<name>` span on its queue track, on the
/// same timeline (backend `EventTimes` already use the shared process
/// clock). The queued→submit→start stations ride along as tags.
pub fn graft_prof(infos: &[ProfInfo], corr: Option<u64>) {
    if !enabled() {
        return;
    }
    for info in infos {
        complete(
            &format!("dev.{}", info.name),
            &info.queue,
            corr,
            None,
            info.t_start,
            info.t_end,
            vec![("queued", Tag::U64(info.t_queued)), ("submit", Tag::U64(info.t_submit))],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_sink_records_nothing_and_scopes_are_inert() {
        // No window armed: the fast path must refuse everything.
        assert!(!enabled());
        let mut sc = SpanScope::begin("svc.request", "svc", Some(1));
        sc.tag("k", 1u64);
        assert!(sc.id().is_none());
        drop(sc);
        assert!(complete("x", "t", None, None, 0, 1, vec![]).is_none());
        assert!(instant("x", "t", None, None, vec![]).is_none());
        assert!(collect_corr(1).is_empty());
    }

    #[test]
    fn window_records_scopes_completes_and_ambient_adoption() {
        let w = Tracing::start();
        let corr = new_corr();
        w.set_ambient(Some(corr));

        let mut root = SpanScope::begin("svc.request", "svc", Some(corr));
        root.tag("req", 7u64);
        let child = root.child("svc.exec");
        let child_id = child.id().unwrap();
        let root_id = root.id().unwrap();
        drop(child);
        drop(root);
        // Corr-less spans adopt the ambient corr.
        complete("sched.task", "be:sim", None, None, 1, 2, vec![]).unwrap();

        let spans = w.finish();
        assert!(!enabled());
        assert_eq!(spans.len(), 3);
        let by_id = |id: u64| spans.iter().find(|s| s.id == id).unwrap();
        assert_eq!(by_id(child_id).parent, Some(root_id));
        assert!(spans.iter().all(|s| s.corr == Some(corr)));
        let root = by_id(root_id);
        assert!(root.t_start <= by_id(child_id).t_start);
        assert!(root.t_end >= by_id(child_id).t_end);
        assert_eq!(root.tag("req"), Some(&Tag::U64(7)));
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let w = Tracing::with_capacity(4);
        for i in 0..10u64 {
            complete("s", "t", Some(i), None, i, i + 1, vec![]);
        }
        assert_eq!(w.dropped(), 6);
        let spans = w.finish();
        assert_eq!(spans.len(), 4);
        // The oldest six are gone; the last four survive in order.
        let corrs: Vec<u64> = spans.iter().map(|s| s.corr.unwrap()).collect();
        assert_eq!(corrs, vec![6, 7, 8, 9]);
    }

    #[test]
    fn req_registry_resolves_shard_tags() {
        let w = Tracing::start();
        let corr = new_corr();
        register_req(42, corr);
        assert_eq!(corr_from_tag("svc.req-42."), Some(corr));
        assert_eq!(corr_from_tag("svc.req-41."), None);
        assert_eq!(corr_from_tag("svc.batch-42."), None);
        unregister_req(42);
        assert_eq!(corr_from_tag("svc.req-42."), None);
        drop(w);
    }

    #[test]
    fn graft_prof_converts_device_slices() {
        let w = Tracing::start();
        let infos = vec![ProfInfo {
            name: "PRNG_4096".to_string(),
            queue: "svc.req-3.sim".to_string(),
            t_queued: 10,
            t_submit: 11,
            t_start: 12,
            t_end: 30,
        }];
        graft_prof(&infos, Some(9));
        let spans = w.finish();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "dev.PRNG_4096");
        assert_eq!(spans[0].track, "svc.req-3.sim");
        assert_eq!(spans[0].corr, Some(9));
        assert_eq!((spans[0].t_start, spans[0].t_end), (12, 30));
    }
}
