//! Span-tree assembly: turn a flat span collection into per-request
//! rooted trees.
//!
//! Attachment runs on two rails, in order:
//!
//! 1. **Explicit parent ids** are honored when the parent exists in
//!    the collection; a span naming a missing parent is an *orphan*.
//! 2. **Interval containment** attaches every remaining span within a
//!    correlation group to its smallest enclosing span; spans nothing
//!    encloses compete for root (earliest start wins) and the losers
//!    attach to the root — simulated backends may model device
//!    timestamps slightly past the host span that awaited them, so
//!    strict containment falls back to the root instead of orphaning.
//!
//! Cycles from hostile explicit links can never hang assembly: trees
//! are materialized by walking down from the roots, and anything
//! unreachable is reported as an orphan.

use std::collections::HashMap;

use super::Span;
use crate::ccl::prof::export::escape_field;

/// One rooted request tree.
#[derive(Clone, Debug)]
pub struct Tree {
    /// Correlation id shared by the tree's spans (`None` for
    /// uncorrelated leftovers that formed their own tree).
    pub corr: Option<u64>,
    /// Index of the root span in [`Forest::spans`].
    pub root: usize,
}

/// Which layers a request tree crossed, by span-name prefix.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Completeness {
    pub edge: bool,
    pub svc: bool,
    pub sched: bool,
    pub dev: bool,
}

impl Completeness {
    /// Full edge-originated coverage: edge → service → shard → device.
    pub fn full(&self) -> bool {
        self.edge && self.svc && self.sched && self.dev
    }

    /// Service-originated coverage (no edge in the path).
    pub fn service_full(&self) -> bool {
        self.svc && self.sched && self.dev
    }
}

/// An assembled forest: every span attached, every tree rooted.
#[derive(Clone, Debug, Default)]
pub struct Forest {
    pub spans: Vec<Span>,
    /// Children of each span (indices into `spans`), start-ordered.
    pub children: Vec<Vec<usize>>,
    /// One per rooted tree, ordered by corr then root start.
    pub trees: Vec<Tree>,
    /// Spans left unattached: missing explicit parents, explicit
    /// self-links, or members of an explicit-link cycle.
    pub orphans: Vec<usize>,
}

impl Forest {
    /// Assemble trees from a flat span collection.
    pub fn build(spans: Vec<Span>) -> Forest {
        let n = spans.len();
        let by_id: HashMap<u64, usize> = spans.iter().enumerate().map(|(i, s)| (s.id, i)).collect();
        // corr → member indices (uncorrelated spans each form their own
        // singleton group unless an explicit parent links them out).
        let mut groups: HashMap<Option<u64>, Vec<usize>> = HashMap::new();
        for (i, s) in spans.iter().enumerate() {
            groups.entry(s.corr).or_default().push(i);
        }

        // attach[i] = Some(parent index) or None for roots; orphans are
        // tracked separately and excluded from attachment.
        let mut attach: Vec<Option<usize>> = vec![None; n];
        let mut is_orphan = vec![false; n];
        let mut is_root = vec![false; n];

        for (corr, members) in &groups {
            // Rail 1: explicit parents.
            let mut unattached: Vec<usize> = Vec::new();
            for &i in members {
                match spans[i].parent {
                    Some(p) => match by_id.get(&p) {
                        Some(&pi) if pi != i => attach[i] = Some(pi),
                        _ => is_orphan[i] = true,
                    },
                    None => unattached.push(i),
                }
            }
            // Rail 2: smallest-enclosing containment among the group's
            // remaining spans (uncorrelated groups skip containment —
            // nothing relates their members).
            if corr.is_none() {
                for &i in &unattached {
                    is_root[i] = true;
                }
                continue;
            }
            let mut rootless: Vec<usize> = Vec::new();
            for &i in &unattached {
                let (s0, s1) = (spans[i].t_start, spans[i].t_end);
                let enclosing = members
                    .iter()
                    .copied()
                    .filter(|&j| j != i && !is_orphan[j])
                    .filter(|&j| {
                        let (j0, j1) = (spans[j].t_start, spans[j].t_end);
                        j0 <= s0
                            && s1 <= j1
                            // Identical intervals: only the earlier-id
                            // span may enclose, so ties cannot cycle.
                            && ((j0, j1) != (s0, s1) || spans[j].id < spans[i].id)
                    })
                    .min_by_key(|&j| (spans[j].duration(), spans[j].id));
                match enclosing {
                    Some(j) => attach[i] = Some(j),
                    None => rootless.push(i),
                }
            }
            // Earliest-starting uncontained span roots the tree; any
            // other uncontained span (device events modeled past the
            // host wall, clock-skewed stragglers) attaches to it.
            rootless.sort_by_key(|&i| (spans[i].t_start, spans[i].id));
            if let Some((&root, rest)) = rootless.split_first() {
                is_root[root] = true;
                for &i in rest {
                    attach[i] = Some(root);
                }
            }
        }

        // Materialize children lists from the roots down; whatever a
        // walk from the roots cannot reach (explicit-link cycles) is
        // orphaned.
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            if is_orphan[i] || is_root[i] {
                continue;
            }
            if let Some(p) = attach[i] {
                children[p].push(i);
            }
        }
        let mut reached = vec![false; n];
        let mut stack: Vec<usize> = (0..n).filter(|&i| is_root[i]).collect();
        while let Some(i) = stack.pop() {
            if std::mem::replace(&mut reached[i], true) {
                continue;
            }
            stack.extend(children[i].iter().copied());
        }
        let mut orphans: Vec<usize> = (0..n).filter(|&i| !reached[i]).collect();
        orphans.sort_unstable();
        for &i in &orphans {
            if let Some(p) = attach[i] {
                children[p].retain(|&c| c != i);
            }
        }
        for c in &mut children {
            c.sort_by_key(|&i| (spans[i].t_start, spans[i].id));
        }

        let mut trees: Vec<Tree> = (0..n)
            .filter(|&i| is_root[i] && reached[i])
            .map(|i| Tree { corr: spans[i].corr, root: i })
            .collect();
        trees.sort_by_key(|t| (t.corr, spans[t.root].t_start, spans[t.root].id));

        Forest { spans, children, trees, orphans }
    }

    /// Indices of `root` and all its descendants.
    pub fn subtree(&self, root: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut stack = vec![root];
        while let Some(i) = stack.pop() {
            out.push(i);
            stack.extend(self.children[i].iter().copied());
        }
        out
    }

    /// Layer coverage of one tree, by span-name prefix.
    pub fn completeness(&self, tree: &Tree) -> Completeness {
        let mut c = Completeness::default();
        for i in self.subtree(tree.root) {
            let name = &self.spans[i].name;
            c.edge |= name.starts_with("edge.");
            c.svc |= name.starts_with("svc.");
            c.sched |= name.starts_with("sched.");
            c.dev |= name.starts_with("dev.");
        }
        c
    }

    /// The tree rooted for `corr`, if exactly one exists.
    pub fn tree_for_corr(&self, corr: u64) -> Option<&Tree> {
        let mut it = self.trees.iter().filter(|t| t.corr == Some(corr));
        let first = it.next()?;
        it.next().is_none().then_some(first)
    }

    /// Indented human rendering, one block per tree.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for tree in &self.trees {
            match tree.corr {
                Some(c) => out.push_str(&format!("request corr={c}\n")),
                None => out.push_str("uncorrelated\n"),
            }
            self.render_node(tree.root, 1, &mut out);
        }
        if !self.orphans.is_empty() {
            out.push_str(&format!("{} orphaned span(s):\n", self.orphans.len()));
            for &i in &self.orphans {
                let s = &self.spans[i];
                out.push_str(&format!(
                    "  !! {} [{}] id={} parent={:?}\n",
                    escape_field(&s.name),
                    escape_field(&s.track),
                    s.id,
                    s.parent
                ));
            }
        }
        out
    }

    fn render_node(&self, i: usize, depth: usize, out: &mut String) {
        let s = &self.spans[i];
        let ms = s.duration() as f64 * 1e-6;
        out.push_str(&format!(
            "{}{} [{}] {:.3} ms",
            "  ".repeat(depth),
            escape_field(&s.name),
            escape_field(&s.track),
            ms
        ));
        if !s.tags.is_empty() {
            let tags: Vec<String> = s
                .tags
                .iter()
                .map(|(k, v)| format!("{k}={}", escape_field(&v.to_string())))
                .collect();
            out.push_str(&format!("  ({})", tags.join(" ")));
        }
        out.push('\n');
        for &c in &self.children[i] {
            self.render_node(c, depth + 1, out);
        }
    }

    /// TSV rendering, one row per span, fields escaped with the shared
    /// profiler-export escaper.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("corr\tid\tparent\ttrack\tstart\tend\tname\n");
        let mut rows: Vec<&Span> = self.spans.iter().collect();
        rows.sort_by_key(|s| (s.corr, s.t_start, s.id));
        for s in rows {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                s.corr.map_or_else(|| "-".to_string(), |c| c.to_string()),
                s.id,
                s.parent.map_or_else(|| "-".to_string(), |p| p.to_string()),
                escape_field(&s.track),
                s.t_start,
                s.t_end,
                escape_field(&s.name),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Tag;

    fn span(
        id: u64,
        parent: Option<u64>,
        corr: Option<u64>,
        name: &str,
        t0: u64,
        t1: u64,
    ) -> Span {
        Span {
            id,
            parent,
            corr,
            name: name.to_string(),
            track: "t".to_string(),
            thread: 0,
            t_start: t0,
            t_end: t1,
            tags: Vec::new(),
        }
    }

    #[test]
    fn explicit_parent_then_containment_then_root_fallback() {
        let f = Forest::build(vec![
            span(1, None, Some(5), "edge.req", 0, 100),
            span(2, Some(1), Some(5), "edge.decode", 1, 3),
            span(3, None, Some(5), "svc.request", 5, 90),
            span(4, None, Some(5), "sched.task", 10, 60),
            // Ends past svc.request: climbs to the next encloser.
            span(5, None, Some(5), "dev.K", 50, 95),
            // Ends past everything (simulated future timestamp):
            // attaches to the root by fallback.
            span(6, None, Some(5), "dev.L", 60, 120),
        ]);
        assert_eq!(f.trees.len(), 1);
        assert!(f.orphans.is_empty());
        let tree = f.tree_for_corr(5).unwrap();
        assert_eq!(f.spans[tree.root].id, 1);
        let kids = |id: u64| -> Vec<u64> {
            let i = f.spans.iter().position(|s| s.id == id).unwrap();
            f.children[i].iter().map(|&c| f.spans[c].id).collect()
        };
        assert_eq!(kids(1), vec![2, 3, 5, 6]);
        assert_eq!(kids(3), vec![4]);
    }

    #[test]
    fn completeness_tracks_layer_prefixes() {
        let f = Forest::build(vec![
            span(1, None, Some(7), "edge.req", 0, 100),
            span(2, None, Some(7), "svc.request", 5, 90),
            span(3, None, Some(7), "sched.task", 10, 60),
            span(4, None, Some(7), "dev.K", 12, 40),
        ]);
        let c = f.completeness(f.tree_for_corr(7).unwrap());
        assert!(c.full());
        let g = Forest::build(vec![
            span(1, None, Some(8), "svc.request", 5, 90),
            span(2, None, Some(8), "sched.task", 10, 60),
        ]);
        let c = g.completeness(g.tree_for_corr(8).unwrap());
        assert!(!c.full() && !c.service_full() && c.svc && c.sched);
    }

    #[test]
    fn missing_parents_and_cycles_are_orphans_not_hangs() {
        let f = Forest::build(vec![
            span(1, None, Some(1), "svc.request", 0, 10),
            span(2, Some(99), Some(1), "svc.exec", 1, 2), // missing parent
            span(3, Some(4), Some(1), "sched.a", 3, 4),   // cycle
            span(4, Some(3), Some(1), "sched.b", 3, 4),   // cycle
            span(5, Some(5), Some(1), "sched.self", 5, 6), // self-link
        ]);
        assert_eq!(f.trees.len(), 1);
        let orphan_ids: Vec<u64> = f.orphans.iter().map(|&i| f.spans[i].id).collect();
        assert_eq!(orphan_ids, vec![2, 3, 4, 5]);
    }

    #[test]
    fn identical_intervals_tie_break_without_cycling() {
        let f = Forest::build(vec![
            span(1, None, Some(3), "svc.request", 0, 10),
            span(2, None, Some(3), "sched.a", 2, 8),
            span(3, None, Some(3), "sched.b", 2, 8),
        ]);
        assert_eq!(f.trees.len(), 1);
        assert!(f.orphans.is_empty());
        // Only the earlier id may enclose an identical interval.
        let i2 = f.spans.iter().position(|s| s.id == 2).unwrap();
        assert!(f.children[i2].iter().any(|&c| f.spans[c].id == 3));
    }

    #[test]
    fn uncorrelated_spans_form_singleton_trees() {
        let f = Forest::build(vec![
            span(1, None, None, "sched.plan", 0, 10),
            span(2, None, None, "sched.task", 2, 8),
        ]);
        assert_eq!(f.trees.len(), 2);
        assert!(f.orphans.is_empty());
    }

    #[test]
    fn tsv_escapes_hostile_names() {
        let mut s = span(1, None, Some(1), "dev.k\tname\n", 0, 10);
        s.track = "q\\ueue".to_string();
        s.tags.push(("note", Tag::Str("v".into())));
        let f = Forest::build(vec![s]);
        let tsv = f.to_tsv();
        let row = tsv.lines().nth(1).unwrap();
        assert_eq!(row.split('\t').count(), 7, "embedded tab must be escaped: {row}");
        assert!(row.contains("dev.k\\tname\\n"));
        assert!(row.contains("q\\\\ueue"));
    }
}
