//! Chrome trace-event export: spans as a Perfetto /
//! `chrome://tracing`-loadable JSON document, plus a dependency-free
//! JSON reader used to validate the export in tests and CI.
//!
//! One process (`pid` 1), one `tid` per distinct span track, named via
//! `thread_name` metadata events. Spans become complete (`"ph": "X"`)
//! events with microsecond `ts`/`dur`; span ids, parent links, corr
//! and typed tags ride in `args`. Label fields run through the shared
//! profiler-export escaper ([`escape_field`]) first — the same
//! convention every other rendering in the stack uses — and then
//! through JSON string escaping.

use std::collections::BTreeMap;

use super::{Span, Tag};
use crate::ccl::prof::export::escape_field;
use crate::ccl::prof::info::ProfInfo;
use crate::ccl::prof::overlap::{compute_overlaps, per_queue_util};

/// JSON-escape a raw string (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Shared-escaper pass, then JSON quoting — the label pipeline.
fn label(s: &str) -> String {
    json_str(&escape_field(s))
}

fn tag_json(tag: &Tag) -> String {
    match tag {
        Tag::U64(v) => v.to_string(),
        Tag::F64(v) => {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        }
        Tag::Bool(v) => v.to_string(),
        Tag::Str(v) => label(v),
    }
}

/// The `cat` field: the span's layer (name up to the first dot).
fn category(name: &str) -> &str {
    name.split('.').next().unwrap_or("span")
}

/// Render spans as a Chrome trace-event JSON document.
pub fn export_chrome(spans: &[Span]) -> String {
    // Stable track→tid assignment, ordered by name.
    let mut tids: BTreeMap<&str, u64> = BTreeMap::new();
    for s in spans {
        let next = tids.len() as u64 + 1;
        tids.entry(s.track.as_str()).or_insert(next);
    }

    let mut events: Vec<String> = Vec::with_capacity(spans.len() + tids.len() + 1);
    events.push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"args\":{\"name\":\"cf4rs\"}}"
            .to_string(),
    );
    for (track, tid) in &tids {
        events.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":{}}}}}",
            label(track)
        ));
    }
    for s in spans {
        let tid = tids[s.track.as_str()];
        let ts = s.t_start as f64 / 1e3;
        let dur = s.duration() as f64 / 1e3;
        let mut args = vec![format!("\"id\":{}", s.id)];
        if let Some(p) = s.parent {
            args.push(format!("\"parent\":{p}"));
        }
        if let Some(c) = s.corr {
            args.push(format!("\"corr\":{c}"));
        }
        args.push(format!("\"thread\":{}", s.thread));
        for (k, v) in &s.tags {
            args.push(format!("{}:{}", label(k), tag_json(v)));
        }
        events.push(format!(
            "{{\"name\":{},\"cat\":{},\"ph\":\"X\",\"ts\":{ts:.3},\"dur\":{dur:.3},\
             \"pid\":1,\"tid\":{tid},\"args\":{{{}}}}}",
            label(&s.name),
            json_str(category(&s.name)),
            args.join(",")
        ));
    }

    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    out.push_str(&events.join(",\n"));
    out.push_str("\n]}\n");
    out
}

/// Per-queue overlap/idle summary spans for the device tracks: one
/// `queue.util` span covering each device queue's active window
/// (busy/utilisation/cross-queue overlap tags from
/// [`per_queue_util`] + [`compute_overlaps`]) and a `queue.idle` span
/// per gap between the queue's busy intervals — so Perfetto shows the
/// idle holes, not just the kernels around them.
pub fn queue_summary_spans(spans: &[Span]) -> Vec<Span> {
    let infos: Vec<ProfInfo> = spans
        .iter()
        .filter(|s| s.name.starts_with("dev."))
        .map(|s| ProfInfo {
            name: s.name["dev.".len()..].to_string(),
            queue: s.track.clone(),
            t_queued: s.t_start,
            t_submit: s.t_start,
            t_start: s.t_start,
            t_end: s.t_end,
        })
        .collect();
    if infos.is_empty() {
        return Vec::new();
    }
    // Cross-queue overlap attribution: an overlapping name pair charges
    // every queue that ran either event.
    let mut name_queues: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for i in &infos {
        let qs = name_queues.entry(i.name.as_str()).or_default();
        if !qs.contains(&i.queue.as_str()) {
            qs.push(i.queue.as_str());
        }
    }
    let mut overlap_ns: BTreeMap<&str, u64> = BTreeMap::new();
    for ov in compute_overlaps(&infos) {
        let mut charged: Vec<&str> = Vec::new();
        for name in [ov.event1.as_str(), ov.event2.as_str()] {
            for &q in name_queues.get(name).into_iter().flatten() {
                if !charged.contains(&q) {
                    charged.push(q);
                }
            }
        }
        for q in charged {
            *overlap_ns.entry(q).or_insert(0) += ov.duration;
        }
    }

    let mut out = Vec::new();
    for u in per_queue_util(&infos) {
        let ov = overlap_ns.get(u.queue.as_str()).copied().unwrap_or(0);
        out.push(Span {
            id: 0,
            parent: None,
            corr: None,
            name: "queue.util".to_string(),
            track: u.queue.clone(),
            thread: 0,
            t_start: u.t_first,
            t_end: u.t_last,
            tags: vec![
                ("busy_ns", Tag::U64(u.busy)),
                ("util_pct", Tag::F64(u.utilisation() * 100.0)),
                ("overlap_ns", Tag::U64(ov)),
            ],
        });
        for w in u.busy_intervals.windows(2) {
            let (gap_start, gap_end) = (w[0].1, w[1].0);
            out.push(Span {
                id: 0,
                parent: None,
                corr: None,
                name: "queue.idle".to_string(),
                track: u.queue.clone(),
                thread: 0,
                t_start: gap_start,
                t_end: gap_end,
                tags: vec![("idle_ns", Tag::U64(gap_end - gap_start))],
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Dependency-free JSON reader (validation only)
// ---------------------------------------------------------------------------

/// A parsed JSON value — just enough structure to verify the export.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Strict recursive-descent JSON parse (whole input must be one value).
pub fn parse_json(s: &str) -> Result<Json, String> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing bytes at offset {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at offset {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                expect(b, pos, b':')?;
                fields.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at offset {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at offset {pos}")),
                }
            }
        }
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        Some(b'f') if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        Some(b'n') if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            std::str::from_utf8(&b[start..*pos])
                .ok()
                .and_then(|s| s.parse::<f64>().ok())
                .map(Json::Num)
                .ok_or_else(|| format!("bad number at offset {start}"))
        }
        None => Err("unexpected end of input".to_string()),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at offset {pos}"));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or_else(|| format!("bad \\u escape at offset {pos}"))?;
                        out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at offset {pos}")),
                }
                *pos += 1;
            }
            Some(&c) => {
                // Multi-byte UTF-8 passes through untouched.
                let len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                let chunk = b
                    .get(*pos..*pos + len)
                    .and_then(|s| std::str::from_utf8(s).ok())
                    .ok_or_else(|| format!("bad utf-8 at offset {pos}"))?;
                out.push_str(chunk);
                *pos += len;
            }
            None => return Err("unterminated string".to_string()),
        }
    }
}

/// Structural summary of a validated Chrome trace document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChromeStats {
    /// `"ph": "X"` complete events.
    pub complete_events: usize,
    /// `"ph": "M"` metadata events.
    pub metadata_events: usize,
    /// Track names announced by `thread_name` metadata.
    pub tracks: Vec<String>,
}

/// Parse an exported document and check the Chrome trace-event
/// contract: top-level `traceEvents` array; every event an object with
/// a string `ph`; every `X` event carrying string `name` and numeric
/// `ts`/`dur`/`pid`/`tid` with `dur >= 0`.
pub fn validate_chrome(doc: &str) -> Result<ChromeStats, String> {
    let root = parse_json(doc)?;
    let events = root
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("missing traceEvents array")?;
    let mut stats = ChromeStats::default();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        match ph {
            "X" => {
                ev.get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| format!("event {i}: X without name"))?;
                for key in ["ts", "dur", "pid", "tid"] {
                    ev.get(key)
                        .and_then(|v| v.as_num())
                        .ok_or_else(|| format!("event {i}: X without numeric {key}"))?;
                }
                if ev.get("dur").and_then(|v| v.as_num()).unwrap_or(-1.0) < 0.0 {
                    return Err(format!("event {i}: negative dur"));
                }
                stats.complete_events += 1;
            }
            "M" => {
                if ev.get("name").and_then(|n| n.as_str()) == Some("thread_name") {
                    if let Some(t) =
                        ev.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str())
                    {
                        stats.tracks.push(t.to_string());
                    }
                }
                stats.metadata_events += 1;
            }
            other => return Err(format!("event {i}: unexpected ph {other:?}")),
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, track: &str, corr: Option<u64>, t0: u64, t1: u64) -> Span {
        Span {
            id: 1,
            parent: None,
            corr,
            name: name.to_string(),
            track: track.to_string(),
            thread: 0,
            t_start: t0,
            t_end: t1,
            tags: Vec::new(),
        }
    }

    #[test]
    fn export_validates_and_round_trips_names() {
        let mut s1 = span("svc.request", "svc", Some(3), 1_000, 91_000);
        s1.id = 7;
        s1.tags.push(("req", Tag::U64(12)));
        s1.tags.push(("backend", Tag::Str("sim".into())));
        let s2 = span("dev.PRNG_4096", "svc.req-12.sim", Some(3), 5_000, 60_000);
        let doc = export_chrome(&[s1, s2]);
        let stats = validate_chrome(&doc).expect("valid chrome json");
        assert_eq!(stats.complete_events, 2);
        assert_eq!(stats.tracks, vec!["svc", "svc.req-12.sim"]);
        // µs conversion: 1_000 ns → 1.000 µs.
        assert!(doc.contains("\"ts\":1.000"));
        assert!(doc.contains("\"corr\":3"));
    }

    #[test]
    fn hostile_labels_stay_inside_json_strings() {
        let s = span("dev.k\"na\\me\t\n", "q\u{1}", Some(1), 0, 10);
        let doc = export_chrome(&[s]);
        let stats = validate_chrome(&doc).expect("hostile labels must not break the doc");
        assert_eq!(stats.complete_events, 1);
        let root = parse_json(&doc).unwrap();
        let events = root.get("traceEvents").unwrap().as_arr().unwrap();
        let x = events.iter().find(|e| e.get("ph").unwrap().as_str() == Some("X")).unwrap();
        let name = x.get("name").unwrap().as_str().unwrap();
        // The shared escaper's visible forms survive the JSON round trip.
        assert!(name.contains("\\t") && name.contains("\\n"), "{name:?}");
    }

    #[test]
    fn queue_summary_emits_util_and_idle_gaps() {
        let spans = vec![
            span("dev.A", "q1", Some(1), 0, 100),
            span("dev.B", "q1", Some(1), 200, 300),
            span("dev.C", "q2", Some(1), 50, 250),
            span("svc.request", "svc", Some(1), 0, 400), // not a device span
        ];
        let summary = queue_summary_spans(&spans);
        let utils: Vec<&Span> = summary.iter().filter(|s| s.name == "queue.util").collect();
        let idles: Vec<&Span> = summary.iter().filter(|s| s.name == "queue.idle").collect();
        assert_eq!(utils.len(), 2);
        assert_eq!(idles.len(), 1, "q1 has one 100 ns gap");
        assert_eq!((idles[0].t_start, idles[0].t_end), (100, 200));
        let q1 = utils.iter().find(|s| s.track == "q1").unwrap();
        assert_eq!(q1.tag("busy_ns"), Some(&Tag::U64(200)));
        // q1's events overlap q2's C for (50..100) + (200..250) = 100 ns.
        assert_eq!(q1.tag("overlap_ns"), Some(&Tag::U64(100)));
        assert!(queue_summary_spans(&[span("svc.x", "s", None, 0, 1)]).is_empty());
    }

    #[test]
    fn parser_rejects_garbage_and_trailing_bytes() {
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2,]").is_err());
        assert!(parse_json("{} junk").is_err());
        assert!(parse_json("\"unterminated").is_err());
        assert!(validate_chrome("{\"traceEvents\": [{\"ph\": 3}]}").is_err());
        assert!(validate_chrome("{\"traceEvents\": 4}").is_err());
        assert!(
            validate_chrome("{\"traceEvents\": [{\"ph\": \"X\", \"name\": \"a\"}]}").is_err()
        );
    }
}
