//! Generic typed device buffer: `Buffer<T: Pod>`.
//!
//! Wraps the v1 byte-oriented [`buffer`](crate::ccl::Buffer) with an
//! element type, so reads and writes move `&[T]`/`Vec<T>` instead of
//! byte slices — no size arithmetic, no `to_le_bytes` casts — and every
//! transfer participates in the session's implicit dependency chain.

use std::marker::PhantomData;

use crate::rawcl::types::MemH;

use super::super::buffer::Buffer as RawBuffer;
use super::super::errors::{CclError, CclResult};
use super::super::event::Event;
use super::pod::{decode, encode, Pod};
use super::session::Session;

/// A typed device buffer owned by a [`Session`].
///
/// Transfers default to queue 0 and to implicit ordering: a read waits
/// for the buffer's last writer, a write waits for the last writer and
/// all readers since. The `*_on` variants pick another session queue
/// (e.g. a dedicated comms queue) with the same ordering guarantees.
pub struct Buffer<'s, T: Pod> {
    sess: &'s Session,
    inner: RawBuffer,
    len: usize,
    _t: PhantomData<T>,
}

impl<'s, T: Pod> Buffer<'s, T> {
    pub(crate) fn wrap(sess: &'s Session, inner: RawBuffer, len: usize) -> Self {
        Self { sess, inner, len, _t: PhantomData }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Device allocation size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.len * T::ELEM.size_bytes()
    }

    /// The raw memory handle (escape hatch into the low tier).
    pub fn handle(&self) -> MemH {
        self.inner.handle()
    }

    /// Write a full buffer's worth of elements (blocking), ordered
    /// after the buffer's current writer and readers.
    pub fn write_slice(&self, data: &[T]) -> CclResult<Event> {
        if data.len() != self.len {
            return Err(CclError::framework(format!(
                "write_slice length mismatch: buffer holds {} element(s), \
                 slice has {}",
                self.len,
                data.len()
            )));
        }
        self.sess.raw_write(self.handle(), 0, &encode(data), 0, &[], true)
    }

    /// Read the whole buffer (blocking) into a typed vector, ordered
    /// after the buffer's last writer — no explicit wait-list needed.
    pub fn read_vec(&self) -> CclResult<Vec<T>> {
        self.read_vec_on(0)
    }

    /// [`read_vec`](Self::read_vec) on the i-th session queue.
    pub fn read_vec_on(&self, qi: usize) -> CclResult<Vec<T>> {
        let mut bytes = vec![0u8; self.size_bytes()];
        self.sess.raw_read(self.handle(), 0, &mut bytes, qi, &[], true)?;
        Ok(decode(&bytes))
    }

    /// Read the raw little-endian bytes into `dst` (blocking) on the
    /// i-th session queue — the zero-copy path for streaming consumers
    /// that forward bytes (the §5 PRNG service's comms thread).
    pub fn read_into_on(&self, qi: usize, dst: &mut [u8]) -> CclResult<Event> {
        if dst.len() != self.size_bytes() {
            return Err(CclError::framework(format!(
                "read_into_on size mismatch: buffer is {} byte(s), \
                 destination {}",
                self.size_bytes(),
                dst.len()
            )));
        }
        self.sess.raw_read(self.handle(), 0, dst, qi, &[], true)
    }
}

impl<T: Pod> Drop for Buffer<'_, T> {
    fn drop(&mut self) {
        // The raw buffer releases itself; just retire the dependency
        // state so a recycled handle can't inherit stale events.
        self.sess.deps.lock().unwrap().forget(self.inner.handle());
    }
}
