//! The [`Session`] facade: context + device selection + queues +
//! program cache + profiler in one handle.
//!
//! A session replaces the four-object setup dance of the v1 tier
//! (context → device → queue → program) with one builder:
//!
//! ```no_run
//! use cf4rs::ccl::v2::Session;
//!
//! let sess = Session::builder().gpu().profiled().build().unwrap();
//! sess.load(&["init_n4096", "rng_n4096"]).unwrap();
//! let buf = sess.buffer::<u64>(4096).unwrap();
//! sess.kernel("prng_init").unwrap()
//!     .global(4096)
//!     .arg(&buf)
//!     .arg(4096u32)
//!     .launch()
//!     .unwrap();
//! let seeds = buf.read_vec().unwrap(); // ordered after the kernel
//! ```

use std::collections::HashMap;
use std::sync::Mutex;

use crate::rawcl::error::CL_BUILD_PROGRAM_FAILURE;
use crate::rawcl::types::{DeviceId, DeviceType, MemFlags, MemH, QueueProps};
use crate::runtime::ArtifactKind;

use super::super::context::Context;
use super::super::device::Device;
use super::super::errors::{CclError, CclResult};
use super::super::event::Event;
use super::super::prof::Prof;
use super::super::program::Program;
use super::super::queue::Queue;
use super::super::selector::FilterChain;
use super::buffer::Buffer;
use super::deps::DepTracker;
use super::launch::Launch;
use super::pod::{encode, Pod};

/// How the [`SessionBuilder`] picks devices.
enum DevicePick {
    /// Default: all GPUs of the first GPU-bearing platform.
    Gpu,
    /// All CPU devices.
    Cpu,
    /// An explicit device-type mask.
    Type(DeviceType),
    /// An explicit flat device index.
    Index(DeviceId),
    /// A selector filter chain (`same_platform` appended by `Context`).
    Filters(FilterChain),
}

/// Builder for [`Session`] — the `ccl_*_new` calls of the v1 tier
/// collapsed into one fluent statement.
pub struct SessionBuilder {
    pick: DevicePick,
    num_queues: usize,
    profiled: bool,
}

impl SessionBuilder {
    /// Select all GPU devices of the first GPU-bearing platform
    /// (the default).
    pub fn gpu(mut self) -> Self {
        self.pick = DevicePick::Gpu;
        self
    }

    /// Select all CPU devices.
    pub fn cpu(mut self) -> Self {
        self.pick = DevicePick::Cpu;
        self
    }

    /// Select devices by type mask.
    pub fn device_type(mut self, t: DeviceType) -> Self {
        self.pick = DevicePick::Type(t);
        self
    }

    /// Select one device by flat index (0 = native CPU, 1/2 = the
    /// simulated GPUs).
    pub fn device_index(mut self, i: u32) -> Self {
        self.pick = DevicePick::Index(DeviceId(i));
        self
    }

    /// Select devices through a [`FilterChain`] — the full plug-in
    /// selector mechanism of the v1 tier, reused as-is.
    pub fn filter(mut self, chain: FilterChain) -> Self {
        self.pick = DevicePick::Filters(chain);
        self
    }

    /// Create `n` command queues (labelled `"Q0"`, `"Q1"`, ...) on the
    /// session device. Default is 1; the double-buffered streaming
    /// pattern wants 2 (compute + comms).
    pub fn queues(mut self, n: usize) -> Self {
        self.num_queues = n.max(1);
        self
    }

    /// Enable event profiling on every queue and start the session's
    /// wall-clock profiling window; harvest with [`Session::profile`].
    pub fn profiled(mut self) -> Self {
        self.profiled = true;
        self
    }

    /// Create the context, pick the device, and create the queues.
    pub fn build(self) -> CclResult<Session> {
        let ctx = match self.pick {
            DevicePick::Gpu => Context::new_gpu()?,
            DevicePick::Cpu => Context::new_cpu()?,
            DevicePick::Type(t) => Context::new_from_type(t)?,
            DevicePick::Index(id) => {
                Context::new_from_devices(&[Device::from_id(id)?])?
            }
            DevicePick::Filters(chain) => Context::new_from_filters(chain)?,
        };
        let dev = ctx.device(0)?;
        let props = if self.profiled {
            QueueProps::PROFILING_ENABLE
        } else {
            QueueProps::empty()
        };
        let mut queues = Vec::with_capacity(self.num_queues);
        for i in 0..self.num_queues {
            let q = Queue::new(&ctx, dev, props)?;
            q.set_label(format!("Q{i}"));
            queues.push(q);
        }
        let prof = if self.profiled {
            let mut p = Prof::new();
            p.start();
            Some(p)
        } else {
            None
        };
        Ok(Session {
            ctx,
            dev,
            queues,
            programs: Mutex::new(Vec::new()),
            kernel_index: Mutex::new(HashMap::new()),
            deps: Mutex::new(DepTracker::default()),
            launch_lock: Mutex::new(()),
            prof: Mutex::new(prof),
        })
    }
}

/// The v2 facade handle — see [`crate::ccl::v2`] for the tier split.
///
/// A `Session` owns one context, one device, `n` queues, the programs
/// loaded into it, and the per-buffer dependency tracker that gives the
/// tier its implicit event chaining. It is `Sync`: the double-buffered
/// streaming services share one session across scoped threads.
pub struct Session {
    ctx: Context,
    dev: Device,
    queues: Vec<Queue>,
    programs: Mutex<Vec<Program>>,
    /// kernel name → index into `programs`.
    kernel_index: Mutex<HashMap<String, usize>>,
    pub(crate) deps: Mutex<DepTracker>,
    /// Serialises the set-args + enqueue window of every launch:
    /// kernel objects are cached per name, so without this two threads
    /// launching the same kernel could interleave their argument sets
    /// (the stateful-positional-args hazard of the v1/OpenCL model).
    pub(crate) launch_lock: Mutex<()>,
    prof: Mutex<Option<Prof>>,
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            pick: DevicePick::Gpu,
            num_queues: 1,
            profiled: false,
        }
    }

    /// The underlying v1 context (escape hatch into the low tier).
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// The session device (index 0 of the context).
    pub fn device(&self) -> Device {
        self.dev
    }

    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }

    /// The i-th command queue (escape hatch into the low tier).
    pub fn queue(&self, i: usize) -> CclResult<&Queue> {
        self.queues.get(i).ok_or_else(|| {
            CclError::framework(format!(
                "queue index {i} out of range (session has {})",
                self.queues.len()
            ))
        })
    }

    /// Load + build named artifacts (HLO modules); their kernels become
    /// available through [`kernel`](Self::kernel). Names outside the
    /// AOT manifest are generated on the fly, as in the v1 tier.
    pub fn load(&self, names: &[&str]) -> CclResult<&Self> {
        let prg = Program::new_from_artifacts(&self.ctx, names)?;
        self.register_program(prg)?;
        Ok(self)
    }

    /// Load + build programs by artifact kind and problem size.
    pub fn load_kinds(&self, kinds: &[(ArtifactKind, usize)]) -> CclResult<&Self> {
        let prg = Program::new_from_kinds(&self.ctx, kinds)?;
        self.register_program(prg)?;
        Ok(self)
    }

    /// Load + build generated modules from explicit generator specs —
    /// for kernels whose geometry `(kind, n)` cannot carry (2-D grids,
    /// sharded-init offsets).
    pub fn load_specs(&self, specs: &[crate::runtime::GenSpec]) -> CclResult<&Self> {
        let prg = Program::new_from_specs(&self.ctx, specs)?;
        self.register_program(prg)?;
        Ok(self)
    }

    /// Build `prg` (folding the build log into the error on failure, so
    /// callers don't need the v1 build-log dance) and index its kernels.
    fn register_program(&self, prg: Program) -> CclResult<()> {
        if let Err(e) = prg.build() {
            if e.code == CL_BUILD_PROGRAM_FAILURE {
                let log = prg.build_log().unwrap_or_default();
                return Err(CclError::from_status(
                    e.code,
                    format!("building program; build log:\n{log}"),
                ));
            }
            return Err(e);
        }
        let names = prg.kernel_names()?;
        let mut programs = self.programs.lock().unwrap();
        let idx = programs.len();
        programs.push(prg);
        drop(programs);
        let mut index = self.kernel_index.lock().unwrap();
        for n in names {
            index.insert(n, idx);
        }
        Ok(())
    }

    /// Kernels currently loaded, sorted by name.
    pub fn kernel_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.kernel_index.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Start a fluent launch of the named kernel:
    /// `sess.kernel("prng_step")?.global(n).arg(&a).arg(&b).launch()?`.
    pub fn kernel(&self, name: &str) -> CclResult<Launch<'_>> {
        // NB: release the index lock before building the error message —
        // kernel_names() takes the same lock.
        let idx = self.kernel_index.lock().unwrap().get(name).copied();
        let Some(idx) = idx else {
            return Err(CclError::framework(format!(
                "kernel {:?} is not loaded (loaded: {:?}); call \
                 Session::load / load_kinds first",
                name,
                self.kernel_names(),
            )));
        };
        let programs = self.programs.lock().unwrap();
        let kernel = programs[idx].kernel(name)?;
        drop(programs);
        Ok(Launch::new(self, kernel, name.to_string()))
    }

    /// Allocate an uninitialised typed device buffer of `len` elements.
    pub fn buffer<T: Pod>(&self, len: usize) -> CclResult<Buffer<'_, T>> {
        let inner = super::super::buffer::Buffer::new(
            &self.ctx,
            MemFlags::READ_WRITE,
            len * T::ELEM.size_bytes(),
        )?;
        Ok(Buffer::wrap(self, inner, len))
    }

    /// Allocate + initialise a typed device buffer from host data.
    pub fn buffer_from<T: Pod>(&self, data: &[T]) -> CclResult<Buffer<'_, T>> {
        let inner = super::super::buffer::Buffer::from_slice(
            &self.ctx,
            MemFlags::READ_WRITE,
            &encode(data),
        )?;
        Ok(Buffer::wrap(self, inner, data.len()))
    }

    /// Finish every queue.
    pub fn finish(&self) -> CclResult<()> {
        for q in &self.queues {
            q.finish()?;
        }
        Ok(())
    }

    /// Harvest the profile: finish all queues, close the wall-clock
    /// window, collect every queue's events and run the analysis.
    ///
    /// One-shot (the profiler's `calc` is one-shot): a second call — or
    /// any call on a session built without
    /// [`profiled`](SessionBuilder::profiled) — is an error.
    pub fn profile(&self) -> CclResult<Prof> {
        self.finish()?;
        let mut slot = self.prof.lock().unwrap();
        let mut prof = slot.take().ok_or_else(|| {
            CclError::framework(
                "no profile to harvest: build the session with .profiled() \
                 (and call profile() at most once)",
            )
        })?;
        drop(slot);
        prof.stop();
        for (i, q) in self.queues.iter().enumerate() {
            prof.add_queue(q.label().unwrap_or_else(|| format!("Q{i}")), q);
        }
        prof.calc()?;
        Ok(prof)
    }

    // ---- internal command paths shared by Buffer/Launch/Pending -------

    /// Enqueue a (blocking) read of `dst.len()` bytes from `h` on queue
    /// `qi`, waiting on `extra` plus — unless `implicit` is off — the
    /// buffer's last writer. The read is recorded for anti-dependency
    /// tracking either way.
    pub(crate) fn raw_read(
        &self,
        h: MemH,
        offset: usize,
        dst: &mut [u8],
        qi: usize,
        extra: &[Event],
        implicit: bool,
    ) -> CclResult<Event> {
        let q = self.queue(qi)?;
        let mut waits: Vec<Event> = extra.to_vec();
        // Snapshot deps, enqueue, and note the access under ONE tracker
        // lock. The old two-acquisition sequence had a window where a
        // concurrent writer could snapshot its anti-dependencies between
        // our snapshot and our note_read — missing this read entirely and
        // losing the WAR edge. The enqueue itself must therefore be
        // non-blocking (a channel send); we wait on the event after the
        // lock is gone.
        let ev = {
            let mut deps = self.deps.lock().unwrap();
            if implicit {
                waits.extend(deps.read_deps(h));
            }
            dedup_events(&mut waits);
            // SAFETY: `dst` outlives the command — we wait on `ev` below
            // before returning.
            let ev = unsafe {
                q.enqueue_read_buffer_h_nb(h, offset, dst.as_mut_ptr(), dst.len(), &waits)?
            };
            let _ = ev.set_name("READ_BUFFER");
            deps.note_read(h, ev);
            ev
        };
        ev.wait()?;
        Ok(ev)
    }

    /// Enqueue a (blocking) write of `src` into `h` on queue `qi`,
    /// waiting on `extra` plus — unless `implicit` is off — the
    /// buffer's last writer and readers. The write becomes the buffer's
    /// last writer either way.
    pub(crate) fn raw_write(
        &self,
        h: MemH,
        offset: usize,
        src: &[u8],
        qi: usize,
        extra: &[Event],
        implicit: bool,
    ) -> CclResult<Event> {
        let q = self.queue(qi)?;
        let mut waits: Vec<Event> = extra.to_vec();
        // Same atomic snapshot-enqueue-note sequence as raw_read: a
        // reader racing between our write_deps snapshot and note_write
        // must either be in the snapshot or observe us as last writer.
        let ev = {
            let mut deps = self.deps.lock().unwrap();
            if implicit {
                waits.extend(deps.write_deps(h));
            }
            dedup_events(&mut waits);
            let ev = q.enqueue_write_buffer_h_nb(h, offset, src, &waits)?;
            let _ = ev.set_name("WRITE_BUFFER");
            deps.note_write(h, ev);
            ev
        };
        // Preserve the blocking semantics the callers rely on.
        ev.wait()?;
        Ok(ev)
    }

    /// Run the static analyzer over the active recording, scoped to this
    /// session's queues.
    ///
    /// Requires an armed [`crate::analysis::Recording`] — start one
    /// *before* building the session (so queue labels are captured), run
    /// the commands to audit, then call `check()`:
    ///
    /// ```no_run
    /// use cf4rs::analysis::Recording;
    /// use cf4rs::ccl::v2::Session;
    ///
    /// let rec = Recording::start();
    /// let sess = Session::builder().build().unwrap();
    /// // ... launches, reads, writes ...
    /// let report = sess.check().unwrap();
    /// assert!(report.is_clean(), "{}", report.render_human());
    /// drop(rec);
    /// ```
    pub fn check(&self) -> CclResult<crate::analysis::Report> {
        let stream = crate::analysis::record::snapshot_active().ok_or_else(|| {
            CclError::framework(
                "Session::check needs an active recording: create a \
                 cf4rs::analysis::Recording before issuing commands",
            )
        })?;
        let mine: Vec<usize> = self
            .queues
            .iter()
            .filter_map(|q| {
                stream.queue_index(crate::analysis::record::RAWCL_SPACE, q.handle().0)
            })
            .collect();
        let mut report = crate::analysis::analyze(&stream);
        report.retain_queues(&mine);
        Ok(report)
    }
}

/// Drop duplicate events (same handle) from a wait list, keeping order.
pub(crate) fn dedup_events(evs: &mut Vec<Event>) {
    let mut seen = std::collections::HashSet::new();
    evs.retain(|e| seen.insert(e.handle()));
}
