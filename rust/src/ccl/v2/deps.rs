//! Per-buffer dependency tracking: the machinery behind implicit event
//! chaining.
//!
//! The [`super::Session`] records, for every device buffer it has seen,
//! the event of the last command that *wrote* it and the events of the
//! commands that have *read* it since. From those two facts the correct
//! wait-list for any new command follows:
//!
//! * a **read** must wait for the last writer (true dependency);
//! * a **write** must wait for the last writer *and* all readers since
//!   (output + anti-dependency), after which the reader set resets.
//!
//! Ordering is derived from the enqueue order the session observes.
//! Commands enqueued from different host threads still need host-side
//! synchronisation to have a defined order (exactly as with explicit
//! wait-lists); what the tracker removes is the *device-side* event
//! bookkeeping.

use std::collections::HashMap;

use crate::rawcl::types::MemH;

use super::super::event::Event;

#[derive(Default)]
struct BufState {
    last_writer: Option<Event>,
    readers: Vec<Event>,
}

/// The session-wide last-writer/reader table.
#[derive(Default)]
pub(crate) struct DepTracker {
    states: HashMap<u64, BufState>,
}

impl DepTracker {
    /// Events a command *reading* `h` must wait for.
    pub fn read_deps(&self, h: MemH) -> Vec<Event> {
        self.states
            .get(&h.0)
            .and_then(|s| s.last_writer)
            .into_iter()
            .collect()
    }

    /// Events a command *writing* `h` must wait for.
    pub fn write_deps(&self, h: MemH) -> Vec<Event> {
        let Some(s) = self.states.get(&h.0) else {
            return Vec::new();
        };
        s.last_writer.into_iter().chain(s.readers.iter().copied()).collect()
    }

    /// Record that `ev` reads `h`.
    pub fn note_read(&mut self, h: MemH, ev: Event) {
        self.states.entry(h.0).or_default().readers.push(ev);
    }

    /// Record that `ev` (over)writes `h`: it becomes the last writer and
    /// obsoletes the accumulated reader set.
    pub fn note_write(&mut self, h: MemH, ev: Event) {
        let st = self.states.entry(h.0).or_default();
        st.last_writer = Some(ev);
        st.readers.clear();
    }

    /// Drop all state for `h` (called when its buffer wrapper drops).
    pub fn forget(&mut self, h: MemH) {
        self.states.remove(&h.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rawcl::types::EventH;

    fn ev(i: u64) -> Event {
        Event::new(EventH(i))
    }

    #[test]
    fn read_waits_on_writer_write_waits_on_both() {
        let mut t = DepTracker::default();
        let h = MemH(42);
        assert!(t.read_deps(h).is_empty());
        assert!(t.write_deps(h).is_empty());

        t.note_write(h, ev(1));
        assert_eq!(t.read_deps(h), vec![ev(1)]);

        t.note_read(h, ev(2));
        t.note_read(h, ev(3));
        // readers don't gate other readers
        assert_eq!(t.read_deps(h), vec![ev(1)]);
        // but they do gate the next writer
        assert_eq!(t.write_deps(h), vec![ev(1), ev(2), ev(3)]);

        // a new write resets the reader set
        t.note_write(h, ev(4));
        assert_eq!(t.read_deps(h), vec![ev(4)]);
        assert_eq!(t.write_deps(h), vec![ev(4)]);

        t.forget(h);
        assert!(t.write_deps(h).is_empty());
    }
}
