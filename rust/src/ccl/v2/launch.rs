//! Fluent, type-checked kernel launches: [`Launch`] and [`Pending`].
//!
//! A launch is built in one expression —
//!
//! ```no_run
//! # use cf4rs::ccl::v2::Session;
//! # let sess = Session::builder().cpu().build().unwrap();
//! # sess.load(&["vecadd_n1024"]).unwrap();
//! # let (bx, by) = (sess.buffer::<f32>(1024).unwrap(), sess.buffer::<f32>(1024).unwrap());
//! # let bo = sess.buffer::<f32>(1024).unwrap();
//! let out = sess.kernel("vecadd").unwrap()
//!     .global(1024)
//!     .arg(&bx)
//!     .arg(&by)
//!     .output(&bo)
//!     .launch().unwrap()
//!     .read().unwrap();
//! ```
//!
//! — and validated *before* anything is enqueued: the argument list is
//! checked against the kernel's ABI spec for arity, buffer-vs-scalar
//! kind, element type and byte size, so a mismatched call fails with
//! one structured error naming the kernel and the offending position
//! instead of a late `CL_INVALID_ARG_*` per slot.
//!
//! Unless [`Launch::independent`] is called, the wait-list is assembled
//! implicitly from the session's per-buffer last-writer/reader tracking
//! (see [`super::deps`]); [`Launch::after`] adds explicit dependencies
//! on top.

use std::marker::PhantomData;

use crate::rawcl;
use crate::rawcl::kernelspec::ArgRole;
use crate::rawcl::types::MemH;
use crate::runtime::literal::ElemType;

use super::super::errors::{check, CclError, CclResult};
use super::super::event::Event;
use super::super::kernel::Kernel;
use super::buffer::Buffer;
use super::pod::Pod;
use super::session::{dedup_events, Session};

/// One collected launch argument. Implementation detail of [`IntoArg`];
/// construct values through [`Launch::arg`] / [`Launch::output`] /
/// [`Launch::skip_arg`].
pub enum LArg {
    /// A device buffer with its element type and byte size.
    Buf { h: MemH, elem: ElemType, bytes: usize },
    /// A private scalar with its element type.
    Scalar { bytes: Vec<u8>, elem: ElemType },
    /// Keep the previously-set value for this slot (`ccl_arg_skip`).
    Skip,
}

/// Anything [`Launch::arg`] accepts: typed buffers and scalars.
pub trait IntoArg {
    fn into_arg(self) -> LArg;
}

impl IntoArg for u32 {
    fn into_arg(self) -> LArg {
        LArg::Scalar { bytes: self.to_le_bytes().to_vec(), elem: ElemType::U32 }
    }
}

impl IntoArg for u64 {
    fn into_arg(self) -> LArg {
        LArg::Scalar { bytes: self.to_le_bytes().to_vec(), elem: ElemType::U64 }
    }
}

impl IntoArg for f32 {
    fn into_arg(self) -> LArg {
        LArg::Scalar { bytes: self.to_le_bytes().to_vec(), elem: ElemType::F32 }
    }
}

impl<'a, 'b, T: Pod> IntoArg for &'a Buffer<'b, T> {
    fn into_arg(self) -> LArg {
        LArg::Buf { h: self.handle(), elem: T::ELEM, bytes: self.size_bytes() }
    }
}

/// A launch being built. `O` is the element type of the designated
/// output buffer (set by [`output`](Self::output)); it types the
/// [`Pending`] handle `launch()` returns.
pub struct Launch<'s, O = ()> {
    sess: &'s Session,
    kernel: Kernel,
    kname: String,
    qi: usize,
    gws: Option<Vec<usize>>,
    lws: Option<Vec<usize>>,
    args: Vec<LArg>,
    extra_waits: Vec<Event>,
    independent: bool,
    ev_name: Option<String>,
    out: Option<(MemH, usize)>,
    _o: PhantomData<O>,
}

impl<'s> Launch<'s> {
    pub(crate) fn new(sess: &'s Session, kernel: Kernel, kname: String) -> Self {
        Self {
            sess,
            kernel,
            kname,
            qi: 0,
            gws: None,
            lws: None,
            args: Vec::new(),
            extra_waits: Vec::new(),
            independent: false,
            ev_name: None,
            out: None,
            _o: PhantomData,
        }
    }
}

impl<'s, O> Launch<'s, O> {
    /// Real 1-D work size. When no [`local`](Self::local) is given, the
    /// local size is suggested for the device and the global size
    /// rounded up, as `ccl_kernel_suggest_worksizes` does.
    pub fn global(mut self, n: usize) -> Self {
        self.gws = Some(vec![n]);
        self
    }

    /// Real N-D work size (1–3 dimensions).
    pub fn global_nd(mut self, dims: &[usize]) -> Self {
        self.gws = Some(dims.to_vec());
        self
    }

    /// Explicit 1-D local work size (skips the suggestion step; the
    /// global size is then used exactly as given).
    pub fn local(mut self, n: usize) -> Self {
        self.lws = Some(vec![n]);
        self
    }

    /// Explicit N-D local work size.
    pub fn local_nd(mut self, dims: &[usize]) -> Self {
        self.lws = Some(dims.to_vec());
        self
    }

    /// Append the next positional argument: a typed buffer or scalar.
    pub fn arg(mut self, a: impl IntoArg) -> Self {
        self.args.push(a.into_arg());
        self
    }

    /// Keep the previously-set value for the next positional slot
    /// (`ccl_arg_skip`): the slot still consumes its index. Skipped
    /// buffer slots are excluded from implicit dependency tracking.
    pub fn skip_arg(mut self) -> Self {
        self.args.push(LArg::Skip);
        self
    }

    /// Add an explicit dependency on top of the implicit ones.
    pub fn after(mut self, ev: &Event) -> Self {
        self.extra_waits.push(*ev);
        self
    }

    /// Add an explicit dependency on a previous launch.
    pub fn after_pending<T>(mut self, p: &Pending<'_, T>) -> Self {
        self.extra_waits.push(p.event());
        self
    }

    /// Opt out of implicit dependency chaining for this launch: only
    /// [`after`](Self::after) dependencies are waited on. The launch is
    /// still *recorded* as its output buffers' writer, so subsequent
    /// commands order correctly.
    pub fn independent(mut self) -> Self {
        self.independent = true;
        self
    }

    /// Enqueue on the i-th session queue (default 0).
    pub fn queue(mut self, qi: usize) -> Self {
        self.qi = qi;
        self
    }

    /// Profiling name for the launch event (default: the kernel name).
    pub fn name(mut self, n: &str) -> Self {
        self.ev_name = Some(n.to_string());
        self
    }

    /// Append the next positional argument — a buffer the kernel writes
    /// — and designate it as *the* output: the returned [`Pending`] is
    /// typed `Pending<T>` and can [`read`](Pending::read) it directly.
    pub fn output<T: Pod>(self, b: &Buffer<'_, T>) -> Launch<'s, T> {
        let mut args = self.args;
        args.push(LArg::Buf { h: b.handle(), elem: T::ELEM, bytes: b.size_bytes() });
        Launch {
            sess: self.sess,
            kernel: self.kernel,
            kname: self.kname,
            qi: self.qi,
            gws: self.gws,
            lws: self.lws,
            args,
            extra_waits: self.extra_waits,
            independent: self.independent,
            ev_name: self.ev_name,
            out: Some((b.handle(), b.len())),
            _o: PhantomData,
        }
    }

    /// Validate the call against the kernel spec, assemble the
    /// wait-list, set the arguments and enqueue — one statement, one
    /// structured error path.
    pub fn launch(self) -> CclResult<Pending<'s, O>> {
        let kerr = |msg: String| {
            CclError::framework(msg).with_object(format!("kernel {:?}", self.kname))
        };

        // -- arity/type check against the ABI spec, before any enqueue --
        let mut roles = Vec::new();
        check(
            rawcl::get_kernel_arg_roles(self.kernel.handle(), &mut roles),
            "querying kernel arg roles",
        )?;
        if self.args.len() != roles.len() {
            return Err(kerr(format!(
                "expects {} argument(s), got {}",
                roles.len(),
                self.args.len()
            )));
        }
        for (i, (arg, role)) in self.args.iter().zip(&roles).enumerate() {
            match (arg, role) {
                (LArg::Skip, _) => {}
                (
                    LArg::Buf { elem, bytes, .. },
                    ArgRole::BufferInput { dtype, bytes: want }
                    | ArgRole::BufferOutput { dtype, bytes: want },
                ) => {
                    if elem != dtype {
                        return Err(kerr(format!(
                            "arg {i}: expects a {} buffer, got {}",
                            dtype.name(),
                            elem.name()
                        )));
                    }
                    if bytes != want {
                        return Err(kerr(format!(
                            "arg {i}: expects a buffer of {want} byte(s), \
                             got {bytes}"
                        )));
                    }
                }
                (LArg::Scalar { elem, .. }, ArgRole::ScalarInput { dtype }) => {
                    if elem != dtype {
                        return Err(kerr(format!(
                            "arg {i}: expects a {} scalar, got {}",
                            dtype.name(),
                            elem.name()
                        )));
                    }
                }
                (LArg::Scalar { bytes, .. }, ArgRole::BakedScalar { bytes: want, .. }) => {
                    if bytes.len() != *want {
                        return Err(kerr(format!(
                            "arg {i}: expects a {want}-byte scalar, got {} byte(s)",
                            bytes.len()
                        )));
                    }
                }
                (LArg::Buf { .. }, ArgRole::ScalarInput { .. } | ArgRole::BakedScalar { .. }) => {
                    return Err(kerr(format!(
                        "arg {i}: expects a scalar, got a buffer"
                    )));
                }
                (LArg::Scalar { .. }, ArgRole::BufferInput { .. } | ArgRole::BufferOutput { .. }) => {
                    return Err(kerr(format!(
                        "arg {i}: expects a buffer, got a scalar"
                    )));
                }
            }
        }

        // -- work sizes: explicit local, or device-suggested ------------
        let rws = self
            .gws
            .clone()
            .ok_or_else(|| kerr("no global work size (call .global(n))".into()))?;
        let (gws, lws) = match self.lws.clone() {
            Some(l) => (rws, l),
            None => self.kernel.suggest_worksizes(self.sess.device(), &rws)?,
        };

        // -- set arguments + enqueue, atomically per session ------------
        // Kernel objects are cached per name, so the stateful positional
        // argument set and the enqueue that snapshots it must not
        // interleave with another thread's launch of the same kernel.
        let _launch_guard = self.sess.launch_lock.lock().unwrap();
        for (i, arg) in self.args.iter().enumerate() {
            let value = match arg {
                LArg::Buf { h, .. } => rawcl::ArgValue::Buffer(*h),
                LArg::Scalar { bytes, .. } => rawcl::ArgValue::Scalar(bytes.clone()),
                LArg::Skip => continue,
            };
            check(
                rawcl::set_kernel_arg(self.kernel.handle(), i, &value),
                &format!("setting kernel arg {i}"),
            )
            .map_err(|e| e.with_object(format!("kernel {:?}", self.kname)))?;
        }

        // -- implicit wait-list + enqueue + record, atomically ----------
        // One tracker lock spans the dependency snapshot, the enqueue
        // (a non-blocking channel send) and the access notes. The old
        // two-acquisition sequence left a window between snapshot and
        // note where a concurrent transfer on another thread could
        // snapshot *its* deps without seeing this launch, losing an
        // ordering edge.
        let queue = self.sess.queue(self.qi)?;
        let mut waits = self.extra_waits.clone();
        let mut deps = self.sess.deps.lock().unwrap();
        if !self.independent {
            for (arg, role) in self.args.iter().zip(&roles) {
                if let LArg::Buf { h, .. } = arg {
                    match role {
                        ArgRole::BufferInput { .. } => waits.extend(deps.read_deps(*h)),
                        ArgRole::BufferOutput { .. } => waits.extend(deps.write_deps(*h)),
                        _ => {}
                    }
                }
            }
        }
        dedup_events(&mut waits);
        let event = self.kernel.enqueue_ndrange(queue, &gws, Some(&lws), &waits)?;
        let _ = event.set_name(self.ev_name.as_deref().unwrap_or(&self.kname));
        for (arg, role) in self.args.iter().zip(&roles) {
            if let LArg::Buf { h, .. } = arg {
                match role {
                    ArgRole::BufferInput { .. } => deps.note_read(*h, event),
                    ArgRole::BufferOutput { .. } => deps.note_write(*h, event),
                    _ => {}
                }
            }
        }
        drop(deps);
        Ok(Pending { sess: self.sess, event, out: self.out, _o: PhantomData })
    }
}

/// Handle for a launched kernel: its event, plus — when the launch
/// designated an [`output`](Launch::output) buffer — a typed `read()`.
pub struct Pending<'s, O = ()> {
    sess: &'s Session,
    event: Event,
    out: Option<(MemH, usize)>,
    _o: PhantomData<O>,
}

impl<O> std::fmt::Debug for Pending<'_, O> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pending")
            .field("event", &self.event)
            .field("out", &self.out)
            .finish()
    }
}

impl<O> Pending<'_, O> {
    /// The launch event, for explicit chaining ([`Launch::after`]) or
    /// the v1 APIs.
    pub fn event(&self) -> Event {
        self.event
    }

    /// Block until the kernel completes.
    pub fn wait(&self) -> CclResult<()> {
        self.event.wait()
    }

    /// On-device duration in ns (profiled sessions, after completion).
    pub fn duration(&self) -> CclResult<u64> {
        self.event.duration()
    }
}

impl<O: Pod> Pending<'_, O> {
    /// Read the designated output buffer (blocking), ordered after this
    /// launch — the terse end of the fluent chain:
    /// `.output(&bo).launch()?.read()?`.
    pub fn read(&self) -> CclResult<Vec<O>> {
        let (h, len) = self.out.ok_or_else(|| {
            CclError::framework("no output buffer: use .output(&buf) before .launch()")
        })?;
        let mut bytes = vec![0u8; len * O::ELEM.size_bytes()];
        self.sess.raw_read(h, 0, &mut bytes, 0, &[self.event], true)?;
        Ok(super::pod::decode(&bytes))
    }
}
