//! # `ccl::v2` — the fluent, typed high tier of the framework
//!
//! The framework now has **two API tiers over one runtime**, in the
//! spirit of EngineCL's tiered design and the typed-buffer/expression
//! launches of the modern C++ OpenCL libraries:
//!
//! * the **v1 tier** (the rest of [`crate::ccl`]) mirrors cf4ocl's
//!   class-per-OpenCL-object design: explicit [`Context`],
//!   [`Queue`], [`Program`], byte-slice [buffers](crate::ccl::Buffer),
//!   positional [`Arg`] lists and hand-threaded wait-lists. It is the stable
//!   low tier — nothing in it changed semantics — and every v2 handle
//!   has an escape hatch down to it ([`Session::context`],
//!   [`Session::queue`], [`Buffer::handle`]).
//! * the **v2 tier** (this module) is a facade over the same wrappers
//!   that removes the per-call ceremony:
//!
//!   1. [`Session`] — one builder bundles device selection (reusing the
//!      v1 [`FilterChain`] plug-in selectors), context, `n` labelled
//!      queues, a program cache and the profiler:
//!      `Session::builder().filter(chain).queues(2).profiled().build()?`.
//!   2. [`Buffer<T>`](Buffer) — generic typed buffers whose
//!      [`read_vec`](Buffer::read_vec)/[`write_slice`](Buffer::write_slice)
//!      move `&[T]`/`Vec<T>`, eliminating byte casts and size
//!      arithmetic.
//!   3. [`Launch`] — a fluent launch builder,
//!      `sess.kernel("prng_step")?.global(n).arg(&a).arg(&b).launch()?`,
//!      validated against the kernel's ABI spec (arity, buffer/scalar
//!      kind, element type, byte size) *before* anything is enqueued,
//!      returning a typed [`Pending`] handle.
//!   4. **implicit dependency chaining** — the session tracks each
//!      buffer's last writer and readers, so sequential launches,
//!      reads and writes are correctly ordered *across queues* with no
//!      explicit wait-lists; [`Launch::after`] adds dependencies and
//!      [`Launch::independent`] opts out.
//!
//! The `harness bench loc` table quantifies the result: the §6.1 PRNG
//! example drops from 266 physical LOC (raw) to 147 (v1, −45%) to 81
//! (v2, −70%), with a bit-identical output stream (see
//! `coordinator::rng_service::run_v2` and the `v2_api` integration
//! tests).
//!
//! [`Context`]: crate::ccl::Context
//! [`Queue`]: crate::ccl::Queue
//! [`Program`]: crate::ccl::Program
//! [`Arg`]: crate::ccl::Arg
//! [`FilterChain`]: crate::ccl::FilterChain

mod buffer;
mod deps;
mod launch;
mod pod;
mod session;

pub use buffer::Buffer;
pub use launch::{IntoArg, LArg, Launch, Pending};
pub use pod::Pod;
pub use session::{Session, SessionBuilder};
