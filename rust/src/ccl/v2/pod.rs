//! Plain-old-data element types for [`super::Buffer`].
//!
//! The substrate stores device memory as little-endian bytes (like
//! OpenCL buffers); [`Pod`] is the contract that lets the v2 tier
//! expose those bytes as typed slices/vectors without the caller ever
//! writing a `to_le_bytes`/`from_le_bytes` cast. Each implementation is
//! pinned to the [`ElemType`] the kernel ABI layer
//! ([`crate::rawcl::kernelspec`]) uses, so launches can type-check
//! buffer and scalar arguments against the kernel spec.

use crate::runtime::literal::ElemType;

/// An element type that can live in a typed device buffer.
///
/// Implemented for the element types the kernel ABIs use: `u32`, `u64`
/// and `f32`. The little-endian encoding matches what the substrate
/// (and the v1 byte-slice API) stores, so v1 and v2 code can share
/// buffers bit-for-bit.
pub trait Pod: Copy + Send + Sync + 'static {
    /// The ABI element type this Rust type maps to.
    const ELEM: ElemType;

    /// Append this value's little-endian bytes to `out`.
    fn write_le(&self, out: &mut Vec<u8>);

    /// Decode one value from exactly `ELEM.size_bytes()` bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

impl Pod for u32 {
    const ELEM: ElemType = ElemType::U32;

    fn write_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn read_le(bytes: &[u8]) -> Self {
        u32::from_le_bytes(bytes.try_into().expect("u32 needs 4 bytes"))
    }
}

impl Pod for u64 {
    const ELEM: ElemType = ElemType::U64;

    fn write_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn read_le(bytes: &[u8]) -> Self {
        u64::from_le_bytes(bytes.try_into().expect("u64 needs 8 bytes"))
    }
}

impl Pod for f32 {
    const ELEM: ElemType = ElemType::F32;

    fn write_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().expect("f32 needs 4 bytes"))
    }
}

/// Encode a typed slice as little-endian bytes.
pub(crate) fn encode<T: Pod>(data: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * T::ELEM.size_bytes());
    for v in data {
        v.write_le(&mut out);
    }
    out
}

/// Decode little-endian bytes as a typed vector (whole elements only).
pub(crate) fn decode<T: Pod>(bytes: &[u8]) -> Vec<T> {
    bytes.chunks_exact(T::ELEM.size_bytes()).map(T::read_le).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_elem_types() {
        let u: Vec<u64> = vec![0, 1, u64::MAX, 0x0123_4567_89ab_cdef];
        assert_eq!(decode::<u64>(&encode(&u)), u);
        let v: Vec<u32> = vec![0, 7, u32::MAX];
        assert_eq!(decode::<u32>(&encode(&v)), v);
        let f: Vec<f32> = vec![0.0, -1.5, f32::MAX];
        assert_eq!(decode::<f32>(&encode(&f)), f);
    }

    #[test]
    fn encoding_is_little_endian_like_v1() {
        // v1 code writes `x.to_le_bytes()`; v2 must match bit-for-bit.
        assert_eq!(encode(&[0x1122_3344u32]), 0x1122_3344u32.to_le_bytes().to_vec());
    }
}
