//! Command-queue wrapper (`CCLQueue`).
//!
//! The decisive convenience over the raw API (paper §4.3): the queue
//! wrapper **keeps every event it generates**, so profiling needs no
//! client-side event bookkeeping — `Prof::add_queue` simply harvests the
//! queue's event list. (In listing S1 the host must allocate and manage
//! an event array by hand; in listing S2 it doesn't.)

use std::sync::Mutex;

use crate::rawcl;
use crate::rawcl::types::{DeviceId, EventH, MemH, QueueH, QueueProps};

use super::buffer::Buffer;
use super::context::Context;
use super::device::Device;
use super::errors::{check, CclError, CclResult};
use super::event::Event;
use super::wrapper::LiveToken;

/// Owning wrapper for a command queue.
pub struct Queue {
    h: QueueH,
    device: Device,
    props: QueueProps,
    /// Optional human-readable label ("Main", "Q1", ...), included in
    /// error messages so a failing enqueue names its queue.
    label: Mutex<Option<String>>,
    /// Every event generated through this wrapper (owned; released on
    /// drop). This is what makes "just add the queue to the profiler"
    /// possible.
    events: Mutex<Vec<EventH>>,
    _live: LiveToken,
}

impl Queue {
    /// `ccl_queue_new(ctx, dev, CL_QUEUE_PROFILING_ENABLE, &err)`.
    pub fn new(ctx: &Context, dev: Device, props: QueueProps) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_command_queue(ctx.handle(), dev.id(), props, &mut st);
        check(st, "creating command queue")?;
        Ok(Self {
            h,
            device: dev,
            props,
            label: Mutex::new(None),
            events: Mutex::new(Vec::new()),
            _live: LiveToken::new(),
        })
    }

    /// Profiling-enabled queue (the common case in the paper).
    pub fn new_profiled(ctx: &Context, dev: Device) -> CclResult<Self> {
        Self::new(ctx, dev, QueueProps::PROFILING_ENABLE)
    }

    pub fn handle(&self) -> QueueH {
        self.h
    }

    pub fn device(&self) -> Device {
        self.device
    }

    /// Attach a human-readable label; it names this queue in error
    /// messages (and is the natural `Prof::add_queue` name). The label
    /// also propagates to the command recorder so lint findings name the
    /// queue the way the user does.
    pub fn set_label(&self, label: impl Into<String>) {
        let label = label.into();
        crate::analysis::record::rawcl_queue_label(self.h, &label);
        *self.label.lock().unwrap() = Some(label);
    }

    pub fn label(&self) -> Option<String> {
        self.label.lock().unwrap().clone()
    }

    /// Error context: the queue's label, or its device name as a
    /// fallback, for [`CclError::with_object`].
    fn obj_name(&self) -> String {
        if let Some(l) = self.label.lock().unwrap().as_ref() {
            return format!("queue {l:?}");
        }
        match self.device.name() {
            Ok(n) => format!("queue on {n:?}"),
            Err(_) => "queue <unknown>".into(),
        }
    }

    pub fn profiling_enabled(&self) -> bool {
        self.props.contains(QueueProps::PROFILING_ENABLE)
    }

    /// Snapshot of all events this queue has generated (for the
    /// profiler). Events remain owned by the queue.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().iter().map(|&h| Event::new(h)).collect()
    }

    /// Number of tracked events.
    pub fn num_events(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// Forget tracked events (frees them; used by long-running services
    /// between profiling windows).
    pub fn clear_events(&self) {
        let mut evs = self.events.lock().unwrap();
        for h in evs.drain(..) {
            rawcl::release_event(h);
        }
    }

    fn track(&self, h: EventH) -> Event {
        self.events.lock().unwrap().push(h);
        Event::new(h)
    }

    /// `ccl_queue_finish`.
    pub fn finish(&self) -> CclResult<()> {
        check(rawcl::finish(self.h), "finishing queue")
            .map_err(|e| e.with_object(self.obj_name()))
    }

    /// `ccl_queue_flush`.
    pub fn flush(&self) -> CclResult<()> {
        check(rawcl::flush(self.h), "flushing queue")
            .map_err(|e| e.with_object(self.obj_name()))
    }

    /// Enqueue a marker that waits on `wait`.
    pub fn enqueue_marker(&self, wait: &[Event]) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_marker(self.h, &hs, Some(&mut evt)),
            "enqueueing marker",
        )?;
        Ok(self.track(evt))
    }

    // -- buffer commands (called via the Buffer wrappers of both API
    //    tiers; the `_h` forms take a raw handle so `ccl::v2` can issue
    //    commands without borrowing a v1 `Buffer`) ----------------------

    pub(crate) fn enqueue_read_buffer_h(
        &self,
        buf: MemH,
        offset: usize,
        dst: &mut [u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_read_buffer(self.h, buf, true, offset, dst, &hs, Some(&mut evt)),
            "enqueueing buffer read",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(self.track(evt))
    }

    /// Non-blocking read enqueue for the v2 session tier: the dependency
    /// tracker must observe the enqueue and note the access under one
    /// lock, and cannot hold that lock across a blocking wait — the
    /// caller waits on the returned event *after* releasing it.
    ///
    /// # Safety
    /// `dst..dst+len` must stay valid until the returned event completes.
    pub(crate) unsafe fn enqueue_read_buffer_h_nb(
        &self,
        buf: MemH,
        offset: usize,
        dst: *mut u8,
        len: usize,
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_read_buffer_raw(
                self.h,
                buf,
                false,
                offset,
                dst,
                len,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing buffer read",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(self.track(evt))
    }

    pub(crate) fn enqueue_read_buffer(
        &self,
        buf: &Buffer,
        offset: usize,
        dst: &mut [u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        self.enqueue_read_buffer_h(buf.handle(), offset, dst, wait)
    }

    pub(crate) fn enqueue_write_buffer_h(
        &self,
        buf: MemH,
        offset: usize,
        src: &[u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_write_buffer(self.h, buf, true, offset, src, &hs, Some(&mut evt)),
            "enqueueing buffer write",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(self.track(evt))
    }

    /// Non-blocking write enqueue (data is snapshot at enqueue, so this
    /// is safe); counterpart of [`Self::enqueue_read_buffer_h_nb`] for
    /// the v2 tier's atomic snapshot-enqueue-note sequence.
    pub(crate) fn enqueue_write_buffer_h_nb(
        &self,
        buf: MemH,
        offset: usize,
        src: &[u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_write_buffer(self.h, buf, false, offset, src, &hs, Some(&mut evt)),
            "enqueueing buffer write",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(self.track(evt))
    }

    pub(crate) fn enqueue_write_buffer(
        &self,
        buf: &Buffer,
        offset: usize,
        src: &[u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        self.enqueue_write_buffer_h(buf.handle(), offset, src, wait)
    }

    pub(crate) fn enqueue_copy_buffer(
        &self,
        src: &Buffer,
        dst: &Buffer,
        src_off: usize,
        dst_off: usize,
        len: usize,
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_copy_buffer(
                self.h,
                src.handle(),
                dst.handle(),
                src_off,
                dst_off,
                len,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing buffer copy",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(self.track(evt))
    }

    pub(crate) fn enqueue_fill_buffer(
        &self,
        buf: &Buffer,
        pattern: &[u8],
        offset: usize,
        len: usize,
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_fill_buffer(
                self.h,
                buf.handle(),
                pattern,
                offset,
                len,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing buffer fill",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(self.track(evt))
    }

    /// Internal: record a kernel event enqueued by the kernel wrapper.
    pub(crate) fn track_kernel_event(&self, h: EventH) -> Event {
        self.track(h)
    }

    /// Queue must belong to the given context's platform; helper for
    /// validation in higher layers.
    pub fn device_id(&self) -> DeviceId {
        self.device.id()
    }
}

impl Drop for Queue {
    fn drop(&mut self) {
        // Make sure the worker is idle before tearing events down.
        let _ = rawcl::finish(self.h);
        self.clear_events();
        rawcl::release_command_queue(self.h);
    }
}

/// Convenience used by examples: propagate one queue error into a
/// `CclError` with a custom message.
pub fn queue_error(msg: &str) -> CclError {
    CclError::framework(msg.to_string())
}
