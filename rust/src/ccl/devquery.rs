//! Device query module (paper §4.4): name → query table powering the
//! `devinfo` utility and custom queries from client code.

use super::device::Device;
use super::errors::{CclError, CclResult};

/// One queryable parameter: CLI name, description, and formatter.
pub struct QueryParam {
    pub name: &'static str,
    pub description: &'static str,
    fetch: fn(&Device) -> CclResult<String>,
}

impl QueryParam {
    pub fn query(&self, dev: &Device) -> CclResult<String> {
        (self.fetch)(&dev.clone())
    }
}

fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

/// The known-parameter table (`ccl_devquery_info_map`).
pub fn known_params() -> &'static [QueryParam] {
    &[
        QueryParam {
            name: "name",
            description: "Device name",
            fetch: |d| d.name(),
        },
        QueryParam {
            name: "vendor",
            description: "Device vendor",
            fetch: |d| d.vendor(),
        },
        QueryParam {
            name: "version",
            description: "Device (driver) version string",
            fetch: |d| d.version(),
        },
        QueryParam {
            name: "type",
            description: "Device type (CPU/GPU/...)",
            fetch: |d| {
                let t = d.device_type()?;
                Ok(if t.intersects(crate::rawcl::types::DeviceType::GPU) {
                    "GPU".to_string()
                } else if t.intersects(crate::rawcl::types::DeviceType::CPU) {
                    "CPU".to_string()
                } else {
                    "OTHER".to_string()
                })
            },
        },
        QueryParam {
            name: "max_compute_units",
            description: "Number of compute units",
            fetch: |d| Ok(d.max_compute_units()?.to_string()),
        },
        QueryParam {
            name: "max_work_group_size",
            description: "Maximum work-group size",
            fetch: |d| Ok(d.max_work_group_size()?.to_string()),
        },
        QueryParam {
            name: "preferred_work_group_size_multiple",
            description: "Preferred work-group size multiple",
            fetch: |d| Ok(d.preferred_wg_multiple()?.to_string()),
        },
        QueryParam {
            name: "max_work_item_sizes",
            description: "Maximum work-item sizes per dimension",
            fetch: |d| Ok(format!("{:?}", d.max_work_item_sizes()?)),
        },
        QueryParam {
            name: "global_mem_size",
            description: "Global memory size",
            fetch: |d| Ok(fmt_bytes(d.global_mem_size()?)),
        },
        QueryParam {
            name: "local_mem_size",
            description: "Local (shared) memory size",
            fetch: |d| Ok(fmt_bytes(d.local_mem_size()?)),
        },
        QueryParam {
            name: "max_clock_frequency",
            description: "Maximum clock frequency (MHz)",
            fetch: |d| Ok(d.max_clock_frequency()?.to_string()),
        },
        QueryParam {
            name: "backend",
            description: "cf4rs backend (native PJRT / simulated)",
            fetch: |d| Ok(format!("{:?}", d.backend()?)),
        },
    ]
}

/// Query one parameter by (case-insensitive, prefix-tolerant) name —
/// cf4ocl's `ccl_devquery_prefix` behaviour.
pub fn query_by_name(dev: &Device, name: &str) -> CclResult<String> {
    let lname = name.to_lowercase();
    let params = known_params();
    // exact match first
    if let Some(p) = params.iter().find(|p| p.name == lname) {
        return p.query(dev);
    }
    // then unique prefix
    let matches: Vec<&QueryParam> =
        params.iter().filter(|p| p.name.starts_with(&lname)).collect();
    match matches.len() {
        1 => matches[0].query(dev),
        0 => Err(CclError::framework(format!("unknown device parameter {name:?}"))),
        n => Err(CclError::framework(format!(
            "ambiguous device parameter {name:?} ({n} matches)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rawcl::types::DeviceId;

    fn gtx() -> Device {
        Device::from_id(DeviceId(1)).unwrap()
    }

    #[test]
    fn table_is_nonempty_and_queryable() {
        let d = gtx();
        for p in known_params() {
            let v = p.query(&d).unwrap();
            assert!(!v.is_empty(), "param {} returned empty", p.name);
        }
    }

    #[test]
    fn query_by_exact_name() {
        assert_eq!(query_by_name(&gtx(), "max_compute_units").unwrap(), "20");
        assert_eq!(query_by_name(&gtx(), "type").unwrap(), "GPU");
    }

    #[test]
    fn query_by_unique_prefix() {
        assert_eq!(query_by_name(&gtx(), "vend").unwrap(), "SimCL (NVIDIA profile)");
    }

    #[test]
    fn ambiguous_prefix_rejected() {
        // "max_" matches several parameters.
        let err = query_by_name(&gtx(), "max_").unwrap_err();
        assert!(err.message.contains("ambiguous"));
    }

    #[test]
    fn unknown_name_rejected() {
        assert!(query_by_name(&gtx(), "quantum_flux").is_err());
    }

    #[test]
    fn bytes_formatter() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(96 << 10), "96.0 KiB");
        assert_eq!(fmt_bytes(8 << 30), "8.0 GiB");
    }
}
