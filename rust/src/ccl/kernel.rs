//! Kernel wrapper (`CCLKernel`): argument helpers + the one-call
//! `set_args_and_enqueue_ndrange` API the paper showcases (§6.1).
//!
//! ```no_run
//! # use cf4rs::ccl::{Arg, Context, Program, Queue};
//! # let ctx = Context::new_gpu().unwrap();
//! # let q = Queue::new_profiled(&ctx, ctx.device(0).unwrap()).unwrap();
//! # let prg = Program::new_from_artifacts(&ctx, &["rng_n4096"]).unwrap();
//! # prg.build().unwrap();
//! # let krng = prg.kernel("prng_step").unwrap();
//! # let (buf1, buf2) = (cf4rs::ccl::Buffer::new(&ctx, cf4rs::rawcl::MemFlags::READ_WRITE, 4096*8).unwrap(), cf4rs::ccl::Buffer::new(&ctx, cf4rs::rawcl::MemFlags::READ_WRITE, 4096*8).unwrap());
//! let evt = krng.set_args_and_enqueue_ndrange(
//!     &q, &[4096], None, &[],
//!     &[Arg::priv_u32(4096), Arg::buf(&buf1), Arg::buf(&buf2)],
//! ).unwrap();
//! ```

use crate::rawcl;
use crate::rawcl::types::{EventH, KernelH, KernelWorkGroupInfo};

use super::buffer::Buffer;
use super::device::Device;
use super::errors::{check, CclError, CclResult};
use super::event::Event;
use super::queue::Queue;
use super::worksize;
use super::wrapper::LiveToken;

/// One kernel argument in the variadic-style API.
///
/// * [`Arg::Buf`] — a buffer argument;
/// * [`Arg::Priv`] — a private scalar by bytes (`ccl_arg_priv`);
/// * [`Arg::Skip`] — keep the previously-set value (`ccl_arg_skip`),
///   used for constant arguments set once outside a loop.
pub enum Arg<'a> {
    Buf(&'a Buffer),
    Priv(Vec<u8>),
    Skip,
}

impl<'a> Arg<'a> {
    pub fn buf(b: &'a Buffer) -> Self {
        Arg::Buf(b)
    }

    /// `ccl_arg_priv(x, cl_uint)`.
    pub fn priv_u32(x: u32) -> Self {
        Arg::Priv(x.to_le_bytes().to_vec())
    }

    pub fn priv_u64(x: u64) -> Self {
        Arg::Priv(x.to_le_bytes().to_vec())
    }

    pub fn priv_f32(x: f32) -> Self {
        Arg::Priv(x.to_le_bytes().to_vec())
    }

    /// `ccl_arg_skip`.
    pub fn skip() -> Self {
        Arg::Skip
    }
}

/// Kernel wrapper. Owning when created standalone ([`Kernel::new`]);
/// non-owning when obtained from a program (`Program::kernel`), matching
/// cf4ocl's ownership rules.
pub struct Kernel {
    h: KernelH,
    owned: bool,
    _live: Option<LiveToken>,
}

impl Kernel {
    /// Standalone constructor (`ccl_kernel_new`): caller-owned.
    pub fn new(prg: &super::program::Program, name: &str) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_kernel(prg.handle(), name, &mut st);
        check(st, &format!("creating kernel {name:?}"))?;
        Ok(Self { h, owned: true, _live: Some(LiveToken::new()) })
    }

    pub(crate) fn non_owning(h: KernelH) -> Self {
        Self { h, owned: false, _live: None }
    }

    pub fn handle(&self) -> KernelH {
        self.h
    }

    /// Error context: the kernel's name, for [`CclError::with_object`].
    fn obj_name(&self) -> String {
        match self.name() {
            Ok(n) => format!("kernel {n:?}"),
            Err(_) => "kernel <unknown>".into(),
        }
    }

    /// Kernel function name.
    pub fn name(&self) -> CclResult<String> {
        let mut s = String::new();
        check(rawcl::get_kernel_function_name(self.h, &mut s), "querying kernel name")?;
        Ok(s)
    }

    pub fn num_args(&self) -> CclResult<usize> {
        let mut n = 0;
        check(rawcl::get_kernel_num_args(self.h, &mut n), "querying kernel arg count")?;
        Ok(n)
    }

    /// `ccl_kernel_set_arg` with the [`Arg`] helpers.
    pub fn set_arg(&self, index: usize, arg: &Arg<'_>) -> CclResult<()> {
        let value = match arg {
            Arg::Buf(b) => rawcl::ArgValue::Buffer(b.handle()),
            Arg::Priv(bytes) => rawcl::ArgValue::Scalar(bytes.clone()),
            Arg::Skip => return Ok(()),
        };
        check(
            rawcl::set_kernel_arg(self.h, index, &value),
            &format!("setting kernel arg {index}"),
        )
        .map_err(|e| e.with_object(self.obj_name()))
    }

    /// Set several args at once, honouring [`Arg::Skip`].
    ///
    /// Every entry consumes its positional index whether or not it is a
    /// skip: `&[skip, buf_a, buf_b]` sets indices 1 and 2 and leaves
    /// index 0 at its previously-set value (`ccl_arg_skip` semantics).
    /// Skipped positions must never shift later indices — a compacting
    /// implementation would silently bind `buf_a` to slot 0.
    /// (`set_arg` is a no-op for `Arg::Skip`, which is what preserves
    /// the positional mapping here.)
    pub fn set_args(&self, args: &[Arg<'_>]) -> CclResult<()> {
        for (i, a) in args.iter().enumerate() {
            self.set_arg(i, a)?;
        }
        Ok(())
    }

    /// `ccl_kernel_enqueue_ndrange`: launch with the current arguments.
    pub fn enqueue_ndrange(
        &self,
        queue: &Queue,
        gws: &[usize],
        lws: Option<&[usize]>,
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_ndrange_kernel(
                queue.handle(),
                self.h,
                gws.len() as u32,
                gws,
                lws,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing kernel",
        )
        .map_err(|e| e.with_object(self.obj_name()))?;
        Ok(queue.track_kernel_event(evt))
    }

    /// The paper's flagship single-call API
    /// (`ccl_kernel_set_args_and_enqueue_ndrange`): set all arguments and
    /// launch in one statement.
    pub fn set_args_and_enqueue_ndrange(
        &self,
        queue: &Queue,
        gws: &[usize],
        lws: Option<&[usize]>,
        wait: &[Event],
        args: &[Arg<'_>],
    ) -> CclResult<Event> {
        self.set_args(args)?;
        self.enqueue_ndrange(queue, gws, lws, wait)
    }

    /// `ccl_kernel_suggest_worksizes`: fill appropriate global/local work
    /// sizes for `rws` real work on `dev` (paper §6.1; handles the
    /// preferred-multiple query, the pre-2.0 divisibility rule and
    /// multiple dimensions).
    pub fn suggest_worksizes(
        &self,
        dev: Device,
        rws: &[usize],
    ) -> CclResult<(Vec<usize>, Vec<usize>)> {
        worksize::suggest_worksizes(Some(self), dev, rws)
    }

    /// Preferred work-group size multiple for `dev`.
    pub fn preferred_wg_multiple(&self, dev: Device) -> CclResult<usize> {
        let mut v = 0;
        check(
            rawcl::get_kernel_work_group_info(
                self.h,
                dev.id(),
                KernelWorkGroupInfo::PreferredWorkGroupSizeMultiple,
                &mut v,
            ),
            "querying preferred work-group multiple",
        )?;
        Ok(v)
    }

    /// Maximum work-group size for `dev`.
    pub fn max_work_group_size(&self, dev: Device) -> CclResult<usize> {
        let mut v = 0;
        check(
            rawcl::get_kernel_work_group_info(
                self.h,
                dev.id(),
                KernelWorkGroupInfo::WorkGroupSize,
                &mut v,
            ),
            "querying kernel max work-group size",
        )?;
        Ok(v)
    }
}

impl Drop for Kernel {
    fn drop(&mut self) {
        if self.owned {
            rawcl::release_kernel(self.h);
        }
    }
}

/// Validation shared with `worksize`: a zero-dim launch is meaningless.
pub(crate) fn check_dims(rws: &[usize]) -> CclResult<()> {
    if rws.is_empty() || rws.len() > 3 {
        return Err(CclError::framework(format!(
            "work size must have 1-3 dimensions, got {}",
            rws.len()
        )));
    }
    if rws.iter().any(|&r| r == 0) {
        return Err(CclError::framework("zero-sized work dimension"));
    }
    Ok(())
}
