//! Event wrapper (`CCLEvent`).
//!
//! Events returned by the framework's enqueue functions are **owned by
//! the queue wrapper** (paper §4.1: objects obtained from non-constructor
//! methods must not be destroyed by client code), so this wrapper is a
//! cheap non-owning handle with typed accessors.

use crate::rawcl;
use crate::rawcl::types::{CommandType, EventH, ProfilingInfo};

use super::errors::{check, CclResult};

/// Owning wrapper for a *user event* (`CCLUserEvent`): an event the host
/// completes, used to gate device commands on host-side conditions.
pub struct UserEvent {
    ev: Event,
    _live: super::wrapper::LiveToken,
}

impl UserEvent {
    /// `ccl_user_event_new(ctx, &err)`.
    pub fn new(ctx: &super::context::Context) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_user_event(ctx.handle(), &mut st);
        check(st, "creating user event")?;
        Ok(Self { ev: Event::new(h), _live: super::wrapper::LiveToken::new() })
    }

    /// The plain event view (for wait lists).
    pub fn event(&self) -> Event {
        self.ev
    }

    /// `ccl_user_event_set_status(evt, CL_COMPLETE, &err)`.
    pub fn complete(&self) -> CclResult<()> {
        check(rawcl::set_user_event_status(self.ev.h, 0), "completing user event")
    }

    /// Complete with a negative error status, failing dependants.
    pub fn fail(&self, code: i32) -> CclResult<()> {
        check(rawcl::set_user_event_status(self.ev.h, code), "failing user event")
    }
}

impl Drop for UserEvent {
    fn drop(&mut self) {
        rawcl::release_event(self.ev.h);
    }
}

/// Non-owning event wrapper.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub struct Event {
    pub(crate) h: EventH,
}

impl Event {
    pub(crate) fn new(h: EventH) -> Self {
        Self { h }
    }

    pub fn handle(&self) -> EventH {
        self.h
    }

    /// Name the event for profiling aggregation
    /// (`ccl_event_set_name(evt, "RNG_KERNEL")`).
    pub fn set_name(&self, name: &str) -> CclResult<()> {
        check(rawcl::set_event_name(self.h, name), "naming event")
    }

    /// Block until the command completes.
    pub fn wait(&self) -> CclResult<()> {
        check(rawcl::wait_for_events(&[self.h]), "waiting on event")
    }

    pub fn command_type(&self) -> CclResult<CommandType> {
        let mut t = CommandType::Marker;
        check(rawcl::get_event_command_type(self.h, &mut t), "querying command type")?;
        Ok(t)
    }

    fn prof(&self, p: ProfilingInfo) -> CclResult<u64> {
        let mut v = 0u64;
        check(
            rawcl::get_event_profiling_info(self.h, p, &mut v),
            "querying event profiling info",
        )?;
        Ok(v)
    }

    pub fn time_queued(&self) -> CclResult<u64> {
        self.prof(ProfilingInfo::Queued)
    }

    pub fn time_submit(&self) -> CclResult<u64> {
        self.prof(ProfilingInfo::Submit)
    }

    pub fn time_start(&self) -> CclResult<u64> {
        self.prof(ProfilingInfo::Start)
    }

    pub fn time_end(&self) -> CclResult<u64> {
        self.prof(ProfilingInfo::End)
    }

    /// Duration (END − START); requires a profiling queue + completion.
    pub fn duration(&self) -> CclResult<u64> {
        Ok(self.time_end()?.saturating_sub(self.time_start()?))
    }

    /// Wait for several events at once (`ccl_event_wait`).
    pub fn wait_all(events: &[Event]) -> CclResult<()> {
        if events.is_empty() {
            return Ok(());
        }
        let hs: Vec<EventH> = events.iter().map(|e| e.h).collect();
        check(rawcl::wait_for_events(&hs), "waiting on event list")
    }
}
