//! Buffer wrapper (`CCLBuffer`, a concrete `CCLMemObj`).

use crate::rawcl;
use crate::rawcl::types::{MemFlags, MemH};

use super::context::Context;
use super::errors::{check, CclResult};
use super::event::Event;
use super::queue::Queue;
use super::wrapper::LiveToken;

/// Owning wrapper for a device buffer.
pub struct Buffer {
    h: MemH,
    size: usize,
    _live: LiveToken,
}

impl Buffer {
    /// `ccl_buffer_new(ctx, flags, size, NULL, &err)`.
    pub fn new(ctx: &Context, flags: MemFlags, size: usize) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_buffer(ctx.handle(), flags, size, None, &mut st);
        check(st, "creating buffer")?;
        Ok(Self { h, size, _live: LiveToken::new() })
    }

    /// Create + initialise from host data (`CL_MEM_COPY_HOST_PTR`).
    pub fn from_slice(ctx: &Context, flags: MemFlags, data: &[u8]) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_buffer(
            ctx.handle(),
            flags | MemFlags::COPY_HOST_PTR,
            data.len(),
            Some(data),
            &mut st,
        );
        check(st, "creating initialised buffer")?;
        Ok(Self { h, size: data.len(), _live: LiveToken::new() })
    }

    pub fn handle(&self) -> MemH {
        self.h
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Blocking read (`ccl_buffer_enqueue_read(buf, cq, CL_TRUE, ...)`).
    ///
    /// The generated event is tracked by the queue for profiling and is
    /// also returned for dependency chaining.
    pub fn enqueue_read(
        &self,
        queue: &Queue,
        offset: usize,
        dst: &mut [u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        queue.enqueue_read_buffer(self, offset, dst, wait)
    }

    /// Blocking write (`ccl_buffer_enqueue_write`).
    pub fn enqueue_write(
        &self,
        queue: &Queue,
        offset: usize,
        src: &[u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        queue.enqueue_write_buffer(self, offset, src, wait)
    }

    /// Device-side copy (`ccl_buffer_enqueue_copy`).
    pub fn enqueue_copy(
        &self,
        queue: &Queue,
        dst: &Buffer,
        src_offset: usize,
        dst_offset: usize,
        len: usize,
        wait: &[Event],
    ) -> CclResult<Event> {
        queue.enqueue_copy_buffer(self, dst, src_offset, dst_offset, len, wait)
    }

    /// Pattern fill (`ccl_buffer_enqueue_fill`).
    pub fn enqueue_fill(
        &self,
        queue: &Queue,
        pattern: &[u8],
        offset: usize,
        len: usize,
        wait: &[Event],
    ) -> CclResult<Event> {
        queue.enqueue_fill_buffer(self, pattern, offset, len, wait)
    }
}

impl Drop for Buffer {
    fn drop(&mut self) {
        rawcl::release_mem_object(self.h);
    }
}
