//! Device selector (paper §4.4): a plug-in filter mechanism.
//!
//! Filters come in two kinds, as in cf4ocl:
//!
//! * **independent** — accept/reject one device on its own merits
//!   (type, vendor, name, backend);
//! * **dependent** — look at the whole candidate list at once (e.g. "keep
//!   only devices sharing the platform of the first candidate", which is
//!   what context creation needs, or "keep the device with most CUs").
//!
//! Client code can extend the mechanism with closures — the "plug-in
//! filters" of the paper.

use crate::rawcl::types::DeviceType;

use super::device::Device;
use super::errors::{CclError, CclResult};

/// A filter step in the chain.
pub enum Filter {
    /// Keep devices for which the predicate holds.
    Independent(Box<dyn Fn(&Device) -> bool>),
    /// Transform the whole candidate list.
    Dependent(Box<dyn Fn(Vec<Device>) -> Vec<Device>>),
}

impl Filter {
    // ---- built-in independent filters (cf4ocl's ccl_devsel_indep_*) ----

    pub fn type_is(t: DeviceType) -> Self {
        Filter::Independent(Box::new(move |d| {
            d.device_type().map(|dt| dt.intersects(t)).unwrap_or(false)
        }))
    }

    pub fn type_gpu() -> Self {
        Self::type_is(DeviceType::GPU)
    }

    pub fn type_cpu() -> Self {
        Self::type_is(DeviceType::CPU)
    }

    /// Case-insensitive substring match on the device name.
    pub fn name_contains(sub: impl Into<String>) -> Self {
        let sub = sub.into().to_lowercase();
        Filter::Independent(Box::new(move |d| {
            d.name().map(|n| n.to_lowercase().contains(&sub)).unwrap_or(false)
        }))
    }

    /// Case-insensitive substring match on the vendor.
    pub fn vendor_contains(sub: impl Into<String>) -> Self {
        let sub = sub.into().to_lowercase();
        Filter::Independent(Box::new(move |d| {
            d.vendor().map(|v| v.to_lowercase().contains(&sub)).unwrap_or(false)
        }))
    }

    // ---- built-in dependent filters (cf4ocl's ccl_devsel_dep_*) ----

    /// Keep the i-th candidate only (cf4ocl's "index" filter).
    pub fn index(i: usize) -> Self {
        Filter::Dependent(Box::new(move |devs| {
            devs.into_iter().skip(i).take(1).collect()
        }))
    }

    /// Keep only candidates on the same platform as the first one
    /// (context devices must share a platform).
    pub fn same_platform() -> Self {
        Filter::Dependent(Box::new(|devs| {
            let Some(first) = devs.first() else { return devs };
            let p = crate::rawcl::device::device(first.id()).unwrap().platform;
            devs.into_iter()
                .filter(|d| crate::rawcl::device::device(d.id()).unwrap().platform == p)
                .collect()
        }))
    }

    /// Keep the single device with the most compute units.
    pub fn most_compute_units() -> Self {
        Filter::Dependent(Box::new(|devs| {
            devs.into_iter()
                .max_by_key(|d| d.max_compute_units().unwrap_or(0))
                .into_iter()
                .collect()
        }))
    }
}

/// An ordered chain of filters applied to the system device list.
#[derive(Default)]
pub struct FilterChain {
    filters: Vec<Filter>,
}

impl FilterChain {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a filter (builder style).
    pub fn add(mut self, f: Filter) -> Self {
        self.filters.push(f);
        self
    }

    /// Plug-in convenience: add an independent closure filter.
    pub fn add_indep(self, f: impl Fn(&Device) -> bool + 'static) -> Self {
        self.add(Filter::Independent(Box::new(f)))
    }

    /// Plug-in convenience: add a dependent closure filter.
    pub fn add_dep(self, f: impl Fn(Vec<Device>) -> Vec<Device> + 'static) -> Self {
        self.add(Filter::Dependent(Box::new(f)))
    }

    /// Run the chain over an explicit candidate list.
    ///
    /// This is the core of the mechanism; [`select`](Self::select) is
    /// `apply` over all system devices, and the backend registry
    /// ([`crate::backend::BackendRegistry::select`]) applies chains to
    /// the devices its backends execute for.
    pub fn apply(&self, mut devs: Vec<Device>) -> Vec<Device> {
        for f in &self.filters {
            devs = match f {
                Filter::Independent(p) => devs.into_iter().filter(|d| p(d)).collect(),
                Filter::Dependent(t) => t(devs),
            };
            if devs.is_empty() {
                break;
            }
        }
        devs
    }

    /// Run the chain over all system devices.
    pub fn select(&self) -> Vec<Device> {
        self.apply(Device::all())
    }

    /// Like [`select`](Self::select) but requiring ≥1 result.
    pub fn select_nonempty(&self) -> CclResult<Vec<Device>> {
        let devs = self.select();
        if devs.is_empty() {
            Err(CclError::framework("no device matched the filter chain"))
        } else {
            Ok(devs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_filter_selects_sim_pair() {
        let devs = FilterChain::new().add(Filter::type_gpu()).select();
        assert_eq!(devs.len(), 2);
        assert!(devs.iter().all(|d| d.is_gpu()));
    }

    #[test]
    fn name_filter() {
        let devs = FilterChain::new().add(Filter::name_contains("7970")).select();
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].name().unwrap(), "SimCL HD 7970");
    }

    #[test]
    fn vendor_filter_case_insensitive() {
        let devs = FilterChain::new().add(Filter::vendor_contains("NVIDIA")).select();
        assert_eq!(devs.len(), 1);
    }

    #[test]
    fn index_filter_after_type() {
        let devs = FilterChain::new()
            .add(Filter::type_gpu())
            .add(Filter::index(1))
            .select();
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].name().unwrap(), "SimCL HD 7970");
    }

    #[test]
    fn most_cus_picks_hd7970() {
        let devs = FilterChain::new()
            .add(Filter::type_gpu())
            .add(Filter::most_compute_units())
            .select();
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].max_compute_units().unwrap(), 32);
    }

    #[test]
    fn plugin_closure_filter() {
        // Custom plug-in: keep devices with a warp/wavefront ≥ 64.
        let devs = FilterChain::new()
            .add_indep(|d| d.preferred_wg_multiple().unwrap_or(0) >= 64)
            .select();
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].name().unwrap(), "SimCL HD 7970");
    }

    #[test]
    fn empty_chain_returns_all() {
        assert_eq!(FilterChain::new().select().len(), 3);
    }

    #[test]
    fn apply_runs_over_an_explicit_candidate_list() {
        use crate::rawcl::types::DeviceId;
        let subset = vec![Device::from_id(DeviceId(2)).unwrap()];
        let kept = FilterChain::new().add(Filter::type_gpu()).apply(subset);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].name().unwrap(), "SimCL HD 7970");
        // A chain can only narrow the candidates it is given.
        let none = FilterChain::new()
            .add(Filter::type_cpu())
            .apply(vec![Device::from_id(DeviceId(1)).unwrap()]);
        assert!(none.is_empty());
    }

    #[test]
    fn nonempty_error_message() {
        let err = FilterChain::new()
            .add(Filter::name_contains("no-such-device"))
            .select_nonempty()
            .unwrap_err();
        assert!(err.message.contains("no device matched"));
    }
}
