//! Comprehensive error reporting (paper §3.2, §4.1, §4.4).
//!
//! cf4ocl reports errors two ways: via return values and via an optional
//! error object carrying a code, a domain and a human-readable message.
//! In Rust the `Result` return *is* the error object, so [`CclError`]
//! plays the role of `CCLErr`: it carries the originating status code,
//! the domain, and a formatted message — and every fallible framework
//! function returns `CclResult<T>`.
//!
//! Two refinements over the plain `CCLErr` model:
//!
//! * **source chaining** — errors that wrap a substrate failure keep the
//!   originating [`StatusError`] and expose it through
//!   [`std::error::Error::source`], so `anyhow`-style chains print the
//!   symbolic OpenCL-like code at the bottom of the chain;
//! * **object context** — the kernel or queue involved in the failing
//!   operation can be attached with [`CclError::with_object`] and shows
//!   up in `Display` output (e.g. `[rawcl] kernel "prng_step":
//!   enqueueing kernel: CL_INVALID_KERNEL_ARGS (-52)`).

use std::fmt;

use crate::rawcl::error::{status_name, ClStatus, StatusError};

/// Where an error originated (`GQuark` domains in cf4ocl).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorDomain {
    /// Propagated substrate (OpenCL-level) error.
    Rawcl,
    /// Framework-level error (bad usage of the ccl API itself).
    Ccl,
    /// Artifact/build-system error (missing manifest, bad HLO, ...).
    Artifacts,
}

impl fmt::Display for ErrorDomain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Self::Rawcl => "rawcl",
            Self::Ccl => "ccl",
            Self::Artifacts => "artifacts",
        })
    }
}

/// The framework error object (cf4ocl's `CCLErr`).
#[derive(Debug, Clone)]
pub struct CclError {
    /// The substrate status code, when the error came from `rawcl`
    /// (`CL_SUCCESS` for purely framework-level errors).
    pub code: ClStatus,
    pub domain: ErrorDomain,
    pub message: String,
    /// The kernel/queue (or other object) the failing operation
    /// involved, when known; included in `Display` output.
    pub object: Option<String>,
    /// The wrapped substrate error, kept for `Error::source` chaining.
    source: Option<StatusError>,
}

impl CclError {
    /// Wrap a substrate status code with context.
    pub fn from_status(code: ClStatus, context: impl Into<String>) -> Self {
        let context = context.into();
        Self {
            code,
            domain: ErrorDomain::Rawcl,
            message: format!("{}: {} ({})", context, status_name(code), code),
            object: None,
            source: Some(StatusError(code)),
        }
    }

    /// A framework-level error with no substrate code.
    pub fn framework(message: impl Into<String>) -> Self {
        Self {
            code: 0,
            domain: ErrorDomain::Ccl,
            message: message.into(),
            object: None,
            source: None,
        }
    }

    /// An artifact/build-path error.
    pub fn artifacts(message: impl Into<String>) -> Self {
        Self {
            code: 0,
            domain: ErrorDomain::Artifacts,
            message: message.into(),
            object: None,
            source: None,
        }
    }

    /// Attach the name of the object (kernel, queue, buffer, session)
    /// the failing operation involved; shown in `Display` output.
    pub fn with_object(mut self, name: impl Into<String>) -> Self {
        self.object = Some(name.into());
        self
    }

    /// The symbolic name of the substrate code (errors-module function).
    pub fn code_name(&self) -> &'static str {
        status_name(self.code)
    }
}

impl fmt::Display for CclError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] ", self.domain)?;
        if let Some(obj) = &self.object {
            write!(f, "{obj}: ")?;
        }
        f.write_str(&self.message)
    }
}

impl std::error::Error for CclError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_ref()
            .map(|s| s as &(dyn std::error::Error + 'static))
    }
}

/// Framework result type.
pub type CclResult<T> = Result<T, CclError>;

/// Convert a substrate status to a result, with lazy context.
pub fn check(code: ClStatus, context: &str) -> CclResult<()> {
    if code == crate::rawcl::error::CL_SUCCESS {
        Ok(())
    } else {
        Err(CclError::from_status(code, context))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rawcl::error::*;

    #[test]
    fn from_status_formats_name_and_code() {
        let e = CclError::from_status(CL_BUILD_PROGRAM_FAILURE, "building program");
        assert_eq!(e.code, CL_BUILD_PROGRAM_FAILURE);
        assert_eq!(e.domain, ErrorDomain::Rawcl);
        assert!(e.message.contains("CL_BUILD_PROGRAM_FAILURE"));
        assert!(e.message.contains("-11"));
        assert!(e.to_string().contains("[rawcl]"));
    }

    #[test]
    fn check_passes_success() {
        assert!(check(CL_SUCCESS, "x").is_ok());
        let e = check(CL_INVALID_KERNEL, "creating kernel").unwrap_err();
        assert_eq!(e.code, CL_INVALID_KERNEL);
        assert!(e.message.starts_with("creating kernel"));
    }

    #[test]
    fn framework_errors_have_no_code() {
        let e = CclError::framework("no devices matched the filter chain");
        assert_eq!(e.code, 0);
        assert_eq!(e.domain, ErrorDomain::Ccl);
        assert_eq!(e.code_name(), "CL_SUCCESS");
    }

    #[test]
    fn rawcl_errors_chain_a_source() {
        use std::error::Error as _;
        let e = CclError::from_status(CL_INVALID_KERNEL, "creating kernel");
        let src = e.source().expect("substrate errors must chain a source");
        assert_eq!(src.to_string(), "CL_INVALID_KERNEL (-48)");
        assert!(src.downcast_ref::<StatusError>().is_some());
        // framework-level errors have nothing to chain
        assert!(CclError::framework("bad usage").source().is_none());
    }

    #[test]
    fn display_includes_the_failing_object() {
        let e = CclError::from_status(CL_INVALID_KERNEL_ARGS, "enqueueing kernel")
            .with_object("kernel \"prng_step\"");
        let s = e.to_string();
        assert!(s.contains("kernel \"prng_step\""), "display: {s}");
        assert!(s.contains("CL_INVALID_KERNEL_ARGS"), "display: {s}");
        assert!(s.starts_with("[rawcl]"), "display: {s}");
    }
}
