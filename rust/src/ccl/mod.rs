//! # `ccl` — the cf4rs framework (the paper's contribution)
//!
//! An object-oriented wrapper layer over [`crate::rawcl`] mirroring
//! cf4ocl's design (paper §3–§4):
//!
//! * one-to-one wrapper classes with clear constructor/destructor
//!   semantics ([`Context`], [`Queue`], [`Program`], [`Kernel`],
//!   [`Buffer`], [`Event`]) — Fig. 1's class hierarchy, with Rust RAII
//!   playing the role of the `*_destroy` functions;
//! * automatic management of intermediate objects: queues keep their
//!   events, programs keep their kernels, info queries return typed
//!   values instead of raw bytes;
//! * a flexible device-selection mechanism ([`selector`]) with plug-in
//!   filters;
//! * comprehensive error reporting ([`errors`]);
//! * integrated profiling with aggregation and overlap detection
//!   ([`prof`]);
//! * a versatile device-query table ([`devquery`]) and a platforms
//!   module ([`platforms`]).
//!
//! These modules form the **v1 tier** — a faithful, stable mirror of
//! the paper's API. The **v2 tier** ([`v2`]) layers a fluent, typed
//! facade (session handle, generic `Buffer<T>`, validated launch
//! builders, implicit event-dependency chaining) over the same
//! wrappers; see [`v2`] for the tier split.

pub mod buffer;
pub mod context;
pub mod device;
pub mod devquery;
pub mod errors;
pub mod event;
pub mod image;
pub mod kernel;
pub mod platforms;
pub mod prof;
pub mod program;
pub mod queue;
pub mod selector;
pub mod v2;
pub mod worksize;
pub mod wrapper;

pub use buffer::Buffer;
pub use context::Context;
pub use device::Device;
pub use errors::{CclError, CclResult, ErrorDomain};
pub use event::{Event, UserEvent};
pub use image::Image;
pub use kernel::{Arg, Kernel};
pub use prof::Prof;
pub use program::Program;
pub use queue::Queue;
pub use selector::{Filter, FilterChain};
pub use worksize::suggest_worksizes;
pub use wrapper::memcheck;
