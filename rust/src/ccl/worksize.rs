//! `suggest_worksizes` — the work-size heuristic of paper §6.1.
//!
//! Given the *real work size* (how many work-items the problem actually
//! needs per dimension), produce:
//!
//! * a local work size (LWS) that is a multiple of the device/kernel
//!   preferred work-group multiple, within per-dimension and total
//!   work-group limits;
//! * a global work size (GWS) that covers the real work size and is a
//!   multiple of the LWS in every dimension (the pre-OpenCL-2.0 rule).
//!
//! Unlike the minimum-LOC approach of listing S1 (which only handles one
//! dimension and requires the preferred-multiple query to exist), this
//! handles multiple dimensions and devices/kernels that cannot report a
//! preferred multiple (falling back to a power-of-two heuristic).

use super::device::Device;
use super::errors::CclResult;
use super::kernel::{check_dims, Kernel};

/// Round `x` up to the next multiple of `m`.
fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Compute suggested (gws, lws) for `rws` real work on `dev`.
///
/// `kernel` refines the limits when given (kernel-specific work-group
/// info); `None` falls back to device limits only — the situation OpenCL
/// 1.0 hosts are stuck with, which cf4ocl handles uniformly.
pub fn suggest_worksizes(
    kernel: Option<&Kernel>,
    dev: Device,
    rws: &[usize],
) -> CclResult<(Vec<usize>, Vec<usize>)> {
    check_dims(rws)?;
    let dims = rws.len();

    // Preferred multiple: kernel query when possible, else device, else 8.
    let pref = match kernel {
        Some(k) => k.preferred_wg_multiple(dev).or_else(|_| dev.preferred_wg_multiple())?,
        None => dev.preferred_wg_multiple().unwrap_or(8),
    }
    .max(1);

    // Work-group capacity limits.
    let max_wg = match kernel {
        Some(k) => k
            .max_work_group_size(dev)
            .or_else(|_| dev.max_work_group_size())?,
        None => dev.max_work_group_size()?,
    };
    let max_item = dev.max_work_item_sizes()?;

    // Start with a 1-item group and grow dimension 0 in units of the
    // preferred multiple, then grow higher dimensions by powers of two,
    // never exceeding per-dimension limits, the total work-group limit,
    // or (rounded-up) real work.
    let mut lws = vec![1usize; dims];
    lws[0] = pref.min(max_item[0]).min(max_wg).min(round_up(rws[0], pref));
    // Grow dim 0 first (coalescing dimension on GPUs).
    while lws[0] * 2 <= max_item[0]
        && product(&lws) * 2 <= max_wg
        && lws[0] * 2 <= round_up(rws[0], pref)
    {
        lws[0] *= 2;
    }
    // Then higher dimensions.
    for d in 1..dims {
        while lws[d] * 2 <= max_item[d]
            && product(&lws) * 2 <= max_wg
            && lws[d] * 2 <= rws[d].next_power_of_two()
        {
            lws[d] *= 2;
        }
    }

    let gws: Vec<usize> = rws.iter().zip(&lws).map(|(&r, &l)| round_up(r, l)).collect();
    Ok((gws, lws))
}

fn product(v: &[usize]) -> usize {
    v.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rawcl::types::DeviceId;

    fn gtx() -> Device {
        Device::from_id(DeviceId(1)).unwrap()
    }

    fn hd() -> Device {
        Device::from_id(DeviceId(2)).unwrap()
    }

    #[test]
    fn one_dim_exact_multiple() {
        let (gws, lws) = suggest_worksizes(None, gtx(), &[1 << 20]).unwrap();
        assert_eq!(gws[0] % lws[0], 0);
        assert!(gws[0] >= 1 << 20);
        assert_eq!(lws[0] % 32, 0, "lws must honour the warp multiple");
        assert!(lws[0] <= 1024);
    }

    #[test]
    fn one_dim_ragged_size_rounds_up() {
        let (gws, lws) = suggest_worksizes(None, gtx(), &[1000]).unwrap();
        assert!(gws[0] >= 1000);
        assert_eq!(gws[0] % lws[0], 0);
    }

    #[test]
    fn small_work_small_groups() {
        let (gws, lws) = suggest_worksizes(None, gtx(), &[16]).unwrap();
        assert_eq!(lws[0], 32, "one preferred multiple");
        assert_eq!(gws[0], 32);
    }

    #[test]
    fn respects_smaller_hd7970_limits() {
        let (gws, lws) = suggest_worksizes(None, hd(), &[1 << 20]).unwrap();
        assert!(lws[0] <= 256, "HD 7970 max work-group is 256");
        assert_eq!(lws[0] % 64, 0, "wavefront multiple");
        assert_eq!(gws[0] % lws[0], 0);
    }

    #[test]
    fn two_dims_product_within_wg_limit() {
        let (gws, lws) = suggest_worksizes(None, gtx(), &[1920, 1080]).unwrap();
        assert!(lws[0] * lws[1] <= 1024);
        for d in 0..2 {
            assert_eq!(gws[d] % lws[d], 0);
            assert!(gws[d] >= [1920, 1080][d]);
        }
    }

    #[test]
    fn three_dims_supported() {
        let (gws, lws) = suggest_worksizes(None, hd(), &[64, 64, 8]).unwrap();
        assert_eq!(gws.len(), 3);
        assert!(lws.iter().product::<usize>() <= 256);
        for d in 0..3 {
            assert_eq!(gws[d] % lws[d], 0);
        }
    }

    #[test]
    fn rejects_bad_dims() {
        assert!(suggest_worksizes(None, gtx(), &[]).is_err());
        assert!(suggest_worksizes(None, gtx(), &[1, 1, 1, 1]).is_err());
        assert!(suggest_worksizes(None, gtx(), &[0]).is_err());
    }

    #[test]
    fn native_cpu_profile_works_too() {
        let dev = Device::from_id(DeviceId(0)).unwrap();
        let (gws, lws) = suggest_worksizes(None, dev, &[4096]).unwrap();
        assert_eq!(gws[0] % lws[0], 0);
        assert!(gws[0] >= 4096);
    }
}
