//! Context wrapper (`CCLContext`).
//!
//! Compare the paper's one-liner (listing S2, line 182):
//!
//! ```no_run
//! # use cf4rs::ccl::Context;
//! let ctx = Context::new_gpu().unwrap();
//! let dev = ctx.device(0).unwrap();
//! ```
//!
//! with the platform/device loop of listing S1 (reproduced by
//! `examples/rng_raw.rs`).

use crate::rawcl;
use crate::rawcl::types::{ContextH, DeviceId, DeviceType};

use super::device::Device;
use super::errors::{check, CclError, CclResult};
use super::selector::{Filter, FilterChain};
use super::wrapper::LiveToken;

/// Owning wrapper for a substrate context.
pub struct Context {
    h: ContextH,
    devices: Vec<Device>,
    _live: LiveToken,
}

impl Context {
    /// Context with all GPU devices of the first GPU-bearing platform
    /// (`ccl_context_new_gpu`).
    pub fn new_gpu() -> CclResult<Self> {
        Self::new_from_type(DeviceType::GPU)
    }

    /// Context with all CPU devices (`ccl_context_new_cpu`).
    pub fn new_cpu() -> CclResult<Self> {
        Self::new_from_type(DeviceType::CPU)
    }

    /// Context from a device-type filter (`ccl_context_new_from_type`).
    pub fn new_from_type(t: DeviceType) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_context_from_type(t, &mut st);
        check(st, "creating context from device type")?;
        Self::from_handle(h)
    }

    /// Context from explicit devices (`ccl_context_new_from_devices`).
    pub fn new_from_devices(devs: &[Device]) -> CclResult<Self> {
        let ids: Vec<DeviceId> = devs.iter().map(|d| d.id()).collect();
        let mut st = 0;
        let h = rawcl::create_context(&ids, &mut st);
        check(st, "creating context from device list")?;
        Self::from_handle(h)
    }

    /// Context from a filter chain (`ccl_context_new_from_filters`).
    ///
    /// A `same_platform` dependent filter is appended automatically, as
    /// contexts cannot span platforms.
    pub fn new_from_filters(chain: FilterChain) -> CclResult<Self> {
        let devs = chain.add(Filter::same_platform()).select_nonempty()?;
        Self::new_from_devices(&devs)
    }

    fn from_handle(h: ContextH) -> CclResult<Self> {
        let mut ids = Vec::new();
        check(rawcl::get_context_devices(h, &mut ids), "querying context devices")?;
        let devices = ids.into_iter().map(|id| Device { id }).collect();
        Ok(Self { h, devices, _live: LiveToken::new() })
    }

    /// The raw handle (cf4ocl always lets you unwrap).
    pub fn handle(&self) -> ContextH {
        self.h
    }

    /// Number of devices in the context.
    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// The i-th device (`ccl_context_get_device`).
    pub fn device(&self, i: usize) -> CclResult<Device> {
        self.devices.get(i).copied().ok_or_else(|| {
            CclError::framework(format!(
                "device index {i} out of range (context has {})",
                self.devices.len()
            ))
        })
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }
}

impl Drop for Context {
    fn drop(&mut self) {
        rawcl::release_context(self.h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_gpu_selects_simcl() {
        let ctx = Context::new_gpu().unwrap();
        assert_eq!(ctx.num_devices(), 2);
        assert!(ctx.device(0).unwrap().is_gpu());
        assert!(ctx.device(2).is_err());
    }

    #[test]
    fn new_cpu_selects_native() {
        let ctx = Context::new_cpu().unwrap();
        assert_eq!(ctx.num_devices(), 1);
        assert_eq!(ctx.device(0).unwrap().name().unwrap(), "cf4rs PJRT CPU");
    }

    #[test]
    fn from_filters_single_device() {
        let ctx = Context::new_from_filters(
            FilterChain::new().add(Filter::name_contains("1080")),
        )
        .unwrap();
        assert_eq!(ctx.num_devices(), 1);
    }

    #[test]
    fn from_filters_appends_same_platform() {
        // No filter at all: all 3 devices span 2 platforms; same_platform
        // must cut to the first platform only.
        let ctx = Context::new_from_filters(FilterChain::new()).unwrap();
        assert_eq!(ctx.num_devices(), 1, "must not span platforms");
    }

    #[test]
    fn handle_released_on_drop() {
        let h = {
            let ctx = Context::new_gpu().unwrap();
            ctx.handle()
        };
        // After drop the substrate must consider the handle dead.
        let mut devs = Vec::new();
        assert_ne!(rawcl::get_context_devices(h, &mut devs), rawcl::CL_SUCCESS);
    }
}
