//! Profiling data types (paper §4.3): `CCLProfInfo`, `CCLProfInst`,
//! `CCLProfAgg` and their sort orders.

/// Non-aggregate, per-event information (`CCLProfInfo`).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfInfo {
    /// Event name (user-assigned, or command-type name).
    pub name: String,
    /// Name of the queue the event ran on (as given to `add_queue`).
    pub queue: String,
    /// Profiling instants, ns on the process profiling clock.
    pub t_queued: u64,
    pub t_submit: u64,
    pub t_start: u64,
    pub t_end: u64,
}

impl ProfInfo {
    pub fn duration(&self) -> u64 {
        self.t_end.saturating_sub(self.t_start)
    }
}

/// Which endpoint a [`ProfInst`] marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstType {
    Start,
    End,
}

/// One event instant (`CCLProfInst`).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfInst {
    pub name: String,
    pub queue: String,
    pub itype: InstType,
    pub instant: u64,
    /// Index into the `ProfInfo` list this instant belongs to.
    pub event_index: usize,
}

/// Aggregated times for all events with the same name (`CCLProfAgg`).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfAgg {
    pub name: String,
    /// Total (absolute) time in ns.
    pub abs_time: u64,
    /// Fraction of the summed duration of all events (0..=1).
    pub rel_time: f64,
    /// Number of events aggregated.
    pub count: usize,
}

/// Overlap between two (named) events (`CCLProfOverlap`).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfOverlap {
    pub event1: String,
    pub event2: String,
    /// Total overlapped time in ns.
    pub duration: u64,
}

/// Sort key for aggregates (paper: `CCL_PROF_AGG_SORT_TIME` etc.).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggSort {
    Time,
    Name,
}

/// Sort key for overlaps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapSort {
    Duration,
    Name,
}

/// Sort direction (`CCL_PROF_SORT_ASC`/`DESC`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortDir {
    Asc,
    Desc,
}

pub fn sort_aggs(aggs: &mut [ProfAgg], key: AggSort, dir: SortDir) {
    aggs.sort_by(|a, b| {
        let ord = match key {
            AggSort::Time => a.abs_time.cmp(&b.abs_time),
            AggSort::Name => a.name.cmp(&b.name),
        };
        match dir {
            SortDir::Asc => ord,
            SortDir::Desc => ord.reverse(),
        }
    });
}

pub fn sort_overlaps(ovs: &mut [ProfOverlap], key: OverlapSort, dir: SortDir) {
    ovs.sort_by(|a, b| {
        let ord = match key {
            OverlapSort::Duration => a.duration.cmp(&b.duration),
            OverlapSort::Name => (&a.event1, &a.event2).cmp(&(&b.event1, &b.event2)),
        };
        match dir {
            SortDir::Asc => ord,
            SortDir::Desc => ord.reverse(),
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agg(name: &str, t: u64) -> ProfAgg {
        ProfAgg { name: name.into(), abs_time: t, rel_time: 0.0, count: 1 }
    }

    #[test]
    fn agg_sorting() {
        let mut v = vec![agg("b", 10), agg("a", 30), agg("c", 20)];
        sort_aggs(&mut v, AggSort::Time, SortDir::Desc);
        assert_eq!(v[0].name, "a");
        assert_eq!(v[2].name, "b");
        sort_aggs(&mut v, AggSort::Name, SortDir::Asc);
        assert_eq!(v[0].name, "a");
        assert_eq!(v[2].name, "c");
    }

    #[test]
    fn overlap_sorting() {
        let mut v = vec![
            ProfOverlap { event1: "x".into(), event2: "y".into(), duration: 5 },
            ProfOverlap { event1: "a".into(), event2: "b".into(), duration: 9 },
        ];
        sort_overlaps(&mut v, OverlapSort::Duration, SortDir::Desc);
        assert_eq!(v[0].duration, 9);
        sort_overlaps(&mut v, OverlapSort::Name, SortDir::Asc);
        assert_eq!(v[0].event1, "a");
    }

    #[test]
    fn info_duration_saturates() {
        let i = ProfInfo {
            name: "e".into(),
            queue: "q".into(),
            t_queued: 0,
            t_submit: 0,
            t_start: 10,
            t_end: 5,
        };
        assert_eq!(i.duration(), 0);
    }
}
