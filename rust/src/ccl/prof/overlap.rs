//! Event-overlap detection (paper §4.3, `CCLProfOverlap`).
//!
//! Overlaps can only occur between commands of *different queues* (a
//! queue is in-order); the paper's Fig. 3 shows the RNG kernel
//! overlapping the buffer reads issued by the comms thread's queue.
//!
//! Sweep-line over event instants: maintain the set of active events; an
//! overlap interval opens when an event starts while another is active
//! and closes when either ends. Durations are accumulated per unordered
//! pair of event *names*, mirroring cf4ocl's reporting.

use std::collections::HashMap;

use super::info::{ProfInfo, ProfOverlap};

/// Compute name-pair overlap totals from per-event records.
///
/// Perf notes (EXPERIMENTS.md §Perf): names and queues are interned to
/// small integer ids up front, the per-event-pair "open interval" map is
/// keyed by a packed `u64`, and totals accumulate per packed *name-id*
/// pair — string work happens only once per distinct name, not once per
/// instant. This took 100k-event analysis from ~42 ms to single-digit
/// ms (see `benches/profiler_calc.rs`).
pub fn compute_overlaps(infos: &[ProfInfo]) -> Vec<ProfOverlap> {
    // Intern names and queues.
    let mut name_ids: HashMap<&str, u32> = HashMap::new();
    let mut names: Vec<&str> = Vec::new();
    let mut ev_name: Vec<u32> = Vec::with_capacity(infos.len());
    let mut ev_queue: Vec<u32> = Vec::with_capacity(infos.len());
    let mut queue_ids: HashMap<&str, u32> = HashMap::new();
    for info in infos {
        let nid = *name_ids.entry(info.name.as_str()).or_insert_with(|| {
            names.push(info.name.as_str());
            (names.len() - 1) as u32
        });
        ev_name.push(nid);
        let ql = queue_ids.len() as u32;
        ev_queue.push(*queue_ids.entry(info.queue.as_str()).or_insert(ql));
    }

    // Timestamps ≥ 2^63 would wrap the packed sort key below and
    // corrupt the sweep order. Process-clock timestamps are < 2^62 ns of
    // uptime, but records can arrive from untrusted TSV files (the
    // parser rejects them, this is defence in depth) — saturate instead
    // of silently corrupting; saturated events collapse to zero length
    // and drop out of the sweep.
    const T_SAT: u64 = (1 << 63) - 1;
    let clamp = |t: u64| t.min(T_SAT);

    // Build the instant list: (time, is_end, event index). Sorting puts
    // ends before starts at equal times so zero-length "touching"
    // intervals don't count as overlapping.
    let mut instants: Vec<(u64, bool, u32)> = Vec::with_capacity(infos.len() * 2);
    for (i, info) in infos.iter().enumerate() {
        if clamp(info.t_end) > clamp(info.t_start) {
            instants.push((clamp(info.t_start), false, i as u32));
            instants.push((clamp(info.t_end), true, i as u32));
        }
    }
    // Single-u64 sort key: (t << 1) | is_start — ends sort before starts
    // at equal times (clamping above keeps t < 2^63).
    instants.sort_unstable_by_key(|&(t, is_end, _)| (t << 1) | (!is_end as u64));

    let mut active: Vec<u32> = Vec::new();
    // Accumulated durations keyed by packed unordered name-id pair.
    let mut totals: HashMap<u64, u64> = HashMap::new();

    let pack = |a: u32, b: u32| ((a.min(b) as u64) << 32) | a.max(b) as u64;

    // Overlap of a pair = end of whichever finishes first minus the later
    // of the two starts — so all accounting can happen at END instants,
    // over the still-active set, with no per-pair open-interval map.
    for (t, is_end, idx) in instants {
        let idx_us = idx as usize;
        if !is_end {
            active.push(idx);
        } else {
            active.retain(|&a| a != idx);
            for &a in &active {
                // Same-queue events cannot overlap (in-order execution);
                // if timestamps say otherwise it is measurement noise.
                if ev_queue[a as usize] == ev_queue[idx_us] {
                    continue;
                }
                let t0 = clamp(infos[a as usize].t_start).max(clamp(infos[idx_us].t_start));
                if t > t0 {
                    let key = pack(ev_name[a as usize], ev_name[idx_us]);
                    *totals.entry(key).or_insert(0) += t - t0;
                }
            }
        }
    }

    let mut out: Vec<ProfOverlap> = totals
        .into_iter()
        .map(|(key, duration)| {
            let (n1, n2) = (names[(key >> 32) as usize], names[(key & 0xFFFF_FFFF) as usize]);
            let (e1, e2) = if n1 <= n2 { (n1, n2) } else { (n2, n1) };
            ProfOverlap {
                event1: e1.to_string(),
                event2: e2.to_string(),
                duration,
            }
        })
        .collect();
    out.sort_by(|a, b| b.duration.cmp(&a.duration));
    out
}

/// Merge sorted-by-start intervals into their disjoint union.
fn union_intervals(mut iv: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::new();
    for (s, e) in iv {
        match out.last_mut() {
            Some((_, ce)) if s <= *ce => *ce = (*ce).max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Total device-busy time: the union length of all event intervals.
/// (Fig. 3's "Tot. of all events (eff.)".)
pub fn effective_total(infos: &[ProfInfo]) -> u64 {
    union_intervals(
        infos
            .iter()
            .filter(|i| i.t_end > i.t_start)
            .map(|i| (i.t_start, i.t_end))
            .collect(),
    )
    .iter()
    .map(|(s, e)| e - s)
    .sum()
}

/// Per-queue busy/idle accounting — the summary's global
/// "time spent in device" line, broken out so a starved queue can't
/// hide behind a busy one.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueueUtil {
    pub queue: String,
    /// Union length of the queue's event intervals, ns.
    pub busy: u64,
    /// First event start on the queue, ns.
    pub t_first: u64,
    /// Last event end on the queue, ns.
    pub t_last: u64,
    /// The queue's disjoint busy intervals, start-ordered (the gaps
    /// between them are the queue's idle windows).
    pub busy_intervals: Vec<(u64, u64)>,
}

impl QueueUtil {
    /// The queue's active window (first start to last end), ns.
    pub fn window(&self) -> u64 {
        self.t_last.saturating_sub(self.t_first)
    }

    /// Busy fraction of the active window, in [0, 1].
    pub fn utilisation(&self) -> f64 {
        if self.window() == 0 {
            return 1.0;
        }
        self.busy as f64 / self.window() as f64
    }
}

/// Per-queue interval-union utilisation, sorted by queue name.
pub fn per_queue_util(infos: &[ProfInfo]) -> Vec<QueueUtil> {
    let mut by_queue: HashMap<&str, Vec<(u64, u64)>> = HashMap::new();
    for i in infos {
        if i.t_end > i.t_start {
            by_queue.entry(i.queue.as_str()).or_default().push((i.t_start, i.t_end));
        }
    }
    let mut out: Vec<QueueUtil> = by_queue
        .into_iter()
        .map(|(queue, iv)| {
            let busy_intervals = union_intervals(iv);
            QueueUtil {
                queue: queue.to_string(),
                busy: busy_intervals.iter().map(|(s, e)| e - s).sum(),
                t_first: busy_intervals.first().map_or(0, |&(s, _)| s),
                t_last: busy_intervals.last().map_or(0, |&(_, e)| e),
                busy_intervals,
            }
        })
        .collect();
    out.sort_by(|a, b| a.queue.cmp(&b.queue));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(name: &str, queue: &str, start: u64, end: u64) -> ProfInfo {
        ProfInfo {
            name: name.into(),
            queue: queue.into(),
            t_queued: start,
            t_submit: start,
            t_start: start,
            t_end: end,
        }
    }

    #[test]
    fn simple_cross_queue_overlap() {
        let infos = vec![
            info("RNG_KERNEL", "main", 0, 100),
            info("READ_BUFFER", "comms", 50, 150),
        ];
        let ovs = compute_overlaps(&infos);
        assert_eq!(ovs.len(), 1);
        assert_eq!(ovs[0].duration, 50);
        assert_eq!(
            (ovs[0].event1.as_str(), ovs[0].event2.as_str()),
            ("READ_BUFFER", "RNG_KERNEL")
        );
    }

    #[test]
    fn same_queue_never_overlaps() {
        let infos = vec![
            info("A", "main", 0, 100),
            info("B", "main", 50, 150), // impossible in-order, treat as noise
        ];
        assert!(compute_overlaps(&infos).is_empty());
    }

    #[test]
    fn touching_intervals_do_not_overlap() {
        let infos = vec![info("A", "q1", 0, 100), info("B", "q2", 100, 200)];
        assert!(compute_overlaps(&infos).is_empty());
    }

    #[test]
    fn containment_counts_inner_length() {
        let infos = vec![info("A", "q1", 0, 1000), info("B", "q2", 200, 300)];
        let ovs = compute_overlaps(&infos);
        assert_eq!(ovs[0].duration, 100);
    }

    #[test]
    fn repeated_names_accumulate() {
        let infos = vec![
            info("RNG_KERNEL", "main", 0, 100),
            info("READ_BUFFER", "comms", 50, 150),
            info("RNG_KERNEL", "main", 200, 300),
            info("READ_BUFFER", "comms", 250, 350),
        ];
        let ovs = compute_overlaps(&infos);
        assert_eq!(ovs.len(), 1, "one name pair");
        assert_eq!(ovs[0].duration, 100, "two 50ns overlaps accumulated");
    }

    #[test]
    fn three_way_overlap_produces_three_pairs() {
        let infos = vec![
            info("A", "q1", 0, 100),
            info("B", "q2", 10, 90),
            info("C", "q3", 20, 80),
        ];
        let ovs = compute_overlaps(&infos);
        assert_eq!(ovs.len(), 3);
        let ab = ovs.iter().find(|o| o.event1 == "A" && o.event2 == "B").unwrap();
        assert_eq!(ab.duration, 80);
        let bc = ovs.iter().find(|o| o.event1 == "B" && o.event2 == "C").unwrap();
        assert_eq!(bc.duration, 60);
    }

    #[test]
    fn huge_timestamps_saturate_instead_of_corrupting_the_sweep() {
        // Regression: with t ≥ 2^63 the packed (t << 1) key wrapped, the
        // huge event's start sorted before everything, and a spurious
        // overlap with ordinary events was reported.
        let infos = vec![
            info("HUGE", "q2", 1 << 63, (1 << 63) + 100),
            info("B", "q1", 10, 100),
        ];
        let ovs = compute_overlaps(&infos);
        assert!(
            ovs.is_empty(),
            "saturated out-of-range event must not overlap: {ovs:?}"
        );
        // Sanity: ordinary events around it are still analysed.
        let infos = vec![
            info("HUGE", "q3", u64::MAX - 5, u64::MAX),
            info("A", "q1", 0, 100),
            info("B", "q2", 50, 150),
        ];
        let ovs = compute_overlaps(&infos);
        assert_eq!(ovs.len(), 1);
        assert_eq!(ovs[0].duration, 50);
    }

    #[test]
    fn effective_total_merges_intervals() {
        let infos = vec![
            info("A", "q1", 0, 100),
            info("B", "q2", 50, 150),
            info("C", "q1", 200, 250),
        ];
        assert_eq!(effective_total(&infos), 150 + 50);
    }

    #[test]
    fn effective_total_empty() {
        assert_eq!(effective_total(&[]), 0);
    }

    #[test]
    fn per_queue_util_unions_within_each_queue() {
        let infos = vec![
            info("A", "q1", 0, 100),
            info("B", "q1", 50, 150),  // overlaps A: union [0, 150)
            info("C", "q1", 200, 250), // 50 ns gap
            info("D", "q2", 0, 40),
            info("Z", "q2", 40, 40), // zero-length, ignored
        ];
        let utils = per_queue_util(&infos);
        assert_eq!(utils.len(), 2);
        let q1 = &utils[0];
        assert_eq!(q1.queue, "q1");
        assert_eq!(q1.busy, 200);
        assert_eq!((q1.t_first, q1.t_last), (0, 250));
        assert_eq!(q1.window(), 250);
        assert!((q1.utilisation() - 0.8).abs() < 1e-9);
        assert_eq!(q1.busy_intervals, vec![(0, 150), (200, 250)]);
        let q2 = &utils[1];
        assert_eq!(q2.queue, "q2");
        assert_eq!(q2.busy, 40);
        assert!((q2.utilisation() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn per_queue_util_empty_and_degenerate() {
        assert!(per_queue_util(&[]).is_empty());
        // A queue with only zero-length events contributes nothing.
        let infos = vec![info("Z", "q", 5, 5)];
        assert!(per_queue_util(&infos).is_empty());
    }
}
