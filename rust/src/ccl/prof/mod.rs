//! Integrated profiler (`CCLProf`, paper §4.3).
//!
//! Usage mirrors the paper (listing S2, lines 252–325):
//!
//! ```no_run
//! # use cf4rs::ccl::{Context, Queue, prof::Prof};
//! # let ctx = Context::new_gpu().unwrap();
//! # let dev = ctx.device(0).unwrap();
//! # let cq_main = Queue::new_profiled(&ctx, dev).unwrap();
//! # let cq_comms = Queue::new_profiled(&ctx, dev).unwrap();
//! let mut prof = Prof::new();
//! prof.start();
//! // ... enqueue kernels and transfers on the queues ...
//! prof.stop();
//! prof.add_queue("Main", &cq_main);
//! prof.add_queue("Comms", &cq_comms);
//! prof.calc().unwrap();
//! eprintln!("{}", prof.summary_default());
//! ```
//!
//! Because [`Queue`](crate::ccl::Queue) wrappers track every event they
//! generate, no client-side event bookkeeping is needed — the decisive
//! difference from the raw-API profiling code in listing S1 (lines
//! 455–523), which also cannot compute overlaps.

pub mod export;
pub mod info;
pub mod overlap;
pub mod summary;

use std::collections::HashMap;
use std::path::Path;

pub use info::{
    AggSort, InstType, OverlapSort, ProfAgg, ProfInfo, ProfInst, ProfOverlap, SortDir,
};

use crate::rawcl::clock;

use super::errors::{CclError, CclResult};
use super::queue::Queue;

/// The profiler object.
#[derive(Default)]
pub struct Prof {
    queues: Vec<(String, Vec<super::event::Event>)>,
    /// Pre-built timelines from non-queue sources (the backend layer);
    /// merged with the queue events in [`calc`](Prof::calc).
    external: Vec<ProfInfo>,
    t_start: Option<u64>,
    t_stop: Option<u64>,
    infos: Vec<ProfInfo>,
    aggs: Vec<ProfAgg>,
    insts: Vec<ProfInst>,
    overlaps: Vec<ProfOverlap>,
    queue_utils: Vec<overlap::QueueUtil>,
    effective_ns: u64,
    calculated: bool,
}

impl Prof {
    /// `ccl_prof_new`.
    pub fn new() -> Self {
        Self::default()
    }

    /// `ccl_prof_start`: begin the host wall-clock window.
    pub fn start(&mut self) {
        self.t_start = Some(clock::now_ns());
    }

    /// `ccl_prof_stop`.
    pub fn stop(&mut self) {
        self.t_stop = Some(clock::now_ns());
    }

    /// Host wall-clock seconds between `start` and `stop`
    /// (`ccl_prof_time_elapsed`).
    pub fn time_elapsed(&self) -> f64 {
        self.elapsed_ns() as f64 * 1e-9
    }

    fn elapsed_ns(&self) -> u64 {
        match (self.t_start, self.t_stop) {
            (Some(a), Some(b)) => b.saturating_sub(a),
            _ => 0,
        }
    }

    /// `ccl_prof_add_queue`: harvest a queue's tracked events.
    ///
    /// The queue wrapper keeps its events alive; the profiler snapshots
    /// them here and reads their timestamps in [`calc`](Self::calc).
    pub fn add_queue(&mut self, name: impl Into<String>, queue: &Queue) {
        self.queues.push((name.into(), queue.events()));
    }

    /// cf4rs extension: harvest a pre-built event timeline that did not
    /// come from a `ccl` queue — e.g. a [`crate::backend::Backend`]'s
    /// drained command log. `queue_name` plays the role the queue name
    /// plays in [`add_queue`](Self::add_queue) (one timeline per
    /// backend), so one profile can aggregate events across every
    /// backend a scheduler dispatched to.
    ///
    /// Entries are `(event name, (queued, submit, start, end))` in ns on
    /// the shared profiling clock.
    pub fn add_timeline(
        &mut self,
        queue_name: impl Into<String>,
        entries: Vec<(String, (u64, u64, u64, u64))>,
    ) {
        let queue = queue_name.into();
        for (name, (t_queued, t_submit, t_start, t_end)) in entries {
            self.external.push(ProfInfo {
                name,
                queue: queue.clone(),
                t_queued,
                t_submit,
                t_start,
                t_end,
            });
        }
    }

    /// `ccl_prof_calc`: run the profiling analysis.
    pub fn calc(&mut self) -> CclResult<()> {
        if self.calculated {
            return Err(CclError::framework("profiling already calculated"));
        }
        let mut infos = Vec::new();
        for (qname, events) in &self.queues {
            for ev in events {
                // Markers and incomplete events are skipped; any other
                // profiling failure (e.g. queue without the profiling
                // flag) is a real error, as in cf4ocl.
                use crate::rawcl::types::CommandType;
                let cmd = ev.command_type().map_err(|e| {
                    CclError::framework(format!("event vanished during calc: {e}"))
                })?;
                if cmd == CommandType::Marker {
                    continue;
                }
                let t_start = ev.time_start()?;
                let t_end = ev.time_end()?;
                infos.push(ProfInfo {
                    name: event_display_name(ev),
                    queue: qname.clone(),
                    t_queued: ev.time_queued()?,
                    t_submit: ev.time_submit()?,
                    t_start,
                    t_end,
                });
            }
        }
        // Merge externally-harvested timelines (backend layer), keeping
        // one globally time-sorted event list.
        infos.append(&mut self.external);
        infos.sort_by_key(|i| (i.t_start, i.t_end));

        // Aggregates by name.
        let mut agg_map: HashMap<String, (u64, usize)> = HashMap::new();
        let mut total: u64 = 0;
        for i in &infos {
            let d = i.duration();
            let e = agg_map.entry(i.name.clone()).or_insert((0, 0));
            e.0 += d;
            e.1 += 1;
            total += d;
        }
        let mut aggs: Vec<ProfAgg> = agg_map
            .into_iter()
            .map(|(name, (abs_time, count))| ProfAgg {
                name,
                abs_time,
                rel_time: if total > 0 { abs_time as f64 / total as f64 } else { 0.0 },
                count,
            })
            .collect();
        aggs.sort_by(|a, b| b.abs_time.cmp(&a.abs_time));

        // Instants.
        let mut insts = Vec::with_capacity(infos.len() * 2);
        for (idx, i) in infos.iter().enumerate() {
            insts.push(ProfInst {
                name: i.name.clone(),
                queue: i.queue.clone(),
                itype: InstType::Start,
                instant: i.t_start,
                event_index: idx,
            });
            insts.push(ProfInst {
                name: i.name.clone(),
                queue: i.queue.clone(),
                itype: InstType::End,
                instant: i.t_end,
                event_index: idx,
            });
        }
        insts.sort_by_key(|i| i.instant);

        self.overlaps = overlap::compute_overlaps(&infos);
        self.queue_utils = overlap::per_queue_util(&infos);
        self.effective_ns = overlap::effective_total(&infos);
        self.aggs = aggs;
        self.insts = insts;
        self.infos = infos;
        self.calculated = true;
        Ok(())
    }

    fn ensure_calculated(&self) -> CclResult<()> {
        if self.calculated {
            Ok(())
        } else {
            Err(CclError::framework("call calc() before accessing results"))
        }
    }

    /// Aggregate event information (`CCLProfAgg` iteration).
    pub fn aggs(&self) -> CclResult<&[ProfAgg]> {
        self.ensure_calculated()?;
        Ok(&self.aggs)
    }

    /// Non-aggregate event information (`CCLProfInfo` iteration).
    pub fn infos(&self) -> CclResult<&[ProfInfo]> {
        self.ensure_calculated()?;
        Ok(&self.infos)
    }

    /// Event instants (`CCLProfInst` iteration).
    pub fn instants(&self) -> CclResult<&[ProfInst]> {
        self.ensure_calculated()?;
        Ok(&self.insts)
    }

    /// Event overlaps (`CCLProfOverlap` iteration).
    pub fn overlaps(&self) -> CclResult<&[ProfOverlap]> {
        self.ensure_calculated()?;
        Ok(&self.overlaps)
    }

    /// Union length of all event intervals, ns.
    pub fn effective_ns(&self) -> CclResult<u64> {
        self.ensure_calculated()?;
        Ok(self.effective_ns)
    }

    /// Per-queue busy/idle accounting (cf4rs extension): interval-union
    /// utilisation for every queue, sorted by queue name.
    pub fn queue_utils(&self) -> CclResult<&[overlap::QueueUtil]> {
        self.ensure_calculated()?;
        Ok(&self.queue_utils)
    }

    /// `ccl_prof_get_summary` with explicit sort flags.
    pub fn summary(
        &self,
        agg_sort: (AggSort, SortDir),
        ov_sort: (OverlapSort, SortDir),
    ) -> CclResult<String> {
        self.ensure_calculated()?;
        Ok(summary::render(
            &self.aggs,
            &self.overlaps,
            &self.queue_utils,
            self.effective_ns,
            self.elapsed_ns(),
            agg_sort,
            ov_sort,
        ))
    }

    /// Summary with the paper's flags: aggregates by time desc, overlaps
    /// by duration desc.
    pub fn summary_default(&self) -> String {
        self.summary(
            (AggSort::Time, SortDir::Desc),
            (OverlapSort::Duration, SortDir::Desc),
        )
        .unwrap_or_else(|e| format!("<{e}>"))
    }

    /// `ccl_prof_export_info_file`: write the Fig. 5 input table.
    pub fn export_tsv(&self, path: impl AsRef<Path>) -> CclResult<()> {
        self.ensure_calculated()?;
        export::write_file(&self.infos, path)
    }

    /// In-memory export (testing + piping).
    pub fn export_string(&self) -> CclResult<String> {
        self.ensure_calculated()?;
        Ok(export::to_tsv(&self.infos))
    }
}

fn event_display_name(ev: &super::event::Event) -> String {
    crate::rawcl::event::lookup(ev.handle())
        .map(|o| o.display_name())
        .unwrap_or_else(|| "UNKNOWN".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn external_timelines_aggregate_and_overlap() {
        let mut prof = Prof::new();
        prof.start();
        prof.add_timeline(
            "backend-a",
            vec![
                ("RNG_KERNEL".into(), (0, 0, 10, 110)),
                ("READ_BUFFER".into(), (0, 0, 120, 220)),
            ],
        );
        prof.add_timeline("backend-b", vec![("RNG_KERNEL".into(), (0, 0, 50, 150))]);
        prof.stop();
        prof.calc().unwrap();
        let aggs = prof.aggs().unwrap();
        let rng = aggs.iter().find(|a| a.name == "RNG_KERNEL").unwrap();
        assert_eq!(rng.count, 2, "events from both backends aggregate");
        assert_eq!(rng.abs_time, 200);
        // The two RNG kernels overlap for [50, 110).
        let ov = prof.overlaps().unwrap();
        assert!(ov.iter().any(|o| o.duration == 60), "overlaps: {ov:?}");
        let s = prof.summary_default();
        assert!(s.contains("RNG_KERNEL"));
        // Per-queue utilisation breaks out each backend's busy fraction.
        assert!(s.contains("Per-queue utilisation"), "{s}");
        assert!(s.contains("backend-a"), "{s}");
        assert!(s.contains("backend-b"), "{s}");
        let utils = prof.queue_utils().unwrap();
        assert_eq!(utils.len(), 2);
        assert_eq!(utils[0].queue, "backend-a");
        // backend-a: [10,110) ∪ [120,220) = 200 busy over a 210 window.
        assert_eq!(utils[0].busy, 200);
        assert_eq!(utils[0].window(), 210);
    }

    #[test]
    fn timelines_merge_time_sorted_with_queue_events() {
        let mut prof = Prof::new();
        prof.add_timeline("late", vec![("B".into(), (0, 0, 200, 300))]);
        prof.add_timeline("early", vec![("A".into(), (0, 0, 0, 100))]);
        prof.calc().unwrap();
        let infos = prof.infos().unwrap();
        assert_eq!(infos[0].name, "A");
        assert_eq!(infos[1].name, "B");
    }
}
