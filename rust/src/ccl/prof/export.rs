//! Profile export (paper §4.3): a table of per-event info —
//! queue name, start instant, end instant, event name — consumable by
//! the `plot_events` utility (Fig. 5).

use std::path::Path;

use super::info::ProfInfo;
use crate::ccl::errors::{CclError, CclResult};

pub const EXPORT_HEADER: &str = "queue\tstart\tend\tname";

/// Serialise per-event records to the export TSV format.
pub fn to_tsv(infos: &[ProfInfo]) -> String {
    let mut out = String::with_capacity(infos.len() * 48 + 32);
    out.push_str(EXPORT_HEADER);
    out.push('\n');
    // Sorted by start instant — the natural timeline order.
    let mut sorted: Vec<&ProfInfo> = infos.iter().collect();
    sorted.sort_by_key(|i| i.t_start);
    for i in sorted {
        out.push_str(&format!("{}\t{}\t{}\t{}\n", i.queue, i.t_start, i.t_end, i.name));
    }
    out
}

/// Write the export table to a file (`ccl_prof_export_info_file`).
pub fn write_file(infos: &[ProfInfo], path: impl AsRef<Path>) -> CclResult<()> {
    std::fs::write(path.as_ref(), to_tsv(infos)).map_err(|e| {
        CclError::framework(format!(
            "writing profile export {}: {e}",
            path.as_ref().display()
        ))
    })
}

/// Parse an export table (used by the `plot_events` utility).
pub fn parse_tsv(text: &str) -> CclResult<Vec<ProfInfo>> {
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h == EXPORT_HEADER => {}
        other => {
            return Err(CclError::framework(format!(
                "bad export header: {other:?} (want {EXPORT_HEADER:?})"
            )))
        }
    }
    let mut out = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            return Err(CclError::framework(format!(
                "export line {}: want 4 columns, got {}",
                ln + 2,
                cols.len()
            )));
        }
        let parse = |s: &str| -> CclResult<u64> {
            s.parse().map_err(|_| {
                CclError::framework(format!("export line {}: bad number {s:?}", ln + 2))
            })
        };
        let start = parse(cols[1])?;
        let end = parse(cols[2])?;
        out.push(ProfInfo {
            name: cols[3].to_string(),
            queue: cols[0].to_string(),
            t_queued: start,
            t_submit: start,
            t_start: start,
            t_end: end,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<ProfInfo> {
        vec![
            ProfInfo {
                name: "RNG_KERNEL".into(),
                queue: "Main".into(),
                t_queued: 10,
                t_submit: 11,
                t_start: 12,
                t_end: 40,
            },
            ProfInfo {
                name: "READ_BUFFER".into(),
                queue: "Comms".into(),
                t_queued: 1,
                t_submit: 2,
                t_start: 3,
                t_end: 50,
            },
        ]
    }

    #[test]
    fn roundtrip() {
        let tsv = to_tsv(&sample());
        let parsed = parse_tsv(&tsv).unwrap();
        assert_eq!(parsed.len(), 2);
        // to_tsv sorts by start: READ_BUFFER first
        assert_eq!(parsed[0].name, "READ_BUFFER");
        assert_eq!(parsed[0].t_start, 3);
        assert_eq!(parsed[1].queue, "Main");
        assert_eq!(parsed[1].t_end, 40);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse_tsv("nope\n").is_err());
    }

    #[test]
    fn rejects_ragged_rows() {
        let bad = format!("{EXPORT_HEADER}\nq\t1\t2\n");
        assert!(parse_tsv(&bad).is_err());
    }

    #[test]
    fn rejects_bad_numbers() {
        let bad = format!("{EXPORT_HEADER}\nq\tx\t2\tname\n");
        assert!(parse_tsv(&bad).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cf4rs_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prof.tsv");
        write_file(&sample(), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(parse_tsv(&text).unwrap().len(), 2);
        std::fs::remove_file(path).ok();
    }
}
