//! Profile export (paper §4.3): a table of per-event info —
//! queue name, start instant, end instant, event name — consumable by
//! the `plot_events` utility (Fig. 5).

use std::path::Path;

use super::info::ProfInfo;
use crate::ccl::errors::{CclError, CclResult};

pub const EXPORT_HEADER: &str = "queue\tstart\tend\tname";

/// Largest timestamp the overlap sweep's packed `(t << 1)` sort key can
/// carry without wrapping. Untrusted TSV input beyond this is rejected
/// at parse; see [`crate::ccl::prof::overlap`].
pub const MAX_TIMESTAMP: u64 = (1 << 63) - 1;

/// Escape a user-assigned queue/event name for one TSV field: `\t`,
/// `\n`, `\r` and `\` become two-character escapes so the record stays
/// one line of exactly four columns. Names without those characters
/// round-trip byte-identical (and are left unallocated).
///
/// Shared with [`crate::analysis::report`] — the lint report's TSV/JSON
/// renderers must escape the same hostile names the profiler does, from
/// one implementation, not a copy.
pub fn escape_field(s: &str) -> std::borrow::Cow<'_, str> {
    if !s.contains(['\t', '\n', '\r', '\\']) {
        return std::borrow::Cow::Borrowed(s);
    }
    let mut out = String::with_capacity(s.len() + 8);
    for c in s.chars() {
        match c {
            '\t' => out.push_str("\\t"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\\' => out.push_str("\\\\"),
            c => out.push(c),
        }
    }
    std::borrow::Cow::Owned(out)
}

/// Invert [`escape_field`]. Unknown escapes are an error — they can only
/// come from a corrupt or foreign file.
pub fn unescape_field(s: &str) -> Result<String, String> {
    if !s.contains('\\') {
        return Ok(s.to_string());
    }
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('t') => out.push('\t'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape \\{}", other.unwrap_or(' '))),
        }
    }
    Ok(out)
}

/// Serialise per-event records to the export TSV format.
///
/// Queue and event names are escaped (`escape_field`) so user-assigned
/// names containing tabs or newlines still produce a table
/// [`parse_tsv`] round-trips exactly.
pub fn to_tsv(infos: &[ProfInfo]) -> String {
    let mut out = String::with_capacity(infos.len() * 48 + 32);
    out.push_str(EXPORT_HEADER);
    out.push('\n');
    // Sorted by start instant — the natural timeline order.
    let mut sorted: Vec<&ProfInfo> = infos.iter().collect();
    sorted.sort_by_key(|i| i.t_start);
    for i in sorted {
        out.push_str(&format!(
            "{}\t{}\t{}\t{}\n",
            escape_field(&i.queue),
            i.t_start,
            i.t_end,
            escape_field(&i.name)
        ));
    }
    out
}

/// Write the export table to a file (`ccl_prof_export_info_file`).
pub fn write_file(infos: &[ProfInfo], path: impl AsRef<Path>) -> CclResult<()> {
    std::fs::write(path.as_ref(), to_tsv(infos)).map_err(|e| {
        CclError::framework(format!(
            "writing profile export {}: {e}",
            path.as_ref().display()
        ))
    })
}

/// Parse an export table (used by the `plot_events` utility).
pub fn parse_tsv(text: &str) -> CclResult<Vec<ProfInfo>> {
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h == EXPORT_HEADER => {}
        other => {
            return Err(CclError::framework(format!(
                "bad export header: {other:?} (want {EXPORT_HEADER:?})"
            )))
        }
    }
    let mut out = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            return Err(CclError::framework(format!(
                "export line {}: want 4 columns, got {}",
                ln + 2,
                cols.len()
            )));
        }
        let parse = |s: &str| -> CclResult<u64> {
            let v: u64 = s.parse().map_err(|_| {
                CclError::framework(format!("export line {}: bad number {s:?}", ln + 2))
            })?;
            // Timestamps ≥ 2^63 would wrap the overlap sweep's packed
            // sort key and silently corrupt the analysis — reject them
            // here, at the untrusted-input boundary.
            if v > MAX_TIMESTAMP {
                return Err(CclError::framework(format!(
                    "export line {}: timestamp {v} exceeds 2^63-1",
                    ln + 2
                )));
            }
            Ok(v)
        };
        let start = parse(cols[1])?;
        let end = parse(cols[2])?;
        // An event ending before it starts would underflow downstream
        // u64 subtractions into absurd durations.
        if end < start {
            return Err(CclError::framework(format!(
                "export line {}: t_end ({end}) < t_start ({start})",
                ln + 2
            )));
        }
        let unesc = |s: &str| -> CclResult<String> {
            unescape_field(s).map_err(|e| {
                CclError::framework(format!("export line {}: {e}", ln + 2))
            })
        };
        out.push(ProfInfo {
            name: unesc(cols[3])?,
            queue: unesc(cols[0])?,
            t_queued: start,
            t_submit: start,
            t_start: start,
            t_end: end,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<ProfInfo> {
        vec![
            ProfInfo {
                name: "RNG_KERNEL".into(),
                queue: "Main".into(),
                t_queued: 10,
                t_submit: 11,
                t_start: 12,
                t_end: 40,
            },
            ProfInfo {
                name: "READ_BUFFER".into(),
                queue: "Comms".into(),
                t_queued: 1,
                t_submit: 2,
                t_start: 3,
                t_end: 50,
            },
        ]
    }

    #[test]
    fn roundtrip() {
        let tsv = to_tsv(&sample());
        let parsed = parse_tsv(&tsv).unwrap();
        assert_eq!(parsed.len(), 2);
        // to_tsv sorts by start: READ_BUFFER first
        assert_eq!(parsed[0].name, "READ_BUFFER");
        assert_eq!(parsed[0].t_start, 3);
        assert_eq!(parsed[1].queue, "Main");
        assert_eq!(parsed[1].t_end, 40);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse_tsv("nope\n").is_err());
    }

    #[test]
    fn rejects_ragged_rows() {
        let bad = format!("{EXPORT_HEADER}\nq\t1\t2\n");
        assert!(parse_tsv(&bad).is_err());
    }

    #[test]
    fn rejects_bad_numbers() {
        let bad = format!("{EXPORT_HEADER}\nq\tx\t2\tname\n");
        assert!(parse_tsv(&bad).is_err());
    }

    #[test]
    fn adversarial_names_roundtrip() {
        // Regression: names containing \t or \n used to be written
        // verbatim, producing a table parse_tsv rejected (ragged rows)
        // or silently mis-columned.
        let infos = vec![
            ProfInfo {
                name: "evil\tname\nwith\rall\\of them".into(),
                queue: "q\tueue".into(),
                t_queued: 1,
                t_submit: 1,
                t_start: 1,
                t_end: 2,
            },
            ProfInfo {
                name: "plain".into(),
                queue: "also plain".into(),
                t_queued: 3,
                t_submit: 3,
                t_start: 3,
                t_end: 4,
            },
        ];
        let tsv = to_tsv(&infos);
        // One header + one line per record, regardless of name content.
        assert_eq!(tsv.lines().count(), 3);
        let parsed = parse_tsv(&tsv).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].name, "evil\tname\nwith\rall\\of them");
        assert_eq!(parsed[0].queue, "q\tueue");
        assert_eq!(parsed[1].name, "plain");
    }

    #[test]
    fn rejects_unknown_escape() {
        let bad = format!("{EXPORT_HEADER}\nq\\x\t1\t2\tname\n");
        let err = parse_tsv(&bad).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_end_before_start_with_line_number() {
        // Regression: records with t_end < t_start were accepted and
        // underflowed downstream u64 subtraction.
        let bad = format!("{EXPORT_HEADER}\nq\t1\t2\tok\nq\t50\t40\tbad\n");
        let err = parse_tsv(&bad).unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("t_end (40) < t_start (50)"), "{err}");
    }

    #[test]
    fn rejects_timestamps_beyond_sort_key_range() {
        // Regression: timestamps ≥ 2^63 wrap the overlap sweep's packed
        // (t << 1) sort key.
        let big = (1u64 << 63) + 5;
        let bad = format!("{EXPORT_HEADER}\nq\t{big}\t{}\tname\n", u64::MAX);
        let err = parse_tsv(&bad).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("exceeds 2^63-1"), "{err}");
        // The boundary value itself is fine.
        let ok = format!("{EXPORT_HEADER}\nq\t0\t{MAX_TIMESTAMP}\tname\n");
        assert_eq!(parse_tsv(&ok).unwrap().len(), 1);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cf4rs_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prof.tsv");
        write_file(&sample(), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(parse_tsv(&text).unwrap().len(), 2);
        std::fs::remove_file(path).ok();
    }
}
