//! Text summary (`ccl_prof_get_summary`) — the Fig. 3 report.

use super::info::{
    sort_aggs, sort_overlaps, AggSort, OverlapSort, ProfAgg, ProfOverlap, SortDir,
};
use super::overlap::QueueUtil;

/// Render the profiling summary in the paper's Fig. 3 layout, extended
/// with per-queue utilisation so a starved queue can't hide behind the
/// global "time spent in device" figure.
pub fn render(
    aggs: &[ProfAgg],
    overlaps: &[ProfOverlap],
    queue_utils: &[QueueUtil],
    effective_ns: u64,
    elapsed_ns: u64,
    agg_sort: (AggSort, SortDir),
    ov_sort: (OverlapSort, SortDir),
) -> String {
    let mut aggs = aggs.to_vec();
    sort_aggs(&mut aggs, agg_sort.0, agg_sort.1);
    let mut overlaps = overlaps.to_vec();
    sort_overlaps(&mut overlaps, ov_sort.0, ov_sort.1);

    let sec = |ns: u64| ns as f64 * 1e-9;
    let mut s = String::new();
    s.push_str("\n Aggregate times by event  :\n");
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );
    s.push_str(
        "   | Event name                     | Rel. time (%) | Abs. time (s)  |\n",
    );
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );
    let mut total_abs = 0u64;
    for a in &aggs {
        s.push_str(&format!(
            "   | {:<30} | {:>13.4} | {:>14.4e} |\n",
            truncate(&a.name, 30),
            a.rel_time * 100.0,
            sec(a.abs_time),
        ));
        total_abs += a.abs_time;
    }
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );
    s.push_str(&format!(
        "   |                                |         Total | {:>14.4e} |\n",
        sec(total_abs)
    ));
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );

    s.push_str(" Event overlaps            :\n");
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );
    s.push_str(
        "   | Event 1                | Event 2                | Overlap (s)   |\n",
    );
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );
    let mut total_ov = 0u64;
    for o in &overlaps {
        s.push_str(&format!(
            "   | {:<22} | {:<22} | {:>13.4e} |\n",
            truncate(&o.event1, 22),
            truncate(&o.event2, 22),
            sec(o.duration),
        ));
        total_ov += o.duration;
    }
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );
    s.push_str(&format!(
        "   |                        |                  Total | {:>13.4e} |\n",
        sec(total_ov)
    ));
    s.push_str(
        "   ------------------------------------------------------------------\n",
    );

    s.push_str(&format!(
        " Tot. of all events (eff.) : {:e}s\n",
        sec(effective_ns)
    ));
    s.push_str(&format!(" Total elapsed time        : {:e}s\n", sec(elapsed_ns)));
    if elapsed_ns > 0 {
        s.push_str(&format!(
            " Time spent in device      : {:.2}%\n",
            sec(effective_ns) / sec(elapsed_ns) * 100.0
        ));
    }
    if !queue_utils.is_empty() {
        s.push_str(" Per-queue utilisation     :\n");
        for q in queue_utils {
            s.push_str(&format!(
                "   {:<22} {:>6.2}% busy ({:.4e}s of {:.4e}s window)\n",
                truncate(&q.queue, 22),
                q.utilisation() * 100.0,
                sec(q.busy),
                sec(q.window()),
            ));
        }
    }
    s
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n.saturating_sub(1)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_figure3_shape() {
        let aggs = vec![
            ProfAgg {
                name: "READ_BUFFER".into(),
                abs_time: 6_652_100_000,
                rel_time: 0.890810,
                count: 10000,
            },
            ProfAgg {
                name: "RNG_KERNEL".into(),
                abs_time: 815_400_000,
                rel_time: 0.109182,
                count: 9999,
            },
            ProfAgg {
                name: "INIT_KERNEL".into(),
                abs_time: 60_000,
                rel_time: 0.000008,
                count: 1,
            },
        ];
        let ovs = vec![ProfOverlap {
            event1: "RNG_KERNEL".into(),
            event2: "READ_BUFFER".into(),
            duration: 15_790_000,
        }];
        let out = render(
            &aggs,
            &ovs,
            &[],
            7_451_659_000,
            9_054_619_000,
            (AggSort::Time, SortDir::Desc),
            (OverlapSort::Duration, SortDir::Desc),
        );
        assert!(out.contains("READ_BUFFER"));
        assert!(out.contains("89.0810"));
        assert!(out.contains("RNG_KERNEL"));
        assert!(out.contains("Tot. of all events (eff.)"));
        assert!(out.contains("Total elapsed time"));
        // READ_BUFFER (89%) sorted above RNG_KERNEL (10.9%)
        let ri = out.find("READ_BUFFER").unwrap();
        let ki = out.find("RNG_KERNEL").unwrap();
        assert!(ri < ki);
    }

    #[test]
    fn name_sort_asc_reorders() {
        let aggs = vec![
            ProfAgg { name: "Z".into(), abs_time: 100, rel_time: 0.9, count: 1 },
            ProfAgg { name: "A".into(), abs_time: 10, rel_time: 0.1, count: 1 },
        ];
        let out = render(
            &aggs,
            &[],
            &[],
            110,
            200,
            (AggSort::Name, SortDir::Asc),
            (OverlapSort::Name, SortDir::Asc),
        );
        assert!(out.find("| A").unwrap() < out.find("| Z").unwrap());
    }

    #[test]
    fn per_queue_utilisation_lines_follow_the_global_figure() {
        let utils = vec![
            QueueUtil {
                queue: "comms".into(),
                busy: 400,
                t_first: 0,
                t_last: 1000,
                busy_intervals: vec![(0, 400)],
            },
            QueueUtil {
                queue: "main".into(),
                busy: 1000,
                t_first: 0,
                t_last: 1000,
                busy_intervals: vec![(0, 1000)],
            },
        ];
        let out = render(
            &[],
            &[],
            &utils,
            1000,
            2000,
            (AggSort::Time, SortDir::Desc),
            (OverlapSort::Duration, SortDir::Desc),
        );
        assert!(out.contains("Per-queue utilisation"), "{out}");
        assert!(out.contains("comms"), "{out}");
        assert!(out.contains("40.00% busy"), "{out}");
        assert!(out.contains("100.00% busy"), "{out}");
        // The starved queue is listed even though the global device-time
        // figure (50%) says nothing about it.
        let gi = out.find("Time spent in device").unwrap();
        let qi = out.find("Per-queue utilisation").unwrap();
        assert!(gi < qi, "{out}");
    }

    #[test]
    fn truncates_long_names() {
        assert_eq!(truncate("short", 30), "short");
        let long = "x".repeat(64);
        assert_eq!(truncate(&long, 30).chars().count(), 30);
    }
}
