//! Platforms module (paper §4.4): works with the *set* of available
//! platforms, unlike the platform wrapper which works with one.

use crate::rawcl;
use crate::rawcl::types::{PlatformId, PlatformInfo};

use super::device::Device;
use super::errors::{check, CclResult};

/// Info snapshot of one platform plus its devices.
pub struct PlatformDesc {
    pub id: PlatformId,
    pub name: String,
    pub vendor: String,
    pub version: String,
    pub devices: Vec<Device>,
}

/// `ccl_platforms_new`: snapshot all platforms in the system.
pub fn all() -> CclResult<Vec<PlatformDesc>> {
    let mut n = 0u32;
    check(rawcl::get_platform_ids(0, None, Some(&mut n)), "counting platforms")?;
    let mut ids = vec![PlatformId(0); n as usize];
    check(rawcl::get_platform_ids(n, Some(&mut ids), None), "listing platforms")?;
    let mut out = Vec::with_capacity(ids.len());
    for id in ids {
        let get = |param: PlatformInfo| -> CclResult<String> {
            let mut buf = Vec::new();
            check(
                rawcl::get_platform_info(id, param, Some(&mut buf), None),
                "querying platform info",
            )?;
            Ok(String::from_utf8_lossy(&buf).into_owned())
        };
        let name = get(PlatformInfo::Name)?;
        let vendor = get(PlatformInfo::Vendor)?;
        let version = get(PlatformInfo::Version)?;
        let devices = crate::rawcl::platform::platform_devices(id)
            .unwrap_or_default()
            .into_iter()
            .map(|d| Device { id: d.id })
            .collect();
        out.push(PlatformDesc { id, name, vendor, version, devices });
    }
    Ok(out)
}

/// Number of platforms (`ccl_platforms_count`).
pub fn count() -> CclResult<usize> {
    Ok(all()?.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_has_both_platforms() {
        let ps = all().unwrap();
        assert_eq!(ps.len(), 2);
        assert!(ps[0].name.contains("PJRT"));
        assert!(ps[1].name.contains("SimCL"));
        assert_eq!(ps[0].devices.len(), 1);
        assert_eq!(ps[1].devices.len(), 2);
    }

    #[test]
    fn count_matches() {
        assert_eq!(count().unwrap(), 2);
    }
}
