//! Device wrapper (`CCLDevice`): typed info queries.
//!
//! Devices are process-lifetime objects in the substrate, so the wrapper
//! is a cheap `Copy` handle with typed accessors replacing the raw
//! size/data query dance (compare `rawcl::get_device_info`).

use crate::rawcl::device::{decode, get_device_info};
use crate::rawcl::error::CL_SUCCESS;
use crate::rawcl::profile::BackendKind;
use crate::rawcl::types::{DeviceId, DeviceInfo, DeviceType};

use super::errors::{CclError, CclResult};

/// Wrapper for one compute device.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub struct Device {
    pub(crate) id: DeviceId,
}

impl Device {
    /// Wrap a raw device id (validating it exists).
    pub fn from_id(id: DeviceId) -> CclResult<Self> {
        if crate::rawcl::device::device(id).is_none() {
            return Err(CclError::framework(format!("no such device: {id:?}")));
        }
        Ok(Self { id })
    }

    /// All devices in the system, across platforms.
    pub fn all() -> Vec<Device> {
        crate::rawcl::device::devices()
            .iter()
            .map(|d| Device { id: d.id })
            .collect()
    }

    /// The raw id — always accessible, like cf4ocl's unwrap functions.
    pub fn id(&self) -> DeviceId {
        self.id
    }

    fn info_bytes(&self, param: DeviceInfo) -> CclResult<Vec<u8>> {
        let mut buf = Vec::new();
        let st = get_device_info(self.id, param, Some(&mut buf), None);
        if st != CL_SUCCESS {
            return Err(CclError::from_status(st, format!("querying {param:?}")));
        }
        Ok(buf)
    }

    /// Device name (`ccl_device_get_info_array(dev, CL_DEVICE_NAME, ...)`).
    pub fn name(&self) -> CclResult<String> {
        Ok(decode::as_string(&self.info_bytes(DeviceInfo::Name)?))
    }

    pub fn vendor(&self) -> CclResult<String> {
        Ok(decode::as_string(&self.info_bytes(DeviceInfo::Vendor)?))
    }

    pub fn version(&self) -> CclResult<String> {
        Ok(decode::as_string(&self.info_bytes(DeviceInfo::Version)?))
    }

    pub fn device_type(&self) -> CclResult<DeviceType> {
        Ok(DeviceType(decode::as_u64(&self.info_bytes(DeviceInfo::Type)?)))
    }

    pub fn max_compute_units(&self) -> CclResult<u32> {
        Ok(decode::as_u32(&self.info_bytes(DeviceInfo::MaxComputeUnits)?))
    }

    pub fn max_work_group_size(&self) -> CclResult<usize> {
        Ok(decode::as_u64(&self.info_bytes(DeviceInfo::MaxWorkGroupSize)?) as usize)
    }

    pub fn preferred_wg_multiple(&self) -> CclResult<usize> {
        Ok(decode::as_u64(&self.info_bytes(DeviceInfo::PreferredWorkGroupSizeMultiple)?)
            as usize)
    }

    pub fn max_work_item_dimensions(&self) -> CclResult<u32> {
        Ok(decode::as_u32(&self.info_bytes(DeviceInfo::MaxWorkItemDimensions)?))
    }

    pub fn max_work_item_sizes(&self) -> CclResult<Vec<usize>> {
        Ok(decode::as_usize_vec(&self.info_bytes(DeviceInfo::MaxWorkItemSizes)?))
    }

    pub fn global_mem_size(&self) -> CclResult<u64> {
        Ok(decode::as_u64(&self.info_bytes(DeviceInfo::GlobalMemSize)?))
    }

    pub fn local_mem_size(&self) -> CclResult<u64> {
        Ok(decode::as_u64(&self.info_bytes(DeviceInfo::LocalMemSize)?))
    }

    pub fn max_clock_frequency(&self) -> CclResult<u32> {
        Ok(decode::as_u32(&self.info_bytes(DeviceInfo::MaxClockFrequency)?))
    }

    /// cf4rs extension: which backend runs kernels for this device.
    pub fn backend(&self) -> CclResult<BackendKind> {
        let s = decode::as_string(&self.info_bytes(DeviceInfo::BackendKind)?);
        Ok(if s == "native" { BackendKind::Native } else { BackendKind::Simulated })
    }

    pub fn is_gpu(&self) -> bool {
        self.device_type()
            .map(|t| t.intersects(DeviceType::GPU))
            .unwrap_or(false)
    }

    pub fn is_cpu(&self) -> bool {
        self.device_type()
            .map(|t| t.intersects(DeviceType::CPU))
            .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_queries() {
        let d = Device::from_id(DeviceId(1)).unwrap();
        assert_eq!(d.name().unwrap(), "SimCL GTX 1080");
        assert_eq!(d.max_compute_units().unwrap(), 20);
        assert_eq!(d.preferred_wg_multiple().unwrap(), 32);
        assert!(d.is_gpu());
        assert!(!d.is_cpu());
        assert_eq!(d.backend().unwrap(), BackendKind::Simulated);
    }

    #[test]
    fn native_device_is_cpu() {
        let d = Device::from_id(DeviceId(0)).unwrap();
        assert!(d.is_cpu());
        assert_eq!(d.backend().unwrap(), BackendKind::Native);
        assert!(d.max_work_item_sizes().unwrap().len() == 3);
    }

    #[test]
    fn all_lists_three() {
        assert_eq!(Device::all().len(), 3);
    }

    #[test]
    fn invalid_id_rejected() {
        assert!(Device::from_id(DeviceId(9)).is_err());
    }
}
