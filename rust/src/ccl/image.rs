//! Image wrapper (`CCLImage`, the other concrete `CCLMemObj` of Fig. 1).

use crate::rawcl;
use crate::rawcl::image::{ImageDesc, ImageFormat};
use crate::rawcl::types::{EventH, MemFlags, MemH};

use super::context::Context;
use super::errors::{check, CclResult};
use super::event::Event;
use super::queue::Queue;
use super::wrapper::LiveToken;

/// Owning wrapper for a 2D image.
pub struct Image {
    h: MemH,
    desc: ImageDesc,
    _live: LiveToken,
}

impl Image {
    /// `ccl_image_new` (2D).
    pub fn new_2d(
        ctx: &Context,
        flags: MemFlags,
        format: ImageFormat,
        width: usize,
        height: usize,
    ) -> CclResult<Self> {
        let desc = ImageDesc { format, width, height };
        let mut st = 0;
        let h = rawcl::create_image2d(ctx.handle(), flags, desc, None, &mut st);
        check(st, "creating 2D image")?;
        Ok(Self { h, desc, _live: LiveToken::new() })
    }

    /// Create + initialise from packed host pixels.
    pub fn from_pixels(
        ctx: &Context,
        flags: MemFlags,
        format: ImageFormat,
        width: usize,
        height: usize,
        pixels: &[u8],
    ) -> CclResult<Self> {
        let desc = ImageDesc { format, width, height };
        let mut st = 0;
        let h = rawcl::create_image2d(
            ctx.handle(),
            flags | MemFlags::COPY_HOST_PTR,
            desc,
            Some(pixels),
            &mut st,
        );
        check(st, "creating initialised 2D image")?;
        Ok(Self { h, desc, _live: LiveToken::new() })
    }

    pub fn handle(&self) -> MemH {
        self.h
    }

    pub fn desc(&self) -> ImageDesc {
        self.desc
    }

    /// Blocking rectangular read (`ccl_image_enqueue_read`); `dst`
    /// receives tightly packed rows.
    pub fn enqueue_read(
        &self,
        queue: &Queue,
        origin: (usize, usize),
        region: (usize, usize),
        dst: &mut [u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_read_image(
                queue.handle(),
                self.h,
                true,
                origin,
                region,
                dst,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing image read",
        )?;
        Ok(queue.track_kernel_event(evt))
    }

    /// Blocking rectangular write (`ccl_image_enqueue_write`).
    pub fn enqueue_write(
        &self,
        queue: &Queue,
        origin: (usize, usize),
        region: (usize, usize),
        src: &[u8],
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_write_image(
                queue.handle(),
                self.h,
                true,
                origin,
                region,
                src,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing image write",
        )?;
        Ok(queue.track_kernel_event(evt))
    }

    /// Fill a rectangle with one pixel (`ccl_image_enqueue_fill`).
    pub fn enqueue_fill(
        &self,
        queue: &Queue,
        pixel: &[u8],
        origin: (usize, usize),
        region: (usize, usize),
        wait: &[Event],
    ) -> CclResult<Event> {
        let hs: Vec<EventH> = wait.iter().map(|e| e.handle()).collect();
        let mut evt = EventH::NULL;
        check(
            rawcl::enqueue_fill_image(
                queue.handle(),
                self.h,
                pixel,
                origin,
                region,
                &hs,
                Some(&mut evt),
            ),
            "enqueueing image fill",
        )?;
        Ok(queue.track_kernel_event(evt))
    }
}

impl Drop for Image {
    fn drop(&mut self) {
        rawcl::release_image(self.h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_roundtrip_through_queue() {
        let ctx = Context::new_gpu().unwrap();
        let dev = ctx.device(0).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let img =
            Image::new_2d(&ctx, MemFlags::READ_WRITE, ImageFormat::R_U8, 16, 8).unwrap();
        assert_eq!(img.desc().byte_len(), 128);

        // fill a band, write a block, read back the composition
        img.enqueue_fill(&q, &[0xAA], (0, 0), (16, 8), &[]).unwrap();
        img.enqueue_write(&q, (4, 2), (2, 2), &[1, 2, 3, 4], &[]).unwrap();
        let mut out = vec![0u8; 16];
        let ev = img.enqueue_read(&q, (4, 1), (4, 4), &mut out, &[]).unwrap();
        ev.set_name("IMG_READ").unwrap();
        // row 0 of the read (image row 1) is still the fill value
        assert_eq!(&out[0..4], &[0xAA; 4]);
        // rows 1-2 contain the written block at columns 0-1
        assert_eq!(&out[4..6], &[1, 2]);
        assert_eq!(&out[8..10], &[3, 4]);
        assert_eq!(&out[6..8], &[0xAA; 2]);
        q.finish().unwrap();
    }

    #[test]
    fn rgba_f32_pixels() {
        let ctx = Context::new_gpu().unwrap();
        let dev = ctx.device(0).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let px: Vec<u8> = [1.0f32, 0.5, 0.25, 1.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let img = Image::new_2d(&ctx, MemFlags::READ_WRITE, ImageFormat::RGBA_F32, 4, 4)
            .unwrap();
        img.enqueue_fill(&q, &px, (1, 1), (2, 2), &[]).unwrap();
        let mut out = vec![0u8; 16];
        img.enqueue_read(&q, (2, 2), (1, 1), &mut out, &[]).unwrap();
        assert_eq!(out, px);
    }

    #[test]
    fn from_pixels_initialises() {
        let ctx = Context::new_gpu().unwrap();
        let dev = ctx.device(0).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let data: Vec<u8> = (0..64).collect();
        let img = Image::from_pixels(
            &ctx,
            MemFlags::READ_ONLY,
            ImageFormat::R_U8,
            8,
            8,
            &data,
        )
        .unwrap();
        let mut out = vec![0u8; 8];
        img.enqueue_read(&q, (0, 3), (8, 1), &mut out, &[]).unwrap();
        assert_eq!(out, (24..32).collect::<Vec<u8>>());
    }

    #[test]
    fn size_mismatches_are_errors() {
        let ctx = Context::new_gpu().unwrap();
        let dev = ctx.device(0).unwrap();
        let q = Queue::new_profiled(&ctx, dev).unwrap();
        let img =
            Image::new_2d(&ctx, MemFlags::READ_WRITE, ImageFormat::R_U8, 4, 4).unwrap();
        let mut small = vec![0u8; 3];
        assert!(img.enqueue_read(&q, (0, 0), (2, 2), &mut small, &[]).is_err());
        assert!(img.enqueue_write(&q, (0, 0), (2, 2), &[0u8; 5], &[]).is_err());
        assert!(img.enqueue_fill(&q, &[0u8; 2], (0, 0), (1, 1), &[]).is_err());
    }
}
