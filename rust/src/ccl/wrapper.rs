//! Common wrapper behaviour (the paper's abstract `CCLWrapper` class).
//!
//! Every owning framework wrapper registers itself here on construction
//! and deregisters on drop, giving [`memcheck`] — the Rust analogue of
//! `ccl_wrapper_memcheck()` which the paper's example asserts before
//! exit (listing S2, line 354).

use std::sync::atomic::{AtomicIsize, Ordering};

static LIVE_WRAPPERS: AtomicIsize = AtomicIsize::new(0);

/// RAII token counted by [`memcheck`]. Owning wrappers hold one.
#[derive(Debug)]
pub struct LiveToken(());

impl LiveToken {
    pub fn new() -> Self {
        LIVE_WRAPPERS.fetch_add(1, Ordering::Relaxed);
        Self(())
    }
}

impl Default for LiveToken {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for LiveToken {
    fn drop(&mut self) {
        LIVE_WRAPPERS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// True iff no owning framework wrappers are alive.
///
/// Like `ccl_wrapper_memcheck()`, this is a debugging aid: call it after
/// destroying everything you created to verify nothing leaked.
pub fn memcheck() -> bool {
    LIVE_WRAPPERS.load(Ordering::Relaxed) == 0
}

/// Current number of live wrappers (diagnostics).
pub fn live_wrappers() -> isize {
    LIVE_WRAPPERS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_counts_up_and_down() {
        let before = live_wrappers();
        let t1 = LiveToken::new();
        let t2 = LiveToken::new();
        assert_eq!(live_wrappers(), before + 2);
        drop(t1);
        assert_eq!(live_wrappers(), before + 1);
        drop(t2);
        assert_eq!(live_wrappers(), before);
    }
}
