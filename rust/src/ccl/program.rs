//! Program wrapper (`CCLProgram`).
//!
//! Compare (paper listing S2, lines 199–212):
//!
//! ```no_run
//! # use cf4rs::ccl::{Context, Program};
//! # let ctx = Context::new_gpu().unwrap();
//! let prg = Program::new_from_source_files(
//!     &ctx,
//!     &["artifacts/init_n4096.hlo.txt", "artifacts/rng_n4096.hlo.txt"],
//! ).unwrap();
//! prg.build().unwrap();
//! let kinit = prg.kernel("prng_init").unwrap();
//! ```
//!
//! with the ~50-line load/create/build/log dance of listing S1
//! (`examples/rng_raw.rs`).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::rawcl;
use crate::rawcl::error::CL_BUILD_PROGRAM_FAILURE;
use crate::rawcl::types::{KernelH, ProgramH};
use crate::runtime::{hlogen, ArtifactKind, Manifest};

use super::context::Context;
use super::errors::{check, CclError, CclResult};
use super::kernel::Kernel;
use super::wrapper::LiveToken;

/// Owning wrapper for a program.
pub struct Program {
    h: ProgramH,
    /// Kernels created through [`kernel`](Self::kernel) — owned by the
    /// program wrapper, mirroring `ccl_program_get_kernel` semantics.
    kernels: Mutex<HashMap<String, KernelH>>,
    _live: LiveToken,
}

impl Program {
    /// `ccl_program_new_from_sources`: in-memory HLO texts.
    pub fn new_from_sources(ctx: &Context, sources: &[String]) -> CclResult<Self> {
        let mut st = 0;
        let h = rawcl::create_program_with_source(ctx.handle(), sources, &mut st);
        check(st, "creating program from source")?;
        Ok(Self { h, kernels: Mutex::new(HashMap::new()), _live: LiveToken::new() })
    }

    /// `ccl_program_new_from_source_files`: loads each file for you —
    /// functionality OpenCL itself lacks (paper §6.1).
    pub fn new_from_source_files<P: AsRef<Path>>(
        ctx: &Context,
        paths: &[P],
    ) -> CclResult<Self> {
        let mut sources = Vec::with_capacity(paths.len());
        for p in paths {
            let p = p.as_ref();
            let text = std::fs::read_to_string(p).map_err(|e| {
                CclError::artifacts(format!("reading kernel file {}: {e}", p.display()))
            })?;
            sources.push(text);
        }
        Self::new_from_sources(ctx, &sources)
    }

    /// cf4rs extension: create from named artifacts (the usual path for
    /// applications built on the AOT pipeline). Names the manifest does
    /// not cover fall back to the HLO generator when they follow the
    /// artifact naming convention (`init_n4096`, `rngk16_n65536`, ...).
    pub fn new_from_artifacts(ctx: &Context, names: &[&str]) -> CclResult<Self> {
        let mut sources = Vec::with_capacity(names.len());
        for n in names {
            let text = hlogen::resolve_named_source(n).map_err(|e| {
                CclError::artifacts(format!("resolving artifact {n:?}: {e}"))
            })?;
            sources.push(text);
        }
        Self::new_from_sources(ctx, &sources)
    }

    /// cf4rs extension: pick device programs by kind + problem size.
    ///
    /// Prefers AOT artifacts from the manifest; any (kind, n) the
    /// manifest does not cover — including the no-manifest case of a
    /// fresh checkout — is satisfied by the HLO generator
    /// ([`crate::runtime::hlogen`]), so programs exist for *every*
    /// problem size. Exception: [`ArtifactKind::RngMulti`] resolves
    /// only through the manifest here (its step count is baked in at
    /// lowering time); use [`new_from_artifacts`]
    /// (Self::new_from_artifacts) with a `rngk<steps>_n<n>` name to
    /// generate a fused module at a chosen k.
    pub fn new_from_kinds(
        ctx: &Context,
        kinds: &[(ArtifactKind, usize)],
    ) -> CclResult<Self> {
        let mut sources = Vec::with_capacity(kinds.len());
        for (kind, n) in kinds {
            let text = if *kind == ArtifactKind::RngMulti {
                // Fused artifacts bake the step count in, so (kind, n)
                // alone cannot parameterise a generated module. Keep the
                // pre-generator behavior (manifest lookup, whatever k it
                // was lowered with) and point callers at the k-carrying
                // named form otherwise.
                let man = Manifest::discover()
                    .map_err(|e| CclError::artifacts(format!("{e:#}")))?;
                let art = man.find(*kind, *n).ok_or_else(|| {
                    CclError::artifacts(format!(
                        "no fused artifact of kind {kind} with n={n}; use \
                         new_from_artifacts(&[\"rngk<steps>_n{n}\"]) to pick \
                         (or generate) a specific step count"
                    ))
                })?;
                std::fs::read_to_string(&art.path).map_err(|e| {
                    CclError::artifacts(format!(
                        "reading artifact {}: {e}",
                        art.path.display()
                    ))
                })?
            } else {
                let spec = hlogen::GenSpec::new(*kind, *n);
                hlogen::resolve_source(&spec).map_err(|e| {
                    CclError::artifacts(format!("resolving {kind} (n={n}) source: {e}"))
                })?
            };
            sources.push(text);
        }
        Self::new_from_sources(ctx, &sources)
    }

    /// cf4rs extension: generate + load modules for explicit generator
    /// specs. Needed by kernels whose geometry `(kind, n)` alone cannot
    /// carry — a sharded init's `gid_offset`, a stencil grid's width, a
    /// matmul's inner dimension.
    pub fn new_from_specs(ctx: &Context, specs: &[hlogen::GenSpec]) -> CclResult<Self> {
        let mut sources = Vec::with_capacity(specs.len());
        for s in specs {
            sources.push(hlogen::resolve_source(s).map_err(|e| {
                CclError::artifacts(format!("resolving generator spec {s:?}: {e}"))
            })?);
        }
        Self::new_from_sources(ctx, &sources)
    }

    pub fn handle(&self) -> ProgramH {
        self.h
    }

    /// `ccl_program_build(prg, NULL, &err)`.
    pub fn build(&self) -> CclResult<()> {
        self.build_with_options("")
    }

    /// Build with OpenCL-style options (`-Dk=16`).
    pub fn build_with_options(&self, options: &str) -> CclResult<()> {
        let st = rawcl::build_program(self.h, None, options);
        if st == CL_BUILD_PROGRAM_FAILURE {
            // Keep the code; the caller typically prints the build log
            // (paper listing S2, lines 206–212).
            return Err(CclError::from_status(st, "building program"));
        }
        check(st, "building program")
    }

    /// `ccl_program_get_build_log`.
    pub fn build_log(&self) -> CclResult<String> {
        let mut log = String::new();
        check(rawcl::get_program_build_log(self.h, &mut log), "querying build log")?;
        Ok(log)
    }

    /// Kernel names available after a successful build.
    pub fn kernel_names(&self) -> CclResult<Vec<String>> {
        let mut names = Vec::new();
        check(
            rawcl::get_program_kernel_names(self.h, &mut names),
            "querying kernel names",
        )?;
        Ok(names)
    }

    /// `ccl_program_get_kernel`: a kernel owned by the program (cached —
    /// repeated calls return the same kernel object).
    pub fn kernel(&self, name: &str) -> CclResult<Kernel> {
        let mut cache = self.kernels.lock().unwrap();
        if let Some(&h) = cache.get(name) {
            return Ok(Kernel::non_owning(h));
        }
        let mut st = 0;
        let h = rawcl::create_kernel(self.h, name, &mut st);
        check(st, &format!("creating kernel {name:?}"))?;
        cache.insert(name.to_string(), h);
        Ok(Kernel::non_owning(h))
    }
}

impl Drop for Program {
    fn drop(&mut self) {
        for (_, h) in self.kernels.lock().unwrap().drain() {
            rawcl::release_kernel(h);
        }
        rawcl::release_program(self.h);
    }
}
