//! # cf4rs — a Rust framework for heterogeneous compute
//!
//! Reproduction of *cf4ocl: a C framework for OpenCL* (Fachada, Lopes,
//! Martins & Rosa, Science of Computer Programming, 2017) on a
//! Rust + JAX + Pallas / PJRT stack.
//!
//! The crate is organised in the same two components as the paper
//! (§3.1): the **library** and the **utilities**, plus the substrate the
//! library wraps:
//!
//! * [`rawcl`] — the low-level, verbose, C-style compute host API that
//!   plays the role OpenCL plays in the paper (substrate; every call
//!   returns an integer status code and takes out-params).
//! * [`runtime`] — the PJRT bridge: loads AOT-lowered HLO artifacts and
//!   executes them on the CPU PJRT client (the "native" device).
//! * [`ccl`] — the framework itself (the paper's contribution): wrapper
//!   classes, device selection, error management and integrated
//!   multi-queue profiling — plus [`ccl::v2`], the fluent typed high
//!   tier (session facade, generic `Buffer<T>`, validated launch
//!   builders, implicit event-dependency chaining) over the same
//!   wrappers.
//! * [`backend`] — the unified execution layer: one `Backend` trait
//!   (compile, alloc, enqueue, wait, timestamps) over both substrates
//!   (`SimBackend` on the simulated devices, `PjrtBackend` on the PJRT
//!   runtime), discovered through a `BackendRegistry` that the `ccl`
//!   device-selection filters select over. New substrates (GPU PJRT
//!   plugins, remote workers) plug in by implementing the trait and
//!   registering — no caller changes.
//! * [`workload`] — the workload-agnostic execution contract: a
//!   [`workload::Workload`] trait (kernels / shard / plan / merge /
//!   verify) with five implementations (PRNG, SAXPY, tree reduction,
//!   2-D 5-point stencil, tiled matmul) and drivers that run any of
//!   them — bit-identically — through the raw substrate, the `ccl` v1
//!   tier, the `ccl::v2` session tier and the sharded scheduler.
//! * [`coordinator`] — the double-buffered streaming pipeline of §5, the
//!   PRNG service built on it, the multi-device work-stealing scheduler
//!   that shards any workload across every registered backend, and the
//!   persistent multi-client [`coordinator::service::ComputeService`]
//!   that micro-batches concurrent requests into shared scheduler
//!   dispatches.
//! * [`metrics`] — live telemetry: lock-free counters/gauges and
//!   log-bucketed mergeable histograms (quantile queries, sliding
//!   window) that instrument the service and scheduler hot paths and
//!   feed the [`coordinator::adaptive`] controller (adaptive batch
//!   window, throughput-proportional shard planning).
//! * [`analysis`] — static analysis over recorded command graphs: a
//!   lightweight recorder threaded through the rawcl/ccl/v2/backend
//!   enqueue paths, a happens-before analyzer (vector clocks per queue),
//!   and typed lint findings (data races, read-before-write, dependency
//!   cycles, dead writes, unwaited host reads) surfaced via
//!   `Session::check()` and the `cf4rs lint` CLI.
//! * [`trace`] — end-to-end request tracing: a lock-light span sink
//!   (relaxed-atomic disabled fast path, ring buffer) threaded through
//!   edge, service, scheduler and the backend boundary, assembled into
//!   per-request span trees with device Prof slices grafted in, and
//!   exported as Chrome trace-event JSON for Perfetto.
//! * [`harness`] — benchmark drivers that regenerate every table and
//!   figure of the paper's evaluation (§6), plus the backend-comparison
//!   table.
//! * [`utils`] — the three command-line utilities (`devinfo`, `cclc`,
//!   `plot_events`).

pub mod analysis;
pub mod backend;
pub mod ccl;
pub mod coordinator;
pub mod harness;
pub mod metrics;
pub mod rawcl;
pub mod runtime;
pub mod trace;
pub mod utils;
pub mod workload;
