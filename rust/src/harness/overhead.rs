//! §6.2 / Fig. 4 overhead study: cf4rs vs raw-substrate implementation
//! across the paper's parameter sweep.
//!
//! Protocol (paper Fig. 4 caption): for each (device, n, i) run each
//! implementation `runs` times, drop the fastest and slowest run, and
//! average the rest; the reported value is the ratio of mean run times
//! with min/max error bars. A ratio > 1 means the cf4rs realisation took
//! longer (framework overhead); ≈ 1 means the overhead is masked by
//! device work.
//!
//! Scaling note (EXPERIMENTS.md): the paper sweeps n = 2^12..2^24 and
//! i = 10^2..10^4 on real GPUs. On this substrate the same *shape* is
//! produced with n = 2^12..2^20 (the artifact ladder) and
//! i = {10, 32, 100}, because the simulated device executes reference
//! kernels on the host: larger i still multiplies the per-iteration
//! profiling/event cost (exposing overhead) and larger n still grows
//! device work faster than framework work (masking it).

use std::time::Duration;

use crate::coordinator::{run_ccl, run_raw, RngConfig, Sink};
use crate::runtime::Manifest;

/// One cell of the Fig. 4 sweep.
#[derive(Debug, Clone)]
pub struct Cell {
    pub device_index: u32,
    pub device_name: &'static str,
    pub n: usize,
    pub iters: usize,
    /// Mean run time of the raw realisation (min/max-trimmed), seconds.
    pub t_raw: f64,
    /// Mean run time of the cf4rs realisation, seconds.
    pub t_ccl: f64,
    /// Overhead ratio t_ccl / t_raw (the Fig. 4 y-value; > 1 = slower).
    pub ratio: f64,
    /// Error bars: (min, max) observed per-run ratio.
    pub ratio_min: f64,
    pub ratio_max: f64,
}

/// Sweep parameters.
pub struct SweepOpts {
    pub devices: Vec<(u32, &'static str)>,
    pub sizes: Vec<usize>,
    pub iters: Vec<usize>,
    pub runs: usize,
}

impl SweepOpts {
    /// Full sweep (several minutes).
    pub fn paper() -> Self {
        let sizes = Manifest::discover()
            .map(|m| m.rng_sizes())
            .unwrap_or_else(|_| vec![4096, 65536]);
        Self {
            devices: vec![(1, "gtx1080sim"), (2, "hd7970sim")],
            sizes,
            iters: vec![10, 32, 100],
            runs: 10,
        }
    }

    /// Reduced sweep for CI / `--quick`.
    pub fn quick() -> Self {
        Self {
            devices: vec![(1, "gtx1080sim")],
            sizes: vec![4096, 65536],
            iters: vec![4, 16],
            runs: 4,
        }
    }
}

fn trimmed_mean(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let trimmed: &[f64] = if xs.len() > 2 { &xs[1..xs.len() - 1] } else { &xs };
    trimmed.iter().sum::<f64>() / trimmed.len() as f64
}

fn time_runs(
    runs: usize,
    mut run_once: impl FnMut() -> Result<Duration, String>,
) -> Result<Vec<f64>, String> {
    let mut out = Vec::with_capacity(runs);
    for _ in 0..runs {
        out.push(run_once()?.as_secs_f64());
    }
    Ok(out)
}

/// Run one sweep cell.
pub fn measure_cell(
    device_index: u32,
    device_name: &'static str,
    n: usize,
    iters: usize,
    runs: usize,
) -> Result<Cell, String> {
    let mk_cfg = || {
        let mut c = RngConfig::new(n, iters);
        c.device_index = device_index;
        c.profile = true; // the paper's worst case: profiling on
        c.sink = Sink::Discard; // stdout > /dev/null
        c
    };
    // Time the *whole* run — including the profiling analysis, which the
    // paper explicitly calls out as cf4ocl's worst case (the overlap
    // calculation runs over every recorded event).
    let raw_times = time_runs(runs, || {
        let t0 = std::time::Instant::now();
        run_raw(&mk_cfg())?;
        Ok(t0.elapsed())
    })?;
    let ccl_times = time_runs(runs, || {
        let t0 = std::time::Instant::now();
        run_ccl(&mk_cfg()).map_err(|e| e.to_string())?;
        Ok(t0.elapsed())
    })?;
    let t_raw = trimmed_mean(raw_times.clone());
    let t_ccl = trimmed_mean(ccl_times.clone());
    // Error bars from extreme per-mean ratios.
    let rmin = ccl_times.iter().cloned().fold(f64::MAX, f64::min)
        / raw_times.iter().cloned().fold(f64::MIN, f64::max);
    let rmax = ccl_times.iter().cloned().fold(f64::MIN, f64::max)
        / raw_times.iter().cloned().fold(f64::MAX, f64::min);
    Ok(Cell {
        device_index,
        device_name,
        n,
        iters,
        t_raw,
        t_ccl,
        ratio: t_ccl / t_raw,
        ratio_min: rmin,
        ratio_max: rmax,
    })
}

/// Run the whole sweep, reporting progress on stderr.
pub fn sweep(opts: &SweepOpts) -> Result<Vec<Cell>, String> {
    let mut cells = Vec::new();
    for &(dev, name) in &opts.devices {
        for &n in &opts.sizes {
            for &iters in &opts.iters {
                eprintln!("  measuring dev={name} n={n} i={iters} ({} runs x2)...", opts.runs);
                cells.push(measure_cell(dev, name, n, iters, opts.runs)?);
            }
        }
    }
    Ok(cells)
}

/// Render the Fig. 4 table (one block per device × i, series over n).
pub fn render(cells: &[Cell]) -> String {
    let mut out = String::new();
    out.push_str("## E3 — §6.2 / Fig. 4 overhead of cf4rs vs raw realisation\n");
    out.push_str("ratio = t_ccl / t_raw (trimmed means; >1 ⇒ framework overhead)\n\n");
    let mut devices: Vec<&str> = cells.iter().map(|c| c.device_name).collect();
    devices.dedup();
    for dev in devices {
        let mut iters: Vec<usize> = cells
            .iter()
            .filter(|c| c.device_name == dev)
            .map(|c| c.iters)
            .collect();
        iters.sort_unstable();
        iters.dedup();
        for i in iters {
            out.push_str(&format!("### {dev}, i = {i}\n"));
            out.push_str(
                "| n | t_raw (s) | t_ccl (s) | ratio | min | max |\n|---|---|---|---|---|---|\n",
            );
            for c in cells.iter().filter(|c| c.device_name == dev && c.iters == i) {
                out.push_str(&format!(
                    "| {} | {:.4} | {:.4} | {:.3} | {:.3} | {:.3} |\n",
                    c.n, c.t_raw, c.t_ccl, c.ratio, c.ratio_min, c.ratio_max
                ));
            }
            out.push('\n');
        }
    }
    out.push_str(&trends(cells));
    out
}

/// E5: the paper's qualitative claims about the overhead trends.
pub fn trends(cells: &[Cell]) -> String {
    let mut out = String::new();
    out.push_str("### E5 — trend checks (paper §6.2 claims)\n");
    // Claim 1: for fixed device+i, overhead falls (or stays flat) as n
    // grows — compare the smallest and largest n.
    let mut ok1 = 0;
    let mut tot1 = 0;
    let keys: Vec<(u32, usize)> = {
        let mut v: Vec<(u32, usize)> =
            cells.iter().map(|c| (c.device_index, c.iters)).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for (dev, i) in &keys {
        let mut series: Vec<&Cell> = cells
            .iter()
            .filter(|c| c.device_index == *dev && c.iters == *i)
            .collect();
        series.sort_by_key(|c| c.n);
        if series.len() >= 2 {
            tot1 += 1;
            let first = series.first().unwrap().ratio;
            let last = series.last().unwrap().ratio;
            if last <= first + 0.05 {
                ok1 += 1;
            }
        }
    }
    out.push_str(&format!(
        "- overhead masked at larger n: {ok1}/{tot1} (dev,i) series \
         have ratio(max n) <= ratio(min n) + 0.05\n"
    ));
    // Claim 2: overhead tends to grow with i (more events => more
    // expensive overlap analysis) — compare smallest and largest i at
    // the smallest n (where device work masks least).
    let mut ok2 = 0;
    let mut tot2 = 0;
    let devs: Vec<u32> = {
        let mut v: Vec<u32> = cells.iter().map(|c| c.device_index).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for dev in &devs {
        let min_n = cells
            .iter()
            .filter(|c| c.device_index == *dev)
            .map(|c| c.n)
            .min()
            .unwrap();
        let mut series: Vec<&Cell> = cells
            .iter()
            .filter(|c| c.device_index == *dev && c.n == min_n)
            .collect();
        series.sort_by_key(|c| c.iters);
        if series.len() >= 2 {
            tot2 += 1;
            if series.last().unwrap().ratio >= series.first().unwrap().ratio - 0.05 {
                ok2 += 1;
            }
        }
    }
    out.push_str(&format!(
        "- overhead exposed at larger i: {ok2}/{tot2} devices have \
         ratio(max i) >= ratio(min i) - 0.05 at the smallest n\n"
    ));
    // Claim 3: mean ratio stays small — "effectively negligible".
    let mean: f64 = cells.iter().map(|c| c.ratio).sum::<f64>() / cells.len().max(1) as f64;
    out.push_str(&format!(
        "- mean overhead ratio across all cells: {mean:.3} (paper: close to 1)\n"
    ));
    out
}

/// Ablation (DESIGN.md §6 design-choice): what does the integrated
/// profiler itself cost? Runs the ccl service with profiling on vs off
/// on one device and reports the ratio per (n, i) cell.
pub fn profiling_ablation(quick: bool) -> Result<String, String> {
    let (sizes, iters, runs) = if quick {
        (vec![4096usize, 65536], vec![8usize, 32], 4)
    } else {
        (vec![4096usize, 65536, 262144], vec![10usize, 32, 100], 8)
    };
    let mut out = String::from(
        "## Ablation — integrated profiling cost (ccl service, gtx1080sim)\n\
         ratio = t(profile on, incl. calc) / t(profile off)\n\n\
         | n | i | t_off (s) | t_on (s) | ratio |\n|---|---|---|---|---|\n",
    );
    for &n in &sizes {
        for &i in &iters {
            let run_with = |profile: bool| -> Result<f64, String> {
                let times = time_runs(runs, || {
                    let mut c = RngConfig::new(n, i);
                    c.device_index = 1;
                    c.profile = profile;
                    c.sink = Sink::Discard;
                    let t0 = std::time::Instant::now();
                    run_ccl(&c).map_err(|e| e.to_string())?;
                    Ok(t0.elapsed())
                })?;
                Ok(trimmed_mean(times))
            };
            let t_off = run_with(false)?;
            let t_on = run_with(true)?;
            out.push_str(&format!(
                "| {n} | {i} | {t_off:.4} | {t_on:.4} | {:.3} |\n",
                t_on / t_off
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_drops_extremes() {
        assert_eq!(trimmed_mean(vec![100.0, 1.0, 2.0, 3.0, 0.0]), 2.0);
        assert_eq!(trimmed_mean(vec![5.0]), 5.0);
        assert_eq!(trimmed_mean(vec![1.0, 3.0]), 2.0);
    }

    #[test]
    fn single_cell_end_to_end() {
        let c = measure_cell(1, "gtx1080sim", 4096, 3, 3).unwrap();
        assert!(c.t_raw > 0.0 && c.t_ccl > 0.0);
        assert!(c.ratio > 0.1 && c.ratio < 10.0, "wild ratio {}", c.ratio);
        assert!(c.ratio_min <= c.ratio_max);
    }

    #[test]
    fn render_contains_table() {
        let cell = Cell {
            device_index: 1,
            device_name: "gtx1080sim",
            n: 4096,
            iters: 10,
            t_raw: 0.01,
            t_ccl: 0.011,
            ratio: 1.1,
            ratio_min: 1.0,
            ratio_max: 1.2,
        };
        let r = render(&[cell]);
        assert!(r.contains("Fig. 4"));
        assert!(r.contains("| 4096 |"));
        assert!(r.contains("trend checks"));
    }
}
