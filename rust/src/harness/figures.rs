//! E2 / E4: regenerate Fig. 3 (profiling summary) and Fig. 5 (queue
//! utilization chart) from live runs of the PRNG service.

use crate::coordinator::{run_ccl, RngConfig, Sink};
use crate::utils::plot_events;

/// E2 — Fig. 3: run the service with profiling and return the summary.
///
/// The paper's run is n=2^24, i=10^4 on a GTX 1080; scaled here to the
/// artifact ladder with the slow-motion timescale so the timeline is
/// model-dominated (see DESIGN.md).
pub fn figure3(n: usize, iters: usize) -> Result<String, String> {
    std::env::set_var("CF4RS_SIM_TIMESCALE", "0.02");
    let mut cfg = RngConfig::new(n, iters);
    cfg.device_index = 1; // GTX 1080 profile
    cfg.profile = true;
    cfg.sink = Sink::Discard;
    let out = run_ccl(&cfg).map_err(|e| e.to_string())?;
    let mut s = format!(
        "## E2 — Fig. 3 profiling summary (n={n}, i={iters}, gtx1080sim)\n"
    );
    s.push_str(&out.prof_summary.ok_or("no summary produced")?);
    Ok(s)
}

/// E4 — Fig. 5: run the service, export the profile, render the chart.
/// Returns (report text, export tsv, svg).
pub fn figure5(n: usize, iters: usize) -> Result<(String, String, String), String> {
    std::env::set_var("CF4RS_SIM_TIMESCALE", "0.02");
    let mut cfg = RngConfig::new(n, iters);
    cfg.device_index = 1;
    cfg.profile = true;
    cfg.sink = Sink::Discard;
    let out = run_ccl(&cfg).map_err(|e| e.to_string())?;
    let tsv = out.prof_export.ok_or("no export produced")?;
    let infos =
        crate::ccl::prof::export::parse_tsv(&tsv).map_err(|e| e.to_string())?;
    let chart =
        plot_events::render_text(&infos, 100).map_err(|e| e.to_string())?;
    let svg = plot_events::render_svg(&infos).map_err(|e| e.to_string())?;
    let mut s = format!(
        "## E4 — Fig. 5 queue utilization chart (n={n}, i={iters}, gtx1080sim)\n"
    );
    s.push_str(&chart);
    Ok((s, tsv, svg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure3_summary_has_paper_rows() {
        let s = figure3(65536, 6).unwrap();
        assert!(s.contains("READ_BUFFER"));
        assert!(s.contains("RNG_KERNEL"));
        assert!(s.contains("INIT_KERNEL"));
        assert!(s.contains("Event overlaps"));
    }

    #[test]
    fn figure5_chart_shows_both_queues() {
        let (report, tsv, svg) = figure5(65536, 4).unwrap();
        assert!(report.contains("Main |"));
        assert!(report.contains("Comms |"));
        assert!(tsv.starts_with("queue\tstart\tend\tname"));
        assert!(svg.starts_with("<svg"));
    }
}
