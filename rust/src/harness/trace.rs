//! The tracing cell: `cf4rs trace` and `bench trace`.
//!
//! * `cf4rs trace [--workload W] [--path P] [--iters I] [--json]
//!   [--tsv] [--out FILE] [--quick]` — replay one (workload × path)
//!   cell under an armed [`Tracing`] window and print the assembled
//!   span forest (human tree by default, Chrome trace-event JSON with
//!   `--json`, TSV with `--tsv`; `--out` writes the Chrome document to
//!   a file). The `service` path submits through an in-process
//!   [`ComputeService`] with the request's `trace` flag set; the
//!   replay paths adopt scheduler/device spans via the window's
//!   ambient correlation id.
//! * `bench trace [--quick]` — the CI observability gate, two-sided:
//!   **zero-cost-when-off** (two disabled arms interleaved with an
//!   enabled arm per workload; the disabled medians must agree within
//!   1% + a noise floor, the enabled median within 5% + floor) and
//!   **completeness** (every traced request through a live in-process
//!   [`EdgeServer`] must assemble into exactly one rooted tree with
//!   edge → service → shard → device descendants and no orphans).
//!   Writes `results/trace.md`, `results/BENCH_trace.json` (schema
//!   [`SCHEMA`]) and `results/trace_chrome.json` — the latter is
//!   structurally validated here with the dependency-free parser
//!   ([`validate_chrome`]) and again in CI with `python -m json.tool`.

use std::sync::Arc;
use std::time::Instant;

use super::json_escape as esc;

use crate::backend::{BackendRegistry, NativeBackend};
use crate::coordinator::edge::proto::{RequestFrame, WorkloadDesc};
use crate::coordinator::edge::{EdgeClient, EdgeOpts, EdgeServer};
use crate::coordinator::scheduler::{run_sharded_workload_on, ShardedConfig};
use crate::coordinator::{ComputeService, Priority, ServiceOpts, WorkloadRequest};
use crate::trace::chrome::{export_chrome, queue_summary_spans, validate_chrome, ChromeStats};
use crate::trace::tree::Forest;
use crate::trace::{self, Span, Tracing};
use crate::workload::{
    exec, MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload, StencilWorkload,
    Workload,
};

/// Version tag of `BENCH_trace.json`. Bump on layout changes so trend
/// tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-trace/1";

/// The execution paths `cf4rs trace` can replay a workload through.
pub const PATHS: [&str; 6] = ["rawcl", "ccl-v1", "ccl-v2", "sharded", "native", "service"];

/// Disabled-tracing A/A tolerance: 1% of the off median, floored so
/// millisecond-scale quick cells don't gate on scheduler noise.
const OFF_PCT: f64 = 0.01;
const OFF_FLOOR_MS: f64 = 3.0;
/// Enabled-tracing tolerance: 5% of the off median, same floor idea.
const ON_PCT: f64 = 0.05;
const ON_FLOOR_MS: f64 = 5.0;

// ---------------------------------------------------------------------------
// Traced replay (shared by the CLI and the bench completeness leg)
// ---------------------------------------------------------------------------

/// One traced replay: the recorded spans plus any run error.
pub struct ReplayOutcome {
    pub spans: Vec<Span>,
    pub dropped: u64,
    pub error: Option<String>,
}

/// Run a workload through the sharded engine on `registry` with
/// profiling forced, then graft the device slice into the trace (the
/// window's ambient corr adopts every span).
fn run_engine_cell<W: Workload + Clone>(
    w: &W,
    iters: usize,
    registry: &BackendRegistry,
) -> Result<(), String> {
    let mut cfg = ShardedConfig::new(w.clone(), iters);
    cfg.min_chunk = (w.units() / 8).max(1);
    cfg.profile = true;
    let out = run_sharded_workload_on(registry, &cfg).map_err(|e| e.to_string())?;
    trace::graft_prof(out.prof_infos.as_deref().unwrap_or(&[]), None);
    Ok(())
}

/// Submit one traced request through an in-process service and wait.
fn run_service_cell<W: Workload + Clone + 'static>(w: &W, iters: usize) -> Result<(), String> {
    let registry = Arc::new(BackendRegistry::with_default_backends());
    let svc = ComputeService::start(registry, ServiceOpts::default());
    let req = WorkloadRequest::new(w.clone()).iters(iters).trace(true);
    let r = svc.submit(req).and_then(|h| h.wait());
    svc.shutdown();
    r.map(|_| ()).map_err(|e| e.to_string())
}

/// Replay one (workload × path) cell under a fresh tracing window.
fn replay_traced<W: Workload + Clone + 'static>(w: &W, iters: usize, path: &str) -> ReplayOutcome {
    let window = Tracing::start();
    let error = if path == "service" {
        // The service allocates the corr at admission; nothing ambient.
        run_service_cell(w, iters).err()
    } else {
        // Replay outside the service: scheduler/device spans carry no
        // corr of their own, so the window adopts them into one.
        let corr = trace::new_corr();
        window.set_ambient(Some(corr));
        let t0 = trace::now_ns();
        let r = match path {
            "rawcl" => exec::run_raw_path(w, iters, 1).map(|_| ()),
            "ccl-v1" => {
                exec::run_ccl_path(w, iters, 0).map(|_| ()).map_err(|e| e.to_string())
            }
            "ccl-v2" => {
                exec::run_v2_path(w, iters, 0).map(|_| ()).map_err(|e| e.to_string())
            }
            "sharded" => {
                run_engine_cell(w, iters, &BackendRegistry::with_default_backends())
            }
            "native" => match NativeBackend::native() {
                Ok(b) => {
                    let reg = BackendRegistry::new();
                    reg.register(Arc::new(b));
                    run_engine_cell(w, iters, &reg)
                }
                Err(e) => Err(e.to_string()),
            },
            other => Err(format!("unknown path {other:?}")),
        };
        // The replay's root span: whatever the cell recorded nests
        // under it by interval containment.
        trace::complete(
            "replay.cell",
            path,
            None,
            None,
            t0,
            trace::now_ns(),
            vec![
                ("workload", trace::Tag::from(w.name())),
                ("iters", trace::Tag::from(iters)),
            ],
        );
        r.err()
    };
    let dropped = window.dropped();
    ReplayOutcome { spans: window.finish(), dropped, error }
}

/// Dispatch a workload name to its concrete type and replay. `None`
/// for an unknown workload name.
fn replay_named(workload: &str, quick: bool, iters: usize, path: &str) -> Option<ReplayOutcome> {
    Some(match workload {
        "prng" => {
            replay_traced(&PrngWorkload::new(if quick { 4096 } else { 65536 }), iters, path)
        }
        "saxpy" => replay_traced(
            &SaxpyWorkload::new(if quick { 4096 } else { 65536 }, 2.5),
            iters,
            path,
        ),
        "reduce" => replay_traced(
            &ReduceWorkload::new(if quick { 8192 } else { 262144 }),
            iters,
            path,
        ),
        "stencil" => {
            let (h, w) = if quick { (24, 16) } else { (64, 64) };
            replay_traced(&StencilWorkload::new(h, w), iters, path)
        }
        "matmul" => {
            replay_traced(&MatmulWorkload::new(if quick { 12 } else { 32 }), iters, path)
        }
        _ => return None,
    })
}

/// Chrome trace-event document for a span collection, per-queue
/// utilisation/idle summary spans appended.
fn chrome_doc(spans: &[Span]) -> String {
    let mut all = spans.to_vec();
    all.extend(queue_summary_spans(spans));
    export_chrome(&all)
}

// ---------------------------------------------------------------------------
// `cf4rs trace` CLI
// ---------------------------------------------------------------------------

/// `cf4rs trace` entrypoint: traced replay, tree/JSON/TSV output.
pub fn trace_main(args: &[String]) -> i32 {
    let mut workload = "prng".to_string();
    let mut path = "service".to_string();
    let mut iters = 2usize;
    let mut json = false;
    let mut tsv = false;
    let mut out: Option<String> = None;
    let mut quick = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut next = |what: &str| -> Result<String, String> {
            it.next().cloned().ok_or(format!("{what} needs a value"))
        };
        let r: Result<(), String> = (|| {
            match a.as_str() {
                "--workload" => workload = next("--workload")?,
                "--path" => path = next("--path")?,
                "--iters" => iters = next("--iters")?.parse().map_err(|e| format!("{e}"))?,
                "--json" => json = true,
                "--tsv" => tsv = true,
                "--out" => out = Some(next("--out")?),
                "--quick" => quick = true,
                other => {
                    return Err(format!(
                        "unknown trace option {other:?}\nusage: cf4rs trace \
                         [--workload prng|saxpy|reduce|stencil|matmul] \
                         [--path rawcl|ccl-v1|ccl-v2|sharded|native|service] \
                         [--iters I] [--json] [--tsv] [--out FILE] [--quick]"
                    ))
                }
            }
            Ok(())
        })();
        if let Err(e) = r {
            eprintln!("trace: {e}");
            return 2;
        }
    }
    if !PATHS.contains(&path.as_str()) {
        eprintln!("trace: unknown path {path:?}");
        return 2;
    }
    if iters == 0 {
        eprintln!("trace: --iters must be > 0");
        return 2;
    }

    let Some(outcome) = replay_named(&workload, quick, iters, &path) else {
        eprintln!("trace: unknown workload {workload:?}");
        return 2;
    };
    if let Some(e) = &outcome.error {
        eprintln!("trace: {workload}/{path} replay failed: {e}");
        return 1;
    }

    let forest = Forest::build(outcome.spans.clone());
    if let Some(file) = &out {
        let doc = chrome_doc(&outcome.spans);
        if let Err(e) = std::fs::write(file, &doc) {
            eprintln!("trace: writing {file}: {e}");
            return 1;
        }
        eprintln!(" * Chrome trace written to {file} (load in Perfetto)");
    }
    if json {
        print!("{}", chrome_doc(&outcome.spans));
    } else if tsv {
        print!("{}", forest.to_tsv());
    } else {
        print!("{}", forest.render_text());
        for tree in &forest.trees {
            let c = forest.completeness(tree);
            let corr = tree.corr.map_or_else(|| "-".to_string(), |c| c.to_string());
            eprintln!(
                " * corr {corr}: edge={} svc={} sched={} dev={}",
                c.edge, c.svc, c.sched, c.dev
            );
        }
        eprintln!(
            " * {} span(s), {} tree(s), {} orphan(s), {} dropped",
            forest.spans.len(),
            forest.trees.len(),
            forest.orphans.len(),
            outcome.dropped
        );
    }
    0
}

// ---------------------------------------------------------------------------
// `bench trace`: the overhead + completeness gate
// ---------------------------------------------------------------------------

/// One workload's interleaved off/on/off overhead measurement, ms.
pub struct OverheadRow {
    pub workload: &'static str,
    pub med_off_a: f64,
    pub med_on: f64,
    pub med_off_b: f64,
    pub error: Option<String>,
}

impl OverheadRow {
    /// Off-median baseline the tolerances scale from.
    fn med_off(&self) -> f64 {
        (self.med_off_a + self.med_off_b) / 2.0
    }

    /// Disabled A/A delta within 1% + floor: the hook sites cost
    /// nothing measurable while the sink is disarmed.
    pub fn overhead_ok(&self) -> bool {
        self.error.is_none()
            && (self.med_off_a - self.med_off_b).abs()
                <= (OFF_PCT * self.med_off()).max(OFF_FLOOR_MS)
    }

    /// Enabled median within 5% + floor of the disabled median.
    pub fn enabled_ok(&self) -> bool {
        self.error.is_none()
            && self.med_on - self.med_off() <= (ON_PCT * self.med_off()).max(ON_FLOOR_MS)
    }
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    match xs.len() {
        0 => 0.0,
        n if n % 2 == 1 => xs[n / 2],
        n => (xs[n / 2 - 1] + xs[n / 2]) / 2.0,
    }
}

/// Wall-time one sharded replay, ms, tracing armed or not.
fn time_run<W: Workload + Clone>(
    w: &W,
    iters: usize,
    registry: &BackendRegistry,
    traced: bool,
) -> Result<f64, String> {
    let window = traced.then(Tracing::start);
    let t0 = Instant::now();
    exec::run_sharded_path(w, iters, registry).map_err(|e| e.to_string())?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    drop(window);
    Ok(ms)
}

/// Interleave off/on/off arms so drift hits all three equally.
fn overhead_cell<W: Workload + Clone>(
    w: &W,
    iters: usize,
    reps: usize,
    registry: &BackendRegistry,
) -> OverheadRow {
    let (mut off_a, mut on, mut off_b) = (Vec::new(), Vec::new(), Vec::new());
    let mut error = None;
    for _ in 0..reps {
        let r = time_run(w, iters, registry, false)
            .and_then(|a| time_run(w, iters, registry, true).map(|b| (a, b)))
            .and_then(|(a, b)| time_run(w, iters, registry, false).map(|c| (a, b, c)));
        match r {
            Ok((a, b, c)) => {
                off_a.push(a);
                on.push(b);
                off_b.push(c);
            }
            Err(e) => {
                error = Some(e);
                break;
            }
        }
    }
    OverheadRow {
        workload: w.name(),
        med_off_a: median(&mut off_a),
        med_on: median(&mut on),
        med_off_b: median(&mut off_b),
        error,
    }
}

fn run_overhead(quick: bool) -> Vec<OverheadRow> {
    let registry = BackendRegistry::with_default_backends();
    let reps = if quick { 3 } else { 5 };
    let mut rows = Vec::new();
    if quick {
        rows.push(overhead_cell(&PrngWorkload::new(4096), 2, reps, &registry));
        rows.push(overhead_cell(&SaxpyWorkload::new(4096, 2.5), 2, reps, &registry));
    } else {
        rows.push(overhead_cell(&PrngWorkload::new(65536), 3, reps, &registry));
        rows.push(overhead_cell(&SaxpyWorkload::new(65536, 2.5), 3, reps, &registry));
        rows.push(overhead_cell(&ReduceWorkload::new(262144), 2, reps, &registry));
    }
    rows
}

/// What the edge completeness leg found.
pub struct CompletenessOutcome {
    pub requests: usize,
    /// Correlated trees assembled (must equal `requests`).
    pub corr_trees: usize,
    /// Correlated trees with edge → svc → sched → dev coverage.
    pub full_trees: usize,
    pub orphans: usize,
    pub oracle_ok: bool,
    pub dropped: u64,
    pub error: Option<String>,
    pub spans: Vec<Span>,
}

impl CompletenessOutcome {
    pub fn ok(&self) -> bool {
        self.error.is_none()
            && self.requests > 0
            && self.corr_trees == self.requests
            && self.full_trees == self.requests
            && self.orphans == 0
            && self.oracle_ok
            && self.dropped == 0
    }

    fn failed(requests: usize, error: String) -> CompletenessOutcome {
        CompletenessOutcome {
            requests,
            corr_trees: 0,
            full_trees: 0,
            orphans: 0,
            oracle_ok: false,
            dropped: 0,
            error: Some(error),
            spans: Vec::new(),
        }
    }
}

/// Drive N traced requests through a live in-process edge server and
/// assemble the recorded spans: every request must come back as one
/// rooted, layer-complete tree.
fn run_completeness(quick: bool) -> CompletenessOutcome {
    let n = if quick { 5 } else { 10 };
    let descs = [
        WorkloadDesc::Prng { n: 2048 },
        WorkloadDesc::Saxpy { n: 2048, a: 2.5 },
        WorkloadDesc::Reduce { n: 4096 },
        WorkloadDesc::Stencil { h: 16, w: 16 },
        WorkloadDesc::Matmul { d: 12 },
    ];
    let iters = 2u32;

    let window = Tracing::start();
    let opts = EdgeOpts {
        registry: Some(Arc::new(BackendRegistry::with_default_backends())),
        ..EdgeOpts::default()
    };
    let server = match EdgeServer::start(0, opts) {
        Ok(s) => s,
        Err(e) => return CompletenessOutcome::failed(n, format!("edge bind: {e}")),
    };
    let addr = server.local_addr();

    let drive = || -> Result<bool, String> {
        let mut client = EdgeClient::connect(addr).map_err(|e| format!("connect: {e}"))?;
        let mut oracle_ok = true;
        for i in 0..n {
            let desc = descs[i % descs.len()];
            let frame = RequestFrame {
                req_id: i as u64 + 1,
                priority: if i % 2 == 0 { Priority::High } else { Priority::Bulk },
                deadline_us: 0,
                iters,
                desc,
                trace: true,
            };
            let resp = client.request(&frame).map_err(|e| format!("request {i}: {e}"))?;
            if resp.req_id != frame.req_id {
                return Err(format!(
                    "request {i}: response correlates {} not {}",
                    resp.req_id, frame.req_id
                ));
            }
            match resp.result {
                Ok(bytes) => {
                    oracle_ok &= bytes == desc.instantiate().reference(iters as usize);
                }
                Err(e) => return Err(format!("request {i}: server refused: {e:?}")),
            }
        }
        Ok(oracle_ok)
    };
    let driven = drive();
    // Drain before snapshotting: the edge.req/edge.reply spans are
    // recorded after the response frame is on the wire.
    server.shutdown();

    let dropped = window.dropped();
    let spans = window.finish();
    let oracle_ok = match driven {
        Ok(ok) => ok,
        Err(e) => {
            let mut out = CompletenessOutcome::failed(n, e);
            out.spans = spans;
            return out;
        }
    };

    let forest = Forest::build(spans.clone());
    let corred: Vec<_> = forest.trees.iter().filter(|t| t.corr.is_some()).collect();
    let full = corred.iter().filter(|t| forest.completeness(t).full()).count();
    CompletenessOutcome {
        requests: n,
        corr_trees: corred.len(),
        full_trees: full,
        orphans: forest.orphans.len(),
        oracle_ok,
        dropped,
        error: None,
        spans,
    }
}

// ---------------------------------------------------------------------------
// Report rendering
// ---------------------------------------------------------------------------

fn render_md(
    rows: &[OverheadRow],
    comp: &CompletenessOutcome,
    chrome: &Result<ChromeStats, String>,
    quick: bool,
) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# End-to-end tracing gate — {} mode\n\n## Overhead (sharded replay, \
         interleaved off/on/off arms)\n\n",
        if quick { "quick" } else { "full" }
    ));
    s.push_str("| workload | off A (ms) | on (ms) | off B (ms) | off gate | on gate |\n");
    s.push_str("|---|---:|---:|---:|---|---|\n");
    for r in rows {
        s.push_str(&format!(
            "| {} | {:.3} | {:.3} | {:.3} | {} | {} |\n",
            r.workload,
            r.med_off_a,
            r.med_on,
            r.med_off_b,
            if r.overhead_ok() { "✓" } else { "**FAIL**" },
            if r.enabled_ok() { "✓" } else { "**FAIL**" },
        ));
    }
    for r in rows {
        if let Some(e) = &r.error {
            s.push_str(&format!("\n* `{}` replay failed: {e}\n", r.workload));
        }
    }
    s.push_str(&format!(
        "\nGates: disabled A/A delta ≤ max({}%, {OFF_FLOOR_MS} ms); enabled \
         delta ≤ max({}%, {ON_FLOOR_MS} ms).\n",
        OFF_PCT * 100.0,
        ON_PCT * 100.0
    ));
    s.push_str("\n## Completeness (traced requests through a live edge)\n\n");
    s.push_str(&format!(
        "* requests: {} — correlated trees: {}, layer-complete \
         (edge→svc→sched→dev): {}, orphans: {}, ring drops: {}, oracle: {}\n",
        comp.requests,
        comp.corr_trees,
        comp.full_trees,
        comp.orphans,
        comp.dropped,
        if comp.oracle_ok { "bit-identical" } else { "**MISMATCH**" },
    ));
    if let Some(e) = &comp.error {
        s.push_str(&format!("* drive FAILED: {e}\n"));
    }
    s.push_str("\n## Chrome export (`results/trace_chrome.json`)\n\n");
    match chrome {
        Ok(st) => s.push_str(&format!(
            "* {} complete events, {} metadata events, {} tracks — parses \
             and validates\n",
            st.complete_events,
            st.metadata_events,
            st.tracks.len()
        )),
        Err(e) => s.push_str(&format!("* validation FAILED: {e}\n")),
    }
    s
}

fn render_json(
    rows: &[OverheadRow],
    comp: &CompletenessOutcome,
    chrome: &Result<ChromeStats, String>,
    quick: bool,
) -> String {
    let overhead_ok = !rows.is_empty() && rows.iter().all(|r| r.overhead_ok());
    let enabled_ok = !rows.is_empty() && rows.iter().all(|r| r.enabled_ok());
    let completeness_ok = comp.ok();
    let chrome_ok = chrome.is_ok();
    let gate_ok = overhead_ok && enabled_ok && completeness_ok && chrome_ok;
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(&format!("  \"overhead_ok\": {overhead_ok},\n"));
    s.push_str(&format!("  \"enabled_ok\": {enabled_ok},\n"));
    s.push_str(&format!("  \"completeness_ok\": {completeness_ok},\n"));
    s.push_str(&format!("  \"chrome_ok\": {chrome_ok},\n"));
    s.push_str(&format!("  \"gate_ok\": {gate_ok},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"med_off_a_ms\": {:.3}, \"med_on_ms\": \
             {:.3}, \"med_off_b_ms\": {:.3}, \"row_off_ok\": {}, \"row_on_ok\": \
             {}{}}}{}\n",
            r.workload,
            r.med_off_a,
            r.med_on,
            r.med_off_b,
            r.overhead_ok(),
            r.enabled_ok(),
            match &r.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"completeness\": {{\"requests\": {}, \"corr_trees\": {}, \
         \"full_trees\": {}, \"orphans\": {}, \"dropped\": {}, \"oracle_ok\": \
         {}{}}},\n",
        comp.requests,
        comp.corr_trees,
        comp.full_trees,
        comp.orphans,
        comp.dropped,
        comp.oracle_ok,
        match &comp.error {
            Some(e) => format!(", \"error\": \"{}\"", esc(e)),
            None => String::new(),
        },
    ));
    match chrome {
        Ok(st) => s.push_str(&format!(
            "  \"chrome\": {{\"complete_events\": {}, \"metadata_events\": {}, \
             \"tracks\": {}}}\n",
            st.complete_events,
            st.metadata_events,
            st.tracks.len()
        )),
        Err(e) => {
            s.push_str(&format!("  \"chrome\": {{\"error\": \"{}\"}}\n", esc(e)))
        }
    }
    s.push_str("}\n");
    s
}

/// Build the `bench trace` report. Returns `(markdown, json, ok)` — the
/// caller writes both files even when a gate failed (the artifacts are
/// the evidence) but must exit non-zero on `!ok`. Also writes the
/// Chrome export of the completeness run to
/// `results/trace_chrome.json` as loadable evidence.
pub fn report(quick: bool) -> (String, String, bool) {
    let rows = run_overhead(quick);
    let comp = run_completeness(quick);
    let doc = chrome_doc(&comp.spans);
    let chrome = validate_chrome(&doc);
    let wrote = super::write_result("trace_chrome.json", &doc);
    let overhead_ok = !rows.is_empty() && rows.iter().all(|r| r.overhead_ok());
    let enabled_ok = !rows.is_empty() && rows.iter().all(|r| r.enabled_ok());
    let ok = overhead_ok && enabled_ok && comp.ok() && chrome.is_ok() && wrote;
    (render_md(&rows, &comp, &chrome, quick), render_json(&rows, &comp, &chrome, quick), ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn medians_and_row_gates() {
        assert_eq!(median(&mut []), 0.0);
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
        let row = OverheadRow {
            workload: "prng",
            med_off_a: 100.0,
            med_on: 104.0,
            med_off_b: 100.5,
            error: None,
        };
        assert!(row.overhead_ok() && row.enabled_ok());
        let slow = OverheadRow { med_on: 200.0, ..row };
        assert!(slow.overhead_ok() && !slow.enabled_ok());
        let skewed = OverheadRow { med_off_a: 100.0, med_off_b: 110.0, ..slow };
        assert!(!skewed.overhead_ok());
        let errored = OverheadRow { error: Some("boom".into()), ..skewed };
        assert!(!errored.overhead_ok() && !errored.enabled_ok());
    }

    #[test]
    fn traced_service_replay_yields_a_service_full_tree() {
        let out = replay_named("prng", true, 2, "service").unwrap();
        assert!(out.error.is_none(), "{:?}", out.error);
        let forest = Forest::build(out.spans);
        let corred: Vec<_> = forest.trees.iter().filter(|t| t.corr.is_some()).collect();
        assert_eq!(corred.len(), 1, "one traced request, one tree");
        let c = forest.completeness(corred[0]);
        assert!(c.service_full(), "svc→sched→dev expected, got {c:?}");
        assert!(forest.orphans.is_empty(), "orphans: {:?}", forest.orphans);
    }

    #[test]
    fn traced_sharded_replay_grafts_device_spans() {
        let out = replay_named("saxpy", true, 1, "sharded").unwrap();
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(out.spans.iter().any(|s| s.name.starts_with("sched.task")));
        assert!(out.spans.iter().any(|s| s.name.starts_with("dev.")));
        // Ambient adoption: every span landed in the replay's corr.
        assert!(out.spans.iter().all(|s| s.corr.is_some()));
        let forest = Forest::build(out.spans);
        assert_eq!(forest.trees.len(), 1, "one ambient corr, one tree");
        assert_eq!(forest.spans[forest.trees[0].root].name, "replay.cell");
    }

    #[test]
    fn json_gates_follow_the_outcomes() {
        let rows = vec![OverheadRow {
            workload: "prng",
            med_off_a: 10.0,
            med_on: 10.5,
            med_off_b: 10.2,
            error: None,
        }];
        let comp = CompletenessOutcome::failed(4, "boom".to_string());
        let j = render_json(&rows, &comp, &Ok(ChromeStats::default()), true);
        assert!(j.contains("\"overhead_ok\": true"));
        assert!(j.contains("\"completeness_ok\": false"));
        assert!(j.contains("\"gate_ok\": false"));
        assert!(j.contains("\"error\": \"boom\""));
        assert!(j.contains(SCHEMA));
    }
}
