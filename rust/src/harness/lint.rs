//! The static-analysis cell: `cf4rs lint` and `bench lint-graph`.
//!
//! Both surfaces replay workloads under the command recorder
//! ([`crate::analysis::Recording`]) and run the happens-before analyzer
//! over the captured streams:
//!
//! * `cf4rs lint [--workload W] [--path P] [--json] [--strict] [--quick]`
//!   — replay the selected (workload × path) cells and report findings;
//!   `--strict` turns any finding into a non-zero exit.
//! * `bench lint-graph [--quick]` — the CI detector gate, two-sided:
//!   the clean 5-workloads × 5-paths matrix must analyze to **zero**
//!   findings, AND every stream in the seeded-bug corpus
//!   ([`crate::analysis::corpus`]) must be flagged with its expected
//!   rule. Writes `results/lint-graph.md` +
//!   `results/BENCH_lint-graph.json` (schema [`SCHEMA`]).
//!
//! A detector that goes quiet fails the corpus side; one that goes noisy
//! fails the clean side. Either way CI turns red.

use std::time::Instant;

use crate::analysis::{analyze, corpus, Recording, Report};
use crate::backend::BackendRegistry;
use crate::workload::{
    exec, MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload,
    StencilWorkload, Workload,
};

/// Version tag of `BENCH_lint-graph.json`. Bump on layout changes so
/// trend tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-lint-graph/1";

/// The five execution paths every workload replays through.
pub const PATHS: [&str; 5] = ["rawcl", "ccl-v1", "ccl-v2", "sharded", "native"];

/// One replayed-and-analyzed (workload × path) cell.
pub struct LintCell {
    pub workload: &'static str,
    pub path: &'static str,
    pub report: Report,
    pub error: Option<String>,
    pub ms: f64,
}

/// Replay one workload through one path under a fresh recording window
/// and analyze the captured stream.
fn run_cell<W: Workload + Clone>(
    w: &W,
    iters: usize,
    path: &'static str,
    registry: &BackendRegistry,
) -> (Report, Option<String>) {
    let rec = Recording::start();
    let outcome = match path {
        "rawcl" => exec::run_raw_path(w, iters, 1),
        "ccl-v1" => exec::run_ccl_path(w, iters, 0).map_err(|e| e.to_string()),
        "ccl-v2" => exec::run_v2_path(w, iters, 0).map_err(|e| e.to_string()),
        "sharded" => {
            exec::run_sharded_path(w, iters, registry).map_err(|e| e.to_string())
        }
        "native" => exec::run_native_path(w, iters),
        other => Err(format!("unknown path {other:?}")),
    };
    let stream = rec.finish();
    (analyze(&stream), outcome.err())
}

/// Replay one workload through the selected paths.
fn lint_workload<W: Workload + Clone>(
    w: &W,
    iters: usize,
    registry: &BackendRegistry,
    path_filter: Option<&str>,
    cells: &mut Vec<LintCell>,
) {
    for path in PATHS {
        if let Some(p) = path_filter {
            if p != path {
                continue;
            }
        }
        let t0 = Instant::now();
        let (report, error) = run_cell(w, iters, path, registry);
        cells.push(LintCell {
            workload: w.name(),
            path,
            report,
            error,
            ms: t0.elapsed().as_secs_f64() * 1e3,
        });
    }
}

/// Replay the selected workloads × paths. `None` filters mean "all".
/// Quick sizes mirror the workloads-matrix quick mode.
pub fn run_matrix(
    quick: bool,
    workload_filter: Option<&str>,
    path_filter: Option<&str>,
) -> Vec<LintCell> {
    let registry = BackendRegistry::with_default_backends();
    let mut cells = Vec::new();
    let want = |name: &str| workload_filter.is_none() || workload_filter == Some(name);

    if quick {
        if want("prng") {
            lint_workload(&PrngWorkload::new(4096), 2, &registry, path_filter, &mut cells);
        }
        if want("saxpy") {
            lint_workload(&SaxpyWorkload::new(4096, 2.5), 2, &registry, path_filter, &mut cells);
        }
        if want("reduce") {
            lint_workload(&ReduceWorkload::new(8192), 2, &registry, path_filter, &mut cells);
        }
        if want("stencil") {
            lint_workload(&StencilWorkload::new(24, 16), 2, &registry, path_filter, &mut cells);
        }
        if want("matmul") {
            lint_workload(&MatmulWorkload::new(12), 2, &registry, path_filter, &mut cells);
        }
    } else {
        if want("prng") {
            lint_workload(&PrngWorkload::new(65536), 4, &registry, path_filter, &mut cells);
        }
        if want("saxpy") {
            lint_workload(&SaxpyWorkload::new(65536, 2.5), 3, &registry, path_filter, &mut cells);
        }
        if want("reduce") {
            lint_workload(&ReduceWorkload::new(262144), 2, &registry, path_filter, &mut cells);
        }
        if want("stencil") {
            lint_workload(&StencilWorkload::new(64, 64), 3, &registry, path_filter, &mut cells);
        }
        if want("matmul") {
            lint_workload(&MatmulWorkload::new(32), 2, &registry, path_filter, &mut cells);
        }
    }
    cells
}

/// One analyzed corpus case for the report.
struct CorpusOutcome {
    name: &'static str,
    expect: &'static str,
    flagged: bool,
    found: Vec<&'static str>,
}

fn run_corpus() -> Vec<CorpusOutcome> {
    corpus::seeded_bugs()
        .into_iter()
        .map(|case| {
            let report = analyze(&case.stream);
            let found: Vec<&'static str> =
                report.findings.iter().map(|f| f.rule.id()).collect();
            CorpusOutcome {
                name: case.name,
                expect: case.expect.id(),
                flagged: found.contains(&case.expect.id()),
                found,
            }
        })
        .collect()
}

fn render_md(cells: &[LintCell], corpus: &[CorpusOutcome], quick: bool) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Command-graph lint gate — {} mode\n\n## Clean matrix (must be \
         zero findings everywhere)\n\n",
        if quick { "quick" } else { "full" }
    ));
    s.push_str("| workload | path | commands | findings | analyze+replay |\n");
    s.push_str("|---|---|---:|---:|---:|\n");
    for c in cells {
        s.push_str(&format!(
            "| {} | {} | {} | {} | {:.2} ms |\n",
            c.workload,
            c.path,
            c.report.n_cmds,
            if c.error.is_some() {
                "**ERROR**".to_string()
            } else {
                c.report.findings.len().to_string()
            },
            c.ms
        ));
    }
    for c in cells {
        if let Some(e) = &c.error {
            s.push_str(&format!("\n* `{}/{}` failed: {e}\n", c.workload, c.path));
        }
        if !c.report.is_clean() {
            s.push_str(&format!(
                "\n### {}/{} findings\n\n```\n{}```\n",
                c.workload,
                c.path,
                c.report.render_human()
            ));
        }
    }
    s.push_str("\n## Seeded-bug corpus (every case must be flagged)\n\n");
    s.push_str("| case | expected rule | flagged | rules found |\n|---|---|---|---|\n");
    for o in corpus {
        s.push_str(&format!(
            "| {} | {} | {} | {} |\n",
            o.name,
            o.expect,
            if o.flagged { "✓" } else { "**MISSED**" },
            o.found.join(", ")
        ));
    }
    s
}

use super::json_escape as esc;

fn render_json(cells: &[LintCell], corpus: &[CorpusOutcome], quick: bool) -> String {
    let clean_findings: usize = cells.iter().map(|c| c.report.findings.len()).sum();
    let clean_ok =
        clean_findings == 0 && cells.iter().all(|c| c.error.is_none()) && !cells.is_empty();
    let corpus_ok = corpus.iter().all(|o| o.flagged) && !corpus.is_empty();
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(&format!("  \"findings\": {clean_findings},\n"));
    s.push_str(&format!("  \"clean_ok\": {clean_ok},\n"));
    s.push_str(&format!("  \"corpus_ok\": {corpus_ok},\n"));
    s.push_str(&format!("  \"gate_ok\": {},\n", clean_ok && corpus_ok));
    s.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"path\": \"{}\", \"commands\": {}, \
             \"cell_findings\": {}, \"ms\": {:.3}{}}}{}\n",
            c.workload,
            c.path,
            c.report.n_cmds,
            c.report.findings.len(),
            c.ms,
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < cells.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n  \"corpus\": [\n");
    for (i, o) in corpus.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"case\": \"{}\", \"expect\": \"{}\", \"flagged\": {}, \
             \"found\": [{}]}}{}\n",
            o.name,
            o.expect,
            o.flagged,
            o.found.iter().map(|r| format!("\"{r}\"")).collect::<Vec<_>>().join(", "),
            if i + 1 < corpus.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Build the `bench lint-graph` report. Returns `(markdown, json, ok)` —
/// the caller writes both files even when a gate failed (the artifacts
/// are the evidence) but must exit non-zero on `!ok`.
pub fn report(quick: bool) -> (String, String, bool) {
    let cells = run_matrix(quick, None, None);
    let corpus = run_corpus();
    let clean_ok = cells.iter().all(|c| c.error.is_none() && c.report.is_clean())
        && !cells.is_empty();
    let corpus_ok = corpus.iter().all(|o| o.flagged) && !corpus.is_empty();
    (
        render_md(&cells, &corpus, quick),
        render_json(&cells, &corpus, quick),
        clean_ok && corpus_ok,
    )
}

/// `cf4rs lint` entrypoint: replay + analyze, human or JSON output.
pub fn lint_main(args: &[String]) -> i32 {
    let mut workload: Option<String> = None;
    let mut path: Option<String> = None;
    let mut json = false;
    let mut strict = false;
    let mut quick = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--workload" => match it.next() {
                Some(w) => workload = Some(w.clone()),
                None => {
                    eprintln!("--workload needs a value");
                    return 2;
                }
            },
            "--path" => match it.next() {
                Some(p) => path = Some(p.clone()),
                None => {
                    eprintln!("--path needs a value");
                    return 2;
                }
            },
            "--json" => json = true,
            "--strict" => strict = true,
            "--quick" => quick = true,
            other => {
                eprintln!(
                    "unknown lint option {other:?}\nusage: cf4rs lint \
                     [--workload prng|saxpy|reduce|stencil|matmul|all] \
                     [--path rawcl|ccl-v1|ccl-v2|sharded|native|all] \
                     [--json] [--strict] [--quick]"
                );
                return 2;
            }
        }
    }
    let wf = workload.as_deref().filter(|w| *w != "all");
    let pf = path.as_deref().filter(|p| *p != "all");
    if let Some(w) = wf {
        if !["prng", "saxpy", "reduce", "stencil", "matmul"].contains(&w) {
            eprintln!("unknown workload {w:?}");
            return 2;
        }
    }
    if let Some(p) = pf {
        if !PATHS.contains(&p) {
            eprintln!("unknown path {p:?}");
            return 2;
        }
    }

    let cells = run_matrix(quick, wf, pf);
    if cells.is_empty() {
        eprintln!("no cells selected");
        return 2;
    }
    let errored = cells.iter().any(|c| c.error.is_some());
    let total: usize = cells.iter().map(|c| c.report.findings.len()).sum();

    if json {
        // One merged report over every replayed cell; `"findings"` is the
        // total, which the CI clean gate greps as `"findings": 0`.
        let mut merged = Report::default();
        for c in &cells {
            merged.findings.extend(c.report.findings.iter().cloned());
            merged.n_cmds += c.report.n_cmds;
            merged.n_queues += c.report.n_queues;
            merged.n_buffers += c.report.n_buffers;
        }
        let meta = [
            ("workload", wf.unwrap_or("all").to_string()),
            ("path", pf.unwrap_or("all").to_string()),
            ("cells", cells.len().to_string()),
        ];
        print!("{}", merged.to_json(&meta));
    } else {
        for c in &cells {
            println!("== {}/{} ==", c.workload, c.path);
            match &c.error {
                Some(e) => println!("  replay FAILED: {e}"),
                None => print!("{}", c.report.render_human()),
            }
            println!();
        }
        println!(
            "{} cell(s), {} finding(s){}",
            cells.len(),
            total,
            if errored { ", with replay errors" } else { "" }
        );
    }
    if errored || (strict && total > 0) {
        1
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_side_of_the_gate_is_green() {
        let outcomes = run_corpus();
        assert_eq!(outcomes.len(), 6);
        for o in &outcomes {
            assert!(o.flagged, "{} missed (found {:?})", o.name, o.found);
        }
    }

    #[test]
    fn single_cell_replay_is_clean() {
        // The full quick matrix runs in CI's bench-gate leg; one cheap
        // cell here keeps the invariant pinned in plain `cargo test`.
        let registry = BackendRegistry::with_default_backends();
        let (report, err) =
            run_cell(&PrngWorkload::new(256), 2, "ccl-v2", &registry);
        assert!(err.is_none(), "{err:?}");
        assert!(report.n_cmds > 0, "recorder captured nothing");
        assert!(report.is_clean(), "{}", report.render_human());
    }

    #[test]
    fn json_gates_follow_the_outcomes() {
        let cells = vec![LintCell {
            workload: "prng",
            path: "rawcl",
            report: Report::default(),
            error: Some("boom".to_string()),
            ms: 1.0,
        }];
        let j = render_json(&cells, &run_corpus(), true);
        assert!(j.contains("\"clean_ok\": false"));
        assert!(j.contains("\"corpus_ok\": true"));
        assert!(j.contains("\"gate_ok\": false"));
        assert!(j.contains(SCHEMA));
    }
}
