//! The compute-service benchmark: throughput/latency under concurrent
//! clients, plus the micro-batching cross-validation gate.
//!
//! Two parts:
//!
//! * **Cross-validation** — for every workload kind, a micro-batch of
//!   mixed-size requests is executed through
//!   [`run_batch`](crate::coordinator::service::run_batch) and each
//!   split-back output is compared bit-for-bit against (a) the same
//!   request run unbatched through the sharded scheduler and (b) the
//!   host oracle. Any divergence fails the run — CI gates on it.
//! * **Sessions** — a [`ComputeService`] session per client count:
//!   every client submits a deterministic mixed-workload request stream,
//!   validates each response against the oracle and records
//!   submit-to-answer latency. The table reports p50/p95 latency and
//!   requests/sec.
//!
//! Emits `results/service.md` (human table) and
//! `results/BENCH_service.json` (machine-readable, schema [`SCHEMA`]).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::BackendRegistry;
use crate::coordinator::scheduler::{run_sharded_workload_on, ShardedConfig};
use crate::coordinator::service::{
    run_batch, ComputeService, ServiceOpts, ServiceReport, ServiceStats,
    WorkloadRequest,
};
use crate::metrics::Histogram;
use crate::workload::{
    MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload, StencilWorkload,
    Workload,
};

/// Version tag of `BENCH_service.json`. Bump on layout changes so trend
/// tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-service/1";

/// A deterministic mixed stream of service requests: all five workload
/// kinds, several sizes per kind (mixed-size same-kind requests are
/// exactly what micro-batching coalesces).
pub fn mixed_request(i: usize, quick: bool) -> WorkloadRequest {
    let s = if quick { 1 } else { 4 };
    match i % 5 {
        0 => WorkloadRequest::new(PrngWorkload::new(1024 * s * (1 + i % 3))).iters(3),
        1 => WorkloadRequest::new(SaxpyWorkload::new(768 * s * (1 + i % 4), 2.5)).iters(3),
        2 => WorkloadRequest::new(ReduceWorkload::new(2048 * s * (1 + i % 2))).iters(2),
        3 => WorkloadRequest::new(StencilWorkload::new(16 + 8 * (i % 3), 24)).iters(2),
        _ => WorkloadRequest::new(MatmulWorkload::new(12 + 4 * (i % 3))).iters(2),
    }
}

/// What one multi-client service session measured.
pub struct SessionOutcome {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Requests answered successfully.
    pub completed: usize,
    /// Submit/wait errors.
    pub failures: usize,
    /// Responses that did not match the host oracle.
    pub mismatches: usize,
    pub wall: Duration,
    /// The service's own latency histogram
    /// ([`ServiceMetrics`](crate::coordinator::ServiceMetrics)
    /// snapshot, ns) — the **same** instrument the `serve --live`
    /// dashboard renders, so harness percentiles and dashboard
    /// percentiles can never disagree.
    pub latency_hist: Histogram,
    pub stats: ServiceStats,
    pub report: ServiceReport,
}

impl SessionOutcome {
    pub fn req_per_s(&self) -> f64 {
        if self.wall.as_secs_f64() > 0.0 {
            self.completed as f64 / self.wall.as_secs_f64()
        } else {
            0.0
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency_hist.quantile(0.50) as f64 * 1e-6
    }

    pub fn p95_ms(&self) -> f64 {
        self.latency_hist.quantile(0.95) as f64 * 1e-6
    }
}

/// Linear-interpolation percentile over an ascending slice: 0 when
/// empty, the sample itself for a single element, and the
/// `(len-1)·q`-positioned interpolation between neighbours otherwise
/// (`q` clamped into `[0, 1]`).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    match sorted {
        [] => 0.0,
        [only] => *only,
        _ => {
            let pos = (sorted.len() - 1) as f64 * q.clamp(0.0, 1.0);
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
        }
    }
}

/// Run one service session: `clients` threads each submitting
/// `requests_per_client` mixed requests, every response validated
/// against the host oracle. With `live`, a dashboard thread prints the
/// service's [`render_live`](crate::coordinator::ServiceMetrics::render_live)
/// line at that period for the session's duration (the `serve --live`
/// surface).
pub fn run_session(
    registry: Arc<BackendRegistry>,
    clients: usize,
    requests_per_client: usize,
    opts: ServiceOpts,
    quick: bool,
    live: Option<Duration>,
) -> SessionOutcome {
    let svc = ComputeService::start(registry, opts);
    let metrics = svc.metrics();
    let completed = AtomicUsize::new(0);
    let failures = AtomicUsize::new(0);
    let mismatches = AtomicUsize::new(0);
    let done = AtomicBool::new(false);
    let t0 = Instant::now();
    let mut wall = Duration::ZERO;
    std::thread::scope(|scope| {
        let mut workers = Vec::with_capacity(clients);
        for c in 0..clients {
            let (svc, completed) = (&svc, &completed);
            let (failures, mismatches) = (&failures, &mismatches);
            workers.push(scope.spawn(move || {
                for k in 0..requests_per_client {
                    let req = mixed_request(c + k * 3, quick);
                    let iters = req.iters.expect("mixed_request sets iters");
                    let expect = req.workload.reference(iters);
                    match svc.submit(req) {
                        Ok(handle) => match handle.wait() {
                            Ok(resp) => {
                                completed.fetch_add(1, Ordering::SeqCst);
                                if resp.output != expect {
                                    mismatches.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => {
                                failures.fetch_add(1, Ordering::SeqCst);
                            }
                        },
                        Err(_) => {
                            failures.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
            }));
        }
        if let Some(period) = live {
            let (done, metrics) = (&done, &metrics);
            scope.spawn(move || {
                while !done.load(Ordering::SeqCst) {
                    eprintln!("{}", metrics.render_live());
                    std::thread::sleep(period);
                }
                // One final line so short sessions still show totals.
                eprintln!("{}", metrics.render_live());
            });
        }
        for w in workers {
            if w.join().is_err() {
                // Explicit joins don't re-panic like scope auto-joins
                // do: a crashed client thread must surface as a failed
                // session, never as a silently shorter one.
                failures.fetch_add(1, Ordering::SeqCst);
            }
        }
        // The session ends when the last client finishes — the
        // dashboard thread's final tick is not part of the wall time.
        wall = t0.elapsed();
        done.store(true, Ordering::SeqCst);
    });
    let latency_hist = metrics.latency_ns.snapshot();
    let stats = svc.stats();
    let report = svc.shutdown();
    SessionOutcome {
        clients,
        requests_per_client,
        completed: completed.into_inner(),
        failures: failures.into_inner(),
        mismatches: mismatches.into_inner(),
        wall,
        latency_hist,
        stats,
        report,
    }
}

/// One workload kind's batched-vs-unbatched verdict.
struct CrossVal {
    workload: &'static str,
    requests: usize,
    ok: bool,
    error: Option<String>,
}

/// Micro-batch 3 mixed-size requests per kind and compare every output
/// against its unbatched scheduler run and the host oracle.
fn cross_validate(registry: &BackendRegistry, quick: bool) -> Vec<CrossVal> {
    let s = if quick { 1 } else { 2 };
    let kinds: Vec<(&'static str, Vec<WorkloadRequest>)> = vec![
        (
            "prng",
            vec![
                WorkloadRequest::new(PrngWorkload::new(1024 * s)).iters(3),
                WorkloadRequest::new(PrngWorkload::new(512 * s)).iters(3),
                WorkloadRequest::new(PrngWorkload::new(2048 * s)).iters(3),
            ],
        ),
        (
            "saxpy",
            vec![
                WorkloadRequest::new(SaxpyWorkload::new(1536 * s, 2.5)).iters(3),
                WorkloadRequest::new(SaxpyWorkload::new(300 * s, -1.25)).iters(3),
                WorkloadRequest::new(SaxpyWorkload::new(2048 * s, 0.5)).iters(3),
            ],
        ),
        (
            "reduce",
            vec![
                WorkloadRequest::new(ReduceWorkload::new(4096 * s)).iters(2),
                WorkloadRequest::new(ReduceWorkload::new(1000 * s)).iters(2),
                WorkloadRequest::new(ReduceWorkload::new(2048 * s)).iters(2),
            ],
        ),
        (
            "stencil",
            vec![
                WorkloadRequest::new(StencilWorkload::new(24, 16)).iters(2),
                WorkloadRequest::new(StencilWorkload::new(16, 32)).iters(2),
                WorkloadRequest::new(StencilWorkload::new(40, 24)).iters(2),
            ],
        ),
        (
            "matmul",
            vec![
                WorkloadRequest::new(MatmulWorkload::new(16)).iters(2),
                WorkloadRequest::new(MatmulWorkload::new(12)).iters(2),
                WorkloadRequest::new(MatmulWorkload::new(24)).iters(2),
            ],
        ),
    ];

    let mut out = Vec::new();
    for (name, reqs) in kinds {
        let opts = ServiceOpts { min_chunk: 256, ..ServiceOpts::default() };
        let n = reqs.len();
        let verdict = (|| -> Result<bool, String> {
            let batched = run_batch(registry, &reqs, &opts).map_err(|e| e.to_string())?;
            if batched.outputs.len() != n {
                return Err(format!(
                    "batch returned {} outputs for {n} requests",
                    batched.outputs.len()
                ));
            }
            for (i, req) in reqs.iter().enumerate() {
                let iters = req.iters.expect("cross_validate sets iters");
                // (a) the same request, unbatched, through the same
                // scheduler; (b) the host oracle.
                let cfg = ShardedConfig::new(req.workload.clone(), iters);
                let unbatched = run_sharded_workload_on(registry, &cfg)
                    .map_err(|e| e.to_string())?
                    .final_output;
                let oracle = req.workload.reference(iters);
                if batched.outputs[i] != unbatched || batched.outputs[i] != oracle {
                    return Ok(false);
                }
            }
            Ok(true)
        })();
        match verdict {
            Ok(ok) => out.push(CrossVal { workload: name, requests: n, ok, error: None }),
            Err(e) => out.push(CrossVal {
                workload: name,
                requests: n,
                ok: false,
                error: Some(e),
            }),
        }
    }
    out
}

fn render_md(crossval: &[CrossVal], sessions: &[SessionOutcome], quick: bool) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Compute service — micro-batching cross-validation and \
         multi-client latency ({} mode)\n\n",
        if quick { "quick" } else { "full" }
    ));
    s.push_str("## Batched vs unbatched (bit-identity gate)\n\n");
    s.push_str("| workload | requests in batch | verdict |\n|---|---:|---|\n");
    for c in crossval {
        let verdict = match (&c.error, c.ok) {
            (Some(e), _) => format!("**ERROR**: {e}"),
            (None, true) => "✓ bit-identical".to_string(),
            (None, false) => "**DIVERGED**".to_string(),
        };
        s.push_str(&format!("| {} | {} | {verdict} |\n", c.workload, c.requests));
    }
    s.push_str(
        "\nEach batch coalesces mixed-size same-kind requests into one \
         request-aligned scheduler dispatch; outputs are split back per \
         request and compared against the unbatched run and the host \
         oracle.\n\n",
    );
    s.push_str("## Concurrent-client sessions (mixed workload stream)\n\n");
    s.push_str(
        "| clients | requests | req/s | p50 ms | p95 ms | batches | \
         coalesced | max batch | errors |\n\
         |---:|---:|---:|---:|---:|---:|---:|---:|---:|\n",
    );
    for o in sessions {
        s.push_str(&format!(
            "| {} | {} | {:.1} | {:.2} | {:.2} | {} | {} | {} | {} |\n",
            o.clients,
            o.completed,
            o.req_per_s(),
            o.p50_ms(),
            o.p95_ms(),
            o.stats.batches,
            o.stats.coalesced,
            o.stats.max_batch,
            o.failures + o.mismatches,
        ));
    }
    s
}

fn render_json(crossval: &[CrossVal], sessions: &[SessionOutcome], quick: bool) -> String {
    use super::json_escape as esc;
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"crossval\": [\n");
    for (i, c) in crossval.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"requests\": {}, \"ok\": {}{}}}{}\n",
            c.workload,
            c.requests,
            c.ok,
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < crossval.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"sessions\": [\n");
    for (i, o) in sessions.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"clients\": {}, \"requests\": {}, \"req_per_s\": {:.3}, \
             \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"wall_ms\": {:.3}, \
             \"batches\": {}, \"coalesced\": {}, \"max_batch\": {}, \
             \"failures\": {}, \"mismatches\": {}}}{}\n",
            o.clients,
            o.completed,
            o.req_per_s(),
            o.p50_ms(),
            o.p95_ms(),
            o.wall.as_secs_f64() * 1e3,
            o.stats.batches,
            o.stats.coalesced,
            o.stats.max_batch,
            o.failures,
            o.mismatches,
            if i + 1 < sessions.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Build the full report. Returns `(markdown, json, validated)` — the
/// caller writes both files even when validation failed (the artifacts
/// are the evidence) but must exit non-zero on `!validated`.
pub fn report(quick: bool) -> (String, String, bool) {
    // A fresh registry keeps profiling/timeline state isolated from the
    // process-global one other harness commands use.
    let registry = Arc::new(BackendRegistry::with_default_backends());

    let crossval = cross_validate(&registry, quick);

    let counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let rpc = if quick { 10 } else { 32 };
    let mut sessions = Vec::new();
    for &clients in counts {
        let opts = ServiceOpts {
            max_batch: 8,
            batch_window: Duration::from_millis(3),
            min_chunk: 1024,
            ..ServiceOpts::default()
        };
        sessions.push(run_session(registry.clone(), clients, rpc, opts, quick, None));
    }

    let validated = crossval.iter().all(|c| c.ok && c.error.is_none())
        && sessions.iter().all(|o| o.failures == 0 && o.mismatches == 0);
    (
        render_md(&crossval, &sessions, quick),
        render_json(&crossval, &sessions, quick),
        validated,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates_and_survives_the_edges() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&v, 0.50), 5.5);
        assert!((percentile(&v, 0.95) - 9.55).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
        // Out-of-range quantiles clamp instead of indexing out.
        assert_eq!(percentile(&v, 2.0), 10.0);
        assert_eq!(percentile(&v, -1.0), 1.0);
        // Empty and single-sample edge cases (the old implementation's
        // regression surface).
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[42.0], 0.95), 42.0);
        assert_eq!(percentile(&[42.0], 0.0), 42.0);
        // Interpolation at q = 0.95 for tiny N: pos = 0.95 between the
        // two samples, not a rounded jump to the max.
        assert!((percentile(&[1.0, 3.0], 0.95) - 2.9).abs() < 1e-12);
        assert!((percentile(&[1.0, 3.0, 5.0], 0.95) - 4.8).abs() < 1e-12);
    }

    #[test]
    fn session_percentiles_come_from_the_service_histogram() {
        use crate::metrics::bucket_index;
        let h = Histogram::new();
        for ms in [1u64, 2, 3, 40] {
            h.record(ms * 1_000_000);
        }
        let o = SessionOutcome {
            clients: 1,
            requests_per_client: 4,
            completed: 4,
            failures: 0,
            mismatches: 0,
            wall: Duration::from_millis(50),
            latency_hist: h,
            stats: ServiceStats::default(),
            report: ServiceReport {
                stats: ServiceStats::default(),
                prof_summary: None,
                prof_export: None,
            },
        };
        // p50 lands in 2 ms's bucket, p95 in 40 ms's — dashboard and
        // harness read the same instrument.
        let ns = |ms: f64| (ms * 1e6) as u64;
        assert_eq!(bucket_index(ns(o.p50_ms())), bucket_index(2_000_000));
        assert_eq!(bucket_index(ns(o.p95_ms())), bucket_index(40_000_000));
    }

    #[test]
    fn mixed_stream_covers_all_kinds() {
        let names: std::collections::BTreeSet<&'static str> =
            (0..10).map(|i| mixed_request(i, true).workload.name()).collect();
        assert_eq!(names.len(), 5, "{names:?}");
    }

    #[test]
    fn cross_validation_passes_on_the_default_registry() {
        let registry = BackendRegistry::with_default_backends();
        for c in cross_validate(&registry, true) {
            assert!(c.error.is_none(), "{}: {:?}", c.workload, c.error);
            assert!(c.ok, "{}: batched != unbatched", c.workload);
        }
    }
}
