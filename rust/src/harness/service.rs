//! The compute-service benchmark: throughput/latency under concurrent
//! clients, plus the micro-batching cross-validation gate.
//!
//! Two parts:
//!
//! * **Cross-validation** — for every workload kind, a micro-batch of
//!   mixed-size requests is executed through
//!   [`run_batch`](crate::coordinator::service::run_batch) and each
//!   split-back output is compared bit-for-bit against (a) the same
//!   request run unbatched through the sharded scheduler and (b) the
//!   host oracle. Any divergence fails the run — CI gates on it.
//! * **Sessions** — a [`ComputeService`] session per client count:
//!   every client submits a deterministic mixed-workload request stream,
//!   validates each response against the oracle and records
//!   submit-to-answer latency. The table reports p50/p95 latency and
//!   requests/sec.
//!
//! Emits `results/service.md` (human table) and
//! `results/BENCH_service.json` (machine-readable, schema [`SCHEMA`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::BackendRegistry;
use crate::coordinator::scheduler::{run_sharded_workload_on, ShardedConfig};
use crate::coordinator::service::{
    run_batch, ComputeService, ServiceOpts, ServiceReport, ServiceStats,
    WorkloadRequest,
};
use crate::workload::{
    MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload, StencilWorkload,
    Workload,
};

/// Version tag of `BENCH_service.json`. Bump on layout changes so trend
/// tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-service/1";

/// A deterministic mixed stream of service requests: all five workload
/// kinds, several sizes per kind (mixed-size same-kind requests are
/// exactly what micro-batching coalesces).
pub fn mixed_request(i: usize, quick: bool) -> WorkloadRequest {
    let s = if quick { 1 } else { 4 };
    match i % 5 {
        0 => WorkloadRequest::new(PrngWorkload::new(1024 * s * (1 + i % 3))).iters(3),
        1 => WorkloadRequest::new(SaxpyWorkload::new(768 * s * (1 + i % 4), 2.5)).iters(3),
        2 => WorkloadRequest::new(ReduceWorkload::new(2048 * s * (1 + i % 2))).iters(2),
        3 => WorkloadRequest::new(StencilWorkload::new(16 + 8 * (i % 3), 24)).iters(2),
        _ => WorkloadRequest::new(MatmulWorkload::new(12 + 4 * (i % 3))).iters(2),
    }
}

/// What one multi-client service session measured.
pub struct SessionOutcome {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Requests answered successfully.
    pub completed: usize,
    /// Submit/wait errors.
    pub failures: usize,
    /// Responses that did not match the host oracle.
    pub mismatches: usize,
    pub wall: Duration,
    /// Per-request submit-to-answer latencies in ms, sorted ascending.
    pub latencies_ms: Vec<f64>,
    pub stats: ServiceStats,
    pub report: ServiceReport,
}

impl SessionOutcome {
    pub fn req_per_s(&self) -> f64 {
        if self.wall.as_secs_f64() > 0.0 {
            self.completed as f64 / self.wall.as_secs_f64()
        } else {
            0.0
        }
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.50)
    }

    pub fn p95_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 0.95)
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run one service session: `clients` threads each submitting
/// `requests_per_client` mixed requests, every response validated
/// against the host oracle.
pub fn run_session(
    registry: Arc<BackendRegistry>,
    clients: usize,
    requests_per_client: usize,
    opts: ServiceOpts,
    quick: bool,
) -> SessionOutcome {
    let svc = ComputeService::start(registry, opts);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::new());
    let failures = AtomicUsize::new(0);
    let mismatches = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let (svc, latencies) = (&svc, &latencies);
            let (failures, mismatches) = (&failures, &mismatches);
            scope.spawn(move || {
                for k in 0..requests_per_client {
                    let req = mixed_request(c + k * 3, quick);
                    let iters = req.iters.expect("mixed_request sets iters");
                    let expect = req.workload.reference(iters);
                    let t = Instant::now();
                    match svc.submit(req) {
                        Ok(handle) => match handle.wait() {
                            Ok(resp) => {
                                latencies
                                    .lock()
                                    .unwrap()
                                    .push(t.elapsed().as_secs_f64() * 1e3);
                                if resp.output != expect {
                                    mismatches.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => {
                                failures.fetch_add(1, Ordering::SeqCst);
                            }
                        },
                        Err(_) => {
                            failures.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let stats = svc.stats();
    let report = svc.shutdown();
    let mut latencies = latencies.into_inner().unwrap();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    SessionOutcome {
        clients,
        requests_per_client,
        completed: latencies.len(),
        failures: failures.into_inner(),
        mismatches: mismatches.into_inner(),
        wall,
        latencies_ms: latencies,
        stats,
        report,
    }
}

/// One workload kind's batched-vs-unbatched verdict.
struct CrossVal {
    workload: &'static str,
    requests: usize,
    ok: bool,
    error: Option<String>,
}

/// Micro-batch 3 mixed-size requests per kind and compare every output
/// against its unbatched scheduler run and the host oracle.
fn cross_validate(registry: &BackendRegistry, quick: bool) -> Vec<CrossVal> {
    let s = if quick { 1 } else { 2 };
    let kinds: Vec<(&'static str, Vec<WorkloadRequest>)> = vec![
        (
            "prng",
            vec![
                WorkloadRequest::new(PrngWorkload::new(1024 * s)).iters(3),
                WorkloadRequest::new(PrngWorkload::new(512 * s)).iters(3),
                WorkloadRequest::new(PrngWorkload::new(2048 * s)).iters(3),
            ],
        ),
        (
            "saxpy",
            vec![
                WorkloadRequest::new(SaxpyWorkload::new(1536 * s, 2.5)).iters(3),
                WorkloadRequest::new(SaxpyWorkload::new(300 * s, -1.25)).iters(3),
                WorkloadRequest::new(SaxpyWorkload::new(2048 * s, 0.5)).iters(3),
            ],
        ),
        (
            "reduce",
            vec![
                WorkloadRequest::new(ReduceWorkload::new(4096 * s)).iters(2),
                WorkloadRequest::new(ReduceWorkload::new(1000 * s)).iters(2),
                WorkloadRequest::new(ReduceWorkload::new(2048 * s)).iters(2),
            ],
        ),
        (
            "stencil",
            vec![
                WorkloadRequest::new(StencilWorkload::new(24, 16)).iters(2),
                WorkloadRequest::new(StencilWorkload::new(16, 32)).iters(2),
                WorkloadRequest::new(StencilWorkload::new(40, 24)).iters(2),
            ],
        ),
        (
            "matmul",
            vec![
                WorkloadRequest::new(MatmulWorkload::new(16)).iters(2),
                WorkloadRequest::new(MatmulWorkload::new(12)).iters(2),
                WorkloadRequest::new(MatmulWorkload::new(24)).iters(2),
            ],
        ),
    ];

    let mut out = Vec::new();
    for (name, reqs) in kinds {
        let opts = ServiceOpts { min_chunk: 256, ..ServiceOpts::default() };
        let n = reqs.len();
        let verdict = (|| -> Result<bool, String> {
            let batched = run_batch(registry, &reqs, &opts).map_err(|e| e.to_string())?;
            if batched.outputs.len() != n {
                return Err(format!(
                    "batch returned {} outputs for {n} requests",
                    batched.outputs.len()
                ));
            }
            for (i, req) in reqs.iter().enumerate() {
                let iters = req.iters.expect("cross_validate sets iters");
                // (a) the same request, unbatched, through the same
                // scheduler; (b) the host oracle.
                let cfg = ShardedConfig::new(req.workload.clone(), iters);
                let unbatched = run_sharded_workload_on(registry, &cfg)
                    .map_err(|e| e.to_string())?
                    .final_output;
                let oracle = req.workload.reference(iters);
                if batched.outputs[i] != unbatched || batched.outputs[i] != oracle {
                    return Ok(false);
                }
            }
            Ok(true)
        })();
        match verdict {
            Ok(ok) => out.push(CrossVal { workload: name, requests: n, ok, error: None }),
            Err(e) => out.push(CrossVal {
                workload: name,
                requests: n,
                ok: false,
                error: Some(e),
            }),
        }
    }
    out
}

fn render_md(crossval: &[CrossVal], sessions: &[SessionOutcome], quick: bool) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Compute service — micro-batching cross-validation and \
         multi-client latency ({} mode)\n\n",
        if quick { "quick" } else { "full" }
    ));
    s.push_str("## Batched vs unbatched (bit-identity gate)\n\n");
    s.push_str("| workload | requests in batch | verdict |\n|---|---:|---|\n");
    for c in crossval {
        let verdict = match (&c.error, c.ok) {
            (Some(e), _) => format!("**ERROR**: {e}"),
            (None, true) => "✓ bit-identical".to_string(),
            (None, false) => "**DIVERGED**".to_string(),
        };
        s.push_str(&format!("| {} | {} | {verdict} |\n", c.workload, c.requests));
    }
    s.push_str(
        "\nEach batch coalesces mixed-size same-kind requests into one \
         request-aligned scheduler dispatch; outputs are split back per \
         request and compared against the unbatched run and the host \
         oracle.\n\n",
    );
    s.push_str("## Concurrent-client sessions (mixed workload stream)\n\n");
    s.push_str(
        "| clients | requests | req/s | p50 ms | p95 ms | batches | \
         coalesced | max batch | errors |\n\
         |---:|---:|---:|---:|---:|---:|---:|---:|---:|\n",
    );
    for o in sessions {
        s.push_str(&format!(
            "| {} | {} | {:.1} | {:.2} | {:.2} | {} | {} | {} | {} |\n",
            o.clients,
            o.completed,
            o.req_per_s(),
            o.p50_ms(),
            o.p95_ms(),
            o.stats.batches,
            o.stats.coalesced,
            o.stats.max_batch,
            o.failures + o.mismatches,
        ));
    }
    s
}

fn render_json(crossval: &[CrossVal], sessions: &[SessionOutcome], quick: bool) -> String {
    use super::json_escape as esc;
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"crossval\": [\n");
    for (i, c) in crossval.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"requests\": {}, \"ok\": {}{}}}{}\n",
            c.workload,
            c.requests,
            c.ok,
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < crossval.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"sessions\": [\n");
    for (i, o) in sessions.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"clients\": {}, \"requests\": {}, \"req_per_s\": {:.3}, \
             \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"wall_ms\": {:.3}, \
             \"batches\": {}, \"coalesced\": {}, \"max_batch\": {}, \
             \"failures\": {}, \"mismatches\": {}}}{}\n",
            o.clients,
            o.completed,
            o.req_per_s(),
            o.p50_ms(),
            o.p95_ms(),
            o.wall.as_secs_f64() * 1e3,
            o.stats.batches,
            o.stats.coalesced,
            o.stats.max_batch,
            o.failures,
            o.mismatches,
            if i + 1 < sessions.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Build the full report. Returns `(markdown, json, validated)` — the
/// caller writes both files even when validation failed (the artifacts
/// are the evidence) but must exit non-zero on `!validated`.
pub fn report(quick: bool) -> (String, String, bool) {
    // A fresh registry keeps profiling/timeline state isolated from the
    // process-global one other harness commands use.
    let registry = Arc::new(BackendRegistry::with_default_backends());

    let crossval = cross_validate(&registry, quick);

    let counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let rpc = if quick { 10 } else { 32 };
    let mut sessions = Vec::new();
    for &clients in counts {
        let opts = ServiceOpts {
            max_batch: 8,
            batch_window: Duration::from_millis(3),
            min_chunk: 1024,
            ..ServiceOpts::default()
        };
        sessions.push(run_session(registry.clone(), clients, rpc, opts, quick));
    }

    let validated = crossval.iter().all(|c| c.ok && c.error.is_none())
        && sessions.iter().all(|o| o.failures == 0 && o.mismatches == 0);
    (
        render_md(&crossval, &sessions, quick),
        render_json(&crossval, &sessions, quick),
        validated,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_picks_sane_indices() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&v, 0.50), 6.0);
        assert_eq!(percentile(&v, 0.95), 10.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn mixed_stream_covers_all_kinds() {
        let names: std::collections::BTreeSet<&'static str> =
            (0..10).map(|i| mixed_request(i, true).workload.name()).collect();
        assert_eq!(names.len(), 5, "{names:?}");
    }

    #[test]
    fn cross_validation_passes_on_the_default_registry() {
        let registry = BackendRegistry::with_default_backends();
        for c in cross_validate(&registry, true) {
            assert!(c.error.is_none(), "{}: {:?}", c.workload, c.error);
            assert!(c.ok, "{}: batched != unbatched", c.workload);
        }
    }
}
