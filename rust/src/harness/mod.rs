//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§6) — see DESIGN.md §3 for the experiment index.
//!
//! * `bench loc`      — E1, the §6.1 LOC comparison table;
//! * `bench overhead` — E3+E5, the Fig. 4 overhead sweep + trend checks;
//! * `bench figure3`  — E2, the Fig. 3 profiling summary;
//! * `bench figure5`  — E4, the Fig. 5 queue utilization chart;
//! * `bench backends` — the backend cross-validation/comparison table;
//! * `bench workloads` — the (workload × path) matrix: every workload
//!   through rawcl/ccl-v1/ccl-v2/sharded, timed and validated
//!   bit-identical (writes `workloads.md` + `BENCH_workloads.json`);
//! * `bench service`  — the compute-service cell: micro-batching
//!   cross-validated bit-identical against unbatched execution, plus
//!   p50/p95 latency + requests/sec at several concurrent-client counts
//!   (writes `service.md` + `BENCH_service.json`);
//! * `bench adaptive` — the adaptive-control cell: static vs adaptive
//!   batch window at 8 clients, uniform vs throughput-proportional
//!   shards on a deterministically skewed registry, all outputs
//!   cross-validated bit-identical (writes `adaptive.md` +
//!   `BENCH_adaptive.json`);
//! * `bench native`   — the native-tier speedup gate: interpreter vs
//!   native median wall per workload at small/large shapes, the 5×5
//!   (workload × path) bit-identity check, and the native ≥ 2×
//!   interpreter requirement at large shapes (writes `native.md` +
//!   `BENCH_native.json`);
//! * `bench zoo`      — the plugin-ABI device-zoo cell: every workload
//!   sharded over the heterogeneous zoo (native + throttled + flaky +
//!   dying + memory-capped) under the paranoid fault policy with
//!   bit-identity asserted, ABI/capability negotiation demos, the
//!   hint-primed warm-start plan, memory-capped planning and the
//!   buffer-pool before/after (writes `zoo.md` + `BENCH_zoo.json`);
//! * `bench edge`     — the serving-edge cell: an open-loop
//!   load generator (fixed arrival schedules, many concurrent
//!   connections, sender/receiver thread pairs) against a live
//!   `cf4rs edge` subprocess, every response oracle-validated
//!   bit-for-bit; gates priority inversion (high p99 < bulk p99 under
//!   mixed load) and overload shedding (bulk sheds first, and only
//!   when offered load exceeds capacity) (writes `edge.md` +
//!   `BENCH_edge.json`);
//! * `bench lint-graph` — the static-analysis detector gate, two-sided:
//!   the clean 5-workloads × 5-paths matrix replayed under the command
//!   recorder must analyze to zero findings, and every seeded-bug
//!   corpus stream must be flagged with its expected rule (writes
//!   `lint-graph.md` + `BENCH_lint-graph.json`);
//! * `bench trace`   — the end-to-end tracing gate, two-sided:
//!   disabled tracing must cost nothing measurable (interleaved
//!   off/on/off arms; the two disabled medians must agree within 1% +
//!   a noise floor, the enabled median within 5%), and every traced
//!   request through a live in-process edge must assemble into exactly
//!   one rooted span tree with edge → service → shard → device
//!   descendants and no orphans; also writes + validates the Chrome
//!   trace-event export (writes `trace.md`, `BENCH_trace.json` and
//!   `trace_chrome.json`);
//! * `bench all`      — everything, written to `results/`.
//!
//! Every failed regeneration — including a failed `results/` write —
//! makes the process exit non-zero, so CI catches harness regressions.

pub mod adaptive;
pub mod backends;
pub mod edge;
pub mod figures;
pub mod lint;
pub mod loc;
pub mod microbench;
pub mod native;
pub mod overhead;
pub mod service;
pub mod trace;
pub mod workloads;
pub mod zoo;

use std::path::Path;

/// Minimal JSON string escape shared by the harness's `BENCH_*.json`
/// emitters (backslash, quote, newline — the characters error strings
/// actually contain).
pub(crate) fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Write one result file; `false` (a harness failure) when the write
/// fails — silently missing result files must fail CI.
#[must_use]
fn write_result(name: &str, content: &str) -> bool {
    let dir = Path::new("results");
    if let Err(e) = std::fs::create_dir_all(dir) {
        eprintln!("  cannot create {}: {e}", dir.display());
        return false;
    }
    let path = dir.join(name);
    match std::fs::write(&path, content) {
        Ok(()) => {
            eprintln!("  wrote {}", path.display());
            true
        }
        Err(e) => {
            eprintln!("  cannot write {}: {e}", path.display());
            false
        }
    }
}

/// `cf4rs bench` entrypoint.
pub fn main(args: &[String]) -> i32 {
    let Some(which) = args.first() else {
        eprintln!(
            "usage: cf4rs bench loc|overhead|figure3|figure5|ablation|backends|\
             workloads|service|adaptive|native|zoo|edge|lint-graph|trace|all [--quick]"
        );
        return 2;
    };
    let quick = args.iter().any(|a| a == "--quick");

    fn run_loc() -> bool {
        match loc::report() {
            Ok(r) => {
                print!("{r}");
                write_result("loc.md", &r)
            }
            Err(e) => {
                eprintln!("loc: {e}");
                false
            }
        }
    }
    fn run_overhead(quick: bool) -> bool {
        let opts = if quick {
            overhead::SweepOpts::quick()
        } else {
            overhead::SweepOpts::paper()
        };
        match overhead::sweep(&opts) {
            Ok(cells) => {
                let r = overhead::render(&cells);
                print!("{r}");
                let mut ok = write_result("overhead.md", &r);
                // machine-readable series for replotting
                let mut csv = String::from("device,n,iters,t_raw,t_ccl,ratio,min,max\n");
                for c in &cells {
                    csv.push_str(&format!(
                        "{},{},{},{:.6},{:.6},{:.4},{:.4},{:.4}\n",
                        c.device_name, c.n, c.iters, c.t_raw, c.t_ccl, c.ratio,
                        c.ratio_min, c.ratio_max
                    ));
                }
                ok &= write_result("overhead.csv", &csv);
                ok
            }
            Err(e) => {
                eprintln!("overhead: {e}");
                false
            }
        }
    }
    fn run_fig3(quick: bool) -> bool {
        let (n, i) = if quick { (65536, 6) } else { (262144, 16) };
        match figures::figure3(n, i) {
            Ok(s) => {
                print!("{s}");
                write_result("figure3.txt", &s)
            }
            Err(e) => {
                eprintln!("figure3: {e}");
                false
            }
        }
    }
    fn run_fig5(quick: bool) -> bool {
        let (n, i) = if quick { (65536, 4) } else { (1048576, 8) };
        match figures::figure5(n, i) {
            Ok((report, tsv, svg)) => {
                print!("{report}");
                // Attempt every write even if one fails (& not &&).
                let mut ok = write_result("figure5.txt", &report);
                ok &= write_result("figure5.tsv", &tsv);
                ok &= write_result("figure5.svg", &svg);
                ok
            }
            Err(e) => {
                eprintln!("figure5: {e}");
                false
            }
        }
    }

    fn run_ablation(quick: bool) -> bool {
        match overhead::profiling_ablation(quick) {
            Ok(s) => {
                print!("{s}");
                write_result("ablation_profiling.md", &s)
            }
            Err(e) => {
                eprintln!("ablation: {e}");
                false
            }
        }
    }

    fn run_backends(quick: bool) -> bool {
        match backends::report(quick) {
            Ok(s) => {
                print!("{s}");
                write_result("backends.md", &s)
            }
            Err(e) => {
                eprintln!("backends: {e}");
                false
            }
        }
    }

    fn run_workloads(quick: bool) -> bool {
        let (md, json, validated) = workloads::report(quick);
        print!("{md}");
        // Write both artifacts even when validation failed — they are
        // the evidence — but fail the run on any divergence.
        let mut ok = write_result("workloads.md", &md);
        ok &= write_result("BENCH_workloads.json", &json);
        if !validated {
            eprintln!("workloads: cross-path validation FAILED (see table)");
        }
        ok && validated
    }

    fn run_service(quick: bool) -> bool {
        let (md, json, validated) = service::report(quick);
        print!("{md}");
        // Write both artifacts even when validation failed — they are
        // the evidence — but fail the run on any divergence.
        let mut ok = write_result("service.md", &md);
        ok &= write_result("BENCH_service.json", &json);
        if !validated {
            eprintln!("service: batched-vs-unbatched cross-validation FAILED");
        }
        ok && validated
    }

    fn run_adaptive(quick: bool) -> bool {
        let (md, json, validated) = adaptive::report(quick);
        print!("{md}");
        // Write both artifacts even when a gate failed — they are the
        // evidence — but fail the run on any gate.
        let mut ok = write_result("adaptive.md", &md);
        ok &= write_result("BENCH_adaptive.json", &json);
        if !validated {
            eprintln!(
                "adaptive: a gate FAILED (bit-identity, window req/s or \
                 proportional-shards wall-time; see table)"
            );
        }
        ok && validated
    }

    fn run_native(quick: bool) -> bool {
        let (md, json, validated) = native::report(quick);
        print!("{md}");
        // Write both artifacts even when a gate failed — they are the
        // evidence — but fail the run on any gate.
        let mut ok = write_result("native.md", &md);
        ok &= write_result("BENCH_native.json", &json);
        if !validated {
            eprintln!(
                "native: a gate FAILED (validation, 5-path bit-identity or \
                 the >=2x large-shape speedup; see table)"
            );
        }
        ok && validated
    }

    fn run_zoo(quick: bool) -> bool {
        let (md, json, validated) = zoo::report(quick);
        print!("{md}");
        // Write both artifacts even when a gate failed — they are the
        // evidence — but fail the run on any gate.
        let mut ok = write_result("zoo.md", &md);
        ok &= write_result("BENCH_zoo.json", &json);
        if !validated {
            eprintln!(
                "zoo: a gate FAILED (bit-identity under faults, negotiation, \
                 warm start, memory-capped plan or pool reuse; see table)"
            );
        }
        ok && validated
    }

    fn run_lint_graph(quick: bool) -> bool {
        let (md, json, validated) = lint::report(quick);
        print!("{md}");
        // Write both artifacts even when a gate failed — they are the
        // evidence — but fail the run on any gate.
        let mut ok = write_result("lint-graph.md", &md);
        ok &= write_result("BENCH_lint-graph.json", &json);
        if !validated {
            eprintln!(
                "lint-graph: a gate FAILED (findings on the clean matrix, a \
                 replay error, or a seeded bug the analyzer missed; see table)"
            );
        }
        ok && validated
    }

    fn run_trace(quick: bool) -> bool {
        let (md, json, validated) = trace::report(quick);
        print!("{md}");
        // Write both artifacts even when a gate failed — they are the
        // evidence — but fail the run on any gate.
        let mut ok = write_result("trace.md", &md);
        ok &= write_result("BENCH_trace.json", &json);
        if !validated {
            eprintln!(
                "trace: a gate FAILED (disabled-tracing overhead, enabled \
                 overhead, tree completeness or the Chrome export; see table)"
            );
        }
        ok && validated
    }

    fn run_edge(quick: bool) -> bool {
        let (md, json, validated) = edge::report(quick);
        print!("{md}");
        // Write both artifacts even when a gate failed — they are the
        // evidence — but fail the run on any gate.
        let mut ok = write_result("edge.md", &md);
        ok &= write_result("BENCH_edge.json", &json);
        if !validated {
            eprintln!(
                "edge: a gate FAILED (oracle identity, high-vs-bulk p99 \
                 ordering or shed discipline; see table)"
            );
        }
        ok && validated
    }

    let ok = match which.as_str() {
        "loc" => run_loc(),
        "ablation" => run_ablation(quick),
        "overhead" => run_overhead(quick),
        "figure3" => run_fig3(quick),
        "figure5" => run_fig5(quick),
        "backends" => run_backends(quick),
        "workloads" => run_workloads(quick),
        "service" => run_service(quick),
        "adaptive" => run_adaptive(quick),
        "native" => run_native(quick),
        "zoo" => run_zoo(quick),
        "edge" => run_edge(quick),
        "lint-graph" => run_lint_graph(quick),
        "trace" => run_trace(quick),
        "all" => {
            let l = run_loc();
            let a = run_fig3(quick);
            let b = run_fig5(quick);
            let c = run_overhead(quick);
            let d = run_ablation(quick);
            let e = run_backends(quick);
            let f = run_workloads(quick);
            let g = run_service(quick);
            let h = run_adaptive(quick);
            let i = run_native(quick);
            let j = run_zoo(quick);
            let k = run_edge(quick);
            let m = run_lint_graph(quick);
            let n = run_trace(quick);
            l && a && b && c && d && e && f && g && h && i && j && k && m && n
        }
        other => {
            eprintln!("unknown bench {other:?}");
            return 2;
        }
    };
    if ok {
        0
    } else {
        1
    }
}
