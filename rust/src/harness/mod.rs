//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§6) — see DESIGN.md §3 for the experiment index.
//!
//! * `bench loc`      — E1, the §6.1 LOC comparison table;
//! * `bench overhead` — E3+E5, the Fig. 4 overhead sweep + trend checks;
//! * `bench figure3`  — E2, the Fig. 3 profiling summary;
//! * `bench figure5`  — E4, the Fig. 5 queue utilization chart;
//! * `bench all`      — everything, written to `results/`.

pub mod figures;
pub mod loc;
pub mod microbench;
pub mod overhead;

use std::path::Path;

fn write_result(name: &str, content: &str) {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir).ok();
    let path = dir.join(name);
    if std::fs::write(&path, content).is_ok() {
        eprintln!("  wrote {}", path.display());
    }
}

/// `cf4rs bench` entrypoint.
pub fn main(args: &[String]) -> i32 {
    let Some(which) = args.first() else {
        eprintln!("usage: cf4rs bench loc|overhead|figure3|figure5|ablation|all [--quick]");
        return 2;
    };
    let quick = args.iter().any(|a| a == "--quick");

    fn run_loc() {
        let r = loc::report();
        print!("{r}");
        write_result("loc.md", &r);
    }
    fn run_overhead(quick: bool) -> bool {
        let opts = if quick {
            overhead::SweepOpts::quick()
        } else {
            overhead::SweepOpts::paper()
        };
        match overhead::sweep(&opts) {
            Ok(cells) => {
                let r = overhead::render(&cells);
                print!("{r}");
                write_result("overhead.md", &r);
                // machine-readable series for replotting
                let mut csv = String::from("device,n,iters,t_raw,t_ccl,ratio,min,max\n");
                for c in &cells {
                    csv.push_str(&format!(
                        "{},{},{},{:.6},{:.6},{:.4},{:.4},{:.4}\n",
                        c.device_name, c.n, c.iters, c.t_raw, c.t_ccl, c.ratio,
                        c.ratio_min, c.ratio_max
                    ));
                }
                write_result("overhead.csv", &csv);
                true
            }
            Err(e) => {
                eprintln!("overhead: {e}");
                false
            }
        }
    }
    fn run_fig3(quick: bool) -> bool {
        let (n, i) = if quick { (65536, 6) } else { (262144, 16) };
        match figures::figure3(n, i) {
            Ok(s) => {
                print!("{s}");
                write_result("figure3.txt", &s);
                true
            }
            Err(e) => {
                eprintln!("figure3: {e}");
                false
            }
        }
    }
    fn run_fig5(quick: bool) -> bool {
        let (n, i) = if quick { (65536, 4) } else { (1048576, 8) };
        match figures::figure5(n, i) {
            Ok((report, tsv, svg)) => {
                print!("{report}");
                write_result("figure5.txt", &report);
                write_result("figure5.tsv", &tsv);
                write_result("figure5.svg", &svg);
                true
            }
            Err(e) => {
                eprintln!("figure5: {e}");
                false
            }
        }
    }

    fn run_ablation(quick: bool) -> bool {
        match overhead::profiling_ablation(quick) {
            Ok(s) => {
                print!("{s}");
                write_result("ablation_profiling.md", &s);
                true
            }
            Err(e) => {
                eprintln!("ablation: {e}");
                false
            }
        }
    }

    let ok = match which.as_str() {
        "loc" => {
            run_loc();
            true
        }
        "ablation" => run_ablation(quick),
        "overhead" => run_overhead(quick),
        "figure3" => run_fig3(quick),
        "figure5" => run_fig5(quick),
        "all" => {
            run_loc();
            let a = run_fig3(quick);
            let b = run_fig5(quick);
            let c = run_overhead(quick);
            let d = run_ablation(quick);
            a && b && c && d
        }
        other => {
            eprintln!("unknown bench {other:?}");
            return 2;
        }
    };
    if ok {
        0
    } else {
        1
    }
}
