//! `bench adaptive` — does closing the measurement→decision loop pay?
//!
//! Three parts, all gated:
//!
//! * **Cross-validation** — a fully adaptive service (adaptive window
//!   + proportional shards) on a *skewed* registry answers requests of
//!   every workload kind; each response must be bit-identical to the
//!   single-device host oracle. Adaptivity must never touch a bit.
//! * **Window** — the same 8-client mixed-stream session twice: once
//!   with the static 3 ms batch window, once with the Nagle-style
//!   [`AdaptiveWindow`](crate::coordinator::AdaptiveWindow). Gate:
//!   adaptive req/s ≥ static req/s (the adaptive window closes batches
//!   as soon as the queue goes idle instead of always burning the full
//!   static wait).
//! * **Shards** — one SAXPY stream over three
//!   [`ThrottledBackend`](crate::backend::ThrottledBackend)s with
//!   1×/3×/9× injected cost: uniform equal shards vs the
//!   [`ShardPlanner`](crate::coordinator::ShardPlanner)'s proportional
//!   plan from *observed* bytes/ns. Gate: proportional median
//!   wall-time ≤ uniform (the slowest backend stops being the
//!   critical path), outputs bit-identical both ways.
//!
//! Emits `results/adaptive.md` + schema-versioned
//! `results/BENCH_adaptive.json`; CI runs `--quick` and fails on any
//! gate.

use std::sync::Arc;
use std::time::Duration;

use crate::backend::{Backend, BackendRegistry, SimBackend, ThrottledBackend};
use crate::coordinator::scheduler::{run_sharded_workload_on, ShardedConfig};
use crate::coordinator::service::{ComputeService, ServiceOpts, WorkloadRequest};
use crate::coordinator::{plan_proportional, ShardPlanner};
use crate::rawcl::types::DeviceId;
use crate::workload::{
    MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload, StencilWorkload,
    Workload,
};

use super::service::{percentile, run_session, SessionOutcome};

/// Version tag of `BENCH_adaptive.json`. Bump on layout changes so
/// trend tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-adaptive/1";

/// Injected per-KiB kernel costs (ns) of the skewed registry: a 1×,
/// a 3× and a 9× backend.
const SKEW_RATES: [u64; 3] = [2_000, 6_000, 18_000];

/// A fresh three-backend registry with deterministic 1×/3×/9× real
/// speed skew (each throttle wraps its own sim-device instance, so
/// compute stays bit-exact and state is isolated).
fn skewed_registry() -> BackendRegistry {
    let reg = BackendRegistry::new();
    for rate in SKEW_RATES {
        let inner: Arc<dyn Backend> =
            Arc::new(SimBackend::new(DeviceId(1)).expect("sim device 1"));
        reg.register(Arc::new(ThrottledBackend::new(inner, rate)));
    }
    reg
}

// ---------------------------------------------------------------------------
// Cross-validation: adaptivity never touches a bit
// ---------------------------------------------------------------------------

struct CrossVal {
    workload: &'static str,
    requests: usize,
    ok: bool,
    error: Option<String>,
}

/// Every workload kind through a fully adaptive service on the skewed
/// registry, each response compared to the host oracle.
fn cross_validate(quick: bool) -> Vec<CrossVal> {
    let s = if quick { 1 } else { 2 };
    // The requests stay KiB-scale, so the injected sleeps stay small —
    // this part gates bits, not time.
    let registry = Arc::new(skewed_registry());
    let opts = ServiceOpts {
        max_batch: 4,
        batch_window: Duration::from_millis(1),
        min_chunk: 256,
        adaptive_window: true,
        adaptive_shards: true,
        ..ServiceOpts::default()
    };
    let svc = ComputeService::start(registry, opts);
    let kinds: Vec<(&'static str, Vec<WorkloadRequest>)> = vec![
        (
            "prng",
            vec![
                WorkloadRequest::new(PrngWorkload::new(1024 * s)).iters(3),
                WorkloadRequest::new(PrngWorkload::new(2048 * s)).iters(3),
            ],
        ),
        (
            "saxpy",
            vec![
                WorkloadRequest::new(SaxpyWorkload::new(1536 * s, 2.5)).iters(3),
                WorkloadRequest::new(SaxpyWorkload::new(640 * s, -0.5)).iters(3),
            ],
        ),
        (
            "reduce",
            vec![
                WorkloadRequest::new(ReduceWorkload::new(4096 * s)).iters(2),
                WorkloadRequest::new(ReduceWorkload::new(1000 * s)).iters(2),
            ],
        ),
        (
            "stencil",
            vec![
                WorkloadRequest::new(StencilWorkload::new(24, 16)).iters(2),
                WorkloadRequest::new(StencilWorkload::new(16, 32)).iters(2),
            ],
        ),
        (
            "matmul",
            vec![
                WorkloadRequest::new(MatmulWorkload::new(16)).iters(2),
                WorkloadRequest::new(MatmulWorkload::new(12)).iters(2),
            ],
        ),
    ];
    let mut out = Vec::new();
    for (name, reqs) in kinds {
        let n = reqs.len();
        let verdict = (|| -> Result<bool, String> {
            let mut ok = true;
            for req in reqs {
                let iters = req.iters.expect("cross_validate sets iters");
                let oracle = req.workload.reference(iters);
                let resp = svc
                    .submit(req)
                    .map_err(|e| e.to_string())?
                    .wait()
                    .map_err(|e| e.to_string())?;
                ok &= resp.output == oracle;
            }
            Ok(ok)
        })();
        match verdict {
            Ok(ok) => {
                out.push(CrossVal { workload: name, requests: n, ok, error: None })
            }
            Err(e) => out.push(CrossVal {
                workload: name,
                requests: n,
                ok: false,
                error: Some(e),
            }),
        }
    }
    drop(svc.shutdown());
    out
}

// ---------------------------------------------------------------------------
// Window experiment: static vs adaptive at 8 clients
// ---------------------------------------------------------------------------

struct WindowCell {
    label: &'static str,
    /// Last repetition's full outcome (the detail the report shows).
    outcome: SessionOutcome,
    /// req/s of every repetition; the gate compares the medians so a
    /// single perturbed run on a noisy CI host cannot flip it.
    rps: Vec<f64>,
}

impl WindowCell {
    fn rps_median(&self) -> f64 {
        median(&self.rps)
    }

    fn clean(&self) -> bool {
        self.outcome.failures == 0 && self.outcome.mismatches == 0
    }
}

fn window_experiment(quick: bool) -> (WindowCell, WindowCell) {
    let registry = Arc::new(BackendRegistry::with_default_backends());
    let clients = 8;
    let rpc = if quick { 6 } else { 24 };
    let reps = if quick { 2 } else { 3 };
    let run = |label: &'static str, adaptive: bool| {
        let mut rps = Vec::with_capacity(reps);
        let mut last = None;
        for _ in 0..reps {
            let opts = ServiceOpts {
                max_batch: 8,
                batch_window: Duration::from_millis(3),
                min_chunk: 1024,
                adaptive_window: adaptive,
                ..ServiceOpts::default()
            };
            let o = run_session(registry.clone(), clients, rpc, opts, quick, None);
            rps.push(o.req_per_s());
            last = Some(o);
        }
        WindowCell { label, outcome: last.expect("reps >= 1"), rps }
    };
    (run("static", false), run("adaptive", true))
}

// ---------------------------------------------------------------------------
// Shard experiment: uniform vs proportional on real skew
// ---------------------------------------------------------------------------

struct ShardExperiment {
    backends: Vec<(String, u64)>,
    shares: Vec<f64>,
    plan: Vec<usize>,
    uniform_wall_ms: Vec<f64>,
    proportional_wall_ms: Vec<f64>,
    bits_ok: bool,
    error: Option<String>,
}

impl ShardExperiment {
    fn uniform_median_ms(&self) -> f64 {
        median(&self.uniform_wall_ms)
    }

    fn proportional_median_ms(&self) -> f64 {
        median(&self.proportional_wall_ms)
    }

    fn ok(&self) -> bool {
        self.bits_ok
            && self.error.is_none()
            && self.proportional_median_ms() <= self.uniform_median_ms()
    }
}

fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 0.5)
}

fn shard_experiment(quick: bool) -> ShardExperiment {
    let reg = skewed_registry();
    let names: Vec<String> = reg.backends().iter().map(|b| b.name()).collect();
    let backends: Vec<(String, u64)> = names.iter().cloned().zip(SKEW_RATES).collect();
    let n = if quick { 96 * 1024 } else { 192 * 1024 };
    let iters = 2;
    let runs = if quick { 2 } else { 3 };
    let w = SaxpyWorkload::new(n, 2.0);
    let oracle = w.reference(iters);
    let planner = ShardPlanner::new();

    let mut exp = ShardExperiment {
        backends,
        shares: Vec::new(),
        plan: Vec::new(),
        uniform_wall_ms: Vec::new(),
        proportional_wall_ms: Vec::new(),
        bits_ok: true,
        error: None,
    };

    // Uniform runs double as the planner's observation source: exactly
    // the service's feedback loop, replayed deterministically.
    for _ in 0..runs {
        let mut cfg = ShardedConfig::new(w, iters);
        cfg.chunks_per_backend = 1; // one equal shard per backend
        cfg.min_chunk = 1;
        match run_sharded_workload_on(&reg, &cfg) {
            Ok(out) => {
                exp.bits_ok &= out.final_output == oracle;
                exp.uniform_wall_ms.push(out.wall.as_secs_f64() * 1e3);
                for load in &out.per_backend {
                    planner.observe(&load.name, load.bytes, load.busy_ns);
                }
            }
            Err(e) => {
                exp.error = Some(format!("uniform run: {e}"));
                return exp;
            }
        }
    }

    let Some(shares) = planner.shares(&names) else {
        exp.error = Some("planner produced no shares after probing".into());
        return exp;
    };
    let (shards, homes) = plan_proportional(n, &shares, 1024);
    exp.shares = shares;
    // Per-backend planned units, aligned to the registry order.
    let mut per_backend_units = vec![0usize; names.len()];
    for (s, &h) in shards.iter().zip(&homes) {
        per_backend_units[h] += s.len;
    }
    exp.plan = per_backend_units;

    for _ in 0..runs {
        let mut cfg = ShardedConfig::new(w, iters);
        cfg.shard_plan = Some(shards.clone());
        cfg.shard_homes = Some(homes.clone());
        match run_sharded_workload_on(&reg, &cfg) {
            Ok(out) => {
                exp.bits_ok &= out.final_output == oracle;
                exp.proportional_wall_ms.push(out.wall.as_secs_f64() * 1e3);
            }
            Err(e) => {
                exp.error = Some(format!("proportional run: {e}"));
                return exp;
            }
        }
    }
    exp
}

// ---------------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------------

fn render_md(
    crossval: &[CrossVal],
    win: &(WindowCell, WindowCell),
    shards: &ShardExperiment,
    quick: bool,
) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Adaptive control — window sizing and proportional shards \
         ({} mode)\n\n",
        if quick { "quick" } else { "full" }
    ));

    s.push_str("## Adaptive service vs host oracle (bit-identity gate)\n\n");
    s.push_str("| workload | requests | verdict |\n|---|---:|---|\n");
    for c in crossval {
        let verdict = match (&c.error, c.ok) {
            (Some(e), _) => format!("**ERROR**: {e}"),
            (None, true) => "✓ bit-identical".to_string(),
            (None, false) => "**DIVERGED**".to_string(),
        };
        s.push_str(&format!("| {} | {} | {verdict} |\n", c.workload, c.requests));
    }

    s.push_str(
        "\n## Batch window: static vs adaptive (8 clients, mixed stream)\n\n",
    );
    s.push_str(
        "| window | req/s (median of reps) | p50 ms | p95 ms | batches | \
         coalesced | errors |\n|---|---:|---:|---:|---:|---:|---:|\n",
    );
    for cell in [&win.0, &win.1] {
        let o = &cell.outcome;
        s.push_str(&format!(
            "| {} | {:.1} | {:.2} | {:.2} | {} | {} | {} |\n",
            cell.label,
            cell.rps_median(),
            o.p50_ms(),
            o.p95_ms(),
            o.stats.batches,
            o.stats.coalesced,
            o.failures + o.mismatches,
        ));
    }
    let speedup = win.1.rps_median() / win.0.rps_median().max(1e-9);
    s.push_str(&format!(
        "\nAdaptive/static throughput ratio: **{speedup:.2}×** (the \
         adaptive window closes as soon as the queue goes idle instead \
         of burning the full 3 ms straggler wait).\n",
    ));

    s.push_str("\n## Shards: uniform vs throughput-proportional (1×/3×/9× skew)\n\n");
    s.push_str("| backend | injected cost (ns/KiB) | observed share | plan (units) |\n");
    s.push_str("|---|---:|---:|---:|\n");
    for (i, (name, rate)) in shards.backends.iter().enumerate() {
        s.push_str(&format!(
            "| {name} | {rate} | {} | {} |\n",
            shards
                .shares
                .get(i)
                .map(|v| format!("{:.1}%", v * 100.0))
                .unwrap_or_else(|| "—".into()),
            shards.plan.get(i).map(|v| v.to_string()).unwrap_or_else(|| "—".into()),
        ));
    }
    s.push_str(&format!(
        "\n| plan | wall ms (median of {}) |\n|---|---:|\n| uniform | {:.2} \
         |\n| proportional | {:.2} |\n",
        shards.uniform_wall_ms.len(),
        shards.uniform_median_ms(),
        shards.proportional_median_ms(),
    ));
    let ratio = shards.uniform_median_ms() / shards.proportional_median_ms().max(1e-9);
    s.push_str(&format!(
        "\nUniform/proportional wall ratio: **{ratio:.2}×**; outputs {}.\n",
        if shards.bits_ok { "bit-identical" } else { "**DIVERGED**" }
    ));
    if let Some(e) = &shards.error {
        s.push_str(&format!("\n**ERROR**: {e}\n"));
    }
    s
}

fn render_json(
    crossval: &[CrossVal],
    win: &(WindowCell, WindowCell),
    shards: &ShardExperiment,
    quick: bool,
    window_ok: bool,
) -> String {
    use super::json_escape as esc;
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"crossval\": [\n");
    for (i, c) in crossval.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"requests\": {}, \"ok\": {}{}}}{}\n",
            c.workload,
            c.requests,
            c.ok,
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < crossval.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"window\": {\n");
    for (cell, comma) in [(&win.0, ","), (&win.1, ",")] {
        let o = &cell.outcome;
        let reps = cell
            .rps
            .iter()
            .map(|v| format!("{v:.3}"))
            .collect::<Vec<_>>()
            .join(", ");
        s.push_str(&format!(
            "    \"{}\": {{\"req_per_s_median\": {:.3}, \"req_per_s_reps\": \
             [{}], \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"batches\": {}, \
             \"coalesced\": {}, \"failures\": {}, \"mismatches\": {}}}{}\n",
            cell.label,
            cell.rps_median(),
            reps,
            o.p50_ms(),
            o.p95_ms(),
            o.stats.batches,
            o.stats.coalesced,
            o.failures,
            o.mismatches,
            comma,
        ));
    }
    s.push_str(&format!(
        "    \"speedup\": {:.3}, \"ok\": {}\n  }},\n",
        win.1.rps_median() / win.0.rps_median().max(1e-9),
        window_ok,
    ));
    s.push_str("  \"shards\": {\n");
    s.push_str("    \"backends\": [\n");
    for (i, (name, rate)) in shards.backends.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"name\": \"{}\", \"rate_ns_per_kib\": {}, \"share\": \
             {:.4}, \"plan_units\": {}}}{}\n",
            esc(name),
            rate,
            shards.shares.get(i).copied().unwrap_or(0.0),
            shards.plan.get(i).copied().unwrap_or(0),
            if i + 1 < shards.backends.len() { "," } else { "" },
        ));
    }
    s.push_str("    ],\n");
    let walls = |xs: &[f64]| {
        xs.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(", ")
    };
    s.push_str(&format!(
        "    \"uniform_wall_ms\": [{}],\n    \"proportional_wall_ms\": [{}],\n",
        walls(&shards.uniform_wall_ms),
        walls(&shards.proportional_wall_ms),
    ));
    s.push_str(&format!(
        "    \"uniform_median_ms\": {:.3}, \"proportional_median_ms\": {:.3}, \
         \"bits_ok\": {}, \"ok\": {}\n  }}\n",
        shards.uniform_median_ms(),
        shards.proportional_median_ms(),
        shards.bits_ok,
        shards.ok(),
    ));
    s.push_str("}\n");
    s
}

/// Build the full report. Returns `(markdown, json, validated)` — the
/// caller writes both files even when a gate failed (the artifacts are
/// the evidence) but must exit non-zero on `!validated`.
pub fn report(quick: bool) -> (String, String, bool) {
    let crossval = cross_validate(quick);
    let win = window_experiment(quick);
    let shards = shard_experiment(quick);

    // Medians over the repeated sessions: one perturbed run on a noisy
    // CI host cannot flip the gate. The structural margin is large —
    // the static arm pays the full 3 ms straggler wait on essentially
    // every batch of the mixed closed-loop stream.
    let window_ok =
        win.0.clean() && win.1.clean() && win.1.rps_median() >= win.0.rps_median();
    let validated = crossval.iter().all(|c| c.ok && c.error.is_none())
        && window_ok
        && shards.ok();
    (
        render_md(&crossval, &win, &shards, quick),
        render_json(&crossval, &win, &shards, quick, window_ok),
        validated,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skewed_registry_has_three_distinct_backends() {
        let reg = skewed_registry();
        assert_eq!(reg.len(), 3);
        let names: std::collections::BTreeSet<String> =
            reg.backends().iter().map(|b| b.name()).collect();
        assert_eq!(names.len(), 3, "{names:?}");
    }

    #[test]
    fn median_of_odd_and_even_slices() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn adaptive_crossval_is_bit_identical() {
        for c in cross_validate(true) {
            assert!(c.error.is_none(), "{}: {:?}", c.workload, c.error);
            assert!(c.ok, "{}: adaptive output diverged from oracle", c.workload);
        }
    }
}
