//! `bench zoo` — the heterogeneous device zoo through the plugin ABI.
//!
//! Five parts, all reported, four gated:
//!
//! * **Chaos identity** — every workload kind sharded across the full
//!   [`zoo_registry`](crate::backend::plugin::zoo_registry) (native +
//!   throttled ×2 + flaky + dying + memory-capped) under
//!   [`FaultPolicy::paranoid`]: injected enqueue errors, wrong-once
//!   reads and a dying device must all be absorbed by retry/quarantine
//!   and every output must stay **bit-identical** to the single-device
//!   oracle. Gates: `identity_ok` (bits) and `engagement_ok` (the
//!   fault machinery demonstrably fired: retries ≥ 1 and at least one
//!   backend quarantined, read from the outcome counters).
//! * **Negotiation** — the ABI handshake rejecting a version-skewed
//!   plugin, capability negotiation rejecting a family-poor plugin at
//!   attach, and the scheduler's typed plan-time
//!   [`CapabilityError`](crate::backend::plugin::CapabilityError)
//!   naming the backend and the missing families. Gate: `caps_ok`.
//! * **Warm start** — a fresh [`ShardPlanner`] primed only from the
//!   zoo's capability cost hints: the *first* proportional plan must
//!   already differ from uniform, with the native tier (largest hint)
//!   holding the largest part. Gate: `warm_start_ok`.
//! * **Memory-capped planning** — [`plan_proportional_capped`] against
//!   the zoo's advertised byte budgets: the memory-capped device's
//!   part must fit its 1 MiB cap (units × per-unit footprint ≤ cap)
//!   while the plan still covers every unit. Gate: `mem_plan_ok`.
//! * **Buffer pool** — the same dispatch sequence without and with a
//!   shared [`BufferPool`]: later rounds must reuse shard output
//!   capacity (pool hits > 0) with bits unchanged; the before/after
//!   walls are reported. Gate: `pool_ok`.
//!
//! Emits `results/zoo.md` + schema-versioned `results/BENCH_zoo.json`;
//! CI runs `--quick` and fails on any gate.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::backend::plugin::{
    sim_plugin, zoo_registry, Capabilities, PluginDecl, PluginRegistry, ABI_VERSION,
    ZOO_ASYM_CAP_BYTES,
};
use crate::backend::{Backend, BackendRegistry, SimBackend};
use crate::coordinator::scheduler::{
    run_sharded_workload_on, shard_footprint_bytes, BufferPool, FaultPolicy,
    ShardedConfig,
};
use crate::coordinator::{
    apportion, plan_proportional, plan_proportional_capped, ShardPlanner,
};
use crate::rawcl::kernelspec::KernelKind;
use crate::rawcl::types::DeviceId;
use crate::workload::{
    MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload, StencilWorkload,
    Workload,
};

/// Version tag of `BENCH_zoo.json`. Bump on layout changes so trend
/// tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-zoo/1";

fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 1 {
        v[mid]
    } else {
        (v[mid - 1] + v[mid]) / 2.0
    }
}

// ---------------------------------------------------------------------------
// Chaos identity: the full zoo under paranoid fault tolerance
// ---------------------------------------------------------------------------

struct ChaosRun {
    workload: &'static str,
    ok: bool,
    retries: u64,
    quarantined: Vec<String>,
    error: Option<String>,
}

fn chaos_run<W: Workload + 'static>(
    reg: &BackendRegistry,
    name: &'static str,
    w: W,
    iters: usize,
) -> ChaosRun {
    let oracle = w.reference(iters);
    let mut cfg = ShardedConfig::new(w, iters);
    cfg.chunks_per_backend = 3;
    cfg.min_chunk = 64;
    cfg.faults = Some(FaultPolicy::paranoid());
    match run_sharded_workload_on(reg, &cfg) {
        Ok(out) => ChaosRun {
            workload: name,
            ok: out.final_output == oracle,
            retries: out.retries,
            quarantined: out.quarantined,
            error: None,
        },
        Err(e) => ChaosRun {
            workload: name,
            ok: false,
            retries: 0,
            quarantined: Vec::new(),
            error: Some(e.to_string()),
        },
    }
}

/// Every workload kind through the zoo with faults enabled. One shared
/// registry: the dying device's launch budget and the flaky device's
/// fault stream carry across runs, like a real degrading rig.
fn chaos_identity(quick: bool) -> Vec<ChaosRun> {
    let s = if quick { 1 } else { 4 };
    let reg = zoo_registry();
    vec![
        chaos_run(&reg, "prng", PrngWorkload::new(8192 * s), 3),
        chaos_run(&reg, "saxpy", SaxpyWorkload::new(8192 * s, 2.0), 3),
        chaos_run(&reg, "reduce", ReduceWorkload::new(16384 * s), 2),
        chaos_run(&reg, "stencil", StencilWorkload::new(48, 24), 2),
        chaos_run(&reg, "matmul", MatmulWorkload::new(24), 2),
    ]
}

// ---------------------------------------------------------------------------
// Negotiation: handshake, attach-time filtering, typed plan-time error
// ---------------------------------------------------------------------------

struct CapsDemo {
    abi_msg: String,
    attached: Vec<String>,
    rejected: Vec<(String, String)>,
    typed_err: String,
    ok: bool,
}

fn negotiation_demo() -> CapsDemo {
    // Handshake: a plugin declaring the wrong ABI version never makes
    // it onto the shelf.
    let shelf = PluginRegistry::new();
    let abi_msg = shelf
        .register(sim_plugin(DeviceId(1)).with_abi_version(ABI_VERSION + 1))
        .expect_err("version skew must be rejected")
        .to_string();

    // Negotiation: attaching against a Matmul requirement keeps the
    // fully-capable plugin and rejects the saxpy-only one with a
    // reason.
    shelf.register(sim_plugin(DeviceId(1))).expect("unique name");
    shelf
        .register(PluginDecl::new(
            "saxpy-only:dev2",
            Capabilities::with_families([KernelKind::Saxpy]).cost_hint(1.0),
            || Ok(Arc::new(SimBackend::new(DeviceId(2))?) as Arc<dyn Backend>),
        ))
        .expect("unique name");
    let out = shelf.attach(&BTreeSet::from([KernelKind::Matmul]));

    // Typed plan-time error: a registry holding only the saxpy-only
    // backend refuses a matmul dispatch by name, before any enqueue.
    let narrow = BackendRegistry::new();
    narrow.register_with_caps(
        Arc::new(SimBackend::new(DeviceId(2)).expect("sim device 2")),
        Capabilities::with_families([KernelKind::Saxpy]),
    );
    let typed_err = run_sharded_workload_on(
        &narrow,
        &ShardedConfig::new(MatmulWorkload::new(8), 1),
    )
    .err()
    .map(|e| e.to_string())
    .unwrap_or_default();

    let ok = abi_msg.contains("ABI")
        && out.attached == vec!["sim:dev1".to_string()]
        && out.rejected.len() == 1
        && typed_err.contains("no capable backend")
        && typed_err.contains("Matmul");
    CapsDemo { abi_msg, attached: out.attached, rejected: out.rejected, typed_err, ok }
}

// ---------------------------------------------------------------------------
// Warm start: capability cost hints skew the very first plan
// ---------------------------------------------------------------------------

struct WarmStart {
    names: Vec<String>,
    hints: Vec<f64>,
    shares: Vec<f64>,
    plan: Vec<usize>,
    uniform: Vec<usize>,
    ok: bool,
}

const WARM_UNITS: usize = 60_000;

fn warm_start_demo() -> WarmStart {
    let reg = zoo_registry();
    // Exactly what `ComputeService::spawn` does with the registry's
    // capability hints — replayed on a fresh planner with zero
    // observations, so the plan below is genuinely first-round.
    let planner = ShardPlanner::new();
    let mut names = Vec::new();
    let mut hints = Vec::new();
    for (b, caps) in reg.entries() {
        let name = b.name();
        let hint = caps.cost_hint_bytes_per_ns.unwrap_or(0.0);
        planner.prime(&name, hint);
        names.push(name);
        hints.push(hint);
    }
    let shares = planner.shares(&names).unwrap_or_default();
    let (shards, homes) = plan_proportional(WARM_UNITS, &shares, 256);
    let mut plan = vec![0usize; names.len()];
    for (s, &h) in shards.iter().zip(&homes) {
        plan[h] += s.len;
    }
    let uniform = apportion(WARM_UNITS, &vec![1.0; names.len()], 256);
    let fastest = hints
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let ok = !shares.is_empty()
        && plan != uniform
        && plan.get(fastest) == plan.iter().max();
    WarmStart { names, hints, shares, plan, uniform, ok }
}

// ---------------------------------------------------------------------------
// Memory-capped planning: the advertised budget bounds the plan
// ---------------------------------------------------------------------------

struct MemPlan {
    per_unit_bytes: usize,
    cap_units: usize,
    asym_units: usize,
    uncapped_asym_units: usize,
    total_units: usize,
    ok: bool,
}

fn mem_plan_demo() -> MemPlan {
    let reg = zoo_registry();
    let entries = reg.entries();
    let planner = ShardPlanner::new();
    let mut names = Vec::new();
    for (b, caps) in &entries {
        let name = b.name();
        planner.prime(&name, caps.cost_hint_bytes_per_ns.unwrap_or(0.0));
        names.push(name);
    }
    let shares = planner.shares(&names).unwrap_or_default();
    // Big enough that the memory-capped device's proportional part
    // would blow its 1 MiB budget without the cap.
    let units = 1_500_000;
    let w = PrngWorkload::new(units);
    let per_unit = shard_footprint_bytes(&w, units).div_ceil(units).max(1);
    let caps_units: Vec<Option<usize>> = entries
        .iter()
        .map(|(_, c)| c.mem_limit_bytes.map(|lim| lim / per_unit))
        .collect();
    let asym = entries
        .iter()
        .position(|(_, c)| c.mem_limit_bytes.is_some())
        .unwrap_or(0);
    let cap_units = caps_units[asym].unwrap_or(0);

    let per_backend = |shards: &[crate::workload::Shard], homes: &[usize]| {
        let mut plan = vec![0usize; entries.len()];
        for (s, &h) in shards.iter().zip(homes) {
            plan[h] += s.len;
        }
        plan
    };
    let (us, uh) = plan_proportional(units, &shares, 256);
    let uncapped = per_backend(&us, &uh);
    let (cs, ch) = plan_proportional_capped(units, &shares, 256, &caps_units);
    let capped = per_backend(&cs, &ch);

    let total: usize = capped.iter().sum();
    let ok = total == units
        && uncapped[asym] * per_unit > ZOO_ASYM_CAP_BYTES // the cap had to bind
        && capped[asym] * per_unit <= ZOO_ASYM_CAP_BYTES
        && capped[asym] > 0; // the small device still participates
    MemPlan {
        per_unit_bytes: per_unit,
        cap_units,
        asym_units: capped[asym],
        uncapped_asym_units: uncapped[asym],
        total_units: total,
        ok,
    }
}

// ---------------------------------------------------------------------------
// Buffer pool: arena reuse across batch waves
// ---------------------------------------------------------------------------

struct PoolCell {
    rounds: usize,
    no_pool_wall_ms: Vec<f64>,
    pool_wall_ms: Vec<f64>,
    hits: u64,
    misses: u64,
    bits_ok: bool,
    error: Option<String>,
}

impl PoolCell {
    fn ok(&self) -> bool {
        self.bits_ok && self.error.is_none() && self.hits > 0
    }
}

fn pool_demo(quick: bool) -> PoolCell {
    let reg = BackendRegistry::with_default_backends();
    let n = if quick { 64 * 1024 } else { 256 * 1024 };
    let rounds = if quick { 6 } else { 12 };
    let iters = 2;
    let w = SaxpyWorkload::new(n, 2.0);
    let oracle = w.reference(iters);
    let mut cell = PoolCell {
        rounds,
        no_pool_wall_ms: Vec::new(),
        pool_wall_ms: Vec::new(),
        hits: 0,
        misses: 0,
        bits_ok: true,
        error: None,
    };
    for pooled in [false, true] {
        let pool = Arc::new(BufferPool::new());
        for _ in 0..rounds {
            let mut cfg = ShardedConfig::new(w, iters);
            cfg.min_chunk = 1024;
            if pooled {
                cfg.buffer_pool = Some(pool.clone());
            }
            match run_sharded_workload_on(&reg, &cfg) {
                Ok(out) => {
                    cell.bits_ok &= out.final_output == oracle;
                    let wall = out.wall.as_secs_f64() * 1e3;
                    if pooled {
                        cell.pool_wall_ms.push(wall);
                    } else {
                        cell.no_pool_wall_ms.push(wall);
                    }
                }
                Err(e) => {
                    cell.error = Some(e.to_string());
                    return cell;
                }
            }
        }
        if pooled {
            cell.hits = pool.hits();
            cell.misses = pool.misses();
        }
    }
    cell
}

// ---------------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------------

fn render_md(
    chaos: &[ChaosRun],
    caps: &CapsDemo,
    warm: &WarmStart,
    mem: &MemPlan,
    pool: &PoolCell,
    quick: bool,
) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Device zoo — plugin ABI, fault tolerance, capability-aware \
         planning ({} mode)\n\n",
        if quick { "quick" } else { "full" }
    ));

    s.push_str("## Bit-identity under faults (paranoid policy, full zoo)\n\n");
    s.push_str("| workload | verdict | retries | quarantined |\n|---|---|---:|---|\n");
    for c in chaos {
        let verdict = match (&c.error, c.ok) {
            (Some(e), _) => format!("**ERROR**: {e}"),
            (None, true) => "✓ bit-identical".to_string(),
            (None, false) => "**DIVERGED**".to_string(),
        };
        s.push_str(&format!(
            "| {} | {verdict} | {} | {} |\n",
            c.workload,
            c.retries,
            if c.quarantined.is_empty() { "—".into() } else { c.quarantined.join(", ") },
        ));
    }
    let total_retries: u64 = chaos.iter().map(|c| c.retries).sum();
    let quarantined: BTreeSet<&String> =
        chaos.iter().flat_map(|c| c.quarantined.iter()).collect();
    s.push_str(&format!(
        "\nTotal retries **{total_retries}**, quarantined backends \
         **{}** — the fault machinery demonstrably engaged.\n",
        quarantined.len()
    ));

    s.push_str("\n## Negotiation\n\n");
    s.push_str(&format!("* ABI handshake: `{}`\n", caps.abi_msg));
    s.push_str(&format!(
        "* Attach vs Matmul requirement: attached `{:?}`, rejected {}\n",
        caps.attached,
        caps.rejected
            .iter()
            .map(|(n, r)| format!("`{n}` ({r})"))
            .collect::<Vec<_>>()
            .join(", "),
    ));
    s.push_str(&format!("* Typed plan-time error: `{}`\n", caps.typed_err));

    s.push_str("\n## Warm start from capability cost hints (first-round plan)\n\n");
    s.push_str("| backend | hint (B/ns) | share | plan (units) | uniform |\n");
    s.push_str("|---|---:|---:|---:|---:|\n");
    for (i, name) in warm.names.iter().enumerate() {
        s.push_str(&format!(
            "| {name} | {:.2} | {} | {} | {} |\n",
            warm.hints.get(i).copied().unwrap_or(0.0),
            warm.shares
                .get(i)
                .map(|v| format!("{:.1}%", v * 100.0))
                .unwrap_or_else(|| "—".into()),
            warm.plan.get(i).map(|v| v.to_string()).unwrap_or_else(|| "—".into()),
            warm.uniform.get(i).map(|v| v.to_string()).unwrap_or_else(|| "—".into()),
        ));
    }
    s.push_str(&format!(
        "\nFirst-round plan {} uniform — the priors warm-start the \
         planner before any observation exists.\n",
        if warm.plan != warm.uniform { "**differs from**" } else { "EQUALS (gate fails)" }
    ));

    s.push_str("\n## Memory-capped planning (1 MiB device budget)\n\n");
    s.push_str(&format!(
        "Per-unit footprint {} B ⇒ cap {} units. Uncapped plan would \
         give the capped device **{}** units; the capped plan gives \
         **{}** ({} B ≤ {} B), total {} of {} units covered.\n",
        mem.per_unit_bytes,
        mem.cap_units,
        mem.uncapped_asym_units,
        mem.asym_units,
        mem.asym_units * mem.per_unit_bytes,
        ZOO_ASYM_CAP_BYTES,
        mem.total_units,
        mem.total_units,
    ));

    s.push_str("\n## Buffer pool: dispatch-arena reuse (before/after)\n\n");
    s.push_str(&format!(
        "| arm | rounds | wall ms (median) |\n|---|---:|---:|\n\
         | fresh allocations | {} | {:.2} |\n| pooled buffers | {} | {:.2} |\n",
        pool.rounds,
        median(&pool.no_pool_wall_ms),
        pool.rounds,
        median(&pool.pool_wall_ms),
    ));
    s.push_str(&format!(
        "\nPool hits **{}**, misses **{}** — after the first round the \
         shard output buffers (and their capacity) are reused across \
         waves instead of reallocated; bits {}.\n",
        pool.hits,
        pool.misses,
        if pool.bits_ok { "unchanged" } else { "**DIVERGED**" },
    ));
    if let Some(e) = &pool.error {
        s.push_str(&format!("\n**ERROR**: {e}\n"));
    }
    s
}

#[allow(clippy::too_many_arguments)]
fn render_json(
    chaos: &[ChaosRun],
    caps: &CapsDemo,
    warm: &WarmStart,
    mem: &MemPlan,
    pool: &PoolCell,
    quick: bool,
    identity_ok: bool,
    engagement_ok: bool,
    gate_ok: bool,
) -> String {
    use super::json_escape as esc;
    let join_f = |xs: &[f64], p: usize| {
        xs.iter().map(|v| format!("{v:.p$}")).collect::<Vec<_>>().join(", ")
    };
    let join_u = |xs: &[usize]| {
        xs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"chaos\": {\n    \"runs\": [\n");
    for (i, c) in chaos.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"workload\": \"{}\", \"ok\": {}, \"retries\": {}, \
             \"quarantined\": [{}]{}}}{}\n",
            c.workload,
            c.ok,
            c.retries,
            c.quarantined
                .iter()
                .map(|q| format!("\"{}\"", esc(q)))
                .collect::<Vec<_>>()
                .join(", "),
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < chaos.len() { "," } else { "" },
        ));
    }
    let total_retries: u64 = chaos.iter().map(|c| c.retries).sum();
    s.push_str(&format!(
        "    ],\n    \"total_retries\": {total_retries},\n    \
         \"identity_ok\": {identity_ok},\n    \"engagement_ok\": {engagement_ok}\n  }},\n",
    ));
    s.push_str(&format!(
        "  \"negotiation\": {{\"attached\": [{}], \"rejected\": {}, \
         \"typed_error\": \"{}\", \"caps_ok\": {}}},\n",
        caps.attached
            .iter()
            .map(|a| format!("\"{}\"", esc(a)))
            .collect::<Vec<_>>()
            .join(", "),
        caps.rejected.len(),
        esc(&caps.typed_err),
        caps.ok,
    ));
    s.push_str(&format!(
        "  \"warm_start\": {{\"hints\": [{}], \"shares\": [{}], \"plan\": [{}], \
         \"uniform\": [{}], \"warm_start_ok\": {}}},\n",
        join_f(&warm.hints, 3),
        join_f(&warm.shares, 4),
        join_u(&warm.plan),
        join_u(&warm.uniform),
        warm.ok,
    ));
    s.push_str(&format!(
        "  \"mem_plan\": {{\"per_unit_bytes\": {}, \"cap_units\": {}, \
         \"asym_units\": {}, \"uncapped_asym_units\": {}, \"total_units\": {}, \
         \"mem_plan_ok\": {}}},\n",
        mem.per_unit_bytes,
        mem.cap_units,
        mem.asym_units,
        mem.uncapped_asym_units,
        mem.total_units,
        mem.ok,
    ));
    s.push_str(&format!(
        "  \"pool\": {{\"hits\": {}, \"misses\": {}, \"no_pool_wall_ms\": [{}], \
         \"pool_wall_ms\": [{}], \"no_pool_median_ms\": {:.3}, \
         \"pool_median_ms\": {:.3}, \"bits_ok\": {}, \"pool_ok\": {}}},\n",
        pool.hits,
        pool.misses,
        join_f(&pool.no_pool_wall_ms, 3),
        join_f(&pool.pool_wall_ms, 3),
        median(&pool.no_pool_wall_ms),
        median(&pool.pool_wall_ms),
        pool.bits_ok,
        pool.ok(),
    ));
    s.push_str(&format!("  \"gate_ok\": {gate_ok}\n"));
    s.push_str("}\n");
    s
}

/// Build the full report. Returns `(markdown, json, validated)` — the
/// caller writes both files even when a gate failed (the artifacts are
/// the evidence) but must exit non-zero on `!validated`.
pub fn report(quick: bool) -> (String, String, bool) {
    let chaos = chaos_identity(quick);
    let caps = negotiation_demo();
    let warm = warm_start_demo();
    let mem = mem_plan_demo();
    let pool = pool_demo(quick);

    let identity_ok = chaos.iter().all(|c| c.ok && c.error.is_none());
    let total_retries: u64 = chaos.iter().map(|c| c.retries).sum();
    let engagement_ok =
        total_retries >= 1 && chaos.iter().any(|c| !c.quarantined.is_empty());
    let gate_ok =
        identity_ok && engagement_ok && caps.ok && warm.ok && mem.ok && pool.ok();
    (
        render_md(&chaos, &caps, &warm, &mem, &pool, quick),
        render_json(
            &chaos,
            &caps,
            &warm,
            &mem,
            &pool,
            quick,
            identity_ok,
            engagement_ok,
            gate_ok,
        ),
        gate_ok,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn negotiation_demo_gates_pass() {
        let caps = negotiation_demo();
        assert!(caps.ok, "abi: {} / typed: {}", caps.abi_msg, caps.typed_err);
    }

    #[test]
    fn warm_start_first_round_plan_is_skewed() {
        let warm = warm_start_demo();
        assert!(warm.ok, "plan {:?} vs uniform {:?}", warm.plan, warm.uniform);
        assert_eq!(warm.plan.iter().sum::<usize>(), WARM_UNITS);
    }

    #[test]
    fn mem_plan_respects_the_advertised_cap() {
        let mem = mem_plan_demo();
        assert!(
            mem.ok,
            "asym {} units × {} B vs cap {} B (uncapped {})",
            mem.asym_units, mem.per_unit_bytes, ZOO_ASYM_CAP_BYTES, mem.uncapped_asym_units
        );
    }
}
