//! Backend-comparison table (cf. Raven's backend-comparison harness):
//! run the same PRNG workload on **every registered backend** through
//! the uniform [`Backend`](crate::backend::Backend) trait, plus once
//! through the multi-device scheduler, and cross-validate every output
//! stream against the host reference — all rows must be bit-identical.

use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::backend::{Backend, BackendRegistry, CompileSpec, LaunchArg};
use crate::coordinator::scheduler::{run_sharded_on, ShardedRngConfig};
use crate::coordinator::Sink;
use crate::rawcl::simexec;
use crate::runtime::executable;

/// FNV-1a 64 over a byte stream — the row fingerprint (same core as the
/// runtime's text-cache key, [`executable::fnv1a_update`]).
#[derive(Clone)]
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Self {
        Self(executable::FNV1A_OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        executable::fnv1a_update(&mut self.0, bytes);
    }

    pub fn digest(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// `Sink::Writer` adapter hashing everything written through it.
struct FnvWriter(Arc<Mutex<Fnv>>);

impl Write for FnvWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().update(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// One table row.
struct Row {
    name: String,
    kind: String,
    wall_ms: f64,
    busy_ms: f64,
    mib_s: f64,
    checksum: u64,
    ok: bool,
}

/// Host-side reference stream fingerprint (init batch + stepped batches).
fn reference_checksum(n: usize, iters: usize) -> u64 {
    let mut state = vec![0u8; n * 8];
    simexec::run_init(&mut state);
    let mut h = Fnv::new();
    h.update(&state);
    let mut next = vec![0u8; n * 8];
    for _ in 1..iters {
        simexec::run_rng(&state, &mut next, 1);
        std::mem::swap(&mut state, &mut next);
        h.update(&state);
    }
    h.digest()
}

/// Drive `iters` batches of `n` words on one backend via the trait.
fn run_single(b: &dyn Backend, n: usize, iters: usize) -> Result<Row, String> {
    let _ = b.drain_timeline(); // profile exactly this run
    let bytes = n * 8;
    let err = |e: crate::backend::BackendError| e.to_string();
    let t0 = Instant::now();
    let k_init = b.compile(&CompileSpec::init(n)).map_err(err)?;
    let k_step = b.compile(&CompileSpec::step(n)).map_err(err)?;
    let front = b.alloc(bytes).map_err(err)?;
    let back = b.alloc(bytes).map_err(err)?;
    let mut host = vec![0u8; bytes];
    let mut h = Fnv::new();

    let ev = b.enqueue(k_init, &[LaunchArg::Buf(front)], None).map_err(err)?;
    b.wait(ev).map_err(err)?;
    b.read(front, 0, &mut host).map_err(err)?;
    h.update(&host);
    let (mut front, mut back) = (front, back);
    for _ in 1..iters {
        let ev = b
            .enqueue(k_step, &[LaunchArg::Buf(front), LaunchArg::Buf(back)], None)
            .map_err(err)?;
        b.wait(ev).map_err(err)?;
        b.read(back, 0, &mut host).map_err(err)?;
        h.update(&host);
        std::mem::swap(&mut front, &mut back);
    }
    let wall = t0.elapsed();
    let busy_ns: u64 = b.drain_timeline().iter().map(|(_, t, _)| t.duration()).sum();
    b.free(front);
    b.free(back);

    let total = (bytes * iters) as f64;
    Ok(Row {
        name: b.name(),
        kind: format!("{:?}", b.kind()),
        wall_ms: wall.as_secs_f64() * 1e3,
        busy_ms: busy_ns as f64 * 1e-6,
        mib_s: total / wall.as_secs_f64() / (1024.0 * 1024.0),
        checksum: h.digest(),
        ok: false, // filled by the caller against the reference
    })
}

/// Run the scheduler over all backends and fingerprint the merged stream.
fn run_sharded_row(
    registry: &BackendRegistry,
    n: usize,
    iters: usize,
) -> Result<Row, String> {
    let hash = Arc::new(Mutex::new(Fnv::new()));
    let mut cfg = ShardedRngConfig::new(n, iters);
    cfg.sink = Sink::Writer(Mutex::new(Box::new(FnvWriter(hash.clone()))));
    cfg.min_chunk = 1024;
    let out = run_sharded_on(registry, &cfg).map_err(|e| e.to_string())?;
    let busy_ns: u64 = out.per_backend.iter().map(|l| l.busy_ns).sum();
    let loads: Vec<String> = out
        .per_backend
        .iter()
        .map(|l| format!("{}×{}", l.tasks, l.name))
        .collect();
    let total = (n * 8 * iters) as f64;
    Ok(Row {
        name: format!(
            "sharded: {} chunks over {}",
            out.num_chunks,
            loads.join(" + ")
        ),
        kind: "Scheduler".to_string(),
        wall_ms: out.wall.as_secs_f64() * 1e3,
        busy_ms: busy_ns as f64 * 1e-6,
        mib_s: total / out.wall.as_secs_f64() / (1024.0 * 1024.0),
        checksum: hash.lock().unwrap().digest(),
        ok: false,
    })
}

/// Build the backend-comparison report. `Err` when any backend's stream
/// diverges from the host reference (CI fails on it).
pub fn report(quick: bool) -> Result<String, String> {
    let (n, iters) = if quick { (16384, 4) } else { (65536, 8) };
    let registry = BackendRegistry::global();
    let reference = reference_checksum(n, iters);

    let mut rows = Vec::new();
    for b in registry.backends() {
        rows.push(run_single(b.as_ref(), n, iters)?);
    }
    rows.push(run_sharded_row(registry, n, iters)?);
    for r in &mut rows {
        r.ok = r.checksum == reference;
    }

    let mut s = String::new();
    s.push_str(&format!(
        "# Backend comparison — n={n}, iters={iters}, reference fnv1a={reference:016x}\n\n"
    ));
    s.push_str(
        "| backend | kind | wall (ms) | busy (ms) | MiB/s | fnv1a | bit-identical |\n\
         |---|---|---:|---:|---:|---|---|\n",
    );
    for r in &rows {
        s.push_str(&format!(
            "| {} | {} | {:.2} | {:.2} | {:.1} | {:016x} | {} |\n",
            r.name,
            r.kind,
            r.wall_ms,
            r.busy_ms,
            r.mib_s,
            r.checksum,
            if r.ok { "yes" } else { "**NO**" },
        ));
    }
    s.push_str(
        "\nAll rows must be bit-identical: every backend executes the same \
         logical kernels (PJRT artifacts vs scalar reference vs sharded \
         merge), so any divergence is a correctness bug, not noise.\n",
    );

    if rows.iter().all(|r| r.ok) {
        Ok(s)
    } else {
        Err(format!("backend divergence detected:\n{s}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_incremental() {
        let mut a = Fnv::new();
        a.update(b"hello ");
        a.update(b"world");
        let mut b = Fnv::new();
        b.update(b"hello world");
        assert_eq!(a.digest(), b.digest());
        assert_ne!(a.digest(), Fnv::new().digest());
    }

    #[test]
    fn comparison_table_is_clean() {
        let report = report(true).expect("backends must agree bit-for-bit");
        assert!(report.contains("| sim:SimCL GTX 1080 |"));
        assert!(report.contains("sharded:"));
        assert!(!report.contains("**NO**"));
    }
}
