//! §6.1 code-complexity comparison: physical LOC of the two example
//! realisations (the paper's 290-vs-183 table).
//!
//! Physical LOC = lines that are neither blank nor comment-only,
//! counting both `//` and `/* ... */` comment styles (the examples use
//! C-style block comments to mirror the listings).

use std::path::Path;

/// Count physical lines of code in Rust/C-like source text.
pub fn physical_loc(source: &str) -> usize {
    let mut count = 0usize;
    let mut in_block = false;
    for line in source.lines() {
        let mut rest = line.trim();
        let mut has_code = false;
        loop {
            if in_block {
                match rest.find("*/") {
                    Some(i) => {
                        in_block = false;
                        rest = rest[i + 2..].trim();
                    }
                    None => break, // whole line inside a block comment
                }
            } else if rest.is_empty() {
                break;
            } else if rest.starts_with("//") {
                break; // line comment: rest of line is comment
            } else if let Some(i) = rest.find("/*") {
                if rest[..i].trim().is_empty() {
                    // only whitespace before the block comment
                    in_block = true;
                    rest = rest[i + 2..].trim();
                } else {
                    has_code = true;
                    in_block = true;
                    rest = rest[i + 2..].trim();
                }
            } else {
                has_code = true;
                break;
            }
        }
        if has_code {
            count += 1;
        }
    }
    count
}

/// One row of the comparison.
#[derive(Debug)]
pub struct LocRow {
    pub label: String,
    pub path: String,
    pub loc: usize,
}

/// Count the two example sources and derive the reduction.
pub fn compare(
    raw_path: impl AsRef<Path>,
    ccl_path: impl AsRef<Path>,
) -> std::io::Result<(LocRow, LocRow, f64)> {
    let read = |p: &Path, label: &str| -> std::io::Result<LocRow> {
        let text = std::fs::read_to_string(p)?;
        Ok(LocRow {
            label: label.to_string(),
            path: p.display().to_string(),
            loc: physical_loc(&text),
        })
    };
    let raw = read(raw_path.as_ref(), "pure rawcl (listing S1 analogue)")?;
    let ccl = read(ccl_path.as_ref(), "cf4rs (listing S2 analogue)")?;
    let reduction = 1.0 - ccl.loc as f64 / raw.loc as f64;
    Ok((raw, ccl, reduction))
}

/// Render the §6.1 table.
pub fn report() -> String {
    let candidates = [
        ("examples/rng_raw.rs", "examples/rng_ccl.rs"),
        ("../examples/rng_raw.rs", "../examples/rng_ccl.rs"),
    ];
    for (raw, ccl) in candidates {
        if Path::new(raw).exists() {
            return match compare(raw, ccl) {
                Ok((r, c, red)) => format!(
                    "## E1 — §6.1 code-complexity comparison (physical LOC)\n\
                     | implementation | file | LOC |\n|---|---|---|\n\
                     | {} | {} | {} |\n| {} | {} | {} |\n\n\
                     cf4rs version is {:.0}% smaller \
                     (paper: 290 vs 183 LOC, 37% smaller)\n",
                    r.label, r.path, r.loc, c.label, c.path, c.loc, red * 100.0
                ),
                Err(e) => format!("loc: {e}\n"),
            };
        }
    }
    "loc: example sources not found (run from the repo root)\n".into()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_code_not_comments() {
        let src = "\n// comment\nlet x = 1; // trailing\n/* block */\n\
                   /* multi\nline\nblock */\nlet y = 2;\n\n";
        assert_eq!(physical_loc(src), 2);
    }

    #[test]
    fn code_before_block_comment_counts() {
        let src = "let x = 1; /* start\n still comment\n end */ let y = 2;\n";
        assert_eq!(physical_loc(src), 2);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(physical_loc(""), 0);
        assert_eq!(physical_loc("\n\n// only comments\n/* x */\n"), 0);
    }

    #[test]
    fn examples_reproduce_the_papers_direction() {
        // The cf4rs example must be meaningfully smaller than the raw
        // one — the paper reports 37%; we accept ≥ 20%.
        let Ok((raw, ccl, red)) = compare("examples/rng_raw.rs", "examples/rng_ccl.rs")
        else {
            return; // not running from repo root
        };
        assert!(
            raw.loc > ccl.loc,
            "raw {} LOC must exceed ccl {} LOC",
            raw.loc,
            ccl.loc
        );
        assert!(red >= 0.20, "reduction only {:.1}% (paper: 37%)", red * 100.0);
    }
}
