//! §6.1 code-complexity comparison: physical LOC of the three example
//! realisations — raw substrate, cf4rs v1 wrappers, cf4rs v2 fluent
//! tier (extending the paper's 290-vs-183 two-column table with the
//! API-redesign column).
//!
//! Physical LOC = lines that are neither blank nor comment-only,
//! counting both `//` and `/* ... */` comment styles (the examples use
//! C-style block comments to mirror the listings).

use std::path::Path;

/// Count physical lines of code in Rust/C-like source text.
pub fn physical_loc(source: &str) -> usize {
    let mut count = 0usize;
    let mut in_block = false;
    for line in source.lines() {
        let mut rest = line.trim();
        let mut has_code = false;
        loop {
            if in_block {
                match rest.find("*/") {
                    Some(i) => {
                        in_block = false;
                        rest = rest[i + 2..].trim();
                    }
                    None => break, // whole line inside a block comment
                }
            } else if rest.is_empty() {
                break;
            } else if rest.starts_with("//") {
                break; // line comment: rest of line is comment
            } else if let Some(i) = rest.find("/*") {
                if rest[..i].trim().is_empty() {
                    // only whitespace before the block comment
                    in_block = true;
                    rest = rest[i + 2..].trim();
                } else {
                    has_code = true;
                    in_block = true;
                    rest = rest[i + 2..].trim();
                }
            } else {
                has_code = true;
                break;
            }
        }
        if has_code {
            count += 1;
        }
    }
    count
}

/// One row of the comparison.
#[derive(Debug)]
pub struct LocRow {
    pub label: String,
    pub path: String,
    pub loc: usize,
}

/// Count one source file.
fn read_row(p: &Path, label: &str) -> std::io::Result<LocRow> {
    let text = std::fs::read_to_string(p)?;
    Ok(LocRow {
        label: label.to_string(),
        path: p.display().to_string(),
        loc: physical_loc(&text),
    })
}

/// Count the raw and v1 example sources and derive the reduction
/// (the paper's original two-column comparison).
pub fn compare(
    raw_path: impl AsRef<Path>,
    ccl_path: impl AsRef<Path>,
) -> std::io::Result<(LocRow, LocRow, f64)> {
    let raw = read_row(raw_path.as_ref(), "pure rawcl (listing S1 analogue)")?;
    let ccl = read_row(ccl_path.as_ref(), "cf4rs v1 (listing S2 analogue)")?;
    let reduction = 1.0 - ccl.loc as f64 / raw.loc as f64;
    Ok((raw, ccl, reduction))
}

/// The three RNG-example realisations as `(label, file)` pairs,
/// resolved relative to `dir` ("" = repo root).
fn tiers(dir: &str) -> [(String, std::path::PathBuf); 3] {
    let base = Path::new(dir);
    [
        (
            "pure rawcl (listing S1 analogue)".to_string(),
            base.join("examples/rng_raw.rs"),
        ),
        (
            "cf4rs v1 (listing S2 analogue)".to_string(),
            base.join("examples/rng_ccl.rs"),
        ),
        (
            "cf4rs v2 (fluent tier)".to_string(),
            base.join("examples/rng_v2.rs"),
        ),
    ]
}

/// Count all three tiers; rows ordered raw, v1, v2.
pub fn compare_tiers(dir: &str) -> std::io::Result<Vec<LocRow>> {
    tiers(dir)
        .iter()
        .map(|(label, path)| read_row(path, label))
        .collect()
}

/// Render the §6.1 table, now with the v2 column: each wrapper tier's
/// LOC and its reduction versus the raw path. `Err` when any example
/// source cannot be counted — the harness must fail the regeneration,
/// not emit a reportless file.
pub fn report() -> Result<String, String> {
    let dir = ["", ".."]
        .into_iter()
        .find(|d| tiers(d)[0].1.exists())
        .ok_or_else(|| "example sources not found (run from the repo root)".to_string())?;
    let rows = compare_tiers(dir).map_err(|e| e.to_string())?;
    let raw_loc = rows[0].loc as f64;
    let mut out = String::from(
        "## E1 — §6.1 code-complexity comparison (physical LOC)\n\
         | implementation | file | LOC | vs raw |\n|---|---|---|---|\n",
    );
    for (i, r) in rows.iter().enumerate() {
        let delta = if i == 0 {
            "—".to_string()
        } else {
            format!("-{:.0}%", (1.0 - r.loc as f64 / raw_loc) * 100.0)
        };
        out.push_str(&format!("| {} | {} | {} | {} |\n", r.label, r.path, r.loc, delta));
    }
    out.push_str(&format!(
        "\nv1 is {:.0}% smaller than raw (paper: 290 vs 183 LOC, 37% \
         smaller); the v2 fluent tier is {:.0}% smaller than raw\n",
        (1.0 - rows[1].loc as f64 / raw_loc) * 100.0,
        (1.0 - rows[2].loc as f64 / raw_loc) * 100.0,
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_code_not_comments() {
        let src = "\n// comment\nlet x = 1; // trailing\n/* block */\n\
                   /* multi\nline\nblock */\nlet y = 2;\n\n";
        assert_eq!(physical_loc(src), 2);
    }

    #[test]
    fn code_before_block_comment_counts() {
        let src = "let x = 1; /* start\n still comment\n end */ let y = 2;\n";
        assert_eq!(physical_loc(src), 2);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(physical_loc(""), 0);
        assert_eq!(physical_loc("\n\n// only comments\n/* x */\n"), 0);
    }

    /// Find the directory holding `examples/` (tests run from `rust/`).
    fn examples_dir() -> Option<&'static str> {
        ["", ".."].into_iter().find(|d| {
            std::path::Path::new(d).join("examples/rng_raw.rs").exists()
        })
    }

    #[test]
    fn examples_reproduce_the_papers_direction() {
        // The cf4rs v1 example must be meaningfully smaller than the
        // raw one — the paper reports 37%; we accept ≥ 20%.
        let Some(dir) = examples_dir() else { return };
        let base = std::path::Path::new(dir);
        let (raw, ccl, red) = compare(
            base.join("examples/rng_raw.rs"),
            base.join("examples/rng_ccl.rs"),
        )
        .unwrap();
        assert!(
            raw.loc > ccl.loc,
            "raw {} LOC must exceed ccl {} LOC",
            raw.loc,
            ccl.loc
        );
        assert!(red >= 0.20, "reduction only {:.1}% (paper: 37%)", red * 100.0);
    }

    #[test]
    fn v2_tier_cuts_at_least_30_percent_vs_raw() {
        // The api_redesign acceptance bar: the fluent tier must shave
        // ≥ 30% of host LOC off the raw path on the RNG example (it
        // should comfortably beat the v1 tier too).
        let Some(dir) = examples_dir() else { return };
        let rows = compare_tiers(dir).unwrap();
        let (raw, v1, v2) = (rows[0].loc, rows[1].loc, rows[2].loc);
        let red_v2 = 1.0 - v2 as f64 / raw as f64;
        assert!(
            red_v2 >= 0.30,
            "v2 reduction only {:.1}% (raw {raw}, v2 {v2})",
            red_v2 * 100.0
        );
        assert!(v2 < v1, "v2 ({v2} LOC) must beat v1 ({v1} LOC)");
    }

    #[test]
    fn report_has_three_rows_and_v2_column() {
        if examples_dir().is_none() {
            return;
        }
        let r = report().unwrap();
        assert!(r.contains("pure rawcl"), "report: {r}");
        assert!(r.contains("cf4rs v1"), "report: {r}");
        assert!(r.contains("cf4rs v2 (fluent tier)"), "report: {r}");
        assert!(r.contains("vs raw"), "report: {r}");
    }
}
