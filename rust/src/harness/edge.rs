//! `bench edge` — the serving-edge cell: an **open-loop** load
//! generator against a live `cf4rs edge` server.
//!
//! Open-loop means senders fire on a fixed arrival schedule
//! (`t0 + k/rate`) and never wait for responses, so a slow server
//! cannot slow the offered load down — the backlog it causes is
//! *measured* (latency from the scheduled arrival time, the standard
//! guard against coordinated omission) instead of hidden.
//!
//! Three scenarios, each against a fresh server (fresh trailing-latency
//! window):
//!
//! 1. **underload** — mixed lanes well under capacity on the default
//!    registry. Gate: every response present, bit-identical to the host
//!    oracle, zero shed.
//! 2. **mixed** — a bulk flood (large PRNG requests, offered load >
//!    capacity on a deterministically throttled device) plus a stream
//!    of small high-priority probes of a *different* kind (so they
//!    never coalesce into the flood's batches). The overload gate is
//!    parked. Gate: high p99 strictly below bulk p99 — the priority
//!    lane visibly overtakes the backlog. A deadline-tagged bulk lane
//!    rides along to demonstrate deadline shedding in the report.
//! 3. **overload** — the same flood against a tight bulk p99 budget, a
//!    loose high budget and a reserved admission slice. Gate: bulk
//!    sheds (> 0), high does not (or at a strictly lower rate) — the
//!    SLO discipline sheds bulk first.
//!
//! The server runs as a **subprocess** (`current_exe() edge --port 0`,
//! port parsed from the `EDGE LISTENING` announce line) when the
//! harness itself was started as `cf4rs bench …`; anywhere else (unit
//! tests, odd embeddings) it falls back in-process. Which mode ran is
//! recorded in the JSON.
//!
//! Writes `edge.md` + `BENCH_edge.json` (schema
//! [`SCHEMA`](self::SCHEMA)); CI greps the gate booleans.

use std::io::BufRead;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::{Backend, BackendRegistry, SimBackend, ThrottledBackend};
use crate::coordinator::edge::client::Received;
use crate::coordinator::edge::proto::{RequestFrame, WireError, WorkloadDesc};
use crate::coordinator::edge::{EdgeClient, EdgeOpts, EdgeServer};
use crate::coordinator::service::{Priority, ServiceOpts};
use crate::rawcl::types::DeviceId;
use crate::workload::Workload;

use super::json_escape;
use super::service::percentile;

/// Version tag of `BENCH_edge.json`. Bump on layout changes so trend
/// tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-edge/1";

/// How long a receiver waits for a missing response before declaring
/// it lost (generous: the drain guarantee answers everything).
const RECV_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// Server under test: subprocess when possible, in-process otherwise
// ---------------------------------------------------------------------------

/// Everything that parameterises one server instance — the single
/// source of truth for both the subprocess argv and the in-process
/// [`EdgeOpts`].
struct ServerCfg {
    queue_cap: usize,
    max_batch: usize,
    window_us: u64,
    high_budget_ms: u64,
    bulk_budget_ms: u64,
    min_gate_samples: u64,
    high_reserve: usize,
    /// `Some(rate)` swaps the registry for one throttled sim device —
    /// a fixed, small capacity the flood can saturate on any machine.
    throttle_ns: Option<u64>,
}

enum ServerHandle {
    Child(std::process::Child),
    Local(Box<EdgeServer>),
}

struct Server {
    addr: String,
    handle: ServerHandle,
    mode: &'static str,
}

/// Subprocess mode is only sound when this process *is* the `cf4rs`
/// binary (argv[1] == "bench") — re-executing a test binary with
/// `edge` argv would run its test filter, not a server.
fn subprocess_mode() -> bool {
    std::env::args().nth(1).as_deref() == Some("bench")
}

fn start_server(cfg: &ServerCfg) -> Result<Server, String> {
    if subprocess_mode() {
        match start_child(cfg) {
            Ok(s) => return Ok(s),
            Err(e) => eprintln!("  edge: subprocess spawn failed ({e}); running in-process"),
        }
    }
    start_local(cfg)
}

fn start_child(cfg: &ServerCfg) -> Result<Server, String> {
    use std::process::{Command, Stdio};
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let mut cmd = Command::new(exe);
    cmd.arg("edge")
        .args(["--port", "0"])
        .args(["--queue-cap", &cfg.queue_cap.to_string()])
        .args(["--max-batch", &cfg.max_batch.to_string()])
        .args(["--window-us", &cfg.window_us.to_string()])
        .args(["--high-budget-ms", &cfg.high_budget_ms.to_string()])
        .args(["--bulk-budget-ms", &cfg.bulk_budget_ms.to_string()])
        .args(["--min-gate-samples", &cfg.min_gate_samples.to_string()])
        .args(["--high-reserve", &cfg.high_reserve.to_string()])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
    if let Some(ns) = cfg.throttle_ns {
        cmd.args(["--throttle-ns", &ns.to_string()]);
    }
    let mut child = cmd.spawn().map_err(|e| format!("spawn: {e}"))?;
    let stdout = child.stdout.take().expect("stdout was piped");
    let mut line = String::new();
    let read = std::io::BufReader::new(stdout).read_line(&mut line);
    let addr = match read {
        Ok(_) => line.trim().strip_prefix("EDGE LISTENING ").map(str::to_string),
        Err(_) => None,
    };
    match addr {
        Some(addr) if !addr.is_empty() => {
            Ok(Server { addr, handle: ServerHandle::Child(child), mode: "subprocess" })
        }
        _ => {
            let _ = child.kill();
            let _ = child.wait();
            Err(format!("no announce line (got {:?})", line.trim()))
        }
    }
}

fn start_local(cfg: &ServerCfg) -> Result<Server, String> {
    let registry = Arc::new(match cfg.throttle_ns {
        Some(rate) => {
            let reg = BackendRegistry::new();
            let inner: Arc<dyn Backend> =
                Arc::new(SimBackend::new(DeviceId(1)).expect("sim device 1"));
            reg.register(Arc::new(ThrottledBackend::new(inner, rate)));
            reg
        }
        None => BackendRegistry::with_default_backends(),
    });
    let opts = EdgeOpts {
        service: ServiceOpts {
            queue_cap: cfg.queue_cap,
            max_batch: cfg.max_batch,
            batch_window: Duration::from_micros(cfg.window_us),
            high_reserve: cfg.high_reserve,
            ..ServiceOpts::default()
        },
        registry: Some(registry),
        high_p99_budget: Duration::from_millis(cfg.high_budget_ms),
        bulk_p99_budget: Duration::from_millis(cfg.bulk_budget_ms),
        min_gate_samples: cfg.min_gate_samples,
        ..EdgeOpts::default()
    };
    let server = EdgeServer::start(0, opts).map_err(|e| format!("bind: {e}"))?;
    Ok(Server {
        addr: server.local_addr().to_string(),
        handle: ServerHandle::Local(Box::new(server)),
        mode: "in-process",
    })
}

/// Stop the server; `Err` describes an unclean exit.
fn stop_server(server: Server) -> Result<(), String> {
    match server.handle {
        ServerHandle::Local(s) => {
            s.shutdown();
            Ok(())
        }
        ServerHandle::Child(mut child) => {
            // Closing stdin is the subprocess's drain signal.
            drop(child.stdin.take());
            let t0 = Instant::now();
            loop {
                match child.try_wait() {
                    Ok(Some(status)) if status.success() => return Ok(()),
                    Ok(Some(status)) => return Err(format!("server exited {status}")),
                    Ok(None) if t0.elapsed() > Duration::from_secs(30) => {
                        let _ = child.kill();
                        let _ = child.wait();
                        return Err("server did not drain within 30 s; killed".into());
                    }
                    Ok(None) => std::thread::sleep(Duration::from_millis(50)),
                    Err(e) => {
                        let _ = child.kill();
                        return Err(format!("waiting on server: {e}"));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Open-loop lanes
// ---------------------------------------------------------------------------

/// One lane of offered load: `conns` connections, each firing
/// `per_conn` identical requests at `rate_hz` on a fixed schedule.
#[derive(Clone, Copy)]
struct LaneSpec {
    label: &'static str,
    priority: Priority,
    desc: WorkloadDesc,
    iters: u32,
    conns: usize,
    per_conn: usize,
    rate_hz: f64,
    /// 0 = untagged.
    deadline_us: u64,
}

/// Merged per-lane tallies.
#[derive(Default)]
struct LaneOutcome {
    sent: usize,
    ok: usize,
    /// Typed refusals: `Overloaded`, `QueueFull`, `DeadlineExceeded`.
    shed: usize,
    /// Everything else that is not a bit-identical answer: execution
    /// errors, undecodable frames, lost connections, lost responses.
    errors: usize,
    mismatches: usize,
    /// Sorted after merge; from the *scheduled* send time.
    latencies_ms: Vec<f64>,
}

impl LaneOutcome {
    fn absorb(&mut self, other: LaneOutcome) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.mismatches += other.mismatches;
        self.latencies_ms.extend(other.latencies_ms);
    }

    fn p_ms(&self, q: f64) -> f64 {
        percentile(&self.latencies_ms, q)
    }

    fn shed_rate(&self) -> f64 {
        if self.sent == 0 { 0.0 } else { self.shed as f64 / self.sent as f64 }
    }
}

struct ScenarioOutcome {
    name: &'static str,
    mode: &'static str,
    wall_s: f64,
    lanes: Vec<(LaneSpec, LaneOutcome)>,
    /// Setup/teardown failures (connection refused, unclean drain…).
    errors: Vec<String>,
}

impl ScenarioOutcome {
    fn lane(&self, label: &str) -> Option<&LaneOutcome> {
        self.lanes.iter().find(|(s, _)| s.label == label).map(|(_, o)| o)
    }

    fn total_shed(&self) -> usize {
        self.lanes.iter().map(|(_, o)| o.shed).sum()
    }
}

/// One connection's sender/receiver pair. The sender fires on the
/// fixed schedule and never waits; the receiver correlates by request
/// id, validates payload bytes against `expect` and measures latency
/// from the scheduled arrival time.
fn run_conn(addr: &str, lane: LaneSpec, expect: &[u8]) -> Result<LaneOutcome, String> {
    let mut send_cli = EdgeClient::connect(addr).map_err(|e| format!("connect: {e}"))?;
    let mut recv_cli = send_cli.try_clone().map_err(|e| format!("clone: {e}"))?;
    recv_cli
        .set_recv_timeout(Some(RECV_TIMEOUT))
        .map_err(|e| format!("timeout: {e}"))?;
    let t0 = Instant::now();
    let sched = |k: usize| t0 + Duration::from_secs_f64(k as f64 / lane.rate_hz);

    std::thread::scope(|scope| {
        let sender = scope.spawn(move || {
            let mut sent = 0usize;
            for k in 0..lane.per_conn {
                if let Some(wait) = sched(k).checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                let frame = RequestFrame {
                    req_id: k as u64,
                    priority: lane.priority,
                    deadline_us: lane.deadline_us,
                    iters: lane.iters,
                    desc: lane.desc,
                    trace: false,
                };
                if send_cli.send(&frame).is_err() {
                    break;
                }
                sent += 1;
            }
            sent
        });

        let receiver = scope.spawn(move || {
            let mut o = LaneOutcome::default();
            let mut got = 0usize;
            while got < lane.per_conn {
                match recv_cli.recv() {
                    Ok(Ok(Received::Response(r))) => {
                        got += 1;
                        match r.result {
                            Ok(bytes) if bytes == expect => {
                                o.ok += 1;
                                let lat = Instant::now()
                                    .saturating_duration_since(sched(r.req_id as usize));
                                o.latencies_ms.push(lat.as_secs_f64() * 1e3);
                            }
                            Ok(_) => o.mismatches += 1,
                            Err(
                                WireError::Overloaded
                                | WireError::QueueFull
                                | WireError::DeadlineExceeded,
                            ) => o.shed += 1,
                            Err(_) => o.errors += 1,
                        }
                    }
                    Ok(Ok(Received::Closed)) => {
                        o.errors += lane.per_conn - got;
                        break;
                    }
                    Ok(Err(_undecodable)) => {
                        got += 1;
                        o.errors += 1;
                    }
                    Err(_timeout_or_io) => {
                        o.errors += lane.per_conn - got;
                        break;
                    }
                }
            }
            o
        });

        let sent = sender.join().expect("sender panicked");
        let mut o = receiver.join().expect("receiver panicked");
        o.sent = sent;
        Ok(o)
    })
}

/// Run every lane of one scenario concurrently against a fresh server.
fn run_scenario(name: &'static str, cfg: &ServerCfg, lanes: &[LaneSpec]) -> ScenarioOutcome {
    let mut errors = Vec::new();
    let server = match start_server(cfg) {
        Ok(s) => s,
        Err(e) => {
            return ScenarioOutcome {
                name,
                mode: "failed",
                wall_s: 0.0,
                lanes: Vec::new(),
                errors: vec![format!("start: {e}")],
            };
        }
    };
    let mode = server.mode;
    let addr = server.addr.clone();
    // The oracle: one reference output per lane (every request in a
    // lane is the same shape, so one host run covers them all).
    let expects: Vec<Vec<u8>> = lanes
        .iter()
        .map(|l| l.desc.instantiate().reference(l.iters as usize))
        .collect();

    let t0 = Instant::now();
    let mut merged: Vec<LaneOutcome> = lanes.iter().map(|_| LaneOutcome::default()).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (li, lane) in lanes.iter().enumerate() {
            for _ in 0..lane.conns {
                let (addr, expect) = (&addr, &expects[li]);
                handles.push((li, scope.spawn(move || run_conn(addr, *lane, expect))));
            }
        }
        for (li, h) in handles {
            match h.join().expect("connection thread panicked") {
                Ok(o) => merged[li].absorb(o),
                Err(e) => errors.push(format!("{}: {e}", lanes[li].label)),
            }
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    if let Err(e) = stop_server(server) {
        errors.push(format!("stop: {e}"));
    }
    for o in &mut merged {
        o.latencies_ms.sort_by(f64::total_cmp);
    }
    ScenarioOutcome {
        name,
        mode,
        wall_s,
        lanes: lanes.iter().copied().zip(merged).collect(),
        errors,
    }
}

// ---------------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------------

fn scenario_underload(quick: bool) -> (ServerCfg, Vec<LaneSpec>) {
    let s = if quick { 1 } else { 3 };
    let cfg = ServerCfg {
        queue_cap: 256,
        max_batch: 16,
        window_us: 1000,
        high_budget_ms: 60_000,
        bulk_budget_ms: 60_000,
        min_gate_samples: 1_000_000, // gate parked: this cell is about identity
        high_reserve: 0,
        throttle_ns: None,
    };
    let lanes = vec![
        LaneSpec {
            label: "high-saxpy",
            priority: Priority::High,
            desc: WorkloadDesc::Saxpy { n: 1024, a: 2.0 },
            iters: 2,
            conns: 1,
            per_conn: 20 * s,
            rate_hz: 25.0,
            deadline_us: 0,
        },
        LaneSpec {
            label: "bulk-prng",
            priority: Priority::Bulk,
            desc: WorkloadDesc::Prng { n: 4096 },
            iters: 2,
            conns: 2,
            per_conn: 15 * s,
            rate_hz: 15.0,
            deadline_us: 0,
        },
        LaneSpec {
            label: "bulk-stencil",
            priority: Priority::Bulk,
            desc: WorkloadDesc::Stencil { h: 32, w: 32 },
            iters: 2,
            conns: 1,
            per_conn: 10 * s,
            rate_hz: 10.0,
            deadline_us: 0,
        },
    ];
    (cfg, lanes)
}

/// The flood (PRNG, ~256 KiB touched per request on a 40 µs/KiB
/// device ⇒ ~20 ms each) is offered at ~80 req/s — utilisation ≈ 1.6,
/// so its queue grows for the whole run while the small high-priority
/// probes (different kind: never coalesced into the flood's batches)
/// keep overtaking at the dispatcher.
fn scenario_mixed(quick: bool) -> (ServerCfg, Vec<LaneSpec>) {
    let s = if quick { 1 } else { 3 };
    let cfg = ServerCfg {
        queue_cap: 512,
        max_batch: 4, // bounds how long a probe waits behind an in-flight batch
        window_us: 1000,
        high_budget_ms: 60_000,
        bulk_budget_ms: 60_000,
        min_gate_samples: 1_000_000, // overload gate parked: pure priority cell
        high_reserve: 0,
        throttle_ns: Some(40_000),
    };
    let lanes = vec![
        LaneSpec {
            label: "high-probe",
            priority: Priority::High,
            desc: WorkloadDesc::Saxpy { n: 256, a: 1.5 },
            iters: 1,
            conns: 1,
            per_conn: 20 * s,
            rate_hz: 20.0,
            deadline_us: 0,
        },
        LaneSpec {
            label: "bulk-flood",
            priority: Priority::Bulk,
            desc: WorkloadDesc::Prng { n: 16384 },
            iters: 2,
            conns: 2,
            per_conn: 30 * s,
            rate_hz: 40.0,
            deadline_us: 0,
        },
        // Not gated — demonstrates deadline shedding under backlog in
        // the report (the budget is far below the flood's queueing
        // delay, so most of these come back DeadlineExceeded).
        LaneSpec {
            label: "bulk-deadline",
            priority: Priority::Bulk,
            desc: WorkloadDesc::Prng { n: 16384 },
            iters: 1,
            conns: 1,
            per_conn: 10 * s,
            rate_hz: 20.0,
            deadline_us: 50_000,
        },
    ];
    (cfg, lanes)
}

/// The same flood against a 40 ms bulk p99 budget (the flood's own
/// batches take ~20-80 ms, so the trailing window trips almost
/// immediately) and a loose 30 s high budget, with 8 admission slots
/// reserved for the high lane so the flood cannot starve it out of the
/// queue either.
fn scenario_overload(quick: bool) -> (ServerCfg, Vec<LaneSpec>) {
    let s = if quick { 1 } else { 3 };
    let cfg = ServerCfg {
        queue_cap: 64,
        max_batch: 8,
        window_us: 1000,
        high_budget_ms: 30_000,
        bulk_budget_ms: 40,
        min_gate_samples: 8,
        high_reserve: 8,
        throttle_ns: Some(40_000),
    };
    let lanes = vec![
        LaneSpec {
            label: "high-probe",
            priority: Priority::High,
            desc: WorkloadDesc::Saxpy { n: 256, a: 1.5 },
            iters: 1,
            conns: 1,
            per_conn: 20 * s,
            rate_hz: 20.0,
            deadline_us: 0,
        },
        LaneSpec {
            label: "bulk-flood",
            priority: Priority::Bulk,
            desc: WorkloadDesc::Prng { n: 16384 },
            iters: 2,
            conns: 2,
            per_conn: 40 * s,
            rate_hz: 50.0,
            deadline_us: 0,
        },
    ];
    (cfg, lanes)
}

// ---------------------------------------------------------------------------
// Gates + rendering
// ---------------------------------------------------------------------------

struct Gates {
    identity_ok: bool,
    priority_ok: bool,
    shed_ok: bool,
    gate_ok: bool,
}

fn evaluate(scenarios: &[ScenarioOutcome]) -> Gates {
    let by = |name: &str| scenarios.iter().find(|s| s.name == name);

    // Identity: zero mismatches and zero transport/execution errors
    // anywhere; underload additionally answers *everything* (no shed).
    let clean = scenarios.iter().all(|s| {
        s.errors.is_empty()
            && s.lanes.iter().all(|(_, o)| o.mismatches == 0 && o.errors == 0)
    });
    let under_full = by("underload").is_some_and(|s| {
        s.total_shed() == 0 && s.lanes.iter().all(|(_, o)| o.sent > 0 && o.ok == o.sent)
    });
    let identity_ok = clean && under_full;

    // Priority: under the mixed flood, high p99 strictly below bulk p99.
    let priority_ok = by("mixed").is_some_and(|s| {
        match (s.lane("high-probe"), s.lane("bulk-flood")) {
            (Some(h), Some(b)) => {
                h.ok > 0 && b.ok > 0 && h.p_ms(0.99) < b.p_ms(0.99)
            }
            _ => false,
        }
    });

    // Shedding: only under overload (underload shed 0 is part of
    // identity_ok), bulk first — high sheds nothing, or at a strictly
    // lower rate than bulk.
    let shed_ok = by("overload").is_some_and(|s| {
        match (s.lane("high-probe"), s.lane("bulk-flood")) {
            (Some(h), Some(b)) => {
                b.shed > 0 && (h.shed == 0 || h.shed_rate() < b.shed_rate())
            }
            _ => false,
        }
    });

    let gate_ok = identity_ok && priority_ok && shed_ok;
    Gates { identity_ok, priority_ok, shed_ok, gate_ok }
}

fn render_md(scenarios: &[ScenarioOutcome], gates: &Gates, quick: bool) -> String {
    let mut md = String::new();
    md.push_str("# Serving edge: open-loop load generator\n\n");
    md.push_str(
        "Open-loop lanes (fixed arrival schedules, latency measured \
         from the scheduled arrival time) against a live `cf4rs edge` \
         server; every successful response validated bit-for-bit \
         against the host oracle.\n\n",
    );
    if quick {
        md.push_str("_Quick mode (CI): reduced request counts._\n\n");
    }
    for s in scenarios {
        md.push_str(&format!("## Scenario `{}` ({}, {:.2} s)\n\n", s.name, s.mode, s.wall_s));
        md.push_str(
            "| lane | prio | sent | ok | shed | err | mism | p50 ms | \
             p95 ms | p99 ms | goodput/s | shed rate |\n\
             |---|---|--:|--:|--:|--:|--:|--:|--:|--:|--:|--:|\n",
        );
        for (spec, o) in &s.lanes {
            let goodput = if s.wall_s > 0.0 { o.ok as f64 / s.wall_s } else { 0.0 };
            md.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {:.2} | {:.2} | {:.2} | {:.1} | {:.2} |\n",
                spec.label,
                spec.priority.label(),
                o.sent,
                o.ok,
                o.shed,
                o.errors,
                o.mismatches,
                o.p_ms(0.50),
                o.p_ms(0.95),
                o.p_ms(0.99),
                goodput,
                o.shed_rate(),
            ));
        }
        md.push('\n');
        for e in &s.errors {
            md.push_str(&format!("- **error**: {e}\n"));
        }
        if !s.errors.is_empty() {
            md.push('\n');
        }
    }
    md.push_str("## Gates\n\n");
    let tick = |b: bool| if b { "PASS" } else { "FAIL" };
    md.push_str(&format!(
        "- oracle identity (all responses bit-identical, underload \
         answers everything): **{}**\n",
        tick(gates.identity_ok)
    ));
    md.push_str(&format!(
        "- priority (mixed: high p99 < bulk p99): **{}**\n",
        tick(gates.priority_ok)
    ));
    md.push_str(&format!(
        "- shed discipline (overload sheds bulk first, never high): **{}**\n",
        tick(gates.shed_ok)
    ));
    md.push_str(&format!("- overall: **{}**\n", tick(gates.gate_ok)));
    md
}

fn render_json(scenarios: &[ScenarioOutcome], gates: &Gates, quick: bool) -> String {
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    j.push_str(&format!("  \"quick\": {quick},\n"));
    j.push_str("  \"scenarios\": [\n");
    for (si, s) in scenarios.iter().enumerate() {
        j.push_str("    {\n");
        j.push_str(&format!("      \"name\": \"{}\",\n", s.name));
        j.push_str(&format!("      \"mode\": \"{}\",\n", s.mode));
        j.push_str(&format!("      \"wall_s\": {:.4},\n", s.wall_s));
        j.push_str("      \"lanes\": [\n");
        for (li, (spec, o)) in s.lanes.iter().enumerate() {
            let goodput = if s.wall_s > 0.0 { o.ok as f64 / s.wall_s } else { 0.0 };
            j.push_str(&format!(
                "        {{\"label\": \"{}\", \"priority\": \"{}\", \
                 \"conns\": {}, \"rate_hz\": {:.1}, \"sent\": {}, \
                 \"ok\": {}, \"shed\": {}, \"errors\": {}, \
                 \"mismatches\": {}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
                 \"p99_ms\": {:.3}, \"goodput_rps\": {:.2}, \
                 \"shed_rate\": {:.4}}}{}\n",
                spec.label,
                spec.priority.label(),
                spec.conns,
                spec.rate_hz,
                o.sent,
                o.ok,
                o.shed,
                o.errors,
                o.mismatches,
                o.p_ms(0.50),
                o.p_ms(0.95),
                o.p_ms(0.99),
                goodput,
                o.shed_rate(),
                if li + 1 == s.lanes.len() { "" } else { "," },
            ));
        }
        j.push_str("      ],\n");
        j.push_str("      \"errors\": [");
        for (ei, e) in s.errors.iter().enumerate() {
            if ei > 0 {
                j.push_str(", ");
            }
            j.push_str(&format!("\"{}\"", json_escape(e)));
        }
        j.push_str("]\n");
        j.push_str(&format!(
            "    }}{}\n",
            if si + 1 == scenarios.len() { "" } else { "," }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"gates\": {{\"identity_ok\": {}, \"priority_ok\": {}, \
         \"shed_ok\": {}, \"gate_ok\": {}}}\n",
        gates.identity_ok, gates.priority_ok, gates.shed_ok, gates.gate_ok
    ));
    j.push_str("}\n");
    j
}

/// Run the cell. Returns `(markdown, json, all_gates_passed)`.
pub fn report(quick: bool) -> (String, String, bool) {
    let mut scenarios = Vec::new();
    for (name, (cfg, lanes)) in [
        ("underload", scenario_underload(quick)),
        ("mixed", scenario_mixed(quick)),
        ("overload", scenario_overload(quick)),
    ] {
        eprintln!("  edge: scenario {name}...");
        scenarios.push(run_scenario(name, &cfg, &lanes));
    }
    let gates = evaluate(&scenarios);
    let md = render_md(&scenarios, &gates, quick);
    let json = render_json(&scenarios, &gates, quick);
    (md, json, gates.gate_ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The gate logic itself, on synthetic outcomes — the network paths
    /// are covered by `tests/edge.rs` and the CI bench leg.
    #[test]
    fn gates_require_priority_inversion_and_bulk_first_shedding() {
        fn lane(label: &'static str, priority: Priority) -> LaneSpec {
            LaneSpec {
                label,
                priority,
                desc: WorkloadDesc::Prng { n: 64 },
                iters: 1,
                conns: 1,
                per_conn: 4,
                rate_hz: 10.0,
                deadline_us: 0,
            }
        }
        fn outcome(ok: usize, shed: usize, lat_ms: f64) -> LaneOutcome {
            LaneOutcome {
                sent: ok + shed,
                ok,
                shed,
                errors: 0,
                mismatches: 0,
                latencies_ms: vec![lat_ms; ok.max(1)],
            }
        }
        let good = vec![
            ScenarioOutcome {
                name: "underload",
                mode: "in-process",
                wall_s: 1.0,
                lanes: vec![(lane("high-saxpy", Priority::High), outcome(4, 0, 1.0))],
                errors: Vec::new(),
            },
            ScenarioOutcome {
                name: "mixed",
                mode: "in-process",
                wall_s: 1.0,
                lanes: vec![
                    (lane("high-probe", Priority::High), outcome(4, 0, 5.0)),
                    (lane("bulk-flood", Priority::Bulk), outcome(4, 0, 200.0)),
                ],
                errors: Vec::new(),
            },
            ScenarioOutcome {
                name: "overload",
                mode: "in-process",
                wall_s: 1.0,
                lanes: vec![
                    (lane("high-probe", Priority::High), outcome(4, 0, 5.0)),
                    (lane("bulk-flood", Priority::Bulk), outcome(2, 2, 30.0)),
                ],
                errors: Vec::new(),
            },
        ];
        let g = evaluate(&good);
        assert!(g.identity_ok && g.priority_ok && g.shed_ok && g.gate_ok);

        // Inverted priorities must fail the priority gate.
        let mut bad = good;
        bad[1].lanes[0].1.latencies_ms = vec![300.0; 4];
        let g = evaluate(&bad);
        assert!(!g.priority_ok && !g.gate_ok);

        // High-lane shedding at a higher rate than bulk fails the
        // shed gate.
        bad[1].lanes[0].1.latencies_ms = vec![5.0; 4];
        bad[2].lanes[0].1 = outcome(1, 3, 5.0);
        let g = evaluate(&bad);
        assert!(!g.shed_ok && !g.gate_ok);
    }

    #[test]
    fn json_shape_is_greppable() {
        let scenarios = vec![ScenarioOutcome {
            name: "underload",
            mode: "in-process",
            wall_s: 0.5,
            lanes: Vec::new(),
            errors: vec!["a \"quoted\" failure".into()],
        }];
        let gates =
            Gates { identity_ok: false, priority_ok: false, shed_ok: false, gate_ok: false };
        let j = render_json(&scenarios, &gates, true);
        assert!(j.contains("\"schema\": \"cf4rs-bench-edge/1\""));
        assert!(j.contains("\"gate_ok\": false"));
        assert!(j.contains("a \\\"quoted\\\" failure"));
    }
}
