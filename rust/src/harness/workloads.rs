//! The (workload × path) cross-validation and timing matrix.
//!
//! Runs every [`Workload`] through all five execution paths — the raw
//! substrate, the `ccl` v1 tier, the fluent `ccl::v2` tier, the
//! multi-backend sharded scheduler and the native parallel-kernel tier
//! — timing each cell and checking its output **bit-for-bit** against
//! the host oracle. Any divergence is a correctness bug and fails the
//! run (CI gates on it).
//!
//! Emits two artifacts:
//! * `results/workloads.md` — the human table;
//! * `results/BENCH_workloads.json` — machine-readable per-cell
//!   median/min/mean (schema [`SCHEMA`]), the repo's perf trajectory.

use std::time::{Duration, Instant};

use crate::backend::BackendRegistry;
use crate::harness::microbench::BenchResult;
use crate::workload::{
    exec, MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload,
    StencilWorkload, Workload,
};

/// Version tag of `BENCH_workloads.json`. Bump on layout changes so
/// trend tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-workloads/1";

const PATHS: [&str; 5] = ["rawcl", "ccl-v1", "ccl-v2", "sharded", "native"];

/// One (workload × path) cell.
struct Cell {
    workload: &'static str,
    path: &'static str,
    units: usize,
    iters: usize,
    /// Wall-clock samples (absent entries = the path errored).
    samples: Vec<Duration>,
    /// Every sample's output matched the host oracle bit-for-bit.
    validated: bool,
    error: Option<String>,
}

impl Cell {
    fn stats(&self) -> BenchResult {
        BenchResult {
            name: format!("{}/{}", self.workload, self.path),
            samples: self.samples.clone(),
        }
    }
}

fn ms(d: Option<Duration>) -> Option<f64> {
    d.map(|d| d.as_secs_f64() * 1e3)
}

/// Time + validate one workload on every path.
fn bench_workload<W: Workload + Clone>(
    w: &W,
    iters: usize,
    samples: usize,
    registry: &BackendRegistry,
    cells: &mut Vec<Cell>,
) {
    let reference = w.reference(iters);
    type Runner<'a> = Box<dyn Fn() -> Result<Vec<u8>, String> + 'a>;
    let runners: Vec<(&'static str, Runner<'_>)> = vec![
        // The raw path runs on a simulated device (exercising the
        // queue-worker reference kernels); v1/v2 run on the native PJRT
        // device (exercising the HLO interpreter); the sharded path
        // spans every backend; the native path runs the banded
        // worker-pool tier. Identical bytes from all of them is the
        // cross-validation.
        ("rawcl", Box::new(|| exec::run_raw_path(w, iters, 1))),
        ("ccl-v1", Box::new(|| exec::run_ccl_path(w, iters, 0).map_err(|e| e.to_string()))),
        ("ccl-v2", Box::new(|| exec::run_v2_path(w, iters, 0).map_err(|e| e.to_string()))),
        (
            "sharded",
            Box::new(|| exec::run_sharded_path(w, iters, registry).map_err(|e| e.to_string())),
        ),
        ("native", Box::new(|| exec::run_native_path(w, iters))),
    ];

    for (path, run) in &runners {
        let mut cell = Cell {
            workload: w.name(),
            path: *path,
            units: w.units(),
            iters,
            samples: Vec::new(),
            validated: true,
            error: None,
        };
        // One unmeasured warmup covers kernel compilation.
        match run() {
            Ok(out) => cell.validated &= out == reference,
            Err(e) => {
                cell.validated = false;
                cell.error = Some(e);
            }
        }
        if cell.error.is_none() {
            for _ in 0..samples {
                let t0 = Instant::now();
                match run() {
                    Ok(out) => {
                        cell.samples.push(t0.elapsed());
                        cell.validated &= out == reference;
                    }
                    Err(e) => {
                        cell.validated = false;
                        cell.error = Some(e);
                        break;
                    }
                }
            }
        }
        cells.push(cell);
    }
}

/// Render the markdown table.
fn render_md(cells: &[Cell], quick: bool) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Workload × path matrix — {} mode, every cell validated \
         bit-identical against the host oracle\n\n",
        if quick { "quick" } else { "full" }
    ));
    s.push_str("| workload | units | iters |");
    for p in PATHS {
        s.push_str(&format!(" {p} |"));
    }
    s.push_str("\n|---|---:|---:|");
    for _ in PATHS {
        s.push_str("---:|");
    }
    s.push('\n');

    let mut row_keys: Vec<&'static str> = Vec::new();
    for c in cells {
        if !row_keys.contains(&c.workload) {
            row_keys.push(c.workload);
        }
    }
    for wname in row_keys {
        let row: Vec<&Cell> = cells.iter().filter(|c| c.workload == wname).collect();
        let first = row.first().expect("row exists");
        s.push_str(&format!("| {} | {} | {} |", wname, first.units, first.iters));
        for p in PATHS {
            let cell = row.iter().find(|c| c.path == p);
            let txt = match cell {
                Some(c) if c.validated => match ms(c.stats().median()) {
                    Some(m) => format!("{m:.2} ms ✓"),
                    None => "✓".to_string(),
                },
                Some(_) => "**DIVERGED**".to_string(),
                None => "—".to_string(),
            };
            s.push_str(&format!(" {txt} |"));
        }
        s.push('\n');
    }
    s.push_str(
        "\nEvery path executes the same logical kernels (scalar reference \
         kernels on simulated devices, the HLO interpreter on the native \
         device, both under the sharded scheduler, and the banded native \
         worker pool), so timing differences are fair game but byte \
         differences are bugs.\n",
    );
    for c in cells {
        if let Some(e) = &c.error {
            s.push_str(&format!("\n* `{}/{}` failed: {e}\n", c.workload, c.path));
        }
    }
    s
}

use super::json_escape as esc;

fn json_num(v: Option<f64>) -> String {
    match v {
        Some(v) if v.is_finite() => format!("{v:.6}"),
        _ => "null".to_string(),
    }
}

/// Render `BENCH_workloads.json`.
fn render_json(cells: &[Cell], quick: bool) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        let st = c.stats();
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"path\": \"{}\", \"units\": {}, \
             \"iters\": {}, \"samples\": {}, \"median_ms\": {}, \
             \"mean_ms\": {}, \"min_ms\": {}, \"validated\": {}{}}}{}\n",
            c.workload,
            c.path,
            c.units,
            c.iters,
            c.samples.len(),
            json_num(ms(st.median())),
            json_num(ms(st.mean())),
            json_num(ms(st.min())),
            c.validated,
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < cells.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Build the full report. Returns `(markdown, json, all_validated)` —
/// the caller writes both files even when validation failed (the
/// artifacts are the evidence) but must exit non-zero on `!ok`.
pub fn report(quick: bool) -> (String, String, bool) {
    let samples = if quick { 3 } else { 5 };
    // A fresh registry keeps profiling/timeline state isolated from the
    // process-global one other harness commands use.
    let registry = BackendRegistry::with_default_backends();
    let mut cells = Vec::new();

    if quick {
        bench_workload(&PrngWorkload::new(8192), 3, samples, &registry, &mut cells);
        bench_workload(&SaxpyWorkload::new(8192, 2.5), 3, samples, &registry, &mut cells);
        bench_workload(&ReduceWorkload::new(16384), 2, samples, &registry, &mut cells);
        bench_workload(&StencilWorkload::new(48, 32), 3, samples, &registry, &mut cells);
        bench_workload(&MatmulWorkload::new(24), 2, samples, &registry, &mut cells);
    } else {
        bench_workload(&PrngWorkload::new(65536), 6, samples, &registry, &mut cells);
        bench_workload(&SaxpyWorkload::new(65536, 2.5), 4, samples, &registry, &mut cells);
        bench_workload(&ReduceWorkload::new(262144), 2, samples, &registry, &mut cells);
        bench_workload(&StencilWorkload::new(96, 96), 4, samples, &registry, &mut cells);
        bench_workload(&MatmulWorkload::new(64), 2, samples, &registry, &mut cells);
    }

    let ok = cells.iter().all(|c| c.validated);
    (render_md(&cells, quick), render_json(&cells, quick), ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping_and_nulls() {
        let cells = vec![Cell {
            workload: "prng",
            path: "rawcl",
            units: 8,
            iters: 1,
            samples: vec![],
            validated: false,
            error: Some("a \"quoted\"\nfailure".to_string()),
        }];
        let j = render_json(&cells, true);
        assert!(j.contains("\"median_ms\": null"));
        assert!(j.contains("a \\\"quoted\\\"\\nfailure"));
        assert!(j.contains(SCHEMA));
        // No trailing comma in a 1-element array.
        assert!(!j.contains("}},\n  ]"));
    }

    #[test]
    fn quick_matrix_is_fully_validated() {
        // The acceptance-criteria invariant: 5 workloads × 5 paths, all
        // bit-identical. (Small sizes keep this test fast; the CI
        // bench-gate runs the real --quick matrix end-to-end.)
        let registry = BackendRegistry::with_default_backends();
        let mut cells = Vec::new();
        bench_workload(&PrngWorkload::new(512), 2, 1, &registry, &mut cells);
        bench_workload(&SaxpyWorkload::new(512, 2.5), 2, 1, &registry, &mut cells);
        bench_workload(&ReduceWorkload::new(512), 1, 1, &registry, &mut cells);
        bench_workload(&StencilWorkload::new(12, 8), 2, 1, &registry, &mut cells);
        bench_workload(&MatmulWorkload::new(8), 1, 1, &registry, &mut cells);
        assert_eq!(cells.len(), 5 * 5);
        for c in &cells {
            assert!(
                c.validated,
                "{}/{} diverged: {:?}",
                c.workload, c.path, c.error
            );
        }
        let md = render_md(&cells, true);
        assert!(md.contains("| prng |") && md.contains("sharded"));
        assert!(!md.contains("DIVERGED"));
    }
}
