//! Minimal benchmarking helper for the `cargo bench` targets.
//!
//! The offline vendor set has no criterion, so this provides the small
//! subset the benches need: warmup, N timed samples, and a
//! median/mean/min report — enough to make regressions visible and to
//! feed EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchResult {
    /// Median sample; `None` when no samples were collected.
    pub fn median(&self) -> Option<Duration> {
        if self.samples.is_empty() {
            return None;
        }
        let mut s = self.samples.clone();
        s.sort();
        Some(s[s.len() / 2])
    }

    /// Minimum sample; `None` when no samples were collected (this used
    /// to `unwrap()` and panic on an empty sample vec).
    pub fn min(&self) -> Option<Duration> {
        self.samples.iter().min().copied()
    }

    /// Mean sample; `None` when no samples were collected.
    pub fn mean(&self) -> Option<Duration> {
        if self.samples.is_empty() {
            return None;
        }
        Some(self.samples.iter().sum::<Duration>() / self.samples.len() as u32)
    }

    /// One-line report (ns for sub-ms results, ms otherwise).
    pub fn report(&self) -> String {
        let fmt = |d: Option<Duration>| match d {
            None => "        (none)".to_string(),
            Some(d) if d < Duration::from_millis(1) => {
                format!("{:>9} ns", d.as_nanos())
            }
            Some(d) => format!("{:>9.3} ms", d.as_secs_f64() * 1e3),
        };
        format!(
            "{:<44} median {}  mean {}  min {}  ({} samples)",
            self.name,
            fmt(self.median()),
            fmt(self.mean()),
            fmt(self.min()),
            self.samples.len()
        )
    }
}

/// Run `f` `samples` times after `warmup` unmeasured runs.
pub fn bench(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed());
    }
    let r = BenchResult { name: name.to_string(), samples: out };
    println!("{}", r.report());
    r
}

/// Like [`bench`] but `f` performs `inner_iters` operations per call;
/// the report is per-operation.
pub fn bench_per_op(
    name: &str,
    warmup: usize,
    samples: usize,
    inner_iters: u32,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed() / inner_iters);
    }
    let r = BenchResult { name: name.to_string(), samples: out };
    println!("{}", r.report());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_sane_stats() {
        let r = bench("noop", 1, 9, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 9);
        assert!(r.min().unwrap() <= r.median().unwrap());
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn per_op_divides() {
        let r = bench_per_op("sleepy", 0, 3, 10, || {
            std::thread::sleep(Duration::from_micros(100));
        });
        // 100 µs / 10 ops = ~10 µs/op
        assert!(r.median().unwrap() < Duration::from_micros(100));
    }

    #[test]
    fn empty_samples_do_not_panic() {
        // Regression: min() used to unwrap() on the empty vec.
        let r = BenchResult { name: "empty".into(), samples: vec![] };
        assert_eq!(r.min(), None);
        assert_eq!(r.median(), None);
        assert_eq!(r.mean(), None);
        assert!(r.report().contains("0 samples"));
    }
}
