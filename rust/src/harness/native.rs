//! The native-tier speedup gate (`bench native`).
//!
//! Races the native parallel-kernel tier
//! ([`NativeBackend`](crate::backend::NativeBackend)) against the
//! interpreting PJRT backend on identical command streams
//! ([`exec::run_backend_path`]) for every workload at a small and a
//! large shape, and re-checks the full 5×5 (workload × path)
//! bit-identity matrix. Three gates, all CI-enforced:
//!
//! * every timed run's output matches the host oracle bit-for-bit;
//! * all five paths (rawcl / ccl-v1 / ccl-v2 / sharded / native) agree
//!   with the oracle for all five workloads;
//! * at large shapes the native tier's median wall is at least
//!   [`MIN_SPEEDUP`]× faster than the interpreter's.
//!
//! Emits two artifacts:
//! * `results/native.md` — the human table;
//! * `results/BENCH_native.json` — machine-readable medians/speedups
//!   (schema [`SCHEMA`]), validated and grepped by the CI native gate.

use std::time::{Duration, Instant};

use crate::backend::{Backend, BackendRegistry, NativeBackend, PjrtBackend};
use crate::harness::microbench::BenchResult;
use crate::workload::{
    exec, MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload,
    StencilWorkload, Workload,
};

/// Version tag of `BENCH_native.json`. Bump on layout changes so trend
/// tooling can dispatch.
pub const SCHEMA: &str = "cf4rs-bench-native/1";

/// The CI bar: at large shapes the native tier must beat the
/// interpreter's median wall by at least this factor.
pub const MIN_SPEEDUP: f64 = 2.0;

/// One (workload × shape) interpreter-vs-native race.
struct Cell {
    workload: &'static str,
    shape: &'static str,
    units: usize,
    iters: usize,
    /// Interpreter wall-clock samples (empty = the arm errored).
    interp: Vec<Duration>,
    /// Native-tier wall-clock samples.
    native: Vec<Duration>,
    /// Every sample's output matched the host oracle bit-for-bit.
    validated: bool,
    error: Option<String>,
}

fn median_ms(samples: &[Duration]) -> Option<f64> {
    BenchResult { name: String::new(), samples: samples.to_vec() }
        .median()
        .map(|d| d.as_secs_f64() * 1e3)
}

impl Cell {
    fn interp_ms(&self) -> Option<f64> {
        median_ms(&self.interp)
    }

    fn native_ms(&self) -> Option<f64> {
        median_ms(&self.native)
    }

    fn speedup(&self) -> Option<f64> {
        match (self.interp_ms(), self.native_ms()) {
            (Some(i), Some(n)) if n > 0.0 => Some(i / n),
            _ => None,
        }
    }

    /// The large-shape perf gate; small shapes are informational only.
    fn gated(&self) -> bool {
        self.shape == "large"
    }

    fn gate_pass(&self) -> bool {
        !self.gated()
            || (self.validated
                && self.speedup().is_some_and(|s| s >= MIN_SPEEDUP))
    }
}

/// One workload's 5-path bit-identity verdict.
struct Identity {
    workload: &'static str,
    ok: bool,
    detail: Option<String>,
}

/// Time one backend arm: one unmeasured warmup (covers kernel
/// compilation), then `samples` measured runs, each validated against
/// the host oracle.
fn time_arm(
    w: &dyn Workload,
    iters: usize,
    samples: usize,
    b: &dyn Backend,
    reference: &[u8],
) -> Result<(Vec<Duration>, bool), String> {
    let mut validated = exec::run_backend_path(w, iters, b)? == *reference;
    let mut walls = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        let out = exec::run_backend_path(w, iters, b)?;
        walls.push(t0.elapsed());
        validated &= out == *reference;
    }
    Ok((walls, validated))
}

fn run_pair(
    w: &dyn Workload,
    iters: usize,
    samples: usize,
    reference: &[u8],
) -> Result<(Vec<Duration>, Vec<Duration>, bool), String> {
    let interp = PjrtBackend::native().map_err(|e| e.to_string())?;
    let native = NativeBackend::native().map_err(|e| e.to_string())?;
    let (ti, vi) = time_arm(w, iters, samples, &interp, reference)?;
    let (tn, vn) = time_arm(w, iters, samples, &native, reference)?;
    Ok((ti, tn, vi && vn))
}

/// Race interpreter vs native on one workload at one shape.
fn bench_pair(
    w: &dyn Workload,
    shape: &'static str,
    iters: usize,
    samples: usize,
    cells: &mut Vec<Cell>,
) {
    let reference = w.reference(iters);
    let mut cell = Cell {
        workload: w.name(),
        shape,
        units: w.units(),
        iters,
        interp: Vec::new(),
        native: Vec::new(),
        validated: true,
        error: None,
    };
    match run_pair(w, iters, samples, &reference) {
        Ok((interp, native, validated)) => {
            cell.interp = interp;
            cell.native = native;
            cell.validated = validated;
        }
        Err(e) => {
            cell.validated = false;
            cell.error = Some(e);
        }
    }
    cells.push(cell);
}

/// Check one workload's output is bit-identical across all five
/// execution paths and the host oracle.
fn identity<W: Workload + Clone>(
    w: &W,
    iters: usize,
    registry: &BackendRegistry,
) -> Identity {
    let reference = w.reference(iters);
    type Runner<'a> = Box<dyn Fn() -> Result<Vec<u8>, String> + 'a>;
    let runners: Vec<(&'static str, Runner<'_>)> = vec![
        ("rawcl", Box::new(|| exec::run_raw_path(w, iters, 1))),
        ("ccl-v1", Box::new(|| exec::run_ccl_path(w, iters, 0).map_err(|e| e.to_string()))),
        ("ccl-v2", Box::new(|| exec::run_v2_path(w, iters, 0).map_err(|e| e.to_string()))),
        (
            "sharded",
            Box::new(|| exec::run_sharded_path(w, iters, registry).map_err(|e| e.to_string())),
        ),
        ("native", Box::new(|| exec::run_native_path(w, iters))),
    ];
    let mut ok = true;
    let mut detail = None;
    for (path, run) in &runners {
        match run() {
            Ok(out) if out == reference => {}
            Ok(_) => {
                ok = false;
                if detail.is_none() {
                    detail = Some(format!("{path} diverged from the host oracle"));
                }
            }
            Err(e) => {
                ok = false;
                if detail.is_none() {
                    detail = Some(format!("{path} failed: {e}"));
                }
            }
        }
    }
    Identity { workload: w.name(), ok, detail }
}

fn all_validated(cells: &[Cell]) -> bool {
    cells.iter().all(|c| c.validated)
}

fn identity_ok(identities: &[Identity]) -> bool {
    identities.iter().all(|i| i.ok)
}

fn speedup_ok(cells: &[Cell]) -> bool {
    cells.iter().filter(|c| c.gated()).all(Cell::gate_pass)
}

/// Render the markdown table.
fn render_md(cells: &[Cell], identities: &[Identity], quick: bool) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# Native tier vs interpreter — {} mode, gate: native ≥ \
         {MIN_SPEEDUP:.0}× at large shapes\n\n",
        if quick { "quick" } else { "full" }
    ));
    s.push_str(
        "| workload | shape | units | iters | interpreter (ms) | \
         native (ms) | speedup | gate |\n\
         |---|---|---:|---:|---:|---:|---:|---|\n",
    );
    let fmt = |v: Option<f64>| match v {
        Some(v) => format!("{v:.2}"),
        None => "—".to_string(),
    };
    for c in cells {
        let gate = if !c.gated() {
            "n/a".to_string()
        } else if c.gate_pass() {
            "pass".to_string()
        } else {
            "**FAIL**".to_string()
        };
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} |\n",
            c.workload,
            c.shape,
            c.units,
            c.iters,
            fmt(c.interp_ms()),
            fmt(c.native_ms()),
            match c.speedup() {
                Some(x) => format!("{x:.2}×"),
                None => "—".to_string(),
            },
            gate,
        ));
    }

    s.push_str("\n## 5×5 bit-identity\n\n");
    s.push_str("| workload | rawcl = ccl-v1 = ccl-v2 = sharded = native = oracle |\n|---|---|\n");
    for i in identities {
        s.push_str(&format!(
            "| {} | {} |\n",
            i.workload,
            if i.ok {
                "identical".to_string()
            } else {
                format!(
                    "**BROKEN** ({})",
                    i.detail.as_deref().unwrap_or("divergence")
                )
            },
        ));
    }
    s.push_str(
        "\nThe native tier runs real banded data-parallel Rust on a \
         persistent worker pool; the interpreter walks the same logical \
         kernels element-by-element. Identical bytes across all five \
         paths is the correctness gate; the median-wall speedup at \
         large shapes is the performance gate.\n",
    );
    for c in cells {
        if !c.validated {
            s.push_str(&format!(
                "\n* `{}/{}` diverged or failed: {}\n",
                c.workload,
                c.shape,
                c.error.as_deref().unwrap_or("output mismatch"),
            ));
        }
    }
    s
}

use super::json_escape as esc;

fn json_num(v: Option<f64>) -> String {
    match v {
        Some(v) if v.is_finite() => format!("{v:.6}"),
        _ => "null".to_string(),
    }
}

/// Render `BENCH_native.json`.
fn render_json(cells: &[Cell], identities: &[Identity], quick: bool) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(&format!("  \"min_speedup\": {MIN_SPEEDUP:.1},\n"));
    s.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"shape\": \"{}\", \"units\": {}, \
             \"iters\": {}, \"samples\": {}, \"interp_median_ms\": {}, \
             \"native_median_ms\": {}, \"speedup\": {}, \
             \"validated\": {}, \"gate_pass\": {}{}}}{}\n",
            c.workload,
            c.shape,
            c.units,
            c.iters,
            c.interp.len().min(c.native.len()),
            json_num(c.interp_ms()),
            json_num(c.native_ms()),
            json_num(c.speedup()),
            c.validated,
            c.gate_pass(),
            match &c.error {
                Some(e) => format!(", \"error\": \"{}\"", esc(e)),
                None => String::new(),
            },
            if i + 1 < cells.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"identity\": [\n");
    for (i, id) in identities.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"ok\": {}{}}}{}\n",
            id.workload,
            id.ok,
            match &id.detail {
                Some(d) => format!(", \"detail\": \"{}\"", esc(d)),
                None => String::new(),
            },
            if i + 1 < identities.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"validated\": {},\n", all_validated(cells)));
    s.push_str(&format!("  \"identity_ok\": {},\n", identity_ok(identities)));
    s.push_str(&format!("  \"speedup_ok\": {},\n", speedup_ok(cells)));
    s.push_str(&format!(
        "  \"gate_ok\": {}\n",
        all_validated(cells) && identity_ok(identities) && speedup_ok(cells)
    ));
    s.push_str("}\n");
    s
}

/// Build the full report. Returns `(markdown, json, gate_ok)` — the
/// caller writes both files even when a gate failed (the artifacts are
/// the evidence) but must exit non-zero on `!gate_ok`.
pub fn report(quick: bool) -> (String, String, bool) {
    let samples = if quick { 3 } else { 5 };
    // A fresh registry keeps profiling/timeline state isolated from the
    // process-global one other harness commands use.
    let registry = BackendRegistry::with_default_backends();
    let mut cells = Vec::new();

    if quick {
        bench_pair(&PrngWorkload::new(4096), "small", 3, samples, &mut cells);
        bench_pair(&PrngWorkload::new(65536), "large", 4, samples, &mut cells);
        bench_pair(&SaxpyWorkload::new(4096, 2.5), "small", 2, samples, &mut cells);
        bench_pair(&SaxpyWorkload::new(131072, 2.5), "large", 2, samples, &mut cells);
        bench_pair(&ReduceWorkload::new(4096), "small", 2, samples, &mut cells);
        bench_pair(&ReduceWorkload::new(262144), "large", 2, samples, &mut cells);
        bench_pair(&StencilWorkload::new(32, 24), "small", 2, samples, &mut cells);
        bench_pair(&StencilWorkload::new(192, 128), "large", 2, samples, &mut cells);
        bench_pair(&MatmulWorkload::new(16), "small", 2, samples, &mut cells);
        bench_pair(&MatmulWorkload::new(96), "large", 2, samples, &mut cells);
    } else {
        bench_pair(&PrngWorkload::new(8192), "small", 3, samples, &mut cells);
        bench_pair(&PrngWorkload::new(262144), "large", 4, samples, &mut cells);
        bench_pair(&SaxpyWorkload::new(8192, 2.5), "small", 2, samples, &mut cells);
        bench_pair(&SaxpyWorkload::new(524288, 2.5), "large", 2, samples, &mut cells);
        bench_pair(&ReduceWorkload::new(8192), "small", 2, samples, &mut cells);
        bench_pair(&ReduceWorkload::new(1048576), "large", 2, samples, &mut cells);
        bench_pair(&StencilWorkload::new(48, 32), "small", 2, samples, &mut cells);
        bench_pair(&StencilWorkload::new(384, 256), "large", 2, samples, &mut cells);
        bench_pair(&MatmulWorkload::new(16), "small", 2, samples, &mut cells);
        bench_pair(&MatmulWorkload::new(144), "large", 2, samples, &mut cells);
    }

    // The identity matrix runs at small shapes — it is a correctness
    // check, not a timing one.
    let identities = vec![
        identity(&PrngWorkload::new(2048), 3, &registry),
        identity(&SaxpyWorkload::new(2048, 2.5), 2, &registry),
        identity(&ReduceWorkload::new(4096), 2, &registry),
        identity(&StencilWorkload::new(24, 16), 2, &registry),
        identity(&MatmulWorkload::new(12), 2, &registry),
    ];

    let ok =
        all_validated(&cells) && identity_ok(&identities) && speedup_ok(&cells);
    (
        render_md(&cells, &identities, quick),
        render_json(&cells, &identities, quick),
        ok,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic(
        shape: &'static str,
        interp_ms: u64,
        native_ms: u64,
        validated: bool,
    ) -> Cell {
        Cell {
            workload: "prng",
            shape,
            units: 1024,
            iters: 2,
            interp: vec![Duration::from_millis(interp_ms); 3],
            native: vec![Duration::from_millis(native_ms); 3],
            validated,
            error: None,
        }
    }

    #[test]
    fn speedup_gate_logic() {
        // 10ms / 2ms = 5× — passes the 2× large-shape bar.
        assert!(synthetic("large", 10, 2, true).gate_pass());
        // 10ms / 8ms = 1.25× — fails it.
        assert!(!synthetic("large", 10, 8, true).gate_pass());
        // A fast but diverging cell still fails.
        assert!(!synthetic("large", 10, 1, false).gate_pass());
        // Small shapes are informational only.
        assert!(synthetic("small", 10, 8, false).gate_pass());
        assert!(speedup_ok(&[
            synthetic("small", 10, 8, true),
            synthetic("large", 10, 2, true),
        ]));
        assert!(!speedup_ok(&[synthetic("large", 10, 8, true)]));
    }

    #[test]
    fn json_escaping_nulls_and_gates() {
        let mut cell = synthetic("large", 10, 8, false);
        cell.interp.clear();
        cell.native.clear();
        cell.error = Some("a \"quoted\"\nfailure".to_string());
        let identities = vec![Identity {
            workload: "prng",
            ok: false,
            detail: Some("native failed: boom".to_string()),
        }];
        let j = render_json(&[cell], &identities, true);
        assert!(j.contains(SCHEMA));
        assert!(j.contains("\"interp_median_ms\": null"));
        assert!(j.contains("\"speedup\": null"));
        assert!(j.contains("a \\\"quoted\\\"\\nfailure"));
        assert!(j.contains("\"identity_ok\": false"));
        assert!(j.contains("\"gate_ok\": false"));
        // No trailing comma in 1-element arrays.
        assert!(!j.contains("}},\n  ]"));
    }

    #[test]
    fn tiny_end_to_end_race_validates() {
        // Real interpreter-vs-native races at tiny shapes: correctness
        // must hold even where the speedup gate would not (small shapes
        // are ungated). The CI bench-gate runs the real --quick report.
        let mut cells = Vec::new();
        bench_pair(&PrngWorkload::new(512), "small", 2, 1, &mut cells);
        bench_pair(&SaxpyWorkload::new(512, 2.5), "small", 2, 1, &mut cells);
        bench_pair(&ReduceWorkload::new(512), "small", 1, 1, &mut cells);
        bench_pair(&StencilWorkload::new(12, 8), "small", 2, 1, &mut cells);
        bench_pair(&MatmulWorkload::new(8), "small", 1, 1, &mut cells);
        for c in &cells {
            assert!(
                c.validated,
                "{}/{} diverged: {:?}",
                c.workload, c.shape, c.error
            );
            assert!(c.speedup().is_some());
        }
        let registry = BackendRegistry::with_default_backends();
        let id = identity(&PrngWorkload::new(256), 2, &registry);
        assert!(id.ok, "identity broken: {:?}", id.detail);
        let md = render_md(&cells, &[id], true);
        assert!(md.contains("| prng | small |"));
        assert!(!md.contains("BROKEN"));
    }
}
