//! Counting semaphore — the Rust analogue of the paper's `cp_sem.h`
//! compatibility header (listing S3).
//!
//! The §5 example synchronises its two host threads with POSIX
//! semaphores; std Rust has no stable counting semaphore, so this is the
//! same ~40-line portability shim the paper ships, in safe Rust.

use std::sync::{Condvar, Mutex};

/// A counting semaphore.
pub struct Semaphore {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    /// `cp_sem_init(&sem, val)`.
    pub fn new(val: usize) -> Self {
        Self { count: Mutex::new(val), cv: Condvar::new() }
    }

    /// `cp_sem_wait`: block while the count is zero, then decrement.
    pub fn wait(&self) {
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    /// `cp_sem_post`: increment and wake one waiter.
    pub fn post(&self) {
        let mut c = self.count.lock().unwrap();
        *c += 1;
        drop(c);
        self.cv.notify_one();
    }

    /// Timed wait: block up to `dur` for a permit. Returns `true` when a
    /// permit was taken, `false` on timeout. (The service dispatcher's
    /// micro-batch window is built on this.)
    pub fn wait_timeout(&self, dur: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + dur;
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            let Some(left) =
                deadline.checked_duration_since(std::time::Instant::now())
            else {
                return false;
            };
            let (guard, _timed_out) = self.cv.wait_timeout(c, left).unwrap();
            c = guard;
        }
        *c -= 1;
        true
    }

    /// Snapshot of the current permit count. Racy by nature — another
    /// thread may take or post a permit right after the read — so it is
    /// only good for advisory decisions (the service's bulk-lane
    /// high-reserve admission check), never for exact accounting.
    pub fn available(&self) -> usize {
        *self.count.lock().unwrap()
    }

    /// Non-blocking variant (used by shutdown paths).
    pub fn try_wait(&self) -> bool {
        let mut c = self.count.lock().unwrap();
        if *c == 0 {
            false
        } else {
            *c -= 1;
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn init_value_allows_that_many_waits() {
        let s = Semaphore::new(2);
        assert!(s.try_wait());
        assert!(s.try_wait());
        assert!(!s.try_wait());
        s.post();
        assert!(s.try_wait());
    }

    #[test]
    fn wait_blocks_until_post() {
        let s = Arc::new(Semaphore::new(0));
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            s2.wait();
            42
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.post();
        assert_eq!(t.join().unwrap(), 42);
    }

    #[test]
    fn try_wait_never_oversubscribes_under_contention() {
        // N permits, 4 threads racing try_wait in a loop: exactly N
        // claims may succeed, never more.
        const PERMITS: usize = 100;
        let s = Arc::new(Semaphore::new(PERMITS));
        let claimed = Arc::new(Mutex::new(0usize));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let (s, claimed) = (s.clone(), claimed.clone());
            handles.push(std::thread::spawn(move || {
                let mut mine = 0usize;
                while s.try_wait() {
                    mine += 1;
                }
                *claimed.lock().unwrap() += mine;
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*claimed.lock().unwrap(), PERMITS);
        assert!(!s.try_wait(), "no permits may remain");
    }

    #[test]
    fn try_wait_drains_on_shutdown() {
        // The shutdown idiom the services use: the producer posts one
        // final time after setting a stop flag; the consumer switches
        // from wait() to try_wait() and drains whatever is left without
        // ever blocking.
        let s = Arc::new(Semaphore::new(0));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (s2, stop2) = (s.clone(), stop.clone());
        let producer = std::thread::spawn(move || {
            for _ in 0..5 {
                s2.post();
            }
            stop2.store(true, std::sync::atomic::Ordering::Release);
            s2.post(); // wake a possibly-blocked consumer
        });
        let mut consumed = 0usize;
        loop {
            if stop.load(std::sync::atomic::Ordering::Acquire) {
                // Drain without blocking — the shutdown path.
                while s.try_wait() {
                    consumed += 1;
                }
                break;
            }
            s.wait();
            consumed += 1;
        }
        producer.join().unwrap();
        // 5 real posts + 1 wake post, every one accounted for, and the
        // consumer exited without deadlocking.
        assert!((5..=6).contains(&consumed), "consumed {consumed}");
        assert!(!s.try_wait() || consumed == 5);
    }

    #[test]
    fn wait_timeout_times_out_and_succeeds() {
        let s = Semaphore::new(0);
        let t0 = std::time::Instant::now();
        assert!(!s.wait_timeout(std::time::Duration::from_millis(30)));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));

        s.post();
        assert!(s.wait_timeout(std::time::Duration::from_millis(30)));

        // A post racing the wait is picked up before the deadline.
        let s = Arc::new(Semaphore::new(0));
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            s2.post();
        });
        assert!(s.wait_timeout(std::time::Duration::from_secs(5)));
        t.join().unwrap();
    }

    #[test]
    fn ping_pong_between_threads() {
        // The §5 pattern: two semaphores alternating two workers.
        let a = Arc::new(Semaphore::new(1));
        let b = Arc::new(Semaphore::new(0));
        let (a2, b2) = (a.clone(), b.clone());
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let t = std::thread::spawn(move || {
            for i in 0..5 {
                a2.wait();
                log2.lock().unwrap().push(format!("A{i}"));
                b2.post();
            }
        });
        for i in 0..5 {
            b.wait();
            log.lock().unwrap().push(format!("B{i}"));
            a.post();
        }
        t.join().unwrap();
        let l = log.lock().unwrap();
        assert_eq!(
            *l,
            vec!["A0", "B0", "A1", "B1", "A2", "B2", "A3", "B3", "A4", "B4"]
        );
    }
}
