//! Counting semaphore — the Rust analogue of the paper's `cp_sem.h`
//! compatibility header (listing S3).
//!
//! The §5 example synchronises its two host threads with POSIX
//! semaphores; std Rust has no stable counting semaphore, so this is the
//! same ~40-line portability shim the paper ships, in safe Rust.

use std::sync::{Condvar, Mutex};

/// A counting semaphore.
pub struct Semaphore {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    /// `cp_sem_init(&sem, val)`.
    pub fn new(val: usize) -> Self {
        Self { count: Mutex::new(val), cv: Condvar::new() }
    }

    /// `cp_sem_wait`: block while the count is zero, then decrement.
    pub fn wait(&self) {
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    /// `cp_sem_post`: increment and wake one waiter.
    pub fn post(&self) {
        let mut c = self.count.lock().unwrap();
        *c += 1;
        drop(c);
        self.cv.notify_one();
    }

    /// Non-blocking variant (used by shutdown paths).
    pub fn try_wait(&self) -> bool {
        let mut c = self.count.lock().unwrap();
        if *c == 0 {
            false
        } else {
            *c -= 1;
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn init_value_allows_that_many_waits() {
        let s = Semaphore::new(2);
        assert!(s.try_wait());
        assert!(s.try_wait());
        assert!(!s.try_wait());
        s.post();
        assert!(s.try_wait());
    }

    #[test]
    fn wait_blocks_until_post() {
        let s = Arc::new(Semaphore::new(0));
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            s2.wait();
            42
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.post();
        assert_eq!(t.join().unwrap(), 42);
    }

    #[test]
    fn ping_pong_between_threads() {
        // The §5 pattern: two semaphores alternating two workers.
        let a = Arc::new(Semaphore::new(1));
        let b = Arc::new(Semaphore::new(0));
        let (a2, b2) = (a.clone(), b.clone());
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let t = std::thread::spawn(move || {
            for i in 0..5 {
                a2.wait();
                log2.lock().unwrap().push(format!("A{i}"));
                b2.post();
            }
        });
        for i in 0..5 {
            b.wait();
            log.lock().unwrap().push(format!("B{i}"));
            a.post();
        }
        t.join().unwrap();
        let l = log.lock().unwrap();
        assert_eq!(
            *l,
            vec!["A0", "B0", "A1", "B1", "A2", "B2", "A3", "B3", "A4", "B4"]
        );
    }
}
