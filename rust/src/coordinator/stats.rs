//! Statistical checks for the PRNG output stream.
//!
//! The paper pipes the stream into Dieharder; that is an external
//! binary, so cf4rs ships built-in screening tests instead (DESIGN.md
//! substitution map): monobit, byte chi-square, and the Wald–Wolfowitz
//! runs test. These are screening tests — they catch broken generators
//! (e.g. unhashed sequential seeds), not subtle statistical flaws.

/// Result of one test: statistic + pass verdict at ~4σ.
#[derive(Debug, Clone, Copy)]
pub struct TestResult {
    pub statistic: f64,
    pub passed: bool,
}

/// Monobit test: fraction of set bits should be ~0.5. The statistic is
/// the normalised deviation |ones - n/2| / sqrt(n/4) (≈ N(0,1)).
pub fn monobit(bytes: &[u8]) -> TestResult {
    let nbits = (bytes.len() * 8) as f64;
    let ones: u64 = bytes.iter().map(|b| b.count_ones() as u64).sum();
    let z = ((ones as f64) - nbits / 2.0).abs() / (nbits / 4.0).sqrt();
    TestResult { statistic: z, passed: z < 4.0 }
}

/// Chi-square over byte values: 255 degrees of freedom, mean 255,
/// std ≈ √510 ≈ 22.6; pass within ±4σ.
pub fn byte_chi2(bytes: &[u8]) -> TestResult {
    let mut counts = [0u64; 256];
    for &b in bytes {
        counts[b as usize] += 1;
    }
    let expected = bytes.len() as f64 / 256.0;
    let chi2: f64 = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expected;
            d * d / expected
        })
        .sum();
    let z = (chi2 - 255.0).abs() / (2.0 * 255.0f64).sqrt();
    TestResult { statistic: chi2, passed: z < 4.0 }
}

/// Wald–Wolfowitz runs test on the bit sequence of `bytes` (sampled at
/// the u64 MSB to keep it O(n/8) yet sensitive to stuck states).
pub fn runs_msb(words: &[u64]) -> TestResult {
    let n = words.len();
    if n < 32 {
        return TestResult { statistic: 0.0, passed: true };
    }
    let bits: Vec<bool> = words.iter().map(|w| w >> 63 == 1).collect();
    let n1 = bits.iter().filter(|&&b| b).count() as f64;
    let n0 = n as f64 - n1;
    if n1 == 0.0 || n0 == 0.0 {
        return TestResult { statistic: f64::INFINITY, passed: false };
    }
    let runs = 1 + bits.windows(2).filter(|w| w[0] != w[1]).count();
    let mean = 2.0 * n1 * n0 / (n1 + n0) + 1.0;
    let var = (mean - 1.0) * (mean - 2.0) / (n1 + n0 - 1.0);
    let z = ((runs as f64) - mean).abs() / var.sqrt();
    TestResult { statistic: z, passed: z < 4.0 }
}

/// Run the whole screening battery over a u64 stream.
pub fn screen(words: &[u64]) -> Vec<(&'static str, TestResult)> {
    let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
    vec![
        ("monobit", monobit(&bytes)),
        ("byte_chi2", byte_chi2(&bytes)),
        ("runs_msb", runs_msb(words)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rawcl::simexec;

    fn prng_stream(n: usize, steps: usize) -> Vec<u64> {
        let mut v: Vec<u64> = (0..n).map(|i| simexec::init_seed(i as u32)).collect();
        for _ in 0..steps {
            for x in v.iter_mut() {
                *x = simexec::xorshift(*x);
            }
        }
        v
    }

    #[test]
    fn prng_stream_passes_battery() {
        let words = prng_stream(1 << 14, 3);
        for (name, r) in screen(&words) {
            assert!(r.passed, "{name} failed: statistic {}", r.statistic);
        }
    }

    #[test]
    fn raw_hashed_seeds_pass_monobit() {
        // Even the unstepped hash output should look uniform.
        let words = prng_stream(1 << 14, 0);
        assert!(monobit(&bytes_of(&words)).passed);
    }

    #[test]
    fn sequential_integers_fail() {
        // The reason listing S4 hashes the gid: raw counters are not
        // random. All three tests must reject them.
        let words: Vec<u64> = (0..(1u64 << 14)).collect();
        let results = screen(&words);
        assert!(
            results.iter().any(|(_, r)| !r.passed),
            "sequential integers passed the battery: {results:?}"
        );
    }

    #[test]
    fn constant_stream_fails_runs() {
        let words = vec![u64::MAX; 4096];
        assert!(!runs_msb(&words).passed);
    }

    #[test]
    fn zero_stream_fails() {
        let words = vec![0u64; 4096];
        let r = screen(&words);
        assert!(r.iter().filter(|(_, t)| !t.passed).count() >= 2);
    }

    #[test]
    fn tiny_input_vacuously_passes_runs() {
        assert!(runs_msb(&[1, 2, 3]).passed);
    }

    fn bytes_of(words: &[u64]) -> Vec<u8> {
        words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }
}
