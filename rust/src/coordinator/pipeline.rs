//! Generic double-buffered producer/consumer pipeline — the §5 / Fig. 2
//! pattern as a reusable abstraction.
//!
//! The PRNG service hard-codes this structure for fidelity with the
//! paper's listings; this module exposes it generically so applications
//! can pipeline *any* "produce batch on device / consume batch on host"
//! workload over two command queues with the same semaphore discipline:
//!
//! * the producer runs on the caller's thread (it owns kernel launches);
//! * the consumer runs on a spawned scope thread;
//! * `sem_ready` gates the consumer on the producer (batch published),
//!   `sem_free` gates the producer on the consumer (buffer reusable);
//! * both closures receive the *slot index* (0/1) of the buffer to use —
//!   buffer swapping is the pipeline's job, not the closures'.

use super::sem::Semaphore;

/// Errors from either side of the pipeline.
#[derive(Debug)]
pub enum PipelineError<E> {
    Producer(E),
    Consumer(E),
    /// A side panicked.
    Panicked,
}

/// Run `iters` iterations of a double-buffered pipeline.
///
/// `produce(iter, slot)` publishes batch `iter` into buffer `slot`;
/// `consume(iter, slot)` drains batch `iter` from buffer `slot`. The
/// pipeline guarantees: consume(i, s) happens-after produce(i, s), and
/// produce(i+1, s') happens-after consume(i-1, s') — the §5 overlap
/// window of exactly one batch in flight per direction.
///
/// `produce` is called for iterations `0..iters` and `consume` for
/// `0..iters`; iteration 0's produce happens before the consumer starts
/// (the paper's init-kernel special case).
pub fn run_double_buffered<E: Send>(
    iters: usize,
    mut produce: impl FnMut(usize, usize) -> Result<(), E> + Send,
    mut consume: impl FnMut(usize, usize) -> Result<(), E> + Send,
) -> Result<(), PipelineError<E>> {
    if iters == 0 {
        return Ok(());
    }
    let sem_ready = Semaphore::new(0);
    let sem_free = Semaphore::new(1); // one batch headroom
    let dead = std::sync::atomic::AtomicBool::new(false);
    let mut producer_err: Option<E> = None;
    let consumer_res: std::sync::Mutex<Option<Result<(), E>>> =
        std::sync::Mutex::new(None);

    std::thread::scope(|scope| {
        let consumer = {
            let (sem_ready, sem_free, consumer_res, dead) =
                (&sem_ready, &sem_free, &consumer_res, &dead);
            let consume = &mut consume;
            scope.spawn(move || {
                for i in 0..iters {
                    sem_ready.wait();
                    // Producer aborted: the post was a shutdown signal,
                    // not a published batch.
                    if dead.load(std::sync::atomic::Ordering::SeqCst) {
                        return;
                    }
                    let r = consume(i, i % 2);
                    sem_free.post();
                    if r.is_err() {
                        *consumer_res.lock().unwrap() = Some(r);
                        return;
                    }
                }
                *consumer_res.lock().unwrap() = Some(Ok(()));
            })
        };

        for i in 0..iters {
            sem_free.wait();
            // Bail out promptly if the consumer died.
            if matches!(&*consumer_res.lock().unwrap(), Some(Err(_))) {
                break;
            }
            match produce(i, i % 2) {
                Ok(()) => sem_ready.post(),
                Err(e) => {
                    producer_err = Some(e);
                    // Signal shutdown and unblock the consumer.
                    dead.store(true, std::sync::atomic::Ordering::SeqCst);
                    sem_ready.post();
                    break;
                }
            }
        }
        let _ = consumer;
    });

    if let Some(e) = producer_err {
        return Err(PipelineError::Producer(e));
    }
    match consumer_res.into_inner().unwrap() {
        Some(Ok(())) => Ok(()),
        Some(Err(e)) => Err(PipelineError::Consumer(e)),
        None => Err(PipelineError::Panicked),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn batches_flow_in_order_with_overlap_window() {
        // Shared "device buffers": two slots.
        let slots = [Mutex::new(0usize), Mutex::new(0usize)];
        let log = Mutex::new(Vec::new());
        let r = run_double_buffered::<()>(
            10,
            |i, s| {
                *slots[s].lock().unwrap() = i * 100;
                log.lock().unwrap().push(format!("P{i}"));
                Ok(())
            },
            |i, s| {
                assert_eq!(*slots[s].lock().unwrap(), i * 100, "batch {i} garbled");
                log.lock().unwrap().push(format!("C{i}"));
                Ok(())
            },
        );
        assert!(r.is_ok());
        let log = log.into_inner().unwrap();
        // every C_i after P_i; every P_{i+2} after C_i (slot reuse rule)
        let pos = |tag: &str| log.iter().position(|x| x == tag).unwrap();
        for i in 0..10 {
            assert!(pos(&format!("P{i}")) < pos(&format!("C{i}")));
            if i + 2 < 10 {
                assert!(
                    pos(&format!("C{i}")) < pos(&format!("P{}", i + 2)),
                    "slot reused before drained"
                );
            }
        }
    }

    #[test]
    fn producer_error_propagates() {
        let consumed = AtomicUsize::new(0);
        let r = run_double_buffered(
            10,
            |i, _| if i == 3 { Err("boom") } else { Ok(()) },
            |_, _| {
                consumed.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
        );
        assert!(matches!(r, Err(PipelineError::Producer("boom"))));
        assert!(consumed.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn consumer_error_propagates_and_stops_producer() {
        let produced = AtomicUsize::new(0);
        let r = run_double_buffered(
            100,
            |_, _| {
                produced.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            |i, _| if i == 2 { Err("sink full") } else { Ok(()) },
        );
        assert!(matches!(r, Err(PipelineError::Consumer("sink full"))));
        assert!(
            produced.load(Ordering::SeqCst) < 100,
            "producer should stop early"
        );
    }

    #[test]
    fn zero_iterations_is_noop() {
        let r = run_double_buffered::<()>(0, |_, _| unreachable!(), |_, _| unreachable!());
        assert!(r.is_ok());
    }

    #[test]
    fn single_iteration() {
        let done = AtomicUsize::new(0);
        run_double_buffered::<()>(
            1,
            |_, s| {
                assert_eq!(s, 0);
                Ok(())
            },
            |_, _| {
                done.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}
