//! Generic double-buffered producer/consumer pipeline — the §5 / Fig. 2
//! pattern as a reusable abstraction.
//!
//! The PRNG service hard-codes this structure for fidelity with the
//! paper's listings; this module exposes it generically so applications
//! can pipeline *any* "produce batch on device / consume batch on host"
//! workload over two command queues with the same semaphore discipline:
//!
//! * the producer runs on the caller's thread (it owns kernel launches);
//! * the consumer runs on a spawned scope thread;
//! * `sem_ready` gates the consumer on the producer (batch published),
//!   `sem_free` gates the producer on the consumer (buffer reusable);
//! * both closures receive the *slot index* (0/1) of the buffer to use —
//!   buffer swapping is the pipeline's job, not the closures'.

use super::sem::Semaphore;

/// Errors from either side of the pipeline.
#[derive(Debug)]
pub enum PipelineError<E> {
    Producer(E),
    Consumer(E),
    /// A side panicked. The pipeline still terminates: each side posts
    /// its peer's semaphore from a panic guard, so the survivor never
    /// blocks on a dead thread, and the panic itself is contained
    /// instead of unwinding through [`run_double_buffered`].
    Panicked,
}

/// Posts `sem` and raises `flag` if the owning thread unwinds while the
/// guard is armed — the panic-safety half of the semaphore discipline:
/// a dead side must still wake its blocked peer exactly once.
struct PanicGuard<'a> {
    sem: &'a Semaphore,
    flag: &'a std::sync::atomic::AtomicBool,
    armed: bool,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.flag.store(true, std::sync::atomic::Ordering::SeqCst);
            self.sem.post();
        }
    }
}

/// Run `iters` iterations of a double-buffered pipeline.
///
/// `produce(iter, slot)` publishes batch `iter` into buffer `slot`;
/// `consume(iter, slot)` drains batch `iter` from buffer `slot`. The
/// pipeline guarantees: consume(i, s) happens-after produce(i, s), and
/// produce(i+1, s') happens-after consume(i-1, s') — the §5 overlap
/// window of exactly one batch in flight per direction.
///
/// `produce` is called for iterations `0..iters` and `consume` for
/// `0..iters`; iteration 0's produce happens before the consumer starts
/// (the paper's init-kernel special case).
pub fn run_double_buffered<E: Send>(
    iters: usize,
    mut produce: impl FnMut(usize, usize) -> Result<(), E> + Send,
    mut consume: impl FnMut(usize, usize) -> Result<(), E> + Send,
) -> Result<(), PipelineError<E>> {
    if iters == 0 {
        return Ok(());
    }
    use std::sync::atomic::{AtomicBool, Ordering};

    let sem_ready = Semaphore::new(0);
    let sem_free = Semaphore::new(1); // one batch headroom
    // producer_dead: producer aborted (error or panic), posts are
    // shutdown signals. consumer_dead: consumer died by panic — without
    // it the producer would block in `sem_free.wait()` forever and
    // `thread::scope` could never join.
    let producer_dead = AtomicBool::new(false);
    let consumer_dead = AtomicBool::new(false);
    let mut producer_err: Option<E> = None;
    let consumer_res: std::sync::Mutex<Option<Result<(), E>>> =
        std::sync::Mutex::new(None);

    // A panicking closure (either side) must neither deadlock the other
    // side nor unwind out of this function: the guards keep the
    // semaphore discipline alive through unwinding, and catch_unwind
    // contains the panic that thread::scope re-raises after joining.
    let scope_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        std::thread::scope(|scope| {
            let consumer = {
                let (sem_ready, sem_free, consumer_res) =
                    (&sem_ready, &sem_free, &consumer_res);
                let (producer_dead, consumer_dead) = (&producer_dead, &consumer_dead);
                let consume = &mut consume;
                scope.spawn(move || {
                    let mut guard = PanicGuard {
                        sem: sem_free,
                        flag: consumer_dead,
                        armed: true,
                    };
                    for i in 0..iters {
                        sem_ready.wait();
                        // Producer aborted: the post was a shutdown
                        // signal, not a published batch.
                        if producer_dead.load(Ordering::SeqCst) {
                            guard.armed = false;
                            return;
                        }
                        let r = consume(i, i % 2);
                        if r.is_err() {
                            // Record the error BEFORE posting: the
                            // producer re-checks consumer_res right
                            // after its wait, and posting first would
                            // let it miss the error, produce one extra
                            // batch and block forever on a semaphore
                            // this thread will never post again.
                            *consumer_res.lock().unwrap() = Some(r);
                            sem_free.post();
                            guard.armed = false;
                            return;
                        }
                        sem_free.post();
                    }
                    *consumer_res.lock().unwrap() = Some(Ok(()));
                    guard.armed = false;
                })
            };

            let mut guard = PanicGuard {
                sem: &sem_ready,
                flag: &producer_dead,
                armed: true,
            };
            for i in 0..iters {
                sem_free.wait();
                // Bail out promptly if the consumer died or errored.
                if consumer_dead.load(Ordering::SeqCst) {
                    break;
                }
                if matches!(&*consumer_res.lock().unwrap(), Some(Err(_))) {
                    break;
                }
                match produce(i, i % 2) {
                    Ok(()) => sem_ready.post(),
                    Err(e) => {
                        producer_err = Some(e);
                        // Signal shutdown and unblock the consumer.
                        producer_dead.store(true, Ordering::SeqCst);
                        sem_ready.post();
                        break;
                    }
                }
            }
            guard.armed = false;
            let _ = consumer;
        });
    }));

    if scope_result.is_err() {
        return Err(PipelineError::Panicked);
    }
    if let Some(e) = producer_err {
        return Err(PipelineError::Producer(e));
    }
    match consumer_res.into_inner().unwrap() {
        Some(Ok(())) => Ok(()),
        Some(Err(e)) => Err(PipelineError::Consumer(e)),
        None => Err(PipelineError::Panicked),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    #[test]
    fn batches_flow_in_order_with_overlap_window() {
        // Shared "device buffers": two slots.
        let slots = [Mutex::new(0usize), Mutex::new(0usize)];
        let log = Mutex::new(Vec::new());
        let r = run_double_buffered::<()>(
            10,
            |i, s| {
                *slots[s].lock().unwrap() = i * 100;
                log.lock().unwrap().push(format!("P{i}"));
                Ok(())
            },
            |i, s| {
                assert_eq!(*slots[s].lock().unwrap(), i * 100, "batch {i} garbled");
                log.lock().unwrap().push(format!("C{i}"));
                Ok(())
            },
        );
        assert!(r.is_ok());
        let log = log.into_inner().unwrap();
        // every C_i after P_i; every P_{i+2} after C_i (slot reuse rule)
        let pos = |tag: &str| log.iter().position(|x| x == tag).unwrap();
        for i in 0..10 {
            assert!(pos(&format!("P{i}")) < pos(&format!("C{i}")));
            if i + 2 < 10 {
                assert!(
                    pos(&format!("C{i}")) < pos(&format!("P{}", i + 2)),
                    "slot reused before drained"
                );
            }
        }
    }

    #[test]
    fn producer_error_propagates() {
        let consumed = AtomicUsize::new(0);
        let r = run_double_buffered(
            10,
            |i, _| if i == 3 { Err("boom") } else { Ok(()) },
            |_, _| {
                consumed.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
        );
        assert!(matches!(r, Err(PipelineError::Producer("boom"))));
        assert!(consumed.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn consumer_error_propagates_and_stops_producer() {
        let produced = AtomicUsize::new(0);
        let r = run_double_buffered(
            100,
            |_, _| {
                produced.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            |i, _| if i == 2 { Err("sink full") } else { Ok(()) },
        );
        assert!(matches!(r, Err(PipelineError::Consumer("sink full"))));
        assert!(
            produced.load(Ordering::SeqCst) < 100,
            "producer should stop early"
        );
    }

    /// Run `f` on a helper thread and fail loudly if it does not finish
    /// within 10 s — the pre-fix symptom of the panic bugs was a
    /// *deadlock*, which would otherwise hang the whole test suite.
    fn with_deadline<T: Send + 'static>(f: impl FnOnce() -> T + Send + 'static) -> T {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = tx.send(f());
        });
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("pipeline deadlocked instead of reporting Panicked")
    }

    #[test]
    fn consumer_panic_terminates_and_reports_panicked() {
        // Regression: the consumer panicking (not Err-ing) used to leave
        // `sem_free` unposted, blocking the producer forever.
        let r = with_deadline(|| {
            let produced = Arc::new(AtomicUsize::new(0));
            let p2 = produced.clone();
            let r = run_double_buffered::<()>(
                100,
                move |_, _| {
                    p2.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                |i, _| {
                    if i == 2 {
                        panic!("consumer died");
                    }
                    Ok(())
                },
            );
            (r, produced.load(Ordering::SeqCst))
        });
        assert!(matches!(r.0, Err(PipelineError::Panicked)), "{:?}", r.0);
        assert!(r.1 < 100, "producer should stop early, produced {}", r.1);
    }

    #[test]
    fn producer_panic_terminates_and_reports_panicked() {
        // Symmetric case: a panicking producer must not leave the
        // consumer blocked in `sem_ready.wait()`.
        let r = with_deadline(|| {
            run_double_buffered::<()>(
                100,
                |i, _| {
                    if i == 3 {
                        panic!("producer died");
                    }
                    Ok(())
                },
                |_, _| Ok(()),
            )
        });
        assert!(matches!(r, Err(PipelineError::Panicked)), "{r:?}");
    }

    #[test]
    fn consumer_panic_on_last_iteration_still_reported() {
        // The producer may already be done when the consumer dies; the
        // scope join must still surface the panic, not swallow it.
        let r = with_deadline(|| {
            run_double_buffered::<()>(
                3,
                |_, _| Ok(()),
                |i, _| {
                    if i == 2 {
                        panic!("late death");
                    }
                    Ok(())
                },
            )
        });
        assert!(matches!(r, Err(PipelineError::Panicked)), "{r:?}");
    }

    #[test]
    fn zero_iterations_is_noop() {
        let r = run_double_buffered::<()>(0, |_, _| unreachable!(), |_, _| unreachable!());
        assert!(r.is_ok());
    }

    #[test]
    fn single_iteration() {
        let done = AtomicUsize::new(0);
        run_double_buffered::<()>(
            1,
            |_, s| {
                assert_eq!(s, 0);
                Ok(())
            },
            |_, _| {
                done.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}
