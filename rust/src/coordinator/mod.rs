//! Layer-3 coordinator: the paper's §5 application pattern as reusable
//! library pieces.
//!
//! * [`sem`] — the counting semaphore of listing S3 (`cp_sem.h`).
//! * [`pipeline`] — the Fig. 2 double-buffered producer/consumer pattern
//!   as a generic reusable abstraction.
//! * [`rng_service`] — the massive-PRNG service (Fig. 2's two-thread,
//!   two-queue, double-buffered pipeline) in both realisations: on the
//!   `ccl` framework and on the raw substrate.
//! * [`scheduler`] — the multi-device realisation: any
//!   [`crate::workload::Workload`] (the PRNG service included) sharded
//!   across every backend in the [`crate::backend`] registry with work
//!   stealing, merged output and cross-backend profiling.
//! * [`adaptive`] — profile-driven adaptive control: the Nagle-style
//!   adaptive batch window, the throughput-proportional shard planner
//!   and the service's live [`crate::metrics`] surface.
//! * [`service`] — the persistent multi-client tier on top of the
//!   scheduler: a thread-safe [`service::ComputeService`] accepting
//!   concurrent requests with bounded-queue admission control,
//!   micro-batching same-kind requests into single request-aligned
//!   dispatches (bit-identical to unbatched execution), and per-batch +
//!   service-wide profiling.
//! * [`edge`] — the network serving tier in front of the service: a
//!   TCP edge speaking a length-prefixed binary protocol with priority
//!   lanes, per-tenant fairness, deadline tagging and SLO-aware
//!   overload control (`cf4rs edge`).
//! * [`stats`] — statistical screening of the output stream (the
//!   Dieharder substitution, see DESIGN.md).

pub mod adaptive;
pub mod edge;
pub mod pipeline;
pub mod rng_service;
pub mod scheduler;
pub mod sem;
pub mod service;
pub mod stats;

pub use adaptive::{
    apportion, apportion_capped, plan_proportional, plan_proportional_capped,
    AdaptiveWindow, ServiceMetrics, ShardPlanner,
};
pub use edge::{EdgeClient, EdgeOpts, EdgeServer};
pub use pipeline::{run_double_buffered, PipelineError};
pub use rng_service::{run_ccl, run_raw, run_v2, RngConfig, RunOutcome, Sink};
pub use scheduler::{
    run_sharded, run_sharded_on, run_sharded_workload, run_sharded_workload_on,
    BufferPool, FaultPolicy, ShardedConfig, ShardedOutcome, ShardedRngConfig,
    WorkloadOutcome,
};
pub use sem::Semaphore;
pub use service::{
    run_batch, BatchOutcome, BatchProf, ComputeService, Priority, Response,
    ResponseHandle, ServiceError, ServiceOpts, ServiceReport, ServiceStats,
    WorkloadRequest,
};
