//! Profile-driven adaptive control: the feedback loop from live
//! measurement ([`crate::metrics`]) to online scheduling decisions.
//!
//! The paper's profiler answers "where did the time go" *after* a run;
//! EngineCL-style adaptive runtimes act on that signal *during* one.
//! This module holds the two controllers the compute service closes
//! the loop with, plus the service's metrics surface:
//!
//! * [`AdaptiveWindow`] — Nagle-style micro-batch window sizing. The
//!   dispatcher's straggler wait tracks an EWMA of observed same-kind
//!   inter-arrival gaps: the window stretches while requests keep
//!   arriving (coalescing stays effective under sustained load) and
//!   collapses toward [`AdaptiveWindow::min`] when the admission queue
//!   goes idle (an un-coalescible request stops burning the full
//!   static window in latency).
//! * [`ShardPlanner`] — throughput-proportional shard planning. Each
//!   dispatch's per-backend `(bytes, busy_ns)` observations (from the
//!   scheduler's drained timelines) feed an EWMA of per-backend
//!   bytes/ns; [`ShardPlanner::shares`] + [`apportion`] turn the next
//!   request's unit count into per-backend shard sizes, so faster
//!   backends get proportionally larger shards and the work-stealing
//!   scheduler starts balanced instead of discovering the skew by
//!   stealing.
//! * [`ServiceMetrics`] — the lock-free instrument set the service
//!   dispatcher records into and `serve --live` renders
//!   ([`ServiceMetrics::render_live`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::ccl::prof::export::escape_field;
use crate::metrics::{Counter, Gauge, Histogram, WindowedHistogram};
use crate::workload::Shard;

use super::service::Priority;

// ---------------------------------------------------------------------------
// Adaptive batch window
// ---------------------------------------------------------------------------

/// How many *consecutive* idle closes before the window re-probes at
/// its initial (static) value. Without the probe the controller would
/// be a one-way ratchet: a steady stream whose inter-arrival gap
/// exceeds the shrunken window never shows the controller a straggler,
/// so nothing would ever re-stretch it and coalescing the static
/// window achieves would be lost forever. The probe costs one static
/// window per [`IDLE_PROBE_EVERY`] requests on a truly serial stream
/// (amortised ~6 %), and re-discovers the arrival rate within one
/// batch on a coalescible one.
const IDLE_PROBE_EVERY: u64 = 16;

/// Nagle-style adaptive micro-batch window — see the [module
/// docs](self) for the control rule.
pub struct AdaptiveWindow {
    min_ns: u64,
    max_ns: u64,
    initial_ns: u64,
    window_ns: AtomicU64,
    gap_ewma_ns: AtomicU64,
    /// Consecutive idle closes since the last straggler.
    idle_streak: AtomicU64,
}

impl AdaptiveWindow {
    /// Explicit bounds; the current window starts at `initial` clamped
    /// into `[min, max]`.
    pub fn new(initial: Duration, min: Duration, max: Duration) -> Self {
        let min_ns = (min.as_nanos() as u64).max(1);
        let max_ns = (max.as_nanos() as u64).max(min_ns);
        let w = (initial.as_nanos() as u64).clamp(min_ns, max_ns);
        Self {
            min_ns,
            max_ns,
            initial_ns: w,
            window_ns: AtomicU64::new(w),
            gap_ewma_ns: AtomicU64::new(0),
            idle_streak: AtomicU64::new(0),
        }
    }

    /// Derive bounds from a static window configuration: start at the
    /// static value, shrink down to `static/64` (floored at 10 µs) when
    /// idle, stretch up to `4 × static` under sustained arrival.
    pub fn from_static(window: Duration) -> Self {
        let w = (window.as_nanos() as u64).max(1);
        let floor = (w / 64).max(10_000);
        let min = floor.min(w);
        Self::new(window, Duration::from_nanos(min), Duration::from_nanos(w * 4))
    }

    /// The current straggler-wait window.
    pub fn window(&self) -> Duration {
        Duration::from_nanos(self.window_ns())
    }

    pub fn window_ns(&self) -> u64 {
        self.window_ns.load(Ordering::Relaxed)
    }

    /// Smallest window the controller will shrink to.
    pub fn min(&self) -> Duration {
        Duration::from_nanos(self.min_ns)
    }

    /// Largest window the controller will stretch to.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// A same-kind straggler arrived `gap_ns` after the previous batch
    /// member: fold it into the inter-arrival EWMA and re-derive the
    /// window as twice the EWMA (wait about two typical gaps before
    /// declaring the queue idle).
    pub fn observe_gap(&self, gap_ns: u64) {
        self.idle_streak.store(0, Ordering::Relaxed);
        let prev = self.gap_ewma_ns.load(Ordering::Relaxed);
        // Floor the stored EWMA at 1 ns: 0 is the "never observed"
        // sentinel, and integer division on near-zero burst gaps must
        // not decay back into it (that would make the next real gap be
        // adopted wholesale instead of blended).
        let ewma = if prev == 0 { gap_ns } else { (3 * prev + gap_ns) / 4 };
        self.gap_ewma_ns.store(ewma.max(1), Ordering::Relaxed);
        let w = (2 * ewma).clamp(self.min_ns, self.max_ns);
        self.window_ns.store(w, Ordering::Relaxed);
    }

    /// A batch closed by timeout without a single straggler: the queue
    /// is idle, halve the window (multiplicative decrease) so lone
    /// requests stop paying the full wait. Every
    /// [`IDLE_PROBE_EVERY`]th consecutive idle close re-probes at the
    /// initial window instead, so a sustained stream arriving *just*
    /// slower than the shrunken window is periodically given a full
    /// window to show its stragglers (see [`IDLE_PROBE_EVERY`]).
    pub fn observe_idle_close(&self) {
        let streak = self.idle_streak.fetch_add(1, Ordering::Relaxed) + 1;
        let w = if streak % IDLE_PROBE_EVERY == 0 {
            self.initial_ns
        } else {
            (self.window_ns() / 2).clamp(self.min_ns, self.max_ns)
        };
        self.window_ns.store(w, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Proportional shard planning
// ---------------------------------------------------------------------------

/// EWMA of observed per-backend throughput, and the proportional shard
/// plans derived from it — see the [module docs](self).
#[derive(Default)]
pub struct ShardPlanner {
    /// Backend name → EWMA bytes per nanosecond.
    speeds: Mutex<BTreeMap<String, f64>>,
}

impl ShardPlanner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed the throughput EWMA with a capability cost hint
    /// (bytes/ns) — the plugin ABI's warm start
    /// ([`Capabilities::cost_hint_bytes_per_ns`]
    /// (crate::backend::plugin::Capabilities)). A prior only fills an
    /// empty slot: once a backend has been observed (or primed), later
    /// primes are no-ops, and real observations fold the prior into
    /// the EWMA like any other sample — measurement always ends up
    /// dominating the hint. Non-finite or non-positive hints are
    /// ignored.
    pub fn prime(&self, backend: &str, bytes_per_ns: f64) {
        if !bytes_per_ns.is_finite() || bytes_per_ns <= 0.0 {
            return;
        }
        self.speeds.lock().unwrap().entry(backend.to_string()).or_insert(bytes_per_ns);
    }

    /// Fold one dispatch's observation for `backend` into its
    /// throughput EWMA. Zero observations are ignored (a backend that
    /// ran nothing this dispatch tells us nothing).
    pub fn observe(&self, backend: &str, bytes: u64, busy_ns: u64) {
        if bytes == 0 || busy_ns == 0 {
            return;
        }
        let s = bytes as f64 / busy_ns as f64;
        let mut speeds = self.speeds.lock().unwrap();
        speeds
            .entry(backend.to_string())
            .and_modify(|e| *e = 0.5 * *e + 0.5 * s)
            .or_insert(s);
    }

    /// Normalized per-backend shares (summing to 1) for `backends`, in
    /// the given order. Backends never observed get the mean speed of
    /// the observed ones. `None` until at least one backend has been
    /// observed, or when there is nothing to apportion (< 2 backends).
    pub fn shares(&self, backends: &[String]) -> Option<Vec<f64>> {
        if backends.len() < 2 {
            return None;
        }
        let speeds = self.speeds.lock().unwrap();
        let known: Vec<f64> = backends.iter().filter_map(|b| speeds.get(b).copied()).collect();
        if known.is_empty() {
            return None;
        }
        let mean = known.iter().sum::<f64>() / known.len() as f64;
        let raw: Vec<f64> =
            backends.iter().map(|b| speeds.get(b).copied().unwrap_or(mean)).collect();
        let total: f64 = raw.iter().sum();
        if !total.is_finite() || total <= 0.0 {
            return None;
        }
        Some(raw.iter().map(|s| s / total).collect())
    }

    /// Snapshot of the current per-backend speed EWMAs (bytes/ns),
    /// sorted by name — for dashboards and reports.
    pub fn speed_snapshot(&self) -> Vec<(String, f64)> {
        self.speeds.lock().unwrap().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }
}

/// Split `units` into `shares.len()` integer parts proportional to
/// `shares` (largest-remainder apportionment, deterministic
/// tie-breaking by index). Parts that would land in `(0, min_chunk)`
/// are folded into the currently largest part, so every non-zero part
/// is at least `min_chunk` (unless `units` itself is smaller — then
/// one part holds everything). The parts always sum to `units`.
pub fn apportion(units: usize, shares: &[f64], min_chunk: usize) -> Vec<usize> {
    assert!(!shares.is_empty(), "apportion needs at least one share");
    // Sanitise BEFORE summing: a negative or non-finite share must not
    // poison the total (it would inflate the other parts past `units`).
    let clamped: Vec<f64> = shares
        .iter()
        .map(|&s| if s.is_finite() && s > 0.0 { s } else { 0.0 })
        .collect();
    let total: f64 = clamped.iter().sum();
    let norm: Vec<f64> = if total > 0.0 {
        clamped.iter().map(|s| s / total).collect()
    } else {
        vec![1.0 / shares.len() as f64; shares.len()]
    };
    let mut parts: Vec<usize> = norm.iter().map(|s| (s * units as f64).floor() as usize).collect();
    // Floor rounding can only under-shoot; hand the remainder out by
    // descending fractional part (ties: lower index first).
    let assigned: usize = parts.iter().sum();
    let mut order: Vec<usize> = (0..shares.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = norm[a] * units as f64 - parts[a] as f64;
        let fb = norm[b] * units as f64 - parts[b] as f64;
        fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    for i in 0..units.saturating_sub(assigned) {
        parts[order[i % order.len()]] += 1;
    }
    // Fold sub-min_chunk crumbs into the largest part.
    let min_chunk = min_chunk.max(1);
    while parts.len() > 1 {
        let Some(small) = (0..parts.len())
            .filter(|&i| parts[i] > 0 && parts[i] < min_chunk)
            .min_by_key(|&i| (parts[i], i))
        else {
            break;
        };
        let largest = (0..parts.len())
            .filter(|&i| i != small)
            .max_by_key(|&i| (parts[i], usize::MAX - i))
            .expect("len > 1, so another part exists");
        if parts[largest] == 0 {
            // `small` is the only non-zero part (units < min_chunk):
            // it keeps its units — the plan must still cover the
            // whole index space.
            break;
        }
        parts[largest] += parts[small];
        parts[small] = 0;
    }
    debug_assert_eq!(parts.iter().sum::<usize>(), units);
    parts
}

/// [`apportion`], then enforce per-part capacity caps (`None` =
/// unlimited). Overflow from capped parts spills onto the uncapped
/// ones proportionally to their shares; a spill that saturates further
/// caps cascades (the saturated set grows every round, so the loop
/// terminates). When the caps are infeasible — total capacity below
/// `units` — the roomiest part absorbs the surplus so the plan still
/// covers the whole index space and the over-budget backend reports
/// the honest out-of-memory error instead of the planner silently
/// dropping work. The parts always sum to `units`.
pub fn apportion_capped(
    units: usize,
    shares: &[f64],
    min_chunk: usize,
    caps: &[Option<usize>],
) -> Vec<usize> {
    assert_eq!(shares.len(), caps.len(), "one cap slot per share");
    let mut parts = apportion(units, shares, min_chunk);
    if caps.iter().all(|c| c.is_none()) {
        return parts;
    }
    let mut saturated = vec![false; parts.len()];
    loop {
        let mut overflow = 0usize;
        for (i, part) in parts.iter_mut().enumerate() {
            if let Some(cap) = caps[i] {
                if *part > cap {
                    overflow += *part - cap;
                    *part = cap;
                    saturated[i] = true;
                }
            }
        }
        if overflow == 0 {
            break;
        }
        if saturated.iter().all(|&s| s) {
            let roomiest = (0..parts.len())
                .max_by_key(|&i| (caps[i].unwrap_or(usize::MAX), usize::MAX - i))
                .expect("apportion rejected empty shares");
            parts[roomiest] += overflow;
            break;
        }
        // Zero/hostile shares still need a positive weight here, or a
        // saturated-cap spill could never land anywhere.
        let spill_shares: Vec<f64> = shares
            .iter()
            .enumerate()
            .map(|(i, &s)| {
                if saturated[i] {
                    0.0
                } else if s.is_finite() && s > 0.0 {
                    s
                } else {
                    f64::MIN_POSITIVE
                }
            })
            .collect();
        for (part, extra) in parts.iter_mut().zip(apportion(overflow, &spill_shares, 1)) {
            *part += extra;
        }
    }
    debug_assert_eq!(parts.iter().sum::<usize>(), units);
    parts
}

/// Turn integer parts into a contiguous shard plan over `[0, units)`
/// plus the home backend of every shard. Zero parts are skipped (the
/// backend simply gets nothing this dispatch).
fn parts_to_plan(parts: &[usize]) -> (Vec<Shard>, Vec<usize>) {
    let mut shards = Vec::new();
    let mut homes = Vec::new();
    let mut lo = 0usize;
    for (backend, &len) in parts.iter().enumerate() {
        if len == 0 {
            continue;
        }
        shards.push(Shard { lo, len });
        homes.push(backend);
        lo += len;
    }
    (shards, homes)
}

/// Turn per-backend shares into a contiguous shard plan over
/// `[0, units)` plus the home backend of every shard.
pub fn plan_proportional(
    units: usize,
    shares: &[f64],
    min_chunk: usize,
) -> (Vec<Shard>, Vec<usize>) {
    parts_to_plan(&apportion(units, shares, min_chunk))
}

/// [`plan_proportional`] with per-backend capacity caps — see
/// [`apportion_capped`].
pub fn plan_proportional_capped(
    units: usize,
    shares: &[f64],
    min_chunk: usize,
    caps: &[Option<usize>],
) -> (Vec<Shard>, Vec<usize>) {
    parts_to_plan(&apportion_capped(units, shares, min_chunk, caps))
}

// ---------------------------------------------------------------------------
// The service's metrics surface
// ---------------------------------------------------------------------------

/// Span of the trailing window `serve --live` reports over.
pub const LIVE_WINDOW: Duration = Duration::from_secs(2);

/// The lock-free instrument set the compute service records into.
/// Reading any of it (the `stats()` snapshot, the live dashboard)
/// never takes a lock the dispatcher hot path holds.
pub struct ServiceMetrics {
    /// Requests accepted into the admission queue.
    pub submitted: Counter,
    /// Requests answered successfully.
    pub answered: Counter,
    /// Requests answered with an execution error.
    pub errors: Counter,
    /// Batches dispatched.
    pub batches: Counter,
    /// Requests that shared a batch with at least one other request.
    pub coalesced: Counter,
    /// Shard tasks re-dispatched by the fault policy (sum of
    /// [`WorkloadOutcome::retries`](super::scheduler::WorkloadOutcome)
    /// over all batches).
    pub retries: Counter,
    /// Batches in which at least one backend was quarantined.
    pub quarantine_events: Counter,
    /// Largest batch dispatched so far.
    pub max_batch: Gauge,
    /// Requests accepted but not yet dispatched.
    pub queue_depth: Gauge,
    /// The dispatcher's current straggler window, ns (static or
    /// adaptive).
    pub window_ns: Gauge,
    /// Submit-to-answer latency, ns, since service start.
    pub latency_ns: Histogram,
    /// Submit-to-answer latency, ns, over the trailing [`LIVE_WINDOW`]
    /// (also the live req/s source).
    pub recent_ns: WindowedHistogram,
    /// Per-lane submit-to-answer latency, ns, since service start —
    /// indexed by [`Priority::index`] (`[high, bulk]`). The instrument
    /// that makes "high overtakes bulk" a measured claim, not a hope.
    pub lane_latency_ns: [Histogram; Priority::COUNT],
    /// Per-lane answered counts.
    pub lane_answered: [Counter; Priority::COUNT],
    /// Requests shed at the dispatcher's dequeue point because their
    /// deadline had already passed, per lane.
    pub shed_deadline: [Counter; Priority::COUNT],
    /// Requests rejected at the serving edge's overload gate (trailing
    /// p99 over the lane's budget), per lane.
    pub shed_overload: [Counter; Priority::COUNT],
    /// Output bytes produced per backend (cold path: one lock per
    /// batch, never per request).
    pub backend_bytes: Mutex<BTreeMap<String, u64>>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        let slot_ns = (LIVE_WINDOW.as_nanos() as u64 / 8).max(1);
        Self {
            submitted: Counter::new(),
            answered: Counter::new(),
            errors: Counter::new(),
            batches: Counter::new(),
            coalesced: Counter::new(),
            retries: Counter::new(),
            quarantine_events: Counter::new(),
            max_batch: Gauge::new(),
            queue_depth: Gauge::new(),
            window_ns: Gauge::new(),
            latency_ns: Histogram::new(),
            recent_ns: WindowedHistogram::new(8, slot_ns),
            lane_latency_ns: [Histogram::new(), Histogram::new()],
            lane_answered: [Counter::new(), Counter::new()],
            shed_deadline: [Counter::new(), Counter::new()],
            shed_overload: [Counter::new(), Counter::new()],
            backend_bytes: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one answered request's latency (cumulative,
    /// trailing-window and per-lane views).
    pub fn record_latency(&self, latency: Duration, priority: Priority) {
        let ns = latency.as_nanos() as u64;
        self.latency_ns.record(ns);
        self.recent_ns.record(ns);
        self.lane_latency_ns[priority.index()].record(ns);
        self.lane_answered[priority.index()].inc();
    }

    /// Total requests shed (deadline + overload, both lanes).
    pub fn total_shed(&self) -> u64 {
        self.shed_deadline.iter().chain(self.shed_overload.iter()).map(|c| c.get()).sum()
    }

    /// Add one dispatch's per-backend output bytes.
    pub fn add_backend_bytes(&self, per_backend: &[(String, u64)]) {
        let mut map = self.backend_bytes.lock().unwrap();
        for (name, bytes) in per_backend {
            *map.entry(name.clone()).or_insert(0) += bytes;
        }
    }

    /// One dashboard line: queue depth, trailing req/s, cumulative
    /// p50/p95/p99 latency, the current batch window and per-backend
    /// byte shares.
    pub fn render_live(&self) -> String {
        let ms = |ns: u64| ns as f64 * 1e-6;
        let (p50, p95, p99) = (
            self.latency_ns.quantile(0.50),
            self.latency_ns.quantile(0.95),
            self.latency_ns.quantile(0.99),
        );
        let mut line = format!(
            "[live] q {:>3} | {:>7.1} req/s ({}s) | p50 {:>7.2} ms  p95 {:>7.2} ms  \
             p99 {:>7.2} ms | win {:>6} us | {} req {} batch",
            self.queue_depth.get(),
            self.recent_ns.rate_per_s(),
            LIVE_WINDOW.as_secs(),
            ms(p50),
            ms(p95),
            ms(p99),
            self.window_ns.get() / 1_000,
            self.answered.get(),
            self.batches.get(),
        );
        if self.lane_answered[Priority::High.index()].get() > 0 {
            line.push_str(&format!(
                " | hi p99 {:.2} ms / blk p99 {:.2} ms",
                ms(self.lane_latency_ns[Priority::High.index()].quantile(0.99)),
                ms(self.lane_latency_ns[Priority::Bulk.index()].quantile(0.99)),
            ));
        }
        let shed = self.total_shed();
        if shed > 0 {
            line.push_str(&format!(" | shed {shed}"));
        }
        let bytes = self.backend_bytes.lock().unwrap();
        let total: u64 = bytes.values().sum();
        if total > 0 {
            line.push_str(" |");
            for (name, b) in bytes.iter() {
                // Backend names come from plugins — escape them like
                // every other export label so a hostile name (embedded
                // newline/tab) cannot forge extra dashboard lines.
                line.push_str(&format!(
                    " {} {:.0}%",
                    escape_field(name),
                    *b as f64 / total as f64 * 100.0
                ));
            }
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_shrinks_on_idle_and_stretches_on_slow_gaps() {
        let w = AdaptiveWindow::from_static(Duration::from_millis(2));
        assert_eq!(w.window(), Duration::from_millis(2));
        // Idle closes halve down to the floor (streak stays below the
        // re-probe period).
        for _ in 0..10 {
            w.observe_idle_close();
        }
        assert_eq!(w.window(), w.min());
        assert_eq!(w.min(), Duration::from_nanos(31_250));
        // Sustained arrivals with ~1 ms gaps stretch it back out.
        for _ in 0..16 {
            w.observe_gap(1_000_000);
        }
        assert_eq!(w.window(), Duration::from_millis(2));
        // Gap EWMA beyond max/2 saturates at max.
        for _ in 0..16 {
            w.observe_gap(1_000_000_000);
        }
        assert_eq!(w.window(), w.max());
        assert_eq!(w.max(), Duration::from_millis(8));
    }

    #[test]
    fn sustained_idle_closes_periodically_reprobe_the_full_window() {
        let w = AdaptiveWindow::from_static(Duration::from_millis(2));
        for _ in 0..(IDLE_PROBE_EVERY - 1) {
            w.observe_idle_close();
        }
        assert_eq!(w.window(), w.min(), "ratcheted down between probes");
        // The IDLE_PROBE_EVERYth consecutive idle close re-opens the
        // full static window so a slower-than-window stream can show
        // its stragglers again.
        w.observe_idle_close();
        assert_eq!(w.window(), Duration::from_millis(2));
        // A straggler resets the streak and re-derives from its gap.
        w.observe_gap(100_000);
        assert_eq!(w.window(), Duration::from_micros(200));
        w.observe_idle_close();
        assert_eq!(w.window(), Duration::from_micros(100));
    }

    #[test]
    fn window_bounds_clamp_initial() {
        let w = AdaptiveWindow::new(
            Duration::from_secs(1),
            Duration::from_micros(10),
            Duration::from_millis(1),
        );
        assert_eq!(w.window(), Duration::from_millis(1));
    }

    #[test]
    fn apportion_is_exact_and_proportionalish() {
        let parts = apportion(1000, &[1.0, 3.0, 1.0], 1);
        assert_eq!(parts.iter().sum::<usize>(), 1000);
        assert_eq!(parts, vec![200, 600, 200]);
        // Remainders hand out deterministically.
        let parts = apportion(10, &[1.0, 1.0, 1.0], 1);
        assert_eq!(parts.iter().sum::<usize>(), 10);
        assert_eq!(parts, vec![4, 3, 3]);
    }

    #[test]
    fn apportion_folds_crumbs_into_the_largest_part() {
        // Share 2 would get ~9 units < min_chunk 64: folded into the
        // largest part, never dropped.
        let parts = apportion(1000, &[0.6, 0.39, 0.01], 64);
        assert_eq!(parts.iter().sum::<usize>(), 1000);
        assert_eq!(parts[2], 0);
        assert!(parts[0] >= 600);
        // units < min_chunk: one part holds everything.
        let parts = apportion(10, &[1.0, 1.0], 1024);
        assert_eq!(parts.iter().sum::<usize>(), 10);
        assert_eq!(parts.iter().filter(|&&p| p > 0).count(), 1);
    }

    #[test]
    fn apportion_sanitises_hostile_shares() {
        // Negative and non-finite shares are treated as zero and must
        // not break the sum invariant.
        let parts = apportion(10, &[2.0, -1.0], 1);
        assert_eq!(parts, vec![10, 0]);
        let parts = apportion(12, &[f64::NAN, 1.0, 1.0], 1);
        assert_eq!(parts.iter().sum::<usize>(), 12);
        assert_eq!(parts[0], 0);
        // All-hostile falls back to uniform.
        let parts = apportion(9, &[-1.0, f64::INFINITY, f64::NAN], 1);
        assert_eq!(parts.iter().sum::<usize>(), 9);
        assert_eq!(parts, vec![3, 3, 3]);
    }

    #[test]
    fn plan_proportional_is_contiguous_with_homes() {
        let (shards, homes) = plan_proportional(1000, &[1.0, 0.0, 3.0], 1);
        assert_eq!(shards.len(), homes.len());
        let mut lo = 0;
        for s in &shards {
            assert_eq!(s.lo, lo);
            assert!(s.len > 0);
            lo += s.len;
        }
        assert_eq!(lo, 1000);
        assert_eq!(homes, vec![0, 2]);
        assert_eq!(shards[1].len, 750);
    }

    #[test]
    fn planner_shares_follow_observed_speeds() {
        let p = ShardPlanner::new();
        let names = vec!["fast".to_string(), "slow".to_string()];
        assert!(p.shares(&names).is_none(), "no observations yet");
        p.observe("fast", 9_000, 1_000);
        p.observe("slow", 1_000, 1_000);
        let shares = p.shares(&names).unwrap();
        assert!((shares[0] - 0.9).abs() < 1e-9, "{shares:?}");
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Unknown backends get the mean of the known.
        let names3 = vec!["fast".to_string(), "slow".to_string(), "new".to_string()];
        let shares3 = p.shares(&names3).unwrap();
        assert!((shares3.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(shares3[2] > shares3[1] && shares3[2] < shares3[0]);
        // EWMA folds new observations in.
        p.observe("slow", 9_000, 1_000);
        let shares = p.shares(&names).unwrap();
        assert!(shares[1] > 0.3, "{shares:?}");
    }

    #[test]
    fn prime_warm_starts_but_never_overrides_observations() {
        let p = ShardPlanner::new();
        let names = vec!["native".to_string(), "sim".to_string()];
        assert!(p.shares(&names).is_none(), "no hints, no observations");
        p.prime("native", 4.0);
        p.prime("sim", 1.0);
        let shares = p.shares(&names).unwrap();
        assert!((shares[0] - 0.8).abs() < 1e-9, "{shares:?}");
        // Re-priming and hostile hints are no-ops.
        p.prime("native", 400.0);
        p.prime("sim", f64::NAN);
        p.prime("sim", -3.0);
        assert_eq!(p.shares(&names).unwrap(), shares);
        // A real observation folds the prior into the EWMA like any
        // other sample: 0.5·1.0 + 0.5·7.0 = 4.0 bytes/ns.
        p.observe("sim", 7_000, 1_000);
        let shares = p.shares(&names).unwrap();
        assert!((shares[1] - 0.5).abs() < 1e-9, "{shares:?}");
        // ...after which a prime can no longer move it.
        p.prime("sim", 0.001);
        assert_eq!(p.shares(&names).unwrap(), shares);
    }

    #[test]
    fn apportion_capped_respects_caps_and_spills_proportionally() {
        // Uncapped plan would be [600, 200, 200]; capping part 0 at
        // 100 spills 500 evenly onto the equal-share takers.
        let parts = apportion_capped(1000, &[3.0, 1.0, 1.0], 1, &[Some(100), None, None]);
        assert_eq!(parts, vec![100, 450, 450]);
        // No caps → plain apportionment.
        assert_eq!(
            apportion_capped(1000, &[1.0, 3.0, 1.0], 1, &[None, None, None]),
            apportion(1000, &[1.0, 3.0, 1.0], 1)
        );
        // Cascading: the first spill pushes part 1 over its own cap,
        // and a second round moves the rest onto the uncapped part.
        let parts =
            apportion_capped(1000, &[3.0, 1.0, 1.0], 1, &[Some(100), Some(300), None]);
        assert_eq!(parts, vec![100, 300, 600]);
        // Infeasible caps: the roomiest part absorbs the surplus so
        // the plan still sums to `units`.
        let parts = apportion_capped(100, &[1.0, 1.0], 1, &[Some(10), Some(20)]);
        assert_eq!(parts, vec![10, 90]);
    }

    #[test]
    fn plan_proportional_capped_keeps_contiguity_under_caps() {
        let (shards, homes) =
            plan_proportional_capped(1000, &[3.0, 1.0], 64, &[Some(128), None]);
        assert_eq!(homes, vec![0, 1]);
        assert_eq!((shards[0].lo, shards[0].len), (0, 128));
        assert_eq!((shards[1].lo, shards[1].len), (128, 872));
    }

    #[test]
    fn metrics_render_live_mentions_the_essentials() {
        let m = ServiceMetrics::new();
        m.answered.inc();
        m.record_latency(Duration::from_millis(3), Priority::Bulk);
        m.window_ns.set(250_000);
        m.add_backend_bytes(&[("sim:a".into(), 3000), ("sim:b".into(), 1000)]);
        let line = m.render_live();
        assert!(line.contains("req/s"), "{line}");
        assert!(line.contains("win    250 us"), "{line}");
        assert!(line.contains("sim:a 75%"), "{line}");
    }

    #[test]
    fn metrics_render_live_escapes_hostile_backend_names() {
        use crate::ccl::prof::export::unescape_field;
        let m = ServiceMetrics::new();
        let hostile = "evil\nname\twith\\tricks";
        m.add_backend_bytes(&[(hostile.into(), 4000)]);
        let line = m.render_live();
        // The dashboard stays one line: control characters are escaped,
        // never emitted raw.
        assert_eq!(line.lines().count(), 1, "{line:?}");
        assert!(!line.contains('\t'), "{line:?}");
        // Round trip: the escaped form recovers the exact name.
        let escaped = escape_field(hostile);
        assert!(line.contains(escaped.as_ref()), "{line:?}");
        assert_eq!(unescape_field(&escaped).as_deref(), Ok(hostile));
    }
}
