//! `coordinator::edge` — the network serving tier in front of
//! [`ComputeService`]: TCP, a length-prefixed binary protocol, priority
//! lanes, per-tenant fairness, deadlines and SLO-aware overload
//! control. This is the layer that turns the in-process service into
//! something "heavy traffic from millions of users" can actually hit.
//!
//! ## Architecture
//!
//! ```text
//! clients ──TCP──► reader thread ──try_submit_with()──► ComputeService
//!    ▲    (one per connection;       │ overload gate,      │ priority
//!    │     many in-flight reqs)      │ deadline tagging    ▼ lanes, DRR
//!    └──◄── writer thread ◄──mpsc── completion callback (dispatcher)
//! ```
//!
//! * **Connection multiplexing** — one reader/writer thread pair per
//!   connection; any number of requests may be in flight at once, and
//!   responses carry the client's correlation id because they complete
//!   out of order (a high-priority probe overtakes queued bulk work).
//! * **Priority lanes + fairness** — the request's priority byte maps
//!   to the service's [`Priority`] lanes; the connection id becomes
//!   the request's tenant, so the bulk lane's deficit round-robin is
//!   per-connection fairness on the wire.
//! * **Overload control** — the [`OverloadGate`] sheds with a typed
//!   [`WireError::Overloaded`] once the trailing-window p99 blows the
//!   lane's budget (bulk budget < high budget ⇒ bulk sheds first);
//!   deadline-tagged requests that expire in the queue come back as
//!   [`WireError::DeadlineExceeded`]. Refusals are answers, not
//!   closed sockets.
//! * **Graceful drain** — [`EdgeServer::shutdown`] stops the
//!   acceptor, winds down readers, then drains the service: every
//!   accepted request's response is written before its writer exits.
//! * **Robustness** — truncated, oversized, bad-magic and bad-version
//!   frames each get their typed error; the connection survives
//!   everything except lost framing (oversized/bad-magic), and the
//!   server never panics on hostile bytes (`examples/edge_fuzz.rs`
//!   drives this with a seeded corpus in CI).

pub mod client;
pub mod overload;
pub mod proto;

pub use client::EdgeClient;
pub use overload::OverloadGate;
pub use proto::{RequestFrame, ResponseFrame, WireError, WorkloadDesc};

use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::backend::BackendRegistry;
use crate::coordinator::adaptive::ServiceMetrics;
use crate::trace;
use crate::coordinator::service::{
    ComputeService, Priority, Response, ServiceError, ServiceOpts, ServiceReport,
    WorkloadRequest,
};

/// How often blocked reads and the acceptor re-check the stop flag.
const POLL: Duration = Duration::from_millis(50);
/// Cap on a stuck client's ability to wedge its writer thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Tunables for [`EdgeServer::start`].
pub struct EdgeOpts {
    /// The wrapped service's configuration (lanes, batching, queue).
    pub service: ServiceOpts,
    /// Backends to execute on (`None` = the process-wide registry).
    pub registry: Option<Arc<BackendRegistry>>,
    /// Overload budget for the high lane's trailing p99 — looser than
    /// the bulk budget, so overload sheds bulk traffic first.
    pub high_p99_budget: Duration,
    /// Overload budget for the bulk lane's trailing p99.
    pub bulk_p99_budget: Duration,
    /// Trailing-window samples below which the gate always admits.
    pub min_gate_samples: u64,
    /// Largest request frame body the server will read.
    pub max_frame: usize,
}

impl Default for EdgeOpts {
    fn default() -> Self {
        Self {
            service: ServiceOpts::default(),
            registry: None,
            high_p99_budget: Duration::from_secs(2),
            bulk_p99_budget: Duration::from_millis(500),
            min_gate_samples: 32,
            max_frame: proto::DEFAULT_MAX_FRAME,
        }
    }
}

/// What [`EdgeServer::shutdown`] returns.
#[derive(Debug)]
pub struct EdgeReport {
    /// The drained service's report (stats + service-wide profile).
    pub service: ServiceReport,
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
}

/// The TCP serving edge — see the [module docs](self).
pub struct EdgeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    svc: Arc<ComputeService>,
    metrics: Arc<ServiceMetrics>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    writers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    connections: Arc<AtomicU64>,
}

impl EdgeServer {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and start serving.
    pub fn start(port: u16, opts: EdgeOpts) -> io::Result<EdgeServer> {
        let EdgeOpts {
            service,
            registry,
            high_p99_budget,
            bulk_p99_budget,
            min_gate_samples,
            max_frame,
        } = opts;
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let svc = Arc::new(match registry {
            Some(r) => ComputeService::start(r, service),
            None => ComputeService::start_global(service),
        });
        let metrics = svc.metrics();
        let gate = OverloadGate::new(high_p99_budget, bulk_p99_budget, min_gate_samples);
        let stop = Arc::new(AtomicBool::new(false));
        let readers = Arc::new(Mutex::new(Vec::new()));
        let writers = Arc::new(Mutex::new(Vec::new()));
        let connections = Arc::new(AtomicU64::new(0));

        let ctx = Arc::new(ConnCtx {
            svc: svc.clone(),
            metrics: metrics.clone(),
            gate,
            stop: stop.clone(),
            max_frame,
        });
        let (readers2, writers2, connections2) =
            (readers.clone(), writers.clone(), connections.clone());
        let stop2 = stop.clone();
        let accept = std::thread::Builder::new()
            .name("cf4rs-edge-accept".into())
            .spawn(move || {
                accept_loop(listener, ctx, stop2, readers2, writers2, connections2)
            })
            .expect("spawn edge acceptor");

        Ok(EdgeServer {
            addr,
            stop,
            accept: Some(accept),
            svc,
            metrics,
            readers,
            writers,
            connections,
        })
    }

    /// The bound address (port resolved when 0 was requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The wrapped service's live metrics surface.
    pub fn metrics(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// Graceful drain: stop accepting connections and frames, answer
    /// every accepted request, flush every writer, then report.
    pub fn shutdown(mut self) -> EdgeReport {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Readers poll the stop flag; joining them drops their service
        // Arcs and their writer senders.
        for h in std::mem::take(&mut *self.readers.lock().unwrap()) {
            let _ = h.join();
        }
        // Drain the service: the dispatcher answers every queued
        // request (firing its connection's callback) before exiting.
        self.svc.initiate_shutdown();
        let svc = std::mem::replace(
            &mut self.svc,
            Arc::new(ComputeService::start_global(ServiceOpts {
                queue_cap: 1,
                ..ServiceOpts::default()
            })),
        );
        let service = match Arc::try_unwrap(svc) {
            Ok(svc) => svc.shutdown(),
            // A reader failed to join and still holds the Arc — settle
            // for a stats snapshot rather than hang.
            Err(svc) => ServiceReport {
                stats: svc.stats(),
                prof_summary: None,
                prof_export: None,
            },
        };
        // Every callback has fired (or been dropped), so every writer's
        // senders are gone: they flush their queues and exit.
        for h in std::mem::take(&mut *self.writers.lock().unwrap()) {
            let _ = h.join();
        }
        EdgeReport { service, connections: self.connections.load(Ordering::SeqCst) }
    }
}

/// State shared by every connection handler.
struct ConnCtx {
    svc: Arc<ComputeService>,
    metrics: Arc<ServiceMetrics>,
    gate: OverloadGate,
    stop: Arc<AtomicBool>,
    max_frame: usize,
}

fn accept_loop(
    listener: TcpListener,
    ctx: Arc<ConnCtx>,
    stop: Arc<AtomicBool>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    writers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    connections: Arc<AtomicU64>,
) {
    let mut next_conn = 1u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn_id = next_conn;
                next_conn += 1;
                connections.fetch_add(1, Ordering::SeqCst);
                match spawn_connection(stream, conn_id, ctx.clone()) {
                    Ok((r, w)) => {
                        readers.lock().unwrap().push(r);
                        writers.lock().unwrap().push(w);
                    }
                    Err(e) => eprintln!("edge: connection {conn_id} setup: {e}"),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    }
}

fn spawn_connection(
    stream: TcpStream,
    conn_id: u64,
    ctx: Arc<ConnCtx>,
) -> io::Result<(JoinHandle<()>, JoinHandle<()>)> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(POLL))?;
    let write_half = stream.try_clone()?;
    write_half.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    let writer = std::thread::Builder::new()
        .name(format!("cf4rs-edge-w{conn_id}"))
        .spawn(move || writer_loop(write_half, rx))?;
    let reader = std::thread::Builder::new()
        .name(format!("cf4rs-edge-r{conn_id}"))
        .spawn(move || reader_loop(stream, conn_id, ctx, tx))?;
    Ok((reader, writer))
}

/// Serialise every frame of one connection onto the socket. Exits when
/// all senders (the reader + every in-flight completion callback) are
/// gone and the queue is flushed — i.e. after the last response.
fn writer_loop(mut stream: TcpStream, rx: mpsc::Receiver<Vec<u8>>) {
    use std::io::Write;
    for frame in rx {
        if stream.write_all(&frame).is_err() {
            // The client hung up; responses have nowhere to go, but we
            // must keep draining so callbacks' sends stay cheap no-ops.
            break;
        }
    }
    let _ = stream.flush();
}

fn reader_loop(
    mut stream: TcpStream,
    conn_id: u64,
    ctx: Arc<ConnCtx>,
    tx: mpsc::Sender<Vec<u8>>,
) {
    let reply = |req_id: u64, result: Result<Vec<u8>, WireError>| {
        let _ = tx.send(ResponseFrame { req_id, result }.encode());
    };
    loop {
        let body = match read_frame_poll(&mut stream, ctx.max_frame, &ctx.stop) {
            PollRead::Frame(b) => b,
            PollRead::Eof | PollRead::Stopped | PollRead::IoError => break,
            PollRead::TooLarge(n) => {
                // Framing is lost — answer, then close.
                reply(0, Err(WireError::TooLarge(n)));
                break;
            }
        };
        // Tracing anchors: the request's edge-side root span runs from
        // frame receipt to reply hand-off. One relaxed load per frame
        // when no trace window is armed.
        let t_read = if trace::enabled() { trace::now_ns() } else { 0 };
        let req = match RequestFrame::decode_body(&body) {
            Ok(req) => req,
            Err((err, req_id)) => {
                // Bad magic means these bytes were never our protocol;
                // answer once and hang up. Structural errors inside a
                // well-addressed frame keep the connection.
                let close = matches!(err, WireError::BadMagic(_));
                reply(req_id, Err(err));
                if close {
                    break;
                }
                continue;
            }
        };
        let t_decoded = if trace::enabled() { trace::now_ns() } else { 0 };
        if ctx.stop.load(Ordering::SeqCst) {
            reply(req.req_id, Err(WireError::ShuttingDown));
            break;
        }
        if !ctx.gate.admit(&ctx.metrics.recent_ns, req.priority) {
            ctx.metrics.shed_overload[req.priority.index()].inc();
            reply(req.req_id, Err(WireError::Overloaded));
            continue;
        }
        // The wire `trace` flag samples this request into the armed
        // trace window: allocate its correlation id here so every
        // downstream span (service, scheduler, device) groups under it.
        let corr = if req.trace && trace::enabled() {
            let c = trace::new_corr();
            trace::complete(
                "edge.decode",
                "edge",
                Some(c),
                None,
                t_read,
                t_decoded,
                vec![
                    ("conn", trace::Tag::from(conn_id)),
                    ("wire_req", trace::Tag::from(req.req_id)),
                ],
            );
            Some(c)
        } else {
            None
        };
        let mut wreq = WorkloadRequest::from_arc(req.desc.instantiate())
            .iters(req.iters as usize)
            .priority(req.priority)
            .tenant(conn_id);
        if let Some(budget) = req.deadline() {
            wreq = wreq.deadline_in(budget);
        }
        if let Some(c) = corr {
            wreq = wreq.corr(c);
        }
        let (tx2, wire_id) = (tx.clone(), req.req_id);
        let cb = Box::new(move |r: Result<Response, ServiceError>| {
            let t_cb = if corr.is_some() && trace::enabled() { trace::now_ns() } else { 0 };
            let ok = r.is_ok();
            let result = match r {
                Ok(resp) => Ok(resp.output),
                Err(e) => Err(wire_error(e)),
            };
            let _ = tx2.send(ResponseFrame { req_id: wire_id, result }.encode());
            if let Some(c) = corr {
                let t_done = trace::now_ns();
                trace::complete(
                    "edge.reply",
                    "edge",
                    Some(c),
                    None,
                    t_cb,
                    t_done,
                    vec![("ok", trace::Tag::from(ok))],
                );
                trace::complete(
                    "edge.req",
                    "edge",
                    Some(c),
                    None,
                    t_read,
                    t_done,
                    vec![
                        ("conn", trace::Tag::from(conn_id)),
                        ("wire_req", trace::Tag::from(wire_id)),
                        ("ok", trace::Tag::from(ok)),
                    ],
                );
            }
        });
        match ctx.svc.try_submit_with(wreq, cb) {
            Ok(_) => {
                if let Some(c) = corr {
                    // Lane admission + submit, closed once the service
                    // accepted the request.
                    trace::complete(
                        "edge.admit",
                        "edge",
                        Some(c),
                        None,
                        t_decoded,
                        trace::now_ns(),
                        vec![("lane", trace::Tag::from(req.priority.label()))],
                    );
                }
            }
            Err(e) => {
                if let Some(c) = corr {
                    // Refused at admission: the callback never fires,
                    // so close the root span here with the error.
                    trace::complete(
                        "edge.req",
                        "edge",
                        Some(c),
                        None,
                        t_read,
                        trace::now_ns(),
                        vec![
                            ("conn", trace::Tag::from(conn_id)),
                            ("wire_req", trace::Tag::from(req.req_id)),
                            ("ok", trace::Tag::from(false)),
                        ],
                    );
                }
                reply(req.req_id, Err(wire_error(e)));
            }
        }
    }
}

/// Map service refusals onto the wire vocabulary.
fn wire_error(e: ServiceError) -> WireError {
    match e {
        ServiceError::QueueFull => WireError::QueueFull,
        ServiceError::ShuttingDown => WireError::ShuttingDown,
        ServiceError::DeadlineExceeded => WireError::DeadlineExceeded,
        ServiceError::Invalid(m) => WireError::BadFrame(m),
        ServiceError::Execution(m) => WireError::Execution(m),
        ServiceError::Abandoned => WireError::Execution("request abandoned".into()),
        ServiceError::Timeout => WireError::Execution("wait timed out".into()),
    }
}

/// What the polling frame reader found.
enum PollRead {
    Frame(Vec<u8>),
    Eof,
    TooLarge(u64),
    Stopped,
    IoError,
}

/// [`proto::read_frame`] against a read-timeout socket: timeouts poll
/// the stop flag instead of failing, so a quiet connection notices
/// shutdown within [`POLL`].
fn read_frame_poll(stream: &mut TcpStream, max: usize, stop: &AtomicBool) -> PollRead {
    let mut len_buf = [0u8; 4];
    match read_buf_poll(stream, &mut len_buf, stop) {
        BufRead::Full => {}
        BufRead::Eof => return PollRead::Eof,
        BufRead::Stopped => return PollRead::Stopped,
        BufRead::IoError => return PollRead::IoError,
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max {
        return PollRead::TooLarge(len as u64);
    }
    let mut body = vec![0u8; len];
    match read_buf_poll(stream, &mut body, stop) {
        BufRead::Full => PollRead::Frame(body),
        BufRead::Eof => PollRead::Eof,
        BufRead::Stopped => PollRead::Stopped,
        BufRead::IoError => PollRead::IoError,
    }
}

enum BufRead {
    Full,
    Eof,
    Stopped,
    IoError,
}

fn read_buf_poll(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> BufRead {
    let mut filled = 0usize;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return BufRead::Eof,
            Ok(n) => filled += n,
            Err(e) => match e.kind() {
                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                    // During drain a half-received frame is abandoned:
                    // the request was never accepted, so the drain
                    // guarantee doesn't cover it.
                    if stop.load(Ordering::SeqCst) {
                        return BufRead::Stopped;
                    }
                }
                io::ErrorKind::Interrupted => {}
                _ => return BufRead::IoError,
            },
        }
    }
    BufRead::Full
}
