//! The edge wire protocol: length-prefixed binary frames.
//!
//! Every frame is a little-endian `u32` body length followed by the
//! body. Both directions share a header (`magic | version | ftype`);
//! decoding is strict — unknown frame types, short bodies and trailing
//! garbage are all typed errors, never panics.
//!
//! ```text
//! request  := len:u32 | magic:u32 | ver:u16 | ftype:u8(=1)
//!           | req_id:u64 | priority:u8 | deadline_us:u64
//!           | iters:u32 | kind:u8 | params...
//! response := len:u32 | magic:u32 | ver:u16 | ftype:u8(=2)
//!           | req_id:u64 | status:u8 | payload_len:u32 | payload
//! ```
//!
//! `status` 0 is success (`payload` = the workload's output bytes,
//! bit-identical to an in-process run); any other value is a
//! [`WireError`] code with the error's detail in the payload. Error
//! payloads round-trip faithfully — a client can recover the observed
//! magic from a [`WireError::BadMagic`], the announced length from a
//! [`WireError::TooLarge`], and the message from the stringy variants.

use std::io::{self, Read, Write};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::service::Priority;
use crate::workload::{
    MatmulWorkload, PrngWorkload, ReduceWorkload, SaxpyWorkload, StencilWorkload,
    Workload,
};

/// Frame magic (`CF4C ED3E` — "cf4ocl edge").
pub const MAGIC: u32 = 0xCF4C_ED3E;
/// Protocol version this build speaks.
pub const VERSION: u16 = 1;
/// Request frame type byte.
pub const FTYPE_REQUEST: u8 = 1;
/// Response frame type byte.
pub const FTYPE_RESPONSE: u8 = 2;
/// High bit of the priority byte: request a per-request trace. Legacy
/// encoders never set it, so the flag is backwards-compatible within
/// wire [`VERSION`] 1.
pub const TRACE_FLAG: u8 = 0x80;
/// Default cap on request frame bodies the server will read. Requests
/// are ~50 bytes; anything near this is hostile or corrupt.
pub const DEFAULT_MAX_FRAME: usize = 1 << 20;
/// Cap on response frame bodies a client will read (response payloads
/// carry workload output, which is legitimately megabytes).
pub const RESPONSE_MAX_FRAME: usize = 1 << 26;

/// Validation caps: largest unit count a single request may ask for.
pub const MAX_UNITS: usize = 1 << 22;
/// Validation caps: largest matmul dimension (d² memory).
pub const MAX_MATMUL_DIM: usize = 1024;
/// Validation caps: most iterations a single request may ask for.
pub const MAX_ITERS: usize = 1024;

// ---------------------------------------------------------------------------
// Typed wire errors
// ---------------------------------------------------------------------------

/// Every way the edge answers "no" — each with a stable status code
/// and a faithful payload, so clients see typed errors, not closed
/// sockets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Frame magic mismatch (payload: the observed magic).
    BadMagic(u32),
    /// Unsupported protocol version (payload: the observed version).
    BadVersion(u16),
    /// Structurally invalid frame — short body, unknown kind, bad
    /// enum byte, trailing garbage, out-of-cap shape (payload: why).
    BadFrame(String),
    /// Announced frame length over the cap (payload: the length). The
    /// server closes the connection after answering — framing is lost.
    TooLarge(u64),
    /// The overload gate shed this request (trailing-window p99 over
    /// the lane's budget). Back off and retry.
    Overloaded,
    /// The admission queue was full.
    QueueFull,
    /// The deadline passed before dispatch; the request was shed.
    DeadlineExceeded,
    /// The server is draining; no new requests are accepted.
    ShuttingDown,
    /// The batch dispatch failed in the scheduler/backend layer.
    Execution(String),
}

impl WireError {
    /// Stable status-byte encoding.
    pub fn code(&self) -> u8 {
        match self {
            WireError::BadMagic(_) => 1,
            WireError::BadVersion(_) => 2,
            WireError::BadFrame(_) => 3,
            WireError::TooLarge(_) => 4,
            WireError::Overloaded => 5,
            WireError::QueueFull => 6,
            WireError::DeadlineExceeded => 7,
            WireError::ShuttingDown => 8,
            WireError::Execution(_) => 9,
        }
    }

    /// Detail bytes carried in the response payload.
    pub fn payload(&self) -> Vec<u8> {
        match self {
            WireError::BadMagic(m) => m.to_le_bytes().to_vec(),
            WireError::BadVersion(v) => v.to_le_bytes().to_vec(),
            WireError::BadFrame(m) | WireError::Execution(m) => m.as_bytes().to_vec(),
            WireError::TooLarge(n) => n.to_le_bytes().to_vec(),
            _ => Vec::new(),
        }
    }

    /// Rebuild from a status byte + payload (the client side of the
    /// round trip). Unknown codes and malformed payloads become
    /// [`WireError::BadFrame`].
    pub fn from_code(code: u8, payload: &[u8]) -> WireError {
        let fixed = |n: usize| -> Option<&[u8]> {
            (payload.len() == n).then_some(payload)
        };
        match code {
            1 => match fixed(4) {
                Some(b) => WireError::BadMagic(u32::from_le_bytes(b.try_into().unwrap())),
                None => WireError::BadFrame("BadMagic payload".into()),
            },
            2 => match fixed(2) {
                Some(b) => {
                    WireError::BadVersion(u16::from_le_bytes(b.try_into().unwrap()))
                }
                None => WireError::BadFrame("BadVersion payload".into()),
            },
            3 => WireError::BadFrame(String::from_utf8_lossy(payload).into_owned()),
            4 => match fixed(8) {
                Some(b) => WireError::TooLarge(u64::from_le_bytes(b.try_into().unwrap())),
                None => WireError::BadFrame("TooLarge payload".into()),
            },
            5 => WireError::Overloaded,
            6 => WireError::QueueFull,
            7 => WireError::DeadlineExceeded,
            8 => WireError::ShuttingDown,
            9 => WireError::Execution(String::from_utf8_lossy(payload).into_owned()),
            other => WireError::BadFrame(format!("unknown status code {other}")),
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            WireError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            WireError::BadFrame(m) => write!(f, "malformed frame: {m}"),
            WireError::TooLarge(n) => write!(f, "frame length {n} over the cap"),
            WireError::Overloaded => write!(f, "server overloaded; request shed"),
            WireError::QueueFull => write!(f, "admission queue full"),
            WireError::DeadlineExceeded => write!(f, "deadline passed; request shed"),
            WireError::ShuttingDown => write!(f, "server shutting down"),
            WireError::Execution(m) => write!(f, "execution failed: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// Workload descriptors
// ---------------------------------------------------------------------------

/// A wire-encodable description of one workload instance — the shapes
/// a remote client may ask the zoo to run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkloadDesc {
    Prng { n: usize },
    Saxpy { n: usize, a: f32 },
    Reduce { n: usize },
    Stencil { h: usize, w: usize },
    Matmul { d: usize },
}

impl WorkloadDesc {
    /// Wire kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            WorkloadDesc::Prng { .. } => 1,
            WorkloadDesc::Saxpy { .. } => 2,
            WorkloadDesc::Reduce { .. } => 3,
            WorkloadDesc::Stencil { .. } => 4,
            WorkloadDesc::Matmul { .. } => 5,
        }
    }

    fn encode_params(&self, out: &mut Vec<u8>) {
        match *self {
            WorkloadDesc::Prng { n } | WorkloadDesc::Reduce { n } => {
                out.extend_from_slice(&(n as u64).to_le_bytes());
            }
            WorkloadDesc::Saxpy { n, a } => {
                out.extend_from_slice(&(n as u64).to_le_bytes());
                out.extend_from_slice(&a.to_bits().to_le_bytes());
            }
            WorkloadDesc::Stencil { h, w } => {
                out.extend_from_slice(&(h as u64).to_le_bytes());
                out.extend_from_slice(&(w as u64).to_le_bytes());
            }
            WorkloadDesc::Matmul { d } => {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
        }
    }

    fn decode_params(kind: u8, cur: &mut Cur<'_>) -> Result<WorkloadDesc, String> {
        Ok(match kind {
            1 => WorkloadDesc::Prng { n: cur.u64()? as usize },
            2 => WorkloadDesc::Saxpy {
                n: cur.u64()? as usize,
                a: f32::from_bits(cur.u32()?),
            },
            3 => WorkloadDesc::Reduce { n: cur.u64()? as usize },
            4 => WorkloadDesc::Stencil {
                h: cur.u64()? as usize,
                w: cur.u64()? as usize,
            },
            5 => WorkloadDesc::Matmul { d: cur.u64()? as usize },
            other => return Err(format!("unknown workload kind {other}")),
        })
    }

    /// Reject shapes a hostile client could use to blow up memory.
    pub fn validate(&self) -> Result<(), String> {
        let in_cap = |what: &str, n: usize| {
            if n == 0 {
                Err(format!("{what} must be non-zero"))
            } else if n > MAX_UNITS {
                Err(format!("{what} {n} over the {MAX_UNITS} cap"))
            } else {
                Ok(())
            }
        };
        match *self {
            WorkloadDesc::Prng { n } | WorkloadDesc::Reduce { n } => in_cap("n", n),
            WorkloadDesc::Saxpy { n, a } => {
                if !a.is_finite() {
                    return Err("saxpy scale must be finite".into());
                }
                in_cap("n", n)
            }
            WorkloadDesc::Stencil { h, w } => {
                in_cap("h", h)?;
                in_cap("w", w)?;
                in_cap("h*w", h.saturating_mul(w))
            }
            WorkloadDesc::Matmul { d } => {
                if d == 0 || d > MAX_MATMUL_DIM {
                    Err(format!("matmul dim {d} outside 1..={MAX_MATMUL_DIM}"))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Materialise the described workload (caller must have
    /// [`validate`](Self::validate)d first).
    pub fn instantiate(&self) -> Arc<dyn Workload> {
        match *self {
            WorkloadDesc::Prng { n } => Arc::new(PrngWorkload::new(n)),
            WorkloadDesc::Saxpy { n, a } => Arc::new(SaxpyWorkload::new(n, a)),
            WorkloadDesc::Reduce { n } => Arc::new(ReduceWorkload::new(n)),
            WorkloadDesc::Stencil { h, w } => Arc::new(StencilWorkload::new(h, w)),
            WorkloadDesc::Matmul { d } => Arc::new(MatmulWorkload::new(d)),
        }
    }
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

/// One client→server request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Client-chosen correlation id, echoed on the response (responses
    /// may arrive out of order — many requests ride one connection).
    pub req_id: u64,
    pub priority: Priority,
    /// Completion budget in microseconds from server receipt
    /// (0 = no deadline).
    pub deadline_us: u64,
    /// Iterations to run (1..=[`MAX_ITERS`]).
    pub iters: u32,
    pub desc: WorkloadDesc,
    /// Request a per-request trace (span tree) for this request. Rides
    /// the high bit of the priority byte, so pre-trace encoders (which
    /// never set it) remain wire-compatible at the same version.
    pub trace: bool,
}

impl RequestFrame {
    /// Deadline budget as a `Duration` (`None` when untagged).
    pub fn deadline(&self) -> Option<Duration> {
        (self.deadline_us > 0).then(|| Duration::from_micros(self.deadline_us))
    }

    /// Encode, length prefix included.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(48);
        body.extend_from_slice(&MAGIC.to_le_bytes());
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.push(FTYPE_REQUEST);
        body.extend_from_slice(&self.req_id.to_le_bytes());
        body.push(self.priority.index() as u8 | if self.trace { TRACE_FLAG } else { 0 });
        body.extend_from_slice(&self.deadline_us.to_le_bytes());
        body.extend_from_slice(&self.iters.to_le_bytes());
        body.push(self.desc.kind());
        self.desc.encode_params(&mut body);
        prefix(body)
    }

    /// Strict decode of a request body. On error, the best-effort
    /// `req_id` recovered from the header rides along so the server
    /// can still correlate its error response (0 when the header never
    /// got that far).
    pub fn decode_body(body: &[u8]) -> Result<RequestFrame, (WireError, u64)> {
        let mut cur = Cur::new(body);
        let (magic, version, ftype) = decode_header(&mut cur).map_err(|e| (e, 0))?;
        if magic != MAGIC {
            return Err((WireError::BadMagic(magic), 0));
        }
        if version != VERSION {
            return Err((WireError::BadVersion(version), 0));
        }
        if ftype != FTYPE_REQUEST {
            return Err((WireError::BadFrame(format!("frame type {ftype}")), 0));
        }
        let req_id = cur.u64().map_err(|e| (WireError::BadFrame(e), 0))?;
        let bad = |e: String| (WireError::BadFrame(e), req_id);
        let prio_byte = cur.u8().map_err(&bad)?;
        let trace = prio_byte & TRACE_FLAG != 0;
        let priority = match prio_byte & !TRACE_FLAG {
            0 => Priority::High,
            1 => Priority::Bulk,
            other => return Err(bad(format!("priority byte {other}"))),
        };
        let deadline_us = cur.u64().map_err(&bad)?;
        let iters = cur.u32().map_err(&bad)?;
        if iters == 0 || iters as usize > MAX_ITERS {
            return Err(bad(format!("iters {iters} outside 1..={MAX_ITERS}")));
        }
        let kind = cur.u8().map_err(&bad)?;
        let desc = WorkloadDesc::decode_params(kind, &mut cur).map_err(&bad)?;
        cur.finish().map_err(&bad)?;
        desc.validate().map_err(&bad)?;
        Ok(RequestFrame { req_id, priority, deadline_us, iters, desc, trace })
    }
}

/// One server→client response.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    /// The request's correlation id, echoed back.
    pub req_id: u64,
    /// Output bytes (bit-identical to an in-process run) or the typed
    /// refusal.
    pub result: Result<Vec<u8>, WireError>,
}

impl ResponseFrame {
    /// Encode, length prefix included.
    pub fn encode(&self) -> Vec<u8> {
        let (status, payload) = match &self.result {
            Ok(bytes) => (0u8, bytes.clone()),
            Err(e) => (e.code(), e.payload()),
        };
        let mut body = Vec::with_capacity(20 + payload.len());
        body.extend_from_slice(&MAGIC.to_le_bytes());
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.push(FTYPE_RESPONSE);
        body.extend_from_slice(&self.req_id.to_le_bytes());
        body.push(status);
        body.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        body.extend_from_slice(&payload);
        prefix(body)
    }

    /// Strict decode of a response body.
    pub fn decode_body(body: &[u8]) -> Result<ResponseFrame, WireError> {
        let mut cur = Cur::new(body);
        let (magic, version, ftype) = decode_header(&mut cur)?;
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        if ftype != FTYPE_RESPONSE {
            return Err(WireError::BadFrame(format!("frame type {ftype}")));
        }
        let bad = WireError::BadFrame;
        let req_id = cur.u64().map_err(bad)?;
        let status = cur.u8().map_err(bad)?;
        let payload_len = cur.u32().map_err(bad)? as usize;
        let payload = cur.bytes(payload_len).map_err(bad)?.to_vec();
        cur.finish().map_err(bad)?;
        let result = match status {
            0 => Ok(payload),
            code => Err(WireError::from_code(code, &payload)),
        };
        Ok(ResponseFrame { req_id, result })
    }
}

fn decode_header(cur: &mut Cur<'_>) -> Result<(u32, u16, u8), WireError> {
    let magic = cur.u32().map_err(WireError::BadFrame)?;
    let version = cur.u16().map_err(WireError::BadFrame)?;
    let ftype = cur.u8().map_err(WireError::BadFrame)?;
    Ok((magic, version, ftype))
}

fn prefix(body: Vec<u8>) -> Vec<u8> {
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    frame
}

// ---------------------------------------------------------------------------
// Framed I/O
// ---------------------------------------------------------------------------

/// What [`read_frame`] found on the stream.
#[derive(Debug)]
pub enum FrameRead {
    /// One complete frame body.
    Frame(Vec<u8>),
    /// Clean EOF at a frame boundary (the peer hung up).
    Eof,
    /// The announced body length exceeded the cap. Framing is lost —
    /// answer, then close the connection.
    TooLarge(u64),
}

/// Read one length-prefixed frame body (blocking).
pub fn read_frame(r: &mut impl Read, max: usize) -> io::Result<FrameRead> {
    let mut len_buf = [0u8; 4];
    if !read_full(r, &mut len_buf)? {
        return Ok(FrameRead::Eof);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max {
        return Ok(FrameRead::TooLarge(len as u64));
    }
    let mut body = vec![0u8; len];
    if !read_full(r, &mut body)? {
        return Ok(FrameRead::Eof);
    }
    Ok(FrameRead::Frame(body))
}

/// Fill `buf` completely; `false` on EOF before the first byte *or*
/// mid-buffer (a truncated frame is indistinguishable from a hangup to
/// the reader — both end the connection).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Ok(false),
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Write one already-encoded frame (length prefix included).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)
}

// ---------------------------------------------------------------------------
// Cursor
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian reader over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(end) => {
                let s = &self.b[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(format!(
                "need {n} bytes at offset {}, body is {}",
                self.pos,
                self.b.len()
            )),
        }
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// Strictness: a valid frame consumes its body exactly.
    fn finish(&self) -> Result<(), String> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes", self.b.len() - self.pos))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrips_every_kind() {
        let descs = [
            WorkloadDesc::Prng { n: 4096 },
            WorkloadDesc::Saxpy { n: 1024, a: 2.5 },
            WorkloadDesc::Reduce { n: 2048 },
            WorkloadDesc::Stencil { h: 32, w: 64 },
            WorkloadDesc::Matmul { d: 48 },
        ];
        for (i, desc) in descs.into_iter().enumerate() {
            let f = RequestFrame {
                req_id: 1000 + i as u64,
                priority: if i % 2 == 0 { Priority::High } else { Priority::Bulk },
                deadline_us: i as u64 * 500,
                iters: 3,
                desc,
                trace: i % 3 == 0,
            };
            let enc = f.encode();
            let (len, body) = enc.split_at(4);
            assert_eq!(
                u32::from_le_bytes(len.try_into().unwrap()) as usize,
                body.len()
            );
            assert_eq!(RequestFrame::decode_body(body).unwrap(), f);
        }
    }

    #[test]
    fn trace_flag_rides_the_priority_high_bit() {
        for (priority, trace) in [
            (Priority::High, false),
            (Priority::High, true),
            (Priority::Bulk, false),
            (Priority::Bulk, true),
        ] {
            let f = RequestFrame {
                req_id: 42,
                priority,
                deadline_us: 0,
                iters: 1,
                desc: WorkloadDesc::Prng { n: 64 },
                trace,
            };
            let enc = f.encode();
            let prio_byte = enc[4 + 4 + 2 + 1 + 8];
            assert_eq!(prio_byte & TRACE_FLAG != 0, trace);
            assert_eq!((prio_byte & !TRACE_FLAG) as usize, priority.index());
            assert_eq!(RequestFrame::decode_body(&enc[4..]).unwrap(), f);
        }
        // Unknown low bits stay rejected even with the flag set.
        let mut enc = RequestFrame {
            req_id: 42,
            priority: Priority::High,
            deadline_us: 0,
            iters: 1,
            desc: WorkloadDesc::Prng { n: 64 },
            trace: true,
        }
        .encode();
        enc[4 + 4 + 2 + 1 + 8] = TRACE_FLAG | 5;
        assert!(matches!(
            RequestFrame::decode_body(&enc[4..]),
            Err((WireError::BadFrame(_), 42))
        ));
    }

    #[test]
    fn response_roundtrips_ok_and_every_error() {
        let results: Vec<Result<Vec<u8>, WireError>> = vec![
            Ok(vec![1, 2, 3, 4]),
            Ok(Vec::new()),
            Err(WireError::BadMagic(0xDEAD_BEEF)),
            Err(WireError::BadVersion(77)),
            Err(WireError::BadFrame("trailing bytes".into())),
            Err(WireError::TooLarge(1 << 40)),
            Err(WireError::Overloaded),
            Err(WireError::QueueFull),
            Err(WireError::DeadlineExceeded),
            Err(WireError::ShuttingDown),
            Err(WireError::Execution("backend died".into())),
        ];
        for (i, result) in results.into_iter().enumerate() {
            let f = ResponseFrame { req_id: i as u64, result };
            let enc = f.encode();
            assert_eq!(ResponseFrame::decode_body(&enc[4..]).unwrap(), f);
        }
    }

    #[test]
    fn decode_rejects_bad_magic_version_type_and_trailing() {
        let good = RequestFrame {
            req_id: 7,
            priority: Priority::Bulk,
            deadline_us: 0,
            iters: 1,
            desc: WorkloadDesc::Prng { n: 64 },
            trace: false,
        }
        .encode();
        let body = &good[4..];

        let mut bad = body.to_vec();
        bad[0] ^= 0xFF;
        assert!(matches!(
            RequestFrame::decode_body(&bad),
            Err((WireError::BadMagic(_), 0))
        ));

        let mut bad = body.to_vec();
        bad[4] = 0xFE;
        assert!(matches!(
            RequestFrame::decode_body(&bad),
            Err((WireError::BadVersion(_), 0))
        ));

        let mut bad = body.to_vec();
        bad[6] = 9; // frame type
        assert!(matches!(
            RequestFrame::decode_body(&bad),
            Err((WireError::BadFrame(_), 0))
        ));

        let mut bad = body.to_vec();
        bad.push(0);
        // Trailing garbage still recovers the req_id for correlation.
        assert!(matches!(
            RequestFrame::decode_body(&bad),
            Err((WireError::BadFrame(_), 7))
        ));
    }

    #[test]
    fn validate_caps_hostile_shapes() {
        assert!(WorkloadDesc::Prng { n: 0 }.validate().is_err());
        assert!(WorkloadDesc::Prng { n: MAX_UNITS + 1 }.validate().is_err());
        assert!(WorkloadDesc::Matmul { d: MAX_MATMUL_DIM + 1 }.validate().is_err());
        assert!(WorkloadDesc::Stencil { h: 1 << 12, w: 1 << 12 }.validate().is_err());
        assert!(WorkloadDesc::Saxpy { n: 8, a: f32::NAN }.validate().is_err());
        assert!(WorkloadDesc::Saxpy { n: 8, a: 2.0 }.validate().is_ok());
    }

    #[test]
    fn truncated_bodies_are_typed_errors_never_panics() {
        let good = RequestFrame {
            req_id: 9,
            priority: Priority::High,
            deadline_us: 123,
            iters: 2,
            desc: WorkloadDesc::Stencil { h: 8, w: 8 },
            trace: false,
        }
        .encode();
        let body = &good[4..];
        for cut in 0..body.len() {
            let r = RequestFrame::decode_body(&body[..cut]);
            assert!(r.is_err(), "truncation at {cut} must not decode");
        }
    }
}
