//! Minimal blocking client for the edge protocol.
//!
//! One [`EdgeClient`] wraps one TCP connection. Requests and responses
//! are decoupled — send many, receive as they complete (responses
//! carry the request's correlation id because the server answers out
//! of order). [`EdgeClient::try_clone`] splits the connection into a
//! sender half and a receiver half for open-loop load generation.

use std::io::{self, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::proto::{
    read_frame, FrameRead, RequestFrame, ResponseFrame, WireError, RESPONSE_MAX_FRAME,
};

/// A blocking connection to an [`EdgeServer`](super::EdgeServer).
pub struct EdgeClient {
    stream: TcpStream,
}

/// What [`EdgeClient::recv`] found.
#[derive(Debug)]
pub enum Received {
    /// One decoded response.
    Response(ResponseFrame),
    /// The server hung up (clean EOF or lost framing).
    Closed,
}

impl EdgeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<EdgeClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(EdgeClient { stream })
    }

    /// Wrap an already-connected stream (e.g. one that has sent raw
    /// bytes outside the protocol and now wants typed decoding).
    pub fn from_stream(stream: TcpStream) -> EdgeClient {
        EdgeClient { stream }
    }

    /// A second handle onto the same connection (shared socket): one
    /// thread sends on a fixed schedule, another receives.
    pub fn try_clone(&self) -> io::Result<EdgeClient> {
        Ok(EdgeClient { stream: self.stream.try_clone()? })
    }

    /// Bound how long [`recv`](Self::recv) may block (`None` = forever).
    pub fn set_recv_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(dur)
    }

    /// Fire one request (does not wait for the response).
    pub fn send(&mut self, frame: &RequestFrame) -> io::Result<()> {
        self.stream.write_all(&frame.encode())
    }

    /// Block for the next response frame. Malformed frames from the
    /// server surface as `Err` in the inner result.
    pub fn recv(&mut self) -> io::Result<Result<Received, WireError>> {
        match read_frame(&mut self.stream, RESPONSE_MAX_FRAME)? {
            FrameRead::Frame(body) => {
                Ok(ResponseFrame::decode_body(&body).map(Received::Response))
            }
            FrameRead::Eof | FrameRead::TooLarge(_) => Ok(Ok(Received::Closed)),
        }
    }

    /// Convenience: send one request and block for one response (only
    /// sound when no other request is in flight on this connection).
    pub fn request(&mut self, frame: &RequestFrame) -> io::Result<ResponseFrame> {
        self.send(frame)?;
        loop {
            match self.recv()? {
                Ok(Received::Response(r)) => return Ok(r),
                Ok(Received::Closed) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "server closed before answering",
                    ))
                }
                Err(e) => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("undecodable response: {e}"),
                    ))
                }
            }
        }
    }
}
