//! SLO-aware overload control for the serving edge.
//!
//! The gate watches the service's trailing-window latency histogram
//! ([`ServiceMetrics::recent_ns`](super::super::adaptive::ServiceMetrics))
//! and refuses admission — a typed [`Overloaded`](super::proto::WireError::Overloaded)
//! wire error, not a closed socket — once the window's p99 blows the
//! requesting lane's budget. Giving the bulk lane a tighter budget than
//! the high lane makes overload shed bulk traffic first: as latency
//! climbs, bulk admission stops while latency-sensitive traffic keeps
//! flowing, and goodput degrades instead of collapsing.
//!
//! Every decision is a pure function of `(histogram, now_ns, lane)`, so
//! tests drive the gate deterministically with
//! [`WindowedHistogram::record_at`] and [`OverloadGate::admit_at`] — no
//! real clock, no sleeps.

use std::time::Duration;

use crate::coordinator::service::Priority;
use crate::metrics::WindowedHistogram;

/// Per-lane trailing-p99 admission budgets — see the [module
/// docs](self).
#[derive(Debug, Clone)]
pub struct OverloadGate {
    /// Per-lane p99 budget, ns, indexed by [`Priority::index`].
    budget_ns: [u64; Priority::COUNT],
    /// Below this many samples in the trailing window the gate always
    /// admits — a handful of slow warm-up requests must not slam the
    /// door on an idle server.
    min_samples: u64,
}

impl OverloadGate {
    pub fn new(high_budget: Duration, bulk_budget: Duration, min_samples: u64) -> Self {
        let mut budget_ns = [0u64; Priority::COUNT];
        budget_ns[Priority::High.index()] = high_budget.as_nanos() as u64;
        budget_ns[Priority::Bulk.index()] = bulk_budget.as_nanos() as u64;
        Self { budget_ns, min_samples }
    }

    /// The lane's p99 budget.
    pub fn budget(&self, priority: Priority) -> Duration {
        Duration::from_nanos(self.budget_ns[priority.index()])
    }

    /// Should a request on `priority` be admitted at `now_ns`, given
    /// the trailing latency window? Deterministic — the testable core.
    pub fn admit_at(&self, recent: &WindowedHistogram, now_ns: u64, priority: Priority) -> bool {
        let snap = recent.snapshot_at(now_ns);
        if snap.count() < self.min_samples {
            return true;
        }
        snap.quantile(0.99) <= self.budget_ns[priority.index()]
    }

    /// [`admit_at`](Self::admit_at) against the real clock.
    pub fn admit(&self, recent: &WindowedHistogram, priority: Priority) -> bool {
        self.admit_at(recent, crate::rawcl::clock::now_ns(), priority)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window() -> WindowedHistogram {
        // 8 slots of 250 ms — matches the service's live window shape.
        WindowedHistogram::new(8, 250_000_000)
    }

    #[test]
    fn admits_until_min_samples() {
        let gate = OverloadGate::new(Duration::from_millis(50), Duration::from_millis(5), 8);
        let w = window();
        let t0 = 1_000_000_000u64;
        for i in 0..7 {
            // Every sample is way over both budgets, but the window is
            // under-sampled: still admitting.
            w.record_at(t0, 1_000_000_000);
            assert!(gate.admit_at(&w, t0, Priority::Bulk), "sample {i}");
        }
        w.record_at(t0, 1_000_000_000);
        assert!(!gate.admit_at(&w, t0, Priority::Bulk), "8th sample trips the gate");
    }

    #[test]
    fn bulk_sheds_before_high() {
        let gate = OverloadGate::new(Duration::from_millis(500), Duration::from_millis(10), 1);
        let w = window();
        let t0 = 5_000_000_000u64;
        // Trailing p99 ≈ 50 ms: over bulk's 10 ms budget, under high's
        // 500 ms one.
        for _ in 0..100 {
            w.record_at(t0, 50_000_000);
        }
        assert!(!gate.admit_at(&w, t0, Priority::Bulk));
        assert!(gate.admit_at(&w, t0, Priority::High));
        // Past 500 ms, even the high lane sheds.
        for _ in 0..100 {
            w.record_at(t0, 2_000_000_000);
        }
        assert!(!gate.admit_at(&w, t0, Priority::High));
    }

    #[test]
    fn gate_reopens_when_the_window_rolls_over() {
        let gate = OverloadGate::new(Duration::from_millis(500), Duration::from_millis(10), 1);
        let w = window();
        let t0 = 10_000_000_000u64;
        for _ in 0..50 {
            w.record_at(t0, 100_000_000);
        }
        assert!(!gate.admit_at(&w, t0, Priority::Bulk));
        // 3 seconds later the bad epoch has aged out of the 2 s window:
        // the gate re-admits on its own, no manual reset.
        let t1 = t0 + 3_000_000_000;
        assert!(gate.admit_at(&w, t1, Priority::Bulk));
    }
}
