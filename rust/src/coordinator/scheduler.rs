//! Multi-device work-stealing scheduler over the unified backend layer.
//!
//! The §5 PRNG service drives *one* device; this module drives **all
//! registered backends at once** (EngineCL-style) — and it is
//! **workload-agnostic**: any [`Workload`] shards across the registry.
//! The principal index space is split into contiguous chunks, every
//! iteration dispatches one task per chunk across the backends' queues,
//! idle backends steal queued tasks from loaded ones, and the per-chunk
//! outputs merge — through the workload's own
//! [`merge`](Workload::merge) — into one result that is
//! **bit-identical** to a single-device run:
//!
//! * PRNG: chunk `[lo, lo+n)` is seeded by `prng_init` with
//!   `gid_offset = lo` (concatenated chunk seeds equal the whole-stream
//!   seed batch) and the xorshift step is elementwise;
//! * reduce: chunks produce partial sums folded with wrapping
//!   (associative) adds;
//! * stencil: row bands carry a one-row halo whose exchange is the
//!   per-iteration re-slice of the merged grid;
//! * saxpy/matmul: elementwise / row-band concatenation.
//!
//! Chunk inputs round-trip through the host every iteration (the PRNG
//! service streams every batch out anyway, and halo exchange needs the
//! merged state), which is what makes stealing cheap: a stolen task
//! just writes its inputs to the thief's buffers. Sticky home
//! assignment keeps chunks on one backend when nobody is starved.
//!
//! Profiling: each backend's drained command timeline feeds
//! [`Prof::add_timeline`], so one profile aggregates kernels and
//! transfers across every backend (names match the single-device
//! service: `INIT_KERNEL`, `RNG_KERNEL`, `READ_BUFFER`, ...).
//!
//! Two plugin-ABI-era additions:
//!
//! * **Capability filtering** — the engine reads each selected
//!   backend's [`Capabilities`] and dispatches only to backends whose
//!   kernel families cover the workload's; an impossible dispatch is a
//!   typed [`CapabilityError`] naming every rejected backend, not a
//!   runtime enqueue failure. Legacy registrations advertise the full
//!   set, so nothing changes for them.
//! * **Opt-in fault tolerance** — with a [`FaultPolicy`], a failed
//!   task is retried on the next healthy backend (bounded by
//!   `max_retries`), backends failing repeatedly are quarantined for
//!   the rest of the run, and `verify_reads` double-reads every shard
//!   output to catch wrong-once results. Without a policy the engine
//!   keeps its historical fail-fast semantics. Recovery is
//!   bit-identical: a retried task re-executes the same pure
//!   `(shard, iter, state)` plan, so merged outputs never depend on
//!   which backend finally ran it.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::analysis::record as arec;
use crate::backend::plugin::{partition_capable, Capabilities, CapabilityError};
use crate::backend::{Backend, BackendRegistry, BufId, CompileSpec, KernelId};
use crate::ccl::errors::{CclError, CclResult};
use crate::ccl::prof::ProfInfo;
use crate::ccl::selector::FilterChain;
use crate::ccl::Prof;
use crate::metrics::Counter;
use crate::rawcl::kernelspec::KernelKind;
use crate::trace;
use crate::workload::{PrngWorkload, Shard, Workload};

use super::rng_service::{sink_consume, Sink};

/// Configuration of one sharded PRNG request.
pub struct ShardedRngConfig {
    /// Random numbers per iteration (the whole-stream `n`).
    pub numrn: usize,
    /// Iterations producing random numbers.
    pub iters: usize,
    /// Target chunks per backend (>1 keeps the stealing deques busy).
    pub chunks_per_backend: usize,
    /// Minimum chunk size in 64-bit words (small requests shard less).
    pub min_chunk: usize,
    /// Aggregate per-backend event timelines into one profile.
    pub profile: bool,
    pub sink: Sink,
    /// Device filter selecting the backends to dispatch to
    /// (`None` = every registered backend).
    pub selector: Option<FilterChain>,
}

impl ShardedRngConfig {
    pub fn new(numrn: usize, iters: usize) -> Self {
        Self {
            numrn,
            iters,
            chunks_per_backend: 2,
            min_chunk: 1024,
            profile: true,
            sink: Sink::Discard,
            selector: None,
        }
    }
}

/// Opt-in fault tolerance for the sharded engine. `None` (the
/// default) keeps the historical fail-fast semantics: the first task
/// failure aborts the run.
#[derive(Debug, Clone, Copy)]
pub struct FaultPolicy {
    /// Times one task may be re-dispatched after a failure before the
    /// run gives up.
    pub max_retries: usize,
    /// Consecutive failures (without an intervening success) after
    /// which a backend is quarantined for the rest of the run.
    pub quarantine_after: usize,
    /// Read every shard output twice and treat a mismatch as a task
    /// failure — catches wrong-once results (a corrupted host read
    /// whose device buffer is intact) before they reach the merge.
    pub verify_reads: bool,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        Self { max_retries: 4, quarantine_after: 2, verify_reads: false }
    }
}

impl FaultPolicy {
    /// The chaos-zoo posture: quarantine on the first failure, verify
    /// every read, retry generously.
    pub fn paranoid() -> Self {
        Self { max_retries: 6, quarantine_after: 1, verify_reads: true }
    }
}

/// A reusable pool of host output buffers, shared across runs. The
/// engine already reuses shard buffers *within* a run (each iteration
/// rewrites the previous iteration's vectors in place); handing the
/// engine a pool extends that reuse *across* runs — batch wave N+1's
/// shard outputs start from wave N's capacity instead of fresh
/// allocations. Hit/miss counters make the reuse observable
/// (`bench zoo` reports them in its before/after note).
#[derive(Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<u8>>>,
    hits: Counter,
    misses: Counter,
}

/// Buffers retained across runs; beyond this, returned buffers drop.
const POOL_MAX_BUFS: usize = 256;

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a pooled buffer (hit) or start a fresh one (miss).
    pub(crate) fn take(&self) -> Vec<u8> {
        match self.free.lock().unwrap().pop() {
            Some(buf) => {
                self.hits.inc();
                buf
            }
            None => {
                self.misses.inc();
                Vec::new()
            }
        }
    }

    /// Return a buffer's capacity to the pool (contents are cleared).
    pub(crate) fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < POOL_MAX_BUFS {
            free.push(buf);
        }
    }

    /// Takes served from pooled capacity.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Takes that had to allocate fresh.
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }
}

/// Per-backend dispatch statistics.
#[derive(Debug, Clone)]
pub struct BackendLoad {
    pub name: String,
    /// Tasks executed (including stolen ones).
    pub tasks: usize,
    /// Tasks this backend stole from another backend's queue.
    pub stolen: usize,
    /// Total busy time from the backend's event timeline, ns (modeled
    /// for simulated backends, measured for native ones).
    pub busy_ns: u64,
    /// Output bytes produced by the tasks this backend executed —
    /// `bytes / busy_ns` is the observed throughput the
    /// [`ShardPlanner`](crate::coordinator::adaptive::ShardPlanner)
    /// folds into its per-backend EWMA.
    pub bytes: u64,
    /// Task attempts that failed on this backend (0 unless a
    /// [`FaultPolicy`] let the run outlive them).
    pub failures: usize,
}

/// What a sharded run produced.
#[derive(Debug)]
pub struct ShardedOutcome {
    pub wall: Duration,
    pub total_bytes: u64,
    /// First-batch sample (when `Sink::Sample`).
    pub sample: Vec<u64>,
    pub num_chunks: usize,
    pub per_backend: Vec<BackendLoad>,
    /// Fig. 3-style aggregate summary across all backends.
    pub prof_summary: Option<String>,
    /// Fig. 5-style event table across all backends.
    pub prof_export: Option<String>,
}

/// Configuration of one sharded workload request — the generalisation
/// of [`ShardedRngConfig`] to any [`Workload`].
pub struct ShardedConfig<W: Workload> {
    pub workload: W,
    /// Iterations to run.
    pub iters: usize,
    /// Target chunks per backend (>1 keeps the stealing deques busy).
    pub chunks_per_backend: usize,
    /// Minimum chunk size in workload units (small requests shard less).
    pub min_chunk: usize,
    /// Aggregate per-backend event timelines into one profile.
    pub profile: bool,
    /// Offered every iteration's merged output (the PRNG service's
    /// streaming sink; use [`Sink::Discard`] when only the final output
    /// matters).
    pub sink: Sink,
    /// Device filter selecting the backends to dispatch to
    /// (`None` = every registered backend).
    pub selector: Option<FilterChain>,
    /// Explicit shard plan overriding the automatic chunking. Must be
    /// ascending, contiguous and cover `[0, workload.units())` exactly.
    /// The compute service uses this to keep micro-batch shards aligned
    /// to request boundaries (a shard must never straddle two requests).
    pub shard_plan: Option<Vec<Shard>>,
    /// Explicit home backend per shard (same length as the shard list,
    /// indices into the selected backend list) overriding the default
    /// round-robin seeding. The adaptive shard planner uses this to
    /// hand faster backends their proportionally larger shards; work
    /// stealing still rebalances if the plan turns out wrong.
    pub shard_homes: Option<Vec<usize>>,
    /// Prefix for the per-backend profile queue labels (e.g.
    /// `"svc.batch-7."`), so exported timelines attribute spans to the
    /// dispatch that produced them. `None` = plain backend names.
    pub queue_tag: Option<String>,
    /// Per-shard launch tag (same length as the shard plan), threaded
    /// through [`Backend::enqueue`] so each shard's kernel spans carry
    /// their originator. The compute service tags every shard with its
    /// request's `svc.req-<id>.` label, making per-request profile
    /// slices exact even inside a fused micro-batch. Tagged spans are
    /// profiled under `<tag><backend name>` queues; untagged spans fall
    /// back to [`queue_tag`](Self::queue_tag).
    pub shard_tags: Option<Vec<String>>,
    /// Opt-in retry/quarantine fault tolerance. `None` preserves the
    /// historical fail-fast behavior.
    pub faults: Option<FaultPolicy>,
    /// Shared host-buffer pool: shard output buffers are taken from it
    /// at run start and returned at run end, so capacity survives
    /// across batch waves. `None` allocates per run (and still reuses
    /// within the run).
    pub buffer_pool: Option<Arc<BufferPool>>,
}

impl<W: Workload> ShardedConfig<W> {
    pub fn new(workload: W, iters: usize) -> Self {
        Self {
            workload,
            iters,
            chunks_per_backend: 2,
            min_chunk: 1,
            profile: false,
            sink: Sink::Discard,
            selector: None,
            shard_plan: None,
            shard_homes: None,
            queue_tag: None,
            shard_tags: None,
            faults: None,
            buffer_pool: None,
        }
    }
}

/// What a sharded workload run produced.
#[derive(Debug)]
pub struct WorkloadOutcome {
    pub wall: Duration,
    /// The last iteration's merged output (must equal
    /// [`Workload::reference`]).
    pub final_output: Vec<u8>,
    /// First-iteration sample (when `Sink::Sample`).
    pub sample: Vec<u64>,
    pub num_chunks: usize,
    pub per_backend: Vec<BackendLoad>,
    /// Fig. 3-style aggregate summary across all backends.
    pub prof_summary: Option<String>,
    /// Fig. 5-style event table across all backends.
    pub prof_export: Option<String>,
    /// The raw merged event records behind the summary/export (when
    /// profiling) — callers aggregating across many runs (the compute
    /// service) feed these to [`Prof::add_timeline`].
    pub prof_infos: Option<Vec<ProfInfo>>,
    /// Task re-dispatches performed after failures (0 without a
    /// [`FaultPolicy`]).
    pub retries: u64,
    /// Backends quarantined during the run, by name.
    pub quarantined: Vec<String>,
}

/// Per-backend scratch owned by the scheduler (kernel + buffer caches).
struct BackendScratch {
    kernels: Mutex<HashMap<CompileSpec, KernelId>>,
    /// Free buffers by size (chunks are near-uniform, so this stays tiny).
    free_bufs: Mutex<Vec<(usize, BufId)>>,
}

impl BackendScratch {
    fn new() -> Self {
        Self {
            kernels: Mutex::new(HashMap::new()),
            free_bufs: Mutex::new(Vec::new()),
        }
    }

    fn kernel(&self, b: &dyn Backend, spec: CompileSpec) -> Result<KernelId, String> {
        if let Some(&k) = self.kernels.lock().unwrap().get(&spec) {
            return Ok(k);
        }
        let k = b.compile(&spec).map_err(|e| e.to_string())?;
        self.kernels.lock().unwrap().insert(spec, k);
        Ok(k)
    }

    fn acquire(&self, b: &dyn Backend, bytes: usize) -> Result<BufId, String> {
        let mut free = self.free_bufs.lock().unwrap();
        if let Some(i) = free.iter().position(|(sz, _)| *sz == bytes) {
            return Ok(free.swap_remove(i).1);
        }
        drop(free);
        b.alloc(bytes).map_err(|e| e.to_string())
    }

    fn release(&self, bytes: usize, buf: BufId) {
        self.free_bufs.lock().unwrap().push((bytes, buf));
    }
}

/// Split `words` into ~`target` contiguous chunks of ≥ `min_chunk` words.
/// (Also used by the compute service to chunk each micro-batch member.)
pub(crate) fn plan_chunks(
    words: usize,
    target: usize,
    min_chunk: usize,
) -> Vec<(usize, usize)> {
    let max_chunks = words.div_ceil(min_chunk.max(1)).max(1);
    let count = target.clamp(1, max_chunks);
    let base = words / count;
    let rem = words % count;
    let mut out = Vec::with_capacity(count);
    let mut lo = 0usize;
    for i in 0..count {
        let n = base + usize::from(i < rem);
        out.push((lo, n));
        lo += n;
    }
    debug_assert_eq!(lo, words);
    out
}

/// The kernel families a workload dispatch requires. Probed with a
/// one-unit shard: kernel *families* are shard-size-independent for
/// every workload, and a whole-index-space probe would straddle member
/// boundaries inside a batch workload.
fn required_kinds(workload: &dyn Workload) -> BTreeSet<KernelKind> {
    workload.kernels(Shard { lo: 0, len: 1 }).iter().map(|s| s.kind).collect()
}

/// Peak device bytes one task over a `units`-long shard allocates (max
/// over the workload's kernels of inputs + output) — the capacity
/// estimate memory-capped planning divides against.
pub(crate) fn shard_footprint_bytes(workload: &dyn Workload, units: usize) -> usize {
    let shard = Shard { lo: 0, len: units.max(1) };
    workload
        .kernels(shard)
        .iter()
        .map(|spec| {
            let (inputs, out) = spec.buffer_layout();
            inputs.iter().sum::<usize>() + out
        })
        .max()
        .unwrap_or(0)
}

/// Run one task: execute `workload.plan(shard, iter, state)` on
/// backend `b`, leaving the shard's output bytes in `out`. Returns the
/// output byte count (the scheduler's per-backend throughput metric).
/// `tag` is the shard's caller label, attached to the kernel launch so
/// the profiled span is attributable to its originating request.
/// `verify_read` double-reads the output and fails on disagreement
/// (the [`FaultPolicy::verify_reads`] countermeasure to wrong-once
/// results).
#[allow(clippy::too_many_arguments)]
fn run_task(
    b: &dyn Backend,
    scratch: &BackendScratch,
    workload: &dyn Workload,
    shard: Shard,
    iter: usize,
    state: &[u8],
    out: &Mutex<Vec<u8>>,
    tag: Option<&str>,
    verify_read: bool,
) -> Result<usize, String> {
    let specs = workload.kernels(shard);
    let plan = workload.plan(shard, iter, state);
    let spec = *specs
        .get(plan.kernel)
        .ok_or_else(|| "plan names a kernel the workload did not declare".to_string())?;
    let kernel = scratch.kernel(b, spec)?;

    // Each backend is one in-order logical queue to the command
    // recorder; shard dispatches interleave across worker threads but
    // same-backend commands stay totally ordered.
    let rec_space =
        if arec::enabled() { Some(format!("be:{}", b.name())) } else { None };

    let mut in_bufs = Vec::with_capacity(plan.inputs.len());
    let mut acquired: Vec<(usize, BufId)> = Vec::new();
    let result: Result<usize, String> = (|| {
        for data in &plan.inputs {
            let buf = scratch.acquire(b, data.len())?;
            acquired.push((data.len(), buf));
            let wev = b.write(buf, 0, data).map_err(|e| e.to_string())?;
            if let Some(space) = &rec_space {
                arec::backend_cmd(
                    space,
                    arec::CmdKind::HostWrite,
                    "WRITE_BUFFER",
                    &[],
                    &[buf.0],
                    Some(wev.0),
                    false,
                );
            }
            in_bufs.push(buf);
        }
        let out_buf = scratch.acquire(b, plan.out_bytes)?;
        acquired.push((plan.out_bytes, out_buf));
        let args = spec.launch_args(&in_bufs, out_buf, &plan.scalars);
        let ev = b.enqueue(kernel, &args, tag).map_err(|e| e.to_string())?;
        if let Some(space) = &rec_space {
            let (reads, writes) = crate::backend::launch_arg_access(&args);
            arec::backend_cmd(
                space,
                arec::CmdKind::Kernel,
                spec.event_name(),
                &reads,
                &writes,
                Some(ev.0),
                false,
            );
        }
        b.wait(ev).map_err(|e| e.to_string())?;
        if let Some(space) = &rec_space {
            arec::backend_host_wait(space, ev.0);
        }
        let mut dst = out.lock().unwrap();
        dst.resize(plan.out_bytes, 0);
        let rev = b.read(out_buf, 0, &mut dst).map_err(|e| e.to_string())?;
        if let Some(space) = &rec_space {
            arec::backend_cmd(
                space,
                arec::CmdKind::HostRead,
                "READ_BUFFER",
                &[out_buf.0],
                &[],
                Some(rev.0),
                true,
            );
        }
        if verify_read {
            // A wrong-once fault corrupts one host read-back while the
            // device buffer keeps the true bytes, so a disagreeing
            // second read exposes it; the retry path then re-runs the
            // task cleanly.
            let mut check = vec![0u8; plan.out_bytes];
            b.read(out_buf, 0, &mut check).map_err(|e| e.to_string())?;
            if *dst != check {
                return Err(format!(
                    "read-back verification mismatch on {}",
                    b.name()
                ));
            }
        }
        Ok(plan.out_bytes)
    })();
    for (bytes, buf) in acquired {
        scratch.release(bytes, buf);
    }
    result
}

/// Run a sharded PRNG request over the global backend registry.
pub fn run_sharded(cfg: &ShardedRngConfig) -> CclResult<ShardedOutcome> {
    run_sharded_on(BackendRegistry::global(), cfg)
}

/// Run a sharded PRNG request over an explicit registry — a thin
/// wrapper putting [`PrngWorkload`] through the workload-agnostic
/// engine (the service's streaming sink semantics are the engine's
/// per-iteration sink).
pub fn run_sharded_on(
    registry: &BackendRegistry,
    cfg: &ShardedRngConfig,
) -> CclResult<ShardedOutcome> {
    let workload = PrngWorkload::new(cfg.numrn);
    let out = run_workload_engine(
        registry,
        &workload,
        &EngineOpts {
            iters: cfg.iters,
            chunks_per_backend: cfg.chunks_per_backend,
            min_chunk: cfg.min_chunk,
            profile: cfg.profile,
            selector: cfg.selector.as_ref(),
            sink: &cfg.sink,
            shard_plan: None,
            shard_homes: None,
            queue_tag: None,
            shard_tags: None,
            faults: None,
            pool: None,
        },
    )?;
    Ok(ShardedOutcome {
        wall: out.wall,
        total_bytes: (8 * cfg.numrn * cfg.iters) as u64,
        sample: out.sample,
        num_chunks: out.num_chunks,
        per_backend: out.per_backend,
        prof_summary: out.prof_summary,
        prof_export: out.prof_export,
    })
}

/// Run a sharded workload over the global backend registry.
pub fn run_sharded_workload<W: Workload>(
    cfg: &ShardedConfig<W>,
) -> CclResult<WorkloadOutcome> {
    run_sharded_workload_on(BackendRegistry::global(), cfg)
}

/// Run a sharded workload over an explicit registry.
pub fn run_sharded_workload_on<W: Workload>(
    registry: &BackendRegistry,
    cfg: &ShardedConfig<W>,
) -> CclResult<WorkloadOutcome> {
    run_workload_engine(
        registry,
        &cfg.workload,
        &EngineOpts {
            iters: cfg.iters,
            chunks_per_backend: cfg.chunks_per_backend,
            min_chunk: cfg.min_chunk,
            profile: cfg.profile,
            selector: cfg.selector.as_ref(),
            sink: &cfg.sink,
            shard_plan: cfg.shard_plan.as_deref(),
            shard_homes: cfg.shard_homes.as_deref(),
            queue_tag: cfg.queue_tag.as_deref(),
            shard_tags: cfg.shard_tags.as_deref(),
            faults: cfg.faults,
            pool: cfg.buffer_pool.as_deref(),
        },
    )
}

/// Borrowed engine parameters — everything about a dispatch except the
/// workload itself.
#[derive(Clone, Copy)]
struct EngineOpts<'a> {
    iters: usize,
    chunks_per_backend: usize,
    min_chunk: usize,
    profile: bool,
    selector: Option<&'a FilterChain>,
    sink: &'a Sink,
    shard_plan: Option<&'a [Shard]>,
    shard_homes: Option<&'a [usize]>,
    queue_tag: Option<&'a str>,
    shard_tags: Option<&'a [String]>,
    faults: Option<FaultPolicy>,
    pool: Option<&'a BufferPool>,
}

/// The workload-agnostic scheduling engine: shard, dispatch with work
/// stealing, merge, iterate.
fn run_workload_engine(
    registry: &BackendRegistry,
    workload: &dyn Workload,
    opts: &EngineOpts<'_>,
) -> CclResult<WorkloadOutcome> {
    let EngineOpts {
        iters,
        chunks_per_backend,
        min_chunk,
        profile,
        selector,
        sink,
        shard_plan,
        shard_homes,
        queue_tag,
        shard_tags,
        faults,
        pool,
    } = *opts;
    let entries: Vec<(Arc<dyn Backend>, Capabilities)> = match selector {
        Some(chain) => registry.select_entries(chain),
        None => registry.entries(),
    };
    if entries.is_empty() {
        return Err(CclError::framework("no backend matched the scheduler selector"));
    }
    if workload.units() == 0 || iters == 0 {
        return Err(CclError::framework(
            "sharded run needs a non-empty workload and iters > 0",
        ));
    }
    // Capability negotiation: dispatch only to backends whose kernel
    // families cover the workload's. Entry order is preserved, so any
    // caller-computed shard homes (planned over the same filtered
    // entry list) stay aligned.
    let required = required_kinds(workload);
    let (backends, rejected) = partition_capable(entries, &required);
    if backends.is_empty() {
        let err = CapabilityError {
            required: required.iter().copied().collect(),
            rejected,
        };
        return Err(CclError::framework(err.to_string()));
    }

    let nb = backends.len();
    let t_plan0 = if trace::enabled() { trace::now_ns() } else { 0 };
    let shards: Vec<Shard> = match shard_plan {
        Some(plan) => {
            // An explicit plan must tile [0, units) exactly — anything
            // else would silently drop or duplicate work.
            let mut lo = 0usize;
            for s in plan {
                if s.lo != lo || s.len == 0 {
                    return Err(CclError::framework(format!(
                        "shard plan must be contiguous from 0 with non-empty \
                         shards; found [{}, {}+{}) where lo {lo} was expected",
                        s.lo, s.lo, s.len
                    )));
                }
                lo += s.len;
            }
            if lo != workload.units() {
                return Err(CclError::framework(format!(
                    "shard plan covers {lo} units, workload has {}",
                    workload.units()
                )));
            }
            plan.to_vec()
        }
        None => plan_chunks(workload.units(), nb * chunks_per_backend.max(1), min_chunk)
            .iter()
            .map(|&(lo, len)| Shard { lo, len })
            .collect(),
    };
    if let Some(homes) = shard_homes {
        if homes.len() != shards.len() {
            return Err(CclError::framework(format!(
                "shard homes cover {} shards, the plan has {}",
                homes.len(),
                shards.len()
            )));
        }
        if let Some(&bad) = homes.iter().find(|&&h| h >= nb) {
            return Err(CclError::framework(format!(
                "shard home {bad} out of range: {nb} backends selected"
            )));
        }
    }
    if let Some(tags) = shard_tags {
        if tags.len() != shards.len() {
            return Err(CclError::framework(format!(
                "shard tags cover {} shards, the plan has {}",
                tags.len(),
                shards.len()
            )));
        }
    }
    if trace::enabled() {
        // One `sched.plan` span per traced request riding this
        // dispatch (recovered from the `svc.req-<id>.` shard tags), or
        // a single corr-less one a replay window's ambient corr adopts.
        let t_plan1 = trace::now_ns();
        let mut corrs: Vec<Option<u64>> = match shard_tags {
            Some(tags) => {
                let mut cs: Vec<u64> =
                    tags.iter().filter_map(|t| trace::corr_from_tag(t)).collect();
                cs.sort_unstable();
                cs.dedup();
                cs.into_iter().map(Some).collect()
            }
            None => Vec::new(),
        };
        if corrs.is_empty() {
            corrs.push(None);
        }
        for corr in corrs {
            trace::complete(
                "sched.plan",
                "sched",
                corr,
                None,
                t_plan0,
                t_plan1,
                vec![
                    ("shards", trace::Tag::from(shards.len())),
                    ("backends", trace::Tag::from(nb)),
                ],
            );
        }
    }
    // Shard output buffers come from the cross-run pool when one is
    // provided; either way they are reused in place across iterations.
    let outputs: Vec<Mutex<Vec<u8>>> = (0..shards.len())
        .map(|_| Mutex::new(pool.map_or_else(Vec::new, BufferPool::take)))
        .collect();

    let scratch: Vec<BackendScratch> =
        (0..nb).map(|_| BackendScratch::new()).collect();
    let deques: Vec<Mutex<VecDeque<usize>>> =
        (0..nb).map(|_| Mutex::new(VecDeque::new())).collect();
    // Per-backend instrumentation: tasks, steals and produced bytes go
    // through lock-free `metrics` counters — the same instruments the
    // service metrics surface uses.
    let tasks_run: Vec<Counter> = (0..nb).map(|_| Counter::new()).collect();
    let stolen: Vec<Counter> = (0..nb).map(|_| Counter::new()).collect();
    let bytes_out: Vec<Counter> = (0..nb).map(|_| Counter::new()).collect();
    let failure: Mutex<Option<String>> = Mutex::new(None);
    // Fault-tolerance state (inert without a policy): quarantine flags
    // and consecutive-failure streaks persist across iterations;
    // per-task retry budgets reset each iteration.
    let quarantined: Vec<AtomicBool> = (0..nb).map(|_| AtomicBool::new(false)).collect();
    let consec_fail: Vec<AtomicUsize> = (0..nb).map(|_| AtomicUsize::new(0)).collect();
    let failed_ctr: Vec<Counter> = (0..nb).map(|_| Counter::new()).collect();
    let retries_ctr = Counter::new();

    // Discard any leftover timeline from earlier uses of these backends
    // so the profile covers exactly this run.
    for b in &backends {
        let _ = b.drain_timeline();
    }

    let mut prof = Prof::new();
    prof.start();
    let t0 = Instant::now();
    let mut sample = Vec::new();
    let mut busy_acc = vec![0u64; nb];
    let mut run_err: Option<CclError> = None;
    let mut state = workload.init_state();
    let mut final_output = Vec::new();

    'iterations: for iter in 0..iters {
        // Seed the deques: sticky home assignment — round-robin, or
        // the explicit (planner-provided) home of each shard. A
        // quarantined home forwards to the next healthy backend.
        for ci in 0..shards.len() {
            let preferred = shard_homes.map_or(ci % nb, |h| h[ci]);
            let home = (0..nb)
                .map(|k| (preferred + k) % nb)
                .find(|&j| !quarantined[j].load(Ordering::SeqCst));
            let Some(home) = home else {
                run_err = Some(CclError::framework(format!(
                    "sharded iteration {iter}: all {nb} backends quarantined"
                )));
                break 'iterations;
            };
            deques[home].lock().unwrap().push_back(ci);
        }
        // Tasks not yet completed this iteration — under a fault
        // policy, idle workers spin on this instead of exiting, since
        // a failed task may be re-queued after their deques drain.
        let remaining = AtomicUsize::new(shards.len());
        let task_retries: Vec<AtomicUsize> =
            (0..shards.len()).map(|_| AtomicUsize::new(0)).collect();

        let state_ref: &[u8] = &state;
        std::thread::scope(|scope| {
            for (bi, backend) in backends.iter().enumerate() {
                let deques = &deques;
                let shards = &shards;
                let outputs = &outputs;
                let scratch = &scratch[bi];
                let tasks_run = &tasks_run[bi];
                let stolen_ctr = &stolen[bi];
                let bytes_ctr = &bytes_out[bi];
                let failure = &failure;
                let quarantined = &quarantined;
                let consec_fail = &consec_fail;
                let failed_ctr = &failed_ctr[bi];
                let retries_ctr = &retries_ctr;
                let remaining = &remaining;
                let task_retries = &task_retries;
                let backend = backend.clone();
                scope.spawn(move || {
                    loop {
                        if failure.lock().unwrap().is_some() {
                            return;
                        }
                        if quarantined[bi].load(Ordering::SeqCst) {
                            return;
                        }
                        // Own queue first; then steal from the most
                        // loaded peer's tail.
                        let mut task = deques[bi].lock().unwrap().pop_front();
                        let mut was_steal = false;
                        let mut stole_from = 0usize;
                        if task.is_none() {
                            let victim = (0..deques.len())
                                .filter(|&j| j != bi)
                                .max_by_key(|&j| deques[j].lock().unwrap().len());
                            if let Some(j) = victim {
                                task = deques[j].lock().unwrap().pop_back();
                                was_steal = task.is_some();
                                stole_from = j;
                            }
                        }
                        let Some(ci) = task else {
                            // Fail-fast mode: drained deques mean the
                            // iteration is done. Under a fault policy a
                            // failed task may still be re-queued, so
                            // spin until every shard is accounted for.
                            if faults.is_none()
                                || remaining.load(Ordering::SeqCst) == 0
                            {
                                return;
                            }
                            std::thread::sleep(Duration::from_micros(50));
                            continue;
                        };
                        // Trace: a `sched.task` span per shard dispatch
                        // on the backend's track, corr recovered from
                        // the shard's `svc.req-<id>.` tag (or adopted
                        // by a replay window's ambient corr). Inert —
                        // one relaxed load — when tracing is off.
                        let (task_corr, mut tsc) = if trace::enabled() {
                            let corr = shard_tags
                                .and_then(|t| trace::corr_from_tag(&t[ci]));
                            let track = format!("be:{}", backend.name());
                            if was_steal {
                                trace::instant(
                                    "sched.steal",
                                    &track,
                                    corr,
                                    None,
                                    vec![
                                        ("thief", trace::Tag::from(bi)),
                                        ("victim", trace::Tag::from(stole_from)),
                                        ("shard", trace::Tag::from(ci)),
                                    ],
                                );
                            }
                            let mut sc = trace::SpanScope::begin(
                                "sched.task",
                                &track,
                                corr,
                            );
                            sc.tag("shard", ci);
                            sc.tag("iter", iter);
                            sc.tag("stolen", was_steal);
                            (corr, sc)
                        } else {
                            (None, trace::SpanScope::disabled())
                        };
                        let r = run_task(
                            backend.as_ref(),
                            scratch,
                            workload,
                            shards[ci],
                            iter,
                            state_ref,
                            &outputs[ci],
                            shard_tags.map(|t| t[ci].as_str()),
                            faults.is_some_and(|p| p.verify_reads),
                        );
                        tsc.tag("ok", r.is_ok());
                        tsc.end();
                        match r {
                            Ok(n) => {
                                tasks_run.inc();
                                bytes_ctr.add(n as u64);
                                if was_steal {
                                    stolen_ctr.inc();
                                }
                                consec_fail[bi].store(0, Ordering::SeqCst);
                                remaining.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(e) => {
                                let Some(policy) = faults else {
                                    failure.lock().unwrap().get_or_insert(e);
                                    return;
                                };
                                failed_ctr.inc();
                                let streak =
                                    consec_fail[bi].fetch_add(1, Ordering::SeqCst) + 1;
                                if streak >= policy.quarantine_after.max(1) {
                                    quarantined[bi].store(true, Ordering::SeqCst);
                                    trace::instant(
                                        "sched.quarantine",
                                        "sched",
                                        task_corr,
                                        None,
                                        vec![
                                            ("backend", trace::Tag::from(bi)),
                                            ("streak", trace::Tag::from(streak)),
                                        ],
                                    );
                                }
                                let attempts =
                                    task_retries[ci].fetch_add(1, Ordering::SeqCst) + 1;
                                if attempts > policy.max_retries {
                                    failure.lock().unwrap().get_or_insert(format!(
                                        "shard {ci} failed {attempts} times, retries \
                                         exhausted: {e}"
                                    ));
                                    return;
                                }
                                retries_ctr.inc();
                                trace::instant(
                                    "sched.retry",
                                    "sched",
                                    task_corr,
                                    None,
                                    vec![
                                        ("shard", trace::Tag::from(ci)),
                                        ("attempt", trace::Tag::from(attempts)),
                                    ],
                                );
                                // Re-queue on the next healthy backend
                                // (round-robin from our right; never a
                                // quarantined one).
                                let target = (1..=nb)
                                    .map(|k| (bi + k) % nb)
                                    .find(|&j| !quarantined[j].load(Ordering::SeqCst));
                                match target {
                                    Some(j) => deques[j].lock().unwrap().push_back(ci),
                                    None => {
                                        failure.lock().unwrap().get_or_insert(format!(
                                            "shard {ci}: every backend quarantined: {e}"
                                        ));
                                        return;
                                    }
                                }
                            }
                        }
                    }
                });
            }
        });

        // A quarantine race can leave tasks queued with no worker left
        // to run them (every survivor exited in the same instant a
        // task was re-queued): without this check the merge below
        // would silently use stale shard buffers.
        if faults.is_some()
            && failure.lock().unwrap().is_none()
            && remaining.load(Ordering::SeqCst) > 0
        {
            failure.lock().unwrap().get_or_insert(format!(
                "{} shards left unfinished after backend quarantines",
                remaining.load(Ordering::SeqCst)
            ));
        }
        if let Some(e) = failure.lock().unwrap().take() {
            run_err = Some(CclError::framework(format!("sharded iteration {iter}: {e}")));
            break;
        }

        // Without profiling, drain (and discard) timelines every
        // iteration so a long streaming run stays memory-bounded; the
        // busy totals still accumulate.
        if !profile {
            for (bi, b) in backends.iter().enumerate() {
                busy_acc[bi] +=
                    b.drain_timeline().iter().map(|(_, t, _)| t.duration()).sum::<u64>();
            }
        }

        // Barrier reached: merge this iteration's shard outputs through
        // the workload (concat / partial-sum fold / halo trim). The
        // merged output feeds the sink (the PRNG service's streaming
        // contract) and derives the next state (halo exchange happens
        // here: the next iteration re-slices the merged grid). Shard
        // buffers are *taken*, not cloned — run_task resizes and
        // rewrites them from scratch next iteration — and on the final
        // iteration the merged vec moves straight into the result, so
        // the streaming hot path does no avoidable full-stream copies.
        let mut iter_outputs: Vec<Vec<u8>> = outputs
            .iter()
            .map(|o| std::mem::take(&mut *o.lock().unwrap()))
            .collect();
        let merged = workload.merge(&shards, &iter_outputs);
        sink_consume(sink, &mut sample, &merged);
        // Hand each shard its buffer back: next iteration's run_task
        // resize() becomes a length reset instead of a reallocation
        // (the dispatch hot path's allocation churn).
        for (slot, buf) in outputs.iter().zip(iter_outputs.drain(..)) {
            *slot.lock().unwrap() = buf;
        }
        if iter + 1 == iters {
            final_output = merged;
        } else {
            state = workload.next_state(state, merged);
        }
    }

    let wall = t0.elapsed();
    prof.stop();

    let mut per_backend = Vec::with_capacity(nb);
    for (bi, b) in backends.iter().enumerate() {
        let timeline = b.drain_timeline();
        let busy_ns =
            busy_acc[bi] + timeline.iter().map(|(_, t, _)| t.duration()).sum::<u64>();
        per_backend.push(BackendLoad {
            name: b.name(),
            tasks: tasks_run[bi].get() as usize,
            stolen: stolen[bi].get() as usize,
            busy_ns,
            bytes: bytes_out[bi].get(),
            failures: failed_ctr[bi].get() as usize,
        });
        if profile {
            // Partition the drained spans by their launch tag: a tagged
            // span (e.g. `svc.req-3.`) gets its own `<tag><backend>`
            // queue, untagged spans (transfers, untagged launches) fall
            // back to the dispatch-wide `queue_tag` prefix. BTreeMap
            // keeps queue order deterministic for the exported table.
            let mut queues: BTreeMap<String, Vec<(String, (u64, u64, u64, u64))>> =
                BTreeMap::new();
            for (name, t, tag) in timeline {
                let queue = match tag.as_deref().or(queue_tag) {
                    Some(tag) => format!("{tag}{}", b.name()),
                    None => b.name(),
                };
                queues
                    .entry(queue)
                    .or_default()
                    .push((name, (t.queued, t.submit, t.start, t.end)));
            }
            for (queue, entries) in queues {
                prof.add_timeline(queue, entries);
            }
        }
    }

    // Release the pooled device buffers — the registry backends are
    // process-lifetime objects, so anything left allocated here leaks.
    for (s, b) in scratch.iter().zip(&backends) {
        for (_, buf) in s.free_bufs.lock().unwrap().drain(..) {
            b.free(buf);
        }
    }
    // Return host shard buffers to the cross-run pool.
    if let Some(pool) = pool {
        for o in &outputs {
            pool.put(std::mem::take(&mut *o.lock().unwrap()));
        }
    }
    if let Some(e) = run_err {
        return Err(e);
    }

    let (prof_summary, prof_export, prof_infos) = if profile {
        prof.calc()?;
        (
            Some(prof.summary_default()),
            Some(prof.export_string()?),
            Some(prof.infos()?.to_vec()),
        )
    } else {
        (None, None, None)
    };

    Ok(WorkloadOutcome {
        wall,
        final_output,
        sample,
        num_chunks: shards.len(),
        per_backend,
        prof_summary,
        prof_export,
        prof_infos,
        retries: retries_ctr.get(),
        quarantined: backends
            .iter()
            .enumerate()
            .filter(|(bi, _)| quarantined[*bi].load(Ordering::SeqCst))
            .map(|(_, b)| b.name())
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::rng_service::expected_first_batch;

    fn cfg(n: usize, iters: usize) -> ShardedRngConfig {
        let mut c = ShardedRngConfig::new(n, iters);
        c.sink = Sink::Sample(64);
        c.min_chunk = 256;
        c
    }

    #[test]
    fn chunk_plan_covers_the_stream() {
        assert_eq!(plan_chunks(10, 3, 1), vec![(0, 4), (4, 3), (7, 3)]);
        assert_eq!(plan_chunks(8, 16, 4), vec![(0, 4), (4, 4)]);
        assert_eq!(plan_chunks(5, 1, 1024), vec![(0, 5)]);
    }

    #[test]
    fn sharded_first_batch_is_the_seed_batch() {
        // Fresh registry: the global one is shared process-wide and
        // other tests' timelines would cross-pollute drains.
        let reg = BackendRegistry::with_default_backends();
        let out = run_sharded_on(&reg, &cfg(4096, 2)).unwrap();
        assert!(out.num_chunks >= 2, "should shard across backends");
        assert_eq!(out.sample.len(), 64);
        for (i, &w) in out.sample.iter().enumerate() {
            assert_eq!(w, expected_first_batch(i), "sample word {i}");
        }
        let total: usize = out.per_backend.iter().map(|l| l.tasks).sum();
        assert_eq!(total, out.num_chunks * 2, "every task accounted for");
    }

    #[test]
    fn zero_work_is_rejected() {
        assert!(run_sharded(&cfg(0, 2)).is_err());
        assert!(run_sharded(&cfg(1024, 0)).is_err());
    }

    #[test]
    fn sharded_stencil_halo_exchange_matches_reference() {
        use crate::workload::StencilWorkload;
        let reg = BackendRegistry::with_default_backends();
        let w = StencilWorkload::new(24, 16);
        let mut scfg = ShardedConfig::new(w, 3);
        scfg.min_chunk = 4; // force several row bands
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert!(out.num_chunks >= 2, "should shard into bands");
        assert_eq!(out.final_output, w.reference(3), "halo exchange must be exact");
    }

    #[test]
    fn explicit_shard_plan_is_respected_and_validated() {
        use crate::workload::SaxpyWorkload;
        let reg = BackendRegistry::with_default_backends();
        let w = SaxpyWorkload::new(1000, 2.5);

        // A valid, deliberately uneven plan runs and is bit-exact.
        let mut scfg = ShardedConfig::new(w, 2);
        scfg.shard_plan = Some(vec![
            Shard { lo: 0, len: 700 },
            Shard { lo: 700, len: 50 },
            Shard { lo: 750, len: 250 },
        ]);
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert_eq!(out.num_chunks, 3);
        assert_eq!(out.final_output, w.reference(2));

        // Gaps, overlaps, short coverage and empty shards are rejected.
        for bad in [
            vec![Shard { lo: 0, len: 500 }, Shard { lo: 600, len: 400 }],
            vec![Shard { lo: 0, len: 600 }, Shard { lo: 500, len: 500 }],
            vec![Shard { lo: 0, len: 999 }],
            vec![Shard { lo: 0, len: 1000 }, Shard { lo: 1000, len: 0 }],
        ] {
            let mut scfg = ShardedConfig::new(w, 1);
            scfg.shard_plan = Some(bad.clone());
            assert!(
                run_sharded_workload_on(&reg, &scfg).is_err(),
                "plan {bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn shard_homes_are_validated_and_respected() {
        use crate::workload::SaxpyWorkload;
        let reg = BackendRegistry::with_default_backends();
        let w = SaxpyWorkload::new(1000, 2.0);

        // An explicit home assignment runs, stays bit-exact, and the
        // per-backend byte counters account for every output byte.
        let mut scfg = ShardedConfig::new(w, 2);
        scfg.shard_plan =
            Some(vec![Shard { lo: 0, len: 600 }, Shard { lo: 600, len: 400 }]);
        scfg.shard_homes = Some(vec![0, 0]);
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert_eq!(out.final_output, w.reference(2));
        let total: u64 = out.per_backend.iter().map(|l| l.bytes).sum();
        assert_eq!(total, 1000 * 4 * 2, "every output byte attributed");

        // Length mismatch is rejected.
        let mut bad = ShardedConfig::new(w, 1);
        bad.shard_plan = Some(vec![Shard { lo: 0, len: 1000 }]);
        bad.shard_homes = Some(vec![0, 0]);
        assert!(run_sharded_workload_on(&reg, &bad).is_err());

        // Out-of-range home index is rejected.
        let mut bad = ShardedConfig::new(w, 1);
        bad.shard_plan = Some(vec![Shard { lo: 0, len: 1000 }]);
        bad.shard_homes = Some(vec![reg.len()]);
        assert!(run_sharded_workload_on(&reg, &bad).is_err());
    }

    #[test]
    fn queue_tag_prefixes_profiled_queue_names() {
        use crate::workload::SaxpyWorkload;
        let reg = BackendRegistry::with_default_backends();
        let mut scfg = ShardedConfig::new(SaxpyWorkload::new(2048, 1.5), 1);
        scfg.profile = true;
        scfg.queue_tag = Some("svc.batch-0.".into());
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        let infos = out.prof_infos.expect("profiling requested");
        assert!(!infos.is_empty());
        assert!(
            infos.iter().all(|i| i.queue.starts_with("svc.batch-0.")),
            "{infos:?}"
        );
    }

    #[test]
    fn shard_tags_partition_profile_queues_per_request() {
        use crate::workload::SaxpyWorkload;
        let reg = BackendRegistry::with_default_backends();
        let w = SaxpyWorkload::new(2048, 2.0);
        let mut scfg = ShardedConfig::new(w, 1);
        scfg.profile = true;
        scfg.queue_tag = Some("svc.batch-0.".into());
        scfg.shard_plan =
            Some(vec![Shard { lo: 0, len: 1024 }, Shard { lo: 1024, len: 1024 }]);
        scfg.shard_tags = Some(vec!["svc.req-1.".into(), "svc.req-2.".into()]);
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert_eq!(out.final_output, w.reference(1));
        let infos = out.prof_infos.expect("profiling requested");
        // Kernel spans carry their shard's request tag; transfers fall
        // back to the batch-wide queue tag.
        for tag in ["svc.req-1.", "svc.req-2."] {
            assert!(
                infos
                    .iter()
                    .any(|i| i.name == "SAXPY_KERNEL" && i.queue.starts_with(tag)),
                "missing kernel span for {tag}: {infos:?}"
            );
        }
        assert!(
            infos
                .iter()
                .filter(|i| i.name != "SAXPY_KERNEL")
                .all(|i| i.queue.starts_with("svc.batch-0.")),
            "{infos:?}"
        );

        // A tag list that does not match the plan is rejected.
        let mut bad = ShardedConfig::new(w, 1);
        bad.shard_plan =
            Some(vec![Shard { lo: 0, len: 1024 }, Shard { lo: 1024, len: 1024 }]);
        bad.shard_tags = Some(vec!["svc.req-1.".into()]);
        assert!(run_sharded_workload_on(&reg, &bad).is_err());
    }

    #[test]
    fn profiled_outcome_carries_raw_infos() {
        use crate::workload::SaxpyWorkload;
        let reg = BackendRegistry::with_default_backends();
        let mut scfg = ShardedConfig::new(SaxpyWorkload::new(4096, 2.0), 2);
        scfg.profile = true;
        scfg.min_chunk = 512;
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        let infos = out.prof_infos.expect("profiling requested");
        assert!(!infos.is_empty());
        assert!(infos.iter().any(|i| i.name == "SAXPY_KERNEL"), "{infos:?}");
    }

    #[test]
    fn sharded_reduce_folds_partial_sums() {
        use crate::workload::ReduceWorkload;
        let reg = BackendRegistry::with_default_backends();
        let w = ReduceWorkload::new(4096);
        let mut scfg = ShardedConfig::new(w, 2);
        scfg.min_chunk = 256;
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert!(out.num_chunks >= 2);
        assert_eq!(out.final_output, w.reference(2));
        assert_eq!(out.final_output.len(), 8, "one u64 word");
    }

    #[test]
    fn capability_gap_is_a_typed_plan_time_error() {
        use crate::backend::SimBackend;
        use crate::rawcl::types::DeviceId;
        use crate::workload::MatmulWorkload;
        let reg = BackendRegistry::new();
        reg.register_with_caps(
            Arc::new(SimBackend::new(DeviceId(1)).unwrap()),
            Capabilities::with_families([KernelKind::Saxpy, KernelKind::VecAdd]),
        );
        let w = MatmulWorkload::new(8);
        let err = run_sharded_workload_on(&reg, &ShardedConfig::new(w, 1)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("no capable backend"), "{msg}");
        assert!(msg.contains("Matmul"), "{msg}");
        assert!(msg.contains("sim:"), "typed error names the backend: {msg}");

        // A capable peer makes the same dispatch run — on it alone.
        reg.register(Arc::new(SimBackend::new(DeviceId(2)).unwrap()));
        let out = run_sharded_workload_on(&reg, &ShardedConfig::new(w, 1)).unwrap();
        assert_eq!(out.final_output, w.reference(1));
        assert_eq!(out.per_backend.len(), 1, "incapable backend filtered out");
    }

    #[test]
    fn fault_policy_retries_deterministically_to_a_bit_identical_result() {
        use crate::backend::{FaultSpec, FaultyBackend, SimBackend};
        use crate::rawcl::types::DeviceId;
        // Single flaky backend, enqueue faults at 500‰: the xorshift
        // draw sequence for seed 42 makes the schedule fully
        // deterministic — 8 tasks (4 shards × 2 iters) hit exactly 7
        // injected failures, every one retried on the same backend.
        let reg = BackendRegistry::new();
        let flaky = Arc::new(FaultyBackend::new(
            Arc::new(SimBackend::new(DeviceId(1)).unwrap()),
            FaultSpec {
                seed: 42,
                enqueue_error_permille: 500,
                corrupt_read_permille: 0,
                slow_launch_ns: 0,
                fail_after: None,
            },
        ));
        reg.register(flaky.clone());
        let w = PrngWorkload::new(1024);
        let mut scfg = ShardedConfig::new(w, 2);
        scfg.chunks_per_backend = 4;
        scfg.min_chunk = 1;
        scfg.faults = Some(FaultPolicy {
            max_retries: 10,
            quarantine_after: 100,
            verify_reads: false,
        });
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert_eq!(out.final_output, w.reference(2), "recovery must be bit-identical");
        assert_eq!(out.retries, 7, "seed 42 at 500‰ over 8 tasks");
        assert_eq!(flaky.counts().enqueue_errors, 7);
        assert_eq!(out.per_backend[0].failures, 7);
        assert!(out.quarantined.is_empty(), "streaks stay under the threshold");
    }

    #[test]
    fn dying_backend_is_quarantined_and_the_run_recovers() {
        use crate::backend::{FaultSpec, FaultyBackend, SimBackend};
        use crate::rawcl::types::DeviceId;
        let reg = BackendRegistry::new();
        reg.register(Arc::new(SimBackend::new(DeviceId(1)).unwrap()));
        let dying = Arc::new(FaultyBackend::new(
            Arc::new(SimBackend::new(DeviceId(2)).unwrap()),
            FaultSpec::dying(0), // every launch fails
        ));
        reg.register(dying.clone());
        let w = PrngWorkload::new(2048);
        let mut scfg = ShardedConfig::new(w, 3);
        scfg.chunks_per_backend = 4;
        scfg.min_chunk = 1;
        scfg.faults = Some(FaultPolicy {
            max_retries: 4,
            quarantine_after: 1,
            verify_reads: false,
        });
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert_eq!(out.final_output, w.reference(3), "recovery must be bit-identical");
        // The dying backend engages unless the healthy peer stole its
        // entire deque first (legal but rare); when it does engage, it
        // must be quarantined after its first failure and every failed
        // task re-dispatched.
        if dying.counts().enqueue_errors > 0 {
            assert_eq!(out.quarantined, vec![dying.name()]);
            assert!(out.retries >= 1);
        }
    }

    #[test]
    fn buffer_pool_reuses_shard_buffers_across_runs() {
        use crate::workload::SaxpyWorkload;
        let reg = BackendRegistry::with_default_backends();
        let pool = Arc::new(BufferPool::new());
        let w = SaxpyWorkload::new(4096, 2.0);
        for round in 0..3 {
            let mut scfg = ShardedConfig::new(w, 2);
            scfg.min_chunk = 512;
            scfg.buffer_pool = Some(pool.clone());
            let out = run_sharded_workload_on(&reg, &scfg).unwrap();
            assert_eq!(out.final_output, w.reference(2), "round {round}");
        }
        assert!(pool.misses() > 0, "the first round allocates fresh");
        assert!(
            pool.hits() > 0,
            "later rounds must reuse capacity (hits {}, misses {})",
            pool.hits(),
            pool.misses()
        );
    }

    #[test]
    fn verify_reads_catches_wrong_once_results() {
        use crate::backend::{FaultSpec, FaultyBackend, SimBackend};
        use crate::rawcl::types::DeviceId;
        // A backend that corrupts EVERY read: without verification its
        // single-backend runs would merge corrupted bytes; with
        // verification every task fails its double-read and the run
        // errors out with retries exhausted (no healthy peer exists).
        let reg = BackendRegistry::new();
        reg.register(Arc::new(FaultyBackend::new(
            Arc::new(SimBackend::new(DeviceId(1)).unwrap()),
            FaultSpec {
                seed: 7,
                enqueue_error_permille: 0,
                corrupt_read_permille: 1000,
                slow_launch_ns: 0,
                fail_after: None,
            },
        )));
        let w = PrngWorkload::new(256);
        let mut scfg = ShardedConfig::new(w, 1);
        scfg.faults = Some(FaultPolicy {
            max_retries: 2,
            quarantine_after: 100,
            verify_reads: true,
        });
        let err = run_sharded_workload_on(&reg, &scfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("read-back verification mismatch"), "{msg}");

        // With a healthy peer, the same chaos recovers bit-identically.
        let reg = BackendRegistry::new();
        reg.register(Arc::new(SimBackend::new(DeviceId(1)).unwrap()));
        reg.register(Arc::new(FaultyBackend::new(
            Arc::new(SimBackend::new(DeviceId(2)).unwrap()),
            FaultSpec {
                seed: 7,
                enqueue_error_permille: 0,
                corrupt_read_permille: 1000,
                slow_launch_ns: 0,
                fail_after: None,
            },
        )));
        let mut scfg = ShardedConfig::new(w, 2);
        scfg.faults = Some(FaultPolicy::paranoid());
        let out = run_sharded_workload_on(&reg, &scfg).unwrap();
        assert_eq!(out.final_output, w.reference(2));
    }
}
