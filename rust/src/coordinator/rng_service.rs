//! The massive-PRNG service (paper §5) as a library.
//!
//! Two host threads (main = kernels, comms = device→host reads + output),
//! two command queues, device-side double buffering, semaphore
//! synchronisation — exactly the structure of Fig. 2. Both realisations
//! are provided:
//!
//! * [`run_ccl`] — built on the `ccl` v1 framework (listing S2's
//!   logic);
//! * [`run_raw`] — built directly on the `rawcl` substrate (listing
//!   S1's logic, with manual event bookkeeping);
//! * [`run_v2`] — built on the `ccl::v2` fluent tier: the session
//!   facade replaces the context/queue/program setup, typed buffers
//!   replace the byte slices, and implicit dependency chaining replaces
//!   the per-iteration `finish()` barrier — with a bit-identical
//!   output stream.
//!
//! The §6.2 overhead harness runs the first two over the paper's
//! parameter sweep; the standalone `examples/rng_{raw,ccl,v2}.rs`
//! programs mirror the same logic as self-contained sources for the
//! §6.1 LOC comparison.

use std::io::Write;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::ccl::{self, Arg};
use crate::rawcl;
use crate::rawcl::types::{DeviceId, MemFlags, QueueProps};
use crate::runtime::{hlogen, ArtifactKind};

use super::sem::Semaphore;

/// Where the generated random bytes go.
pub enum Sink {
    /// Drop them (the §6.2 benchmark redirects to /dev/null).
    Discard,
    /// Keep the first `n` words for validation.
    Sample(usize),
    /// Stream to a writer (the real §5 use case).
    Writer(Mutex<Box<dyn Write + Send>>),
}

/// Service configuration (the example's `n` and `i` CLI parameters).
pub struct RngConfig {
    /// Random numbers per iteration (`n`); must match an artifact size.
    pub numrn: usize,
    /// Iterations producing random numbers (`i`).
    pub iters: usize,
    /// Flat device index (0 = native CPU, 1/2 = simulated GPUs).
    pub device_index: u32,
    /// Enable event profiling (the WITH_PROFILING build flag).
    pub profile: bool,
    pub sink: Sink,
}

impl RngConfig {
    pub fn new(numrn: usize, iters: usize) -> Self {
        Self {
            numrn,
            iters,
            device_index: 1,
            profile: true,
            sink: Sink::Discard,
        }
    }
}

/// What a run produced.
#[derive(Debug)]
pub struct RunOutcome {
    pub wall: Duration,
    pub total_bytes: u64,
    /// Fig. 3-style summary (ccl path, when profiling).
    pub prof_summary: Option<String>,
    /// Fig. 5 export table (ccl path, when profiling).
    pub prof_export: Option<String>,
    /// Basic per-category totals in ns (raw path, when profiling):
    /// (init kernel, rng kernels, reads).
    pub raw_prof: Option<(u64, u64, u64)>,
    /// Sampled first batch (when `Sink::Sample`).
    pub sample: Vec<u64>,
}

pub(crate) fn sink_consume(sink: &Sink, sample_out: &mut Vec<u64>, bytes: &[u8]) {
    match sink {
        Sink::Discard => {}
        Sink::Sample(n) => {
            if sample_out.is_empty() {
                sample_out.extend(
                    bytes
                        .chunks_exact(8)
                        .take(*n)
                        .map(|c| u64::from_le_bytes(c.try_into().unwrap())),
                );
            }
        }
        Sink::Writer(w) => {
            let _ = w.lock().unwrap().write_all(bytes);
        }
    }
}

/// The cf4rs-framework realisation (listing S2).
pub fn run_ccl(cfg: &RngConfig) -> ccl::CclResult<RunOutcome> {
    let n = cfg.numrn;
    let dev = ccl::Device::from_id(DeviceId(cfg.device_index))?;
    let ctx = ccl::Context::new_from_devices(&[dev])?;
    let props = if cfg.profile {
        QueueProps::PROFILING_ENABLE
    } else {
        QueueProps::empty()
    };
    let cq_main = ccl::Queue::new(&ctx, dev, props)?;
    let cq_comms = ccl::Queue::new(&ctx, dev, props)?;

    let prg = ccl::Program::new_from_kinds(
        &ctx,
        &[(ArtifactKind::Init, n), (ArtifactKind::Rng, n)],
    )?;
    prg.build()?;
    let kinit = prg.kernel("prng_init")?;
    let krng = prg.kernel("prng_step")?;

    let bufdev1 = ccl::Buffer::new(&ctx, MemFlags::READ_WRITE, n * 8)?;
    let bufdev2 = ccl::Buffer::new(&ctx, MemFlags::READ_WRITE, n * 8)?;

    let (gws, lws) = kinit.suggest_worksizes(dev, &[n])?;

    let sem_rng = Semaphore::new(1);
    let sem_comm = Semaphore::new(1);
    let mut sample = Vec::new();
    let comms_err: Mutex<Option<ccl::CclError>> = Mutex::new(None);

    let t0 = Instant::now();
    let mut prof = ccl::Prof::new();
    prof.start();

    // init kernel (seeds + first batch)
    let evt = kinit.set_args_and_enqueue_ndrange(
        &cq_main,
        &gws,
        Some(&lws),
        &[],
        &[Arg::buf(&bufdev1), Arg::priv_u32(n as u32)],
    )?;
    evt.set_name("INIT_KERNEL")?;

    // fixed rng arg (set once; skipped in the loop)
    krng.set_arg(0, &Arg::priv_u32(n as u32))?;
    cq_main.finish()?;

    std::thread::scope(|scope| -> ccl::CclResult<()> {
        // comms thread: read each batch and push it to the sink
        let comms = {
            let (b1, b2) = (&bufdev1, &bufdev2);
            let (sem_rng, sem_comm) = (&sem_rng, &sem_comm);
            let (cq, sink) = (&cq_comms, &cfg.sink);
            let (sample, comms_err) = (&mut sample, &comms_err);
            let iters = cfg.iters;
            scope.spawn(move || {
                let mut host = vec![0u8; n * 8];
                let mut front = b1;
                let mut back = b2;
                for _ in 0..iters {
                    sem_rng.wait();
                    let r = front.enqueue_read(cq, 0, &mut host, &[]);
                    // Publish a failure BEFORE waking the producer, so
                    // it cannot observe the post, miss the error, and
                    // block forever on the next wait.
                    match r {
                        Ok(ev) => {
                            sem_comm.post();
                            let _ = ev.set_name("READ_BUFFER");
                        }
                        Err(e) => {
                            *comms_err.lock().unwrap() = Some(e);
                            sem_comm.post();
                            return;
                        }
                    }
                    sink_consume(sink, sample, &host);
                    std::mem::swap(&mut front, &mut back);
                }
            })
        };

        // main thread: produce the next batches
        let mut front = &bufdev1;
        let mut back = &bufdev2;
        for _ in 0..cfg.iters.saturating_sub(1) {
            sem_comm.wait();
            if let Some(e) = comms_err.lock().unwrap().take() {
                return Err(e);
            }
            let evt = krng.set_args_and_enqueue_ndrange(
                &cq_main,
                &gws,
                Some(&lws),
                &[],
                &[Arg::skip(), Arg::buf(front), Arg::buf(back)],
            )?;
            evt.set_name("RNG_KERNEL")?;
            cq_main.finish()?;
            sem_rng.post();
            std::mem::swap(&mut front, &mut back);
        }
        comms.join().map_err(|_| ccl::CclError::framework("comms thread panicked"))?;
        Ok(())
    })?;
    if let Some(e) = comms_err.lock().unwrap().take() {
        return Err(e);
    }

    cq_main.finish()?;
    cq_comms.finish()?;
    prof.stop();
    let wall = t0.elapsed();

    let (prof_summary, prof_export) = if cfg.profile {
        prof.add_queue("Main", &cq_main);
        prof.add_queue("Comms", &cq_comms);
        prof.calc()?;
        (Some(prof.summary_default()), Some(prof.export_string()?))
    } else {
        (None, None)
    };

    Ok(RunOutcome {
        wall,
        total_bytes: (8 * n * cfg.iters) as u64,
        prof_summary,
        prof_export,
        raw_prof: None,
        sample,
    })
}

/// The `ccl::v2` fluent-tier realisation: same two-thread,
/// double-buffered pipeline as [`run_ccl`], same bit-identical stream,
/// but the session facade owns the setup and the per-buffer dependency
/// tracker orders kernels and cross-queue reads — no per-iteration
/// `finish()`, no explicit wait-lists, no byte-slice casts.
pub fn run_v2(cfg: &RngConfig) -> ccl::CclResult<RunOutcome> {
    use crate::ccl::v2::Session;

    let n = cfg.numrn;
    let mut builder = Session::builder().device_index(cfg.device_index).queues(2);
    if cfg.profile {
        builder = builder.profiled();
    }
    let sess = builder.build()?;
    sess.load_kinds(&[(ArtifactKind::Init, n), (ArtifactKind::Rng, n)])?;

    let bufdev1 = sess.buffer::<u64>(n)?;
    let bufdev2 = sess.buffer::<u64>(n)?;

    let sem_rng = Semaphore::new(1);
    let sem_comm = Semaphore::new(1);
    let mut sample = Vec::new();
    let comms_err: Mutex<Option<ccl::CclError>> = Mutex::new(None);

    let t0 = Instant::now();

    // Seed batch: the launch is recorded as bufdev1's writer, so the
    // comms thread's first read is ordered after it automatically.
    sess.kernel("prng_init")?
        .global(n)
        .arg(&bufdev1)
        .arg(n as u32)
        .name("INIT_KERNEL")
        .launch()?;

    std::thread::scope(|scope| -> ccl::CclResult<()> {
        // Comms thread: read each batch on queue 1 and push it to the
        // sink. The implicit last-writer dependency replaces both the
        // explicit wait-list and the producer's finish() barrier.
        let comms = {
            let (b1, b2) = (&bufdev1, &bufdev2);
            let (sem_rng, sem_comm) = (&sem_rng, &sem_comm);
            let sink = &cfg.sink;
            let (sample, comms_err) = (&mut sample, &comms_err);
            let iters = cfg.iters;
            scope.spawn(move || {
                let mut host = vec![0u8; n * 8];
                let (mut front, mut back) = (b1, b2);
                for _ in 0..iters {
                    sem_rng.wait();
                    let r = front.read_into_on(1, &mut host);
                    // Publish a failure BEFORE waking the producer, so
                    // it cannot observe the post, miss the error, and
                    // block forever on the next wait.
                    if let Err(e) = r {
                        *comms_err.lock().unwrap() = Some(e);
                        sem_comm.post();
                        return;
                    }
                    sem_comm.post();
                    sink_consume(sink, sample, &host);
                    std::mem::swap(&mut front, &mut back);
                }
            })
        };

        // Main thread: produce the next batches. Each launch reads the
        // front buffer (waiting on its writer implicitly) and claims
        // the back buffer as its output.
        let (mut front, mut back) = (&bufdev1, &bufdev2);
        for _ in 0..cfg.iters.saturating_sub(1) {
            sem_comm.wait();
            if let Some(e) = comms_err.lock().unwrap().take() {
                return Err(e);
            }
            sess.kernel("prng_step")?
                .global(n)
                .arg(n as u32)
                .arg(front)
                .arg(back)
                .name("RNG_KERNEL")
                .launch()?;
            sem_rng.post();
            std::mem::swap(&mut front, &mut back);
        }
        comms
            .join()
            .map_err(|_| ccl::CclError::framework("comms thread panicked"))?;
        Ok(())
    })?;
    if let Some(e) = comms_err.lock().unwrap().take() {
        return Err(e);
    }

    sess.finish()?;
    let wall = t0.elapsed();

    let (prof_summary, prof_export) = if cfg.profile {
        let prof = sess.profile()?;
        (Some(prof.summary_default()), Some(prof.export_string()?))
    } else {
        (None, None)
    };

    Ok(RunOutcome {
        wall,
        total_bytes: (8 * n * cfg.iters) as u64,
        prof_summary,
        prof_export,
        raw_prof: None,
        sample,
    })
}

/// The pure-substrate realisation (listing S1), with the raw API's
/// manual status handling and event bookkeeping.
pub fn run_raw(cfg: &RngConfig) -> Result<RunOutcome, String> {
    use rawcl::*;

    let n = cfg.numrn;
    macro_rules! chk {
        ($st:expr, $what:expr) => {
            if $st != CL_SUCCESS {
                return Err(format!("{}: {} ({})", $what, status_name($st), $st));
            }
        };
    }

    // device + context (the listing's platform loop lives in the raw
    // example; here the device index is explicit)
    let dev = DeviceId(cfg.device_index);
    let mut st = CL_SUCCESS;
    let ctx = create_context(&[dev], &mut st);
    chk!(st, "create context");

    let props = if cfg.profile {
        QueueProps::PROFILING_ENABLE
    } else {
        QueueProps::empty()
    };
    let cq_main = create_command_queue(ctx, dev, props, &mut st);
    chk!(st, "create main queue");
    let cq_comms = create_command_queue(ctx, dev, props, &mut st);
    chk!(st, "create comms queue");

    // kernel sources: manifest artifacts when present, generated HLO
    // otherwise (the listing reads .cl files)
    let mut sources = Vec::new();
    for kind in [ArtifactKind::Init, ArtifactKind::Rng] {
        sources.push(
            hlogen::resolve_source(&hlogen::GenSpec::new(kind, n))
                .map_err(|e| format!("resolving {kind} (n={n}) source: {e}"))?,
        );
    }
    let prg = create_program_with_source(ctx, &sources, &mut st);
    chk!(st, "create program");
    let st2 = build_program(prg, None, "");
    if st2 == CL_BUILD_PROGRAM_FAILURE {
        let mut log = String::new();
        get_program_build_log(prg, &mut log);
        return Err(format!("build failure:\n{log}"));
    }
    chk!(st2, "build program");

    let kinit = create_kernel(prg, "prng_init", &mut st);
    chk!(st, "create init kernel");
    let krng = create_kernel(prg, "prng_step", &mut st);
    chk!(st, "create rng kernel");

    let bufdev1 = create_buffer(ctx, MemFlags::READ_WRITE, n * 8, None, &mut st);
    chk!(st, "create buffer 1");
    let bufdev2 = create_buffer(ctx, MemFlags::READ_WRITE, n * 8, None, &mut st);
    chk!(st, "create buffer 2");

    // work sizes: the listing's minimum-LOC approach
    let mut lws = 0usize;
    chk!(
        get_kernel_work_group_info(
            kinit,
            dev,
            KernelWorkGroupInfo::PreferredWorkGroupSizeMultiple,
            &mut lws
        ),
        "work group info"
    );
    let gws = n.div_ceil(lws) * lws;

    // manual event storage (listing S1 line 373)
    let mut read_events: Vec<EventH> = Vec::with_capacity(cfg.iters);
    let mut rng_events: Vec<EventH> = Vec::with_capacity(cfg.iters);
    let read_events_mx = Mutex::new(&mut read_events);

    let sem_rng = Semaphore::new(1);
    let sem_comm = Semaphore::new(1);
    let mut sample = Vec::new();
    let comms_err: Mutex<Option<String>> = Mutex::new(None);

    let t0 = Instant::now();

    // init kernel
    let narg = ArgValue::Scalar((n as u32).to_le_bytes().to_vec());
    chk!(set_kernel_arg(kinit, 0, &ArgValue::Buffer(bufdev1)), "init arg 0");
    chk!(set_kernel_arg(kinit, 1, &narg), "init arg 1");
    let mut evt_kinit = EventH::NULL;
    chk!(
        enqueue_ndrange_kernel(cq_main, kinit, 1, &[gws], Some(&[lws]), &[], Some(&mut evt_kinit)),
        "enqueue init"
    );
    chk!(set_kernel_arg(krng, 0, &narg), "rng arg 0");
    chk!(finish(cq_main), "finish after init");

    std::thread::scope(|scope| {
        // comms thread
        let comms = {
            let (sem_rng, sem_comm) = (&sem_rng, &sem_comm);
            let (sink, sample) = (&cfg.sink, &mut sample);
            let (comms_err, read_events_mx) = (&comms_err, &read_events_mx);
            let iters = cfg.iters;
            scope.spawn(move || {
                let mut host = vec![0u8; n * 8];
                let (mut front, mut back) = (bufdev1, bufdev2);
                for _ in 0..iters {
                    sem_rng.wait();
                    let mut evt = EventH::NULL;
                    let st = enqueue_read_buffer(
                        cq_comms, front, true, 0, &mut host, &[], Some(&mut evt),
                    );
                    sem_comm.post();
                    if st != CL_SUCCESS {
                        *comms_err.lock().unwrap() =
                            Some(format!("read: {}", status_name(st)));
                        return;
                    }
                    read_events_mx.lock().unwrap().push(evt);
                    sink_consume(sink, sample, &host);
                    std::mem::swap(&mut front, &mut back);
                }
            })
        };

        // main thread
        let (mut front, mut back) = (bufdev1, bufdev2);
        for _ in 0..cfg.iters.saturating_sub(1) {
            sem_comm.wait();
            if comms_err.lock().unwrap().is_some() {
                break;
            }
            let mut evt = EventH::NULL;
            let st1 = set_kernel_arg(krng, 1, &ArgValue::Buffer(front));
            let st2 = set_kernel_arg(krng, 2, &ArgValue::Buffer(back));
            let st3 = enqueue_ndrange_kernel(
                cq_main, krng, 1, &[gws], Some(&[lws]), &[], Some(&mut evt),
            );
            let st4 = finish(cq_main);
            sem_rng.post();
            if st1 != CL_SUCCESS || st2 != CL_SUCCESS || st3 != CL_SUCCESS || st4 != CL_SUCCESS {
                *comms_err.lock().unwrap() = Some("kernel loop failure".into());
                break;
            }
            rng_events.push(evt);
            std::mem::swap(&mut front, &mut back);
        }
        comms.join().ok();
    });
    if let Some(e) = comms_err.lock().unwrap().take() {
        return Err(e);
    }
    finish(cq_main);
    finish(cq_comms);
    let wall = t0.elapsed();

    // basic profiling totals (the listing's WITH_PROFILING block):
    // query each stored event one by one — no overlap detection.
    let raw_prof = if cfg.profile {
        let total = |evts: &[EventH]| -> u64 {
            evts.iter()
                .map(|&e| {
                    let (mut s, mut t) = (0u64, 0u64);
                    get_event_profiling_info(e, ProfilingInfo::Start, &mut s);
                    get_event_profiling_info(e, ProfilingInfo::End, &mut t);
                    t.saturating_sub(s)
                })
                .sum()
        };
        let tkinit = total(&[evt_kinit]);
        let tkrng = total(&rng_events);
        let tcomms = total(&read_events);
        Some((tkinit, tkrng, tcomms))
    } else {
        None
    };

    // manual release of every object (the listing's cleanup block)
    release_event(evt_kinit);
    for e in rng_events.iter().chain(read_events.iter()) {
        release_event(*e);
    }
    release_mem_object(bufdev1);
    release_mem_object(bufdev2);
    release_kernel(kinit);
    release_kernel(krng);
    release_program(prg);
    release_command_queue(cq_main);
    release_command_queue(cq_comms);
    release_context(ctx);

    Ok(RunOutcome {
        wall,
        total_bytes: (8 * n * cfg.iters) as u64,
        prof_summary: None,
        prof_export: None,
        raw_prof,
        sample,
    })
}

/// Expected value of sample element `i` after the first batch: the init
/// kernel's output (the first batch *is* the seed batch).
pub fn expected_first_batch(i: usize) -> u64 {
    rawcl::simexec::init_seed(i as u32)
}
