//! `ComputeService` — a persistent, thread-safe compute service with
//! request micro-batching.
//!
//! The paper's §5 application is one producer feeding one consumer; this
//! module is the *service* generalisation the ROADMAP's north star asks
//! for: many client threads [`submit`](ComputeService::submit)ting
//! [`WorkloadRequest`]s concurrently to a long-lived dispatcher that
//! owns scheduling, batching and profiling (EngineCL-style — the
//! runtime, not each application, owns the plumbing).
//!
//! ## Architecture
//!
//! ```text
//! clients ──submit()──► bounded queue ──► dispatcher ──► BatchWorkload
//!    ▲     (Semaphore       │              (batch         │ shard-aligned
//!    │      backpressure)   │               window)       ▼ dispatch
//!    └──◄── ResponseHandle ◄┴──────────────────────── work-stealing
//!           (result + Prof slice)                     scheduler, all
//!                                                     backends
//! ```
//!
//! * **Admission control** — the queue is bounded by
//!   [`ServiceOpts::queue_cap`]; [`ComputeService::submit`] blocks for a
//!   slot (backpressure) while [`ComputeService::try_submit`] returns
//!   [`ServiceError::QueueFull`] immediately. Both are gated on the
//!   existing [`Semaphore`] — the same primitive the §5 pipeline uses.
//! * **Priority lanes + per-tenant fairness** — the admission queue is
//!   two lanes: [`Priority::High`] requests overtake
//!   [`Priority::Bulk`] ones at the dispatcher's dequeue point, and
//!   the bulk lane is deficit-round-robin across tenant ids
//!   (connection ids at the serving edge), so one tenant's flood
//!   cannot starve another's trickle. Defaults are bit-transparent:
//!   a plain [`WorkloadRequest`] is `Bulk`, tenant 0, no deadline —
//!   exactly the old FIFO behaviour.
//! * **Deadlines** — a request tagged with
//!   [`WorkloadRequest::deadline`] that is already past due when the
//!   dispatcher dequeues it is shed with
//!   [`ServiceError::DeadlineExceeded`] instead of executed (the
//!   answer would be useless; the capacity goes to requests that can
//!   still meet theirs). The shedding clock is injectable
//!   ([`ServiceOpts::clock`]) so tests drive it deterministically.
//! * **Micro-batching** — the dispatcher coalesces up to
//!   [`ServiceOpts::max_batch`] queued requests of the same workload
//!   kind (same `name()` and iteration count), waiting up to
//!   [`ServiceOpts::batch_window`] for stragglers. The batch becomes one
//!   `BatchWorkload` dispatch across **all** backends; each request
//!   occupies its own member-aligned shard range, so every trait call
//!   delegates with member-local coordinates and the batched bytes are
//!   **bit-identical** to running each request alone — the split back
//!   per request is a pure slice.
//! * **Profiling** — when [`ServiceOpts::profile`] is set, every batch's
//!   cross-backend timeline (via
//!   [`Prof::add_timeline`](crate::ccl::Prof::add_timeline)) is
//!   aggregated service-wide. Each request gets a unique id whose
//!   `svc.req-<id>.` tag rides on its shards' kernel launches, so the
//!   [`BatchProf`] slice on each [`Response`] is **per-request exact**
//!   (only that request's kernel spans), not a whole-batch blur;
//!   transfers and other shared spans stay under the batch's
//!   `svc.batch-<n>.` queues, and [`ComputeService::shutdown`] renders
//!   the whole service profile across both.
//! * **Shutdown drain** — [`ComputeService::shutdown`] stops admission,
//!   drains every already-accepted request (their handles all resolve),
//!   joins the dispatcher and reports. Dropping the service does the
//!   same join. A client that panics mid-flight merely drops its
//!   [`ResponseHandle`]; the service is unaffected.
//! * **Live telemetry** — the dispatcher records into a lock-free
//!   [`ServiceMetrics`] surface (counters, latency histograms, a
//!   trailing window): [`ComputeService::stats`] is a snapshot view
//!   over those counters that never contends with the hot path, and
//!   [`ComputeService::metrics`] hands the whole surface to dashboards
//!   (`cf4rs serve --live`).
//! * **Adaptive control** — with [`ServiceOpts::adaptive_window`] the
//!   straggler wait is sized online (Nagle-style, from observed
//!   inter-arrival gaps: close early when the queue goes idle, stretch
//!   under sustained arrival); with [`ServiceOpts::adaptive_shards`]
//!   batch shards are sized proportionally to each backend's observed
//!   bytes/ns ([`ShardPlanner`]). Neither changes a single output bit
//!   — batching and shard placement are bit-transparent by
//!   construction, and `bench adaptive` cross-validates it.
//!
//! ## Example
//!
//! ```
//! use cf4rs::coordinator::service::{ComputeService, ServiceOpts, WorkloadRequest};
//! use cf4rs::workload::{SaxpyWorkload, Workload};
//!
//! let svc = ComputeService::start_global(ServiceOpts::default());
//! let w = SaxpyWorkload::new(1024, 2.0);
//! let handle = svc.submit(WorkloadRequest::new(w).iters(2)).unwrap();
//! let resp = handle.wait().unwrap();
//! assert_eq!(resp.output, w.reference(2));
//! let report = svc.shutdown();
//! assert_eq!(report.stats.requests, 1);
//! ```

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::backend::plugin::Capabilities;
use crate::backend::{BackendRegistry, CompileSpec};
use crate::ccl::errors::{CclError, CclResult};
use crate::ccl::prof::ProfInfo;
use crate::ccl::selector::FilterChain;
use crate::ccl::Prof;
use crate::rawcl::kernelspec::KernelKind;
use crate::trace;
use crate::workload::{IterPlan, Shard, Workload};

use super::adaptive::{
    plan_proportional_capped, AdaptiveWindow, ServiceMetrics, ShardPlanner,
};
use super::scheduler::{
    plan_chunks, run_sharded_workload_on, shard_footprint_bytes, BackendLoad,
    BufferPool, FaultPolicy, ShardedConfig,
};
use super::sem::Semaphore;

// ---------------------------------------------------------------------------
// Requests, responses, errors
// ---------------------------------------------------------------------------

/// Which admission lane a request rides in.
///
/// `High` requests overtake `Bulk` ones at the dispatcher's dequeue
/// point (strict priority); `Bulk` requests are served deficit
/// round-robin across tenants. The default is `Bulk` so existing
/// callers are bit-transparent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive: dequeued before any bulk request.
    High,
    /// Throughput traffic (the default): deficit-round-robin per
    /// tenant behind the high lane.
    #[default]
    Bulk,
}

impl Priority {
    /// Number of lanes (the length of per-lane metric arrays).
    pub const COUNT: usize = 2;

    /// Dense lane index: `High` = 0, `Bulk` = 1 (indexes the per-lane
    /// arrays on [`ServiceMetrics`]).
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Bulk => 1,
        }
    }

    /// Short human label (`"high"` / `"bulk"`).
    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Bulk => "bulk",
        }
    }
}

/// One unit of work submitted to the service.
pub struct WorkloadRequest {
    /// The computation to run (shared so the batch can hold it too).
    pub workload: Arc<dyn Workload>,
    /// Iterations to run (`None` = the workload's
    /// [`default_iters`](Workload::default_iters)).
    pub iters: Option<usize>,
    /// Admission lane (`None` = [`ServiceOpts::default_priority`],
    /// which defaults to [`Priority::Bulk`] — the old behaviour).
    pub priority: Option<Priority>,
    /// Absolute completion deadline: a request still queued past this
    /// instant is shed with [`ServiceError::DeadlineExceeded`] at the
    /// dispatcher's dequeue point (`None` =
    /// [`ServiceOpts::default_deadline`], which defaults to none).
    pub deadline: Option<Instant>,
    /// Fairness accounting id for the bulk lane's deficit round-robin
    /// (the serving edge uses the connection id). Tenant 0 — the
    /// default — is just another tenant; in-process callers that never
    /// set it all share one FIFO, the old behaviour.
    pub tenant: u64,
    /// Collect a span tree for this request (needs an armed
    /// [`trace`](crate::trace) window; a no-op otherwise). The tree
    /// rides back on [`Response::trace`].
    pub trace: bool,
    /// Correlation id grouping this request's spans with spans an
    /// upstream layer (the serving edge) already opened. `None` — the
    /// default — allocates a fresh id at admission when tracing.
    pub corr: Option<u64>,
}

impl WorkloadRequest {
    pub fn new(workload: impl Workload + 'static) -> Self {
        Self::from_arc(Arc::new(workload))
    }

    pub fn from_arc(workload: Arc<dyn Workload>) -> Self {
        Self {
            workload,
            iters: None,
            priority: None,
            deadline: None,
            tenant: 0,
            trace: false,
            corr: None,
        }
    }

    /// Override the iteration count.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = Some(iters);
        self
    }

    /// Ride the given admission lane.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = Some(priority);
        self
    }

    /// Set an absolute completion deadline.
    pub fn deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set a deadline relative to now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }

    /// Set the fairness tenant id (bulk-lane round-robin key).
    pub fn tenant(mut self, tenant: u64) -> Self {
        self.tenant = tenant;
        self
    }

    /// Request a span tree ([`Response::trace`]) for this request.
    pub fn trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Group this request's spans under an existing correlation id
    /// (implies [`trace`](Self::trace)).
    pub fn corr(mut self, corr: u64) -> Self {
        self.corr = Some(corr);
        self.trace = true;
        self
    }

    fn resolved_iters(&self) -> usize {
        self.iters.unwrap_or_else(|| self.workload.default_iters())
    }
}

/// Why a submit or wait failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// `try_submit`: the admission queue is at capacity — back off.
    QueueFull,
    /// The service no longer accepts requests.
    ShuttingDown,
    /// The request was rejected before execution (empty workload,
    /// zero iterations, ...).
    Invalid(String),
    /// The batch dispatch failed in the scheduler/backend layer.
    Execution(String),
    /// The service dropped the request without answering (dispatcher
    /// died) — a bug guard, not a normal outcome.
    Abandoned,
    /// [`ResponseHandle::wait_timeout`] gave up waiting.
    Timeout,
    /// The request's deadline had already passed when the dispatcher
    /// dequeued it — shed instead of executed (the answer would have
    /// been useless; the capacity goes to requests that can still meet
    /// theirs).
    DeadlineExceeded,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::QueueFull => write!(f, "service admission queue is full"),
            ServiceError::ShuttingDown => write!(f, "service is shutting down"),
            ServiceError::Invalid(m) => write!(f, "invalid request: {m}"),
            ServiceError::Execution(m) => write!(f, "batch execution failed: {m}"),
            ServiceError::Abandoned => write!(f, "request abandoned by the service"),
            ServiceError::Timeout => write!(f, "timed out waiting for the response"),
            ServiceError::DeadlineExceeded => {
                write!(f, "deadline passed before dispatch; request shed")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// Profile slice attached to a [`Response`]: the request's own kernel
/// spans (grouped under its `svc.req-<id>.` queues), rendered with the
/// id of the batch it rode in. Falls back to the whole-batch profile
/// when the request produced no tagged span of its own.
#[derive(Debug)]
pub struct BatchProf {
    pub batch_id: u64,
    pub batch_size: usize,
    /// Fig. 3-style summary of the slice across all backends.
    pub summary: String,
    /// Fig. 5-style export table of the slice.
    pub export: String,
}

/// What one request produced.
#[derive(Debug)]
pub struct Response {
    /// The request's output bytes — bit-identical to an unbatched run.
    pub output: Vec<u8>,
    /// Submit-to-completion latency.
    pub latency: Duration,
    /// Sequence number of the batch this request rode in.
    pub batch_id: u64,
    /// How many requests shared that batch.
    pub batch_size: usize,
    /// The service-unique id assigned to this request at admission —
    /// the `<id>` in the `svc.req-<id>.` profile queues.
    pub req_id: u64,
    /// This request's profile slice (when the service profiles): its
    /// own kernel spans under `svc.req-<id>.` queues.
    pub prof: Option<Arc<BatchProf>>,
    /// The request's span tree (when it was submitted with
    /// [`WorkloadRequest::trace`] inside an armed
    /// [`trace::Tracing`](crate::trace::Tracing) window): every span
    /// sharing the request's correlation id that had completed by
    /// fulfilment — admission, batch-window wait, plan, execution,
    /// scheduler tasks and grafted device events.
    pub trace: Option<Arc<Vec<crate::trace::Span>>>,
}

impl Response {
    /// The request's span tree, assembled — `None` when the request
    /// was not traced (or the trace window was not armed).
    pub fn trace(&self) -> Option<crate::trace::tree::Forest> {
        self.trace.as_ref().map(|s| crate::trace::tree::Forest::build(s.to_vec()))
    }

    /// Decode the output as little-endian u64s.
    pub fn as_u64s(&self) -> Vec<u64> {
        self.output
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// Decode the output as little-endian f32s.
    pub fn as_f32s(&self) -> Vec<f32> {
        self.output
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

/// Completion callback for [`ComputeService::try_submit_with`] — runs
/// on the dispatcher thread, so it must be quick (the serving edge's
/// callbacks just encode a frame and hand it to a writer thread).
pub type ResponseCallback = Box<dyn FnOnce(Result<Response, ServiceError>) + Send>;

/// What one request's completion slot currently holds.
enum SlotState {
    /// Nobody has answered yet; a [`ResponseHandle`] may be waiting.
    Empty,
    /// Callback-mode slot ([`ComputeService::try_submit_with`]): the
    /// first fulfilment consumes the callback instead of parking the
    /// result for a waiting handle.
    Callback(ResponseCallback),
    /// Answered; waiting for the handle to take it.
    Ready(Result<Response, ServiceError>),
    /// Taken by the handle, delivered to a callback, or cancelled.
    Done,
}

struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl Slot {
    fn new(cb: Option<ResponseCallback>) -> Self {
        let state = match cb {
            Some(cb) => SlotState::Callback(cb),
            None => SlotState::Empty,
        };
        Self { state: Mutex::new(state), cv: Condvar::new() }
    }

    /// First writer wins; later fulfilments (e.g. the Abandoned guard
    /// after a normal answer) are no-ops. Callback-mode slots run the
    /// callback (outside the lock) instead of parking the result.
    fn fulfill(&self, r: Result<Response, ServiceError>) {
        let mut st = self.state.lock().unwrap();
        match std::mem::replace(&mut *st, SlotState::Done) {
            SlotState::Empty => {
                *st = SlotState::Ready(r);
                self.cv.notify_all();
            }
            SlotState::Callback(cb) => {
                drop(st);
                cb(r);
            }
            prev @ SlotState::Ready(_) => *st = prev,
            SlotState::Done => {}
        }
    }

    /// Defuse a slot whose request never reached the queue: neither the
    /// callback nor the Abandoned drop-guard must fire when admission
    /// itself failed — the admission error IS the answer.
    fn cancel(&self) {
        *self.state.lock().unwrap() = SlotState::Done;
    }
}

/// The client's handle to a submitted request.
pub struct ResponseHandle {
    slot: Arc<Slot>,
}

impl ResponseHandle {
    /// Block until the service answers.
    pub fn wait(self) -> Result<Response, ServiceError> {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, SlotState::Done) {
                SlotState::Ready(r) => return r,
                other => *st = other,
            }
            st = self.slot.cv.wait(st).unwrap();
        }
    }

    /// Block up to `dur`; [`ServiceError::Timeout`] if the service has
    /// not answered by then.
    pub fn wait_timeout(self, dur: Duration) -> Result<Response, ServiceError> {
        let deadline = Instant::now() + dur;
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, SlotState::Done) {
                SlotState::Ready(r) => return r,
                other => *st = other,
            }
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return Err(ServiceError::Timeout);
            };
            let (guard, _) = self.slot.cv.wait_timeout(st, left).unwrap();
            st = guard;
        }
    }

    /// Has the service answered yet?
    pub fn is_ready(&self) -> bool {
        matches!(*self.slot.state.lock().unwrap(), SlotState::Ready(_))
    }
}

// ---------------------------------------------------------------------------
// Service configuration and stats
// ---------------------------------------------------------------------------

/// Tunables for [`ComputeService::start`].
pub struct ServiceOpts {
    /// Bounded admission-queue capacity (requests accepted but not yet
    /// dispatched). `submit` blocks when full; `try_submit` errors.
    pub queue_cap: usize,
    /// Most requests coalesced into one micro-batch.
    pub max_batch: usize,
    /// How long the dispatcher holds an under-full batch open waiting
    /// for more same-kind requests.
    pub batch_window: Duration,
    /// Scheduler chunking: target chunks per backend within a batch.
    pub chunks_per_backend: usize,
    /// Scheduler chunking: minimum shard size in workload units.
    pub min_chunk: usize,
    /// Profile every batch and aggregate service-wide. Kernel spans get
    /// `svc.req-<id>.`-prefixed queue labels (their request's id);
    /// shared spans (transfers) get the batch's `svc.batch-<n>.`
    /// prefix, so exports attribute every span to its originator.
    pub profile: bool,
    /// Size the straggler wait online ([`AdaptiveWindow`] seeded from
    /// `batch_window`) instead of always waiting the full static
    /// window. Output bits are unaffected.
    pub adaptive_window: bool,
    /// Size batch shards proportionally to each backend's observed
    /// bytes/ns ([`ShardPlanner`]) instead of uniformly. Output bits
    /// are unaffected; shards stay request-aligned.
    pub adaptive_shards: bool,
    /// Device filter selecting the backends batches dispatch to —
    /// resolved **once** at service start into a filtered registry
    /// snapshot (filter chains hold closures and are not cloneable
    /// per batch).
    pub selector: Option<FilterChain>,
    /// Opt-in fault tolerance for batch dispatches
    /// ([`FaultPolicy`]): failed shard tasks are retried and
    /// repeatedly-failing backends quarantined instead of failing the
    /// whole batch. `None` (the default) keeps the scheduler's
    /// fail-fast behavior.
    pub faults: Option<FaultPolicy>,
    /// Lane for requests that don't set one. The default
    /// ([`Priority::Bulk`]) keeps every existing `submit()` caller
    /// bit-transparent: a single-lane FIFO, exactly the old queue.
    pub default_priority: Priority,
    /// Deadline budget applied to requests that don't set one (`None`,
    /// the default = no deadline — nothing is ever shed).
    pub default_deadline: Option<Duration>,
    /// Deficit-round-robin quantum for the bulk lane, in workload
    /// units credited per tenant visit. Larger quanta favour batch
    /// locality; smaller quanta favour fine-grained fairness.
    pub drr_quantum: usize,
    /// Queue slots `try_submit` keeps free for the high lane: a bulk
    /// request is rejected with [`ServiceError::QueueFull`] while free
    /// slots ≤ this reserve, so latency traffic can still be admitted
    /// when bulk traffic has the queue nearly full. 0 (the default)
    /// disables the reserve. Blocking `submit` is unaffected.
    pub high_reserve: usize,
    /// Clock the dispatcher reads for deadline shedding — injectable
    /// so tests drive shedding deterministically with a fake clock.
    /// `None` (the default) uses [`Instant::now`].
    pub clock: Option<ServiceClock>,
}

/// Injectable dispatcher clock — see [`ServiceOpts::clock`].
pub type ServiceClock = Arc<dyn Fn() -> Instant + Send + Sync>;

impl Default for ServiceOpts {
    fn default() -> Self {
        Self {
            queue_cap: 64,
            max_batch: 16,
            batch_window: Duration::from_millis(2),
            chunks_per_backend: 2,
            min_chunk: 1024,
            profile: false,
            adaptive_window: false,
            adaptive_shards: false,
            selector: None,
            faults: None,
            default_priority: Priority::Bulk,
            default_deadline: None,
            drr_quantum: 4096,
            high_reserve: 0,
            clock: None,
        }
    }
}

/// Snapshot of the service's running totals — a view over the
/// lock-free [`ServiceMetrics`] counters, so taking one never contends
/// with the dispatcher hot path.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    /// Requests answered (successfully executed).
    pub requests: usize,
    /// Batches dispatched.
    pub batches: usize,
    /// Requests that shared a batch with at least one other request.
    pub coalesced: usize,
    /// Largest batch dispatched.
    pub max_batch: usize,
    /// Requests answered with an execution error.
    pub errors: usize,
    /// Shard tasks re-dispatched by the fault policy.
    pub retries: usize,
    /// Batches in which at least one backend was quarantined.
    pub quarantine_events: usize,
    /// Requests shed at the dequeue point because their deadline had
    /// already passed (both lanes).
    pub deadline_shed: usize,
}

/// What [`ComputeService::shutdown`] returns.
#[derive(Debug)]
pub struct ServiceReport {
    pub stats: ServiceStats,
    /// Service-wide Fig. 3-style summary across every profiled batch.
    pub prof_summary: Option<String>,
    /// Service-wide Fig. 5-style export table.
    pub prof_export: Option<String>,
}

// ---------------------------------------------------------------------------
// The batch: K same-kind requests as one schedulable workload
// ---------------------------------------------------------------------------

/// K same-kind requests coalesced into one scheduler dispatch.
///
/// Member `m` owns the batch index range `[base[m], base[m+1])`. Every
/// [`Workload`] call maps its (request-aligned) shard to the owning
/// member and delegates with member-local coordinates and a
/// member-local state slice, so each request computes exactly the bytes
/// it would compute alone — the bit-identity contract micro-batching
/// rests on. Shards are planned by [`plan_batch_shards`], which never
/// lets one straddle a request boundary.
struct BatchWorkload {
    members: Vec<Arc<dyn Workload>>,
    /// Cumulative unit offsets; `base[members.len()]` = total units.
    base: Vec<usize>,
    /// Per-member byte lengths of the current global state. Written
    /// between iterations (`init_state`/`next_state`), read by `plan`
    /// during one.
    state_lens: Mutex<Vec<usize>>,
    /// Per-member byte lengths of the last merged output.
    merged_lens: Mutex<Vec<usize>>,
}

impl BatchWorkload {
    fn new(members: Vec<Arc<dyn Workload>>) -> Self {
        let mut base = Vec::with_capacity(members.len() + 1);
        base.push(0usize);
        for m in &members {
            base.push(base.last().unwrap() + m.units());
        }
        let k = members.len();
        Self {
            members,
            base,
            state_lens: Mutex::new(vec![0; k]),
            merged_lens: Mutex::new(vec![0; k]),
        }
    }

    /// The member owning `shard`, and the shard in member coordinates.
    fn member_of(&self, shard: Shard) -> (usize, Shard) {
        let m = self.base.partition_point(|&b| b <= shard.lo) - 1;
        debug_assert!(
            shard.lo + shard.len <= self.base[m + 1],
            "shard {shard:?} straddles a request boundary"
        );
        (m, Shard { lo: shard.lo - self.base[m], len: shard.len })
    }

    fn member_state_slice<'a>(&self, m: usize, state: &'a [u8]) -> &'a [u8] {
        let lens = self.state_lens.lock().unwrap();
        let lo: usize = lens[..m].iter().sum();
        &state[lo..lo + lens[m]]
    }

    /// Split the final merged output back into per-request byte vectors.
    fn split_final(&self, merged: &[u8]) -> Vec<Vec<u8>> {
        let lens = self.merged_lens.lock().unwrap();
        debug_assert_eq!(lens.iter().sum::<usize>(), merged.len());
        let mut out = Vec::with_capacity(lens.len());
        let mut lo = 0usize;
        for &l in lens.iter() {
            out.push(merged[lo..lo + l].to_vec());
            lo += l;
        }
        out
    }
}

impl Workload for BatchWorkload {
    fn name(&self) -> &'static str {
        "service-batch"
    }

    fn units(&self) -> usize {
        *self.base.last().unwrap()
    }

    fn unit_bytes(&self) -> usize {
        self.members.first().map(|m| m.unit_bytes()).unwrap_or(1)
    }

    fn init_state(&self) -> Vec<u8> {
        let mut lens = self.state_lens.lock().unwrap();
        let mut state = Vec::new();
        for (i, m) in self.members.iter().enumerate() {
            let s = m.init_state();
            lens[i] = s.len();
            state.extend_from_slice(&s);
        }
        state
    }

    fn kernels(&self, shard: Shard) -> Vec<CompileSpec> {
        let (m, local) = self.member_of(shard);
        self.members[m].kernels(local)
    }

    fn plan(&self, shard: Shard, iter: usize, state: &[u8]) -> IterPlan {
        let (m, local) = self.member_of(shard);
        self.members[m].plan(local, iter, self.member_state_slice(m, state))
    }

    fn global_dims(&self, shard: Shard, iter: usize) -> Vec<usize> {
        let (m, local) = self.member_of(shard);
        self.members[m].global_dims(local, iter)
    }

    fn merge(&self, shards: &[Shard], outputs: &[Vec<u8>]) -> Vec<u8> {
        let mut lens = self.merged_lens.lock().unwrap();
        let mut merged = Vec::new();
        let mut i = 0usize;
        for (m, member) in self.members.iter().enumerate() {
            let start = i;
            let mut local = Vec::new();
            while i < shards.len() {
                let (mi, ls) = self.member_of(shards[i]);
                if mi != m {
                    break;
                }
                local.push(ls);
                i += 1;
            }
            let part = member.merge(&local, &outputs[start..i]);
            lens[m] = part.len();
            merged.extend_from_slice(&part);
        }
        debug_assert_eq!(i, shards.len(), "every shard must belong to a member");
        merged
    }

    fn next_state(&self, prev: Vec<u8>, merged: Vec<u8>) -> Vec<u8> {
        let mut state_lens = self.state_lens.lock().unwrap();
        let merged_lens = self.merged_lens.lock().unwrap();
        let mut next = Vec::with_capacity(prev.len().max(merged.len()));
        let (mut plo, mut mlo) = (0usize, 0usize);
        for (m, member) in self.members.iter().enumerate() {
            let p = prev[plo..plo + state_lens[m]].to_vec();
            let g = merged[mlo..mlo + merged_lens[m]].to_vec();
            plo += state_lens[m];
            mlo += merged_lens[m];
            let ns = member.next_state(p, g);
            state_lens[m] = ns.len();
            next.extend_from_slice(&ns);
        }
        next
    }

    fn reference(&self, iters: usize) -> Vec<u8> {
        let mut out = Vec::new();
        for m in &self.members {
            out.extend_from_slice(&m.reference(iters));
        }
        out
    }
}

/// Request-aligned shard plan for a batch: chunk each member
/// independently toward `target_chunks` total, so no shard ever
/// straddles two requests and small requests stay whole (one launch).
fn plan_batch_shards(
    members: &[Arc<dyn Workload>],
    target_chunks: usize,
    min_chunk: usize,
) -> Vec<Shard> {
    let total: usize = members.iter().map(|m| m.units()).sum();
    let ideal = total.div_ceil(target_chunks.max(1)).max(min_chunk.max(1));
    let mut shards = Vec::new();
    let mut base = 0usize;
    for m in members {
        let u = m.units();
        let count = u.div_ceil(ideal).max(1);
        for (lo, len) in plan_chunks(u, count, 1) {
            shards.push(Shard { lo: base + lo, len });
        }
        base += u;
    }
    shards
}

/// What [`run_batch`] produced.
#[derive(Debug)]
pub struct BatchOutcome {
    /// Per-request output bytes, in request order — each bit-identical
    /// to that request's unbatched execution.
    pub outputs: Vec<Vec<u8>>,
    pub wall: Duration,
    pub num_chunks: usize,
    /// Per-backend load (tasks, steals, busy time, produced bytes) —
    /// the observation the adaptive shard planner feeds on.
    pub per_backend: Vec<BackendLoad>,
    /// Shard tasks re-dispatched by the fault policy (0 without one).
    pub retries: u64,
    /// Backends quarantined during this batch.
    pub quarantined: Vec<String>,
    pub prof_summary: Option<String>,
    pub prof_export: Option<String>,
    pub prof_infos: Option<Vec<ProfInfo>>,
}

/// Execute one micro-batch synchronously — the dispatcher's execution
/// path, exposed so the harness and tests can cross-validate batching
/// deterministically. All requests must resolve to the same iteration
/// count (the dispatcher's batch key guarantees this; callers here must
/// uphold it).
pub fn run_batch(
    registry: &BackendRegistry,
    requests: &[WorkloadRequest],
    opts: &ServiceOpts,
) -> CclResult<BatchOutcome> {
    if requests.is_empty() {
        return Err(CclError::framework("run_batch needs at least one request"));
    }
    let iters = requests[0].resolved_iters();
    for r in requests {
        if r.workload.units() == 0 {
            return Err(CclError::framework("batched workload has zero units"));
        }
        if r.resolved_iters() != iters {
            return Err(CclError::framework(
                "all requests in a batch must share the iteration count",
            ));
        }
    }
    let members: Vec<Arc<dyn Workload>> =
        requests.iter().map(|r| r.workload.clone()).collect();
    match &opts.selector {
        Some(chain) => {
            let sub = BackendRegistry::new();
            for (b, caps) in registry.select_entries(chain) {
                sub.register_with_caps(b, caps);
            }
            run_members(&sub, members, iters, opts, opts.profile, None, None, None, None)
        }
        None => {
            run_members(registry, members, iters, opts, opts.profile, None, None, None, None)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_members(
    registry: &BackendRegistry,
    members: Vec<Arc<dyn Workload>>,
    iters: usize,
    opts: &ServiceOpts,
    profile: bool,
    queue_tag: Option<String>,
    member_tags: Option<Vec<String>>,
    plan: Option<(Vec<Shard>, Vec<usize>)>,
    pool: Option<Arc<BufferPool>>,
) -> CclResult<BatchOutcome> {
    let nb = registry.len().max(1);
    let mut cfg = ShardedConfig::new(BatchWorkload::new(members), iters);
    match plan {
        Some((shards, homes)) => {
            cfg.shard_plan = Some(shards);
            cfg.shard_homes = Some(homes);
        }
        None => {
            cfg.shard_plan = Some(plan_batch_shards(
                &cfg.workload.members,
                nb * opts.chunks_per_backend.max(1),
                opts.min_chunk,
            ));
        }
    }
    if let Some(tags) = member_tags {
        // Label every shard with its owning member's tag (shards are
        // request-aligned, so the mapping is unambiguous): the shard's
        // kernel spans then profile under that request's queues.
        let shard_plan = cfg.shard_plan.as_ref().expect("batch always plans shards");
        cfg.shard_tags = Some(
            shard_plan
                .iter()
                .map(|&s| tags[cfg.workload.member_of(s).0].clone())
                .collect(),
        );
    }
    cfg.profile = profile;
    cfg.queue_tag = queue_tag;
    cfg.faults = opts.faults;
    cfg.buffer_pool = pool;
    let out = run_sharded_workload_on(registry, &cfg)?;
    let outputs = cfg.workload.split_final(&out.final_output);
    Ok(BatchOutcome {
        outputs,
        wall: out.wall,
        num_chunks: out.num_chunks,
        per_backend: out.per_backend,
        retries: out.retries,
        quarantined: out.quarantined,
        prof_summary: out.prof_summary,
        prof_export: out.prof_export,
        prof_infos: out.prof_infos,
    })
}

/// Throughput-proportional, request-aligned shard plan for a batch:
/// each member is apportioned across the backends by their observed
/// byte/ns shares (unknown backends get their capability cost hint,
/// or the mean), so no shard ever straddles two requests and fast
/// backends start with more work. Backends whose capabilities lack
/// the batch's kernel families are skipped — in registry order, the
/// same filter the scheduler applies, so the homes computed here
/// index the backend list the engine actually dispatches to. A
/// backend advertising a memory limit is capped at the units whose
/// device footprint fits it ([`plan_proportional_capped`]). `None`
/// until the planner has at least one speed (observed or primed).
fn plan_members_proportional(
    registry: &BackendRegistry,
    members: &[Arc<dyn Workload>],
    min_chunk: usize,
    planner: &ShardPlanner,
) -> Option<(Vec<Shard>, Vec<usize>)> {
    // Batches are same-kind, so member 0's probe shard names every
    // member's kernel families (exactly the engine's own probe).
    let required: BTreeSet<KernelKind> = members
        .first()?
        .kernels(Shard { lo: 0, len: 1 })
        .iter()
        .map(|s| s.kind)
        .collect();
    let capable: Vec<(Arc<dyn crate::backend::Backend>, Capabilities)> = registry
        .entries()
        .into_iter()
        .filter(|(_, c)| c.missing(&required).is_empty())
        .collect();
    if capable.is_empty() {
        return None; // let the engine surface the typed CapabilityError
    }
    let names: Vec<String> = capable.iter().map(|(b, _)| b.name()).collect();
    let shares = planner.shares(&names)?;
    let mut shards = Vec::new();
    let mut homes = Vec::new();
    let mut base = 0usize;
    for m in members {
        let u = m.units();
        // Peak device bytes one unit of this member costs — the
        // denominator turning a byte budget into a unit cap.
        let per_unit = shard_footprint_bytes(m.as_ref(), u).div_ceil(u.max(1)).max(1);
        let caps_units: Vec<Option<usize>> = capable
            .iter()
            .map(|(_, c)| c.mem_limit_bytes.map(|lim| lim / per_unit))
            .collect();
        let (s, h) = plan_proportional_capped(u, &shares, min_chunk, &caps_units);
        for (shard, home) in s.iter().zip(&h) {
            shards.push(Shard { lo: base + shard.lo, len: shard.len });
            homes.push(*home);
        }
        base += u;
    }
    Some((shards, homes))
}

// ---------------------------------------------------------------------------
// The service proper
// ---------------------------------------------------------------------------

/// Which registry the dispatcher executes against.
enum Registry {
    Global,
    Owned(Arc<BackendRegistry>),
}

impl Registry {
    fn get(&self) -> &BackendRegistry {
        match self {
            Registry::Global => BackendRegistry::global(),
            Registry::Owned(r) => r,
        }
    }
}

/// An accepted request waiting for (or undergoing) dispatch.
struct Pending {
    workload: Arc<dyn Workload>,
    iters: usize,
    slot: Arc<Slot>,
    submitted: Instant,
    /// Service-unique id assigned at admission; tags the request's
    /// shards (`svc.req-<id>.`) so its profile slice is exact.
    req_id: u64,
    /// Resolved admission lane.
    priority: Priority,
    /// Resolved absolute deadline (None = never shed).
    deadline: Option<Instant>,
    /// Bulk-lane fairness key.
    tenant: u64,
    /// Cached [`Workload::units`] — the DRR cost of dequeuing this
    /// request.
    units: usize,
    /// Trace correlation id (`Some` iff this request is being traced
    /// inside an armed trace window).
    corr: Option<u64>,
    /// Submission timestamp on the trace clock (meaningful only when
    /// `corr` is set; anchors the `svc.request` / `svc.wait` spans).
    t_submit_ns: u64,
}

impl Pending {
    fn fulfill(&self, r: Result<Response, ServiceError>) {
        self.slot.fulfill(r);
    }

    fn key(&self) -> (&'static str, usize) {
        (self.workload.name(), self.iters)
    }
}

impl Drop for Pending {
    /// Bug guard: an accepted request must never vanish silently — if
    /// the dispatcher dies before answering, the client's `wait()`
    /// resolves to [`ServiceError::Abandoned`] instead of hanging.
    fn drop(&mut self) {
        self.slot.fulfill(Err(ServiceError::Abandoned));
    }
}

/// The two-lane admission queue at the dispatcher's dequeue point.
///
/// The high lane is a plain FIFO always served first. The bulk lane is
/// a set of per-tenant FIFOs served deficit round-robin in workload
/// units: each visit credits the front tenant
/// [`ServiceOpts::drr_quantum`] units, and a tenant dequeues only when
/// its accumulated deficit covers the front request's unit cost — so a
/// tenant flooding big requests cannot starve another's trickle of
/// small ones, yet a lone tenant keeps plain FIFO latency (its requests
/// are never held back when no one else is waiting).
struct LaneQueues {
    high: VecDeque<Pending>,
    /// Per-tenant bulk FIFOs; a tenant has an entry here (and in
    /// `deficit`) iff it is in `ring`.
    bulk: BTreeMap<u64, VecDeque<Pending>>,
    /// Round-robin order over active bulk tenants.
    ring: VecDeque<u64>,
    /// Per-tenant DRR deficit, in workload units.
    deficit: BTreeMap<u64, usize>,
    quantum: usize,
    len: usize,
}

impl LaneQueues {
    fn new(quantum: usize) -> Self {
        Self {
            high: VecDeque::new(),
            bulk: BTreeMap::new(),
            ring: VecDeque::new(),
            deficit: BTreeMap::new(),
            quantum: quantum.max(1),
            len: 0,
        }
    }

    fn push(&mut self, p: Pending) {
        self.len += 1;
        match p.priority {
            Priority::High => self.high.push_back(p),
            Priority::Bulk => {
                let t = p.tenant;
                if !self.bulk.contains_key(&t) {
                    self.bulk.insert(t, VecDeque::new());
                    self.deficit.insert(t, 0);
                    self.ring.push_back(t);
                }
                self.bulk.get_mut(&t).expect("tenant queue just ensured").push_back(p);
            }
        }
    }

    /// Dequeue the next request under the lane discipline: high lane
    /// first, then DRR over bulk tenants.
    fn pop_next(&mut self) -> Option<Pending> {
        if let Some(p) = self.high.pop_front() {
            self.len -= 1;
            return Some(p);
        }
        // DRR: every rotation credits one tenant a quantum, so some
        // tenant's deficit eventually covers its front cost and the
        // loop terminates.
        while let Some(&t) = self.ring.front() {
            let q = self.bulk.get_mut(&t).expect("ring tenants have a queue");
            let Some(front) = q.front() else {
                self.retire(t);
                continue;
            };
            let cost = front.units.max(1);
            let d = self.deficit.get_mut(&t).expect("ring tenants have a deficit");
            // A lone tenant skips the deficit dance — round-robin with
            // one participant is FIFO, and holding its requests back
            // would only add latency.
            if *d >= cost || self.ring.len() == 1 {
                *d = d.saturating_sub(cost);
                let p = q.pop_front().expect("front() was Some");
                self.len -= 1;
                if q.is_empty() {
                    self.retire(t);
                }
                return Some(p);
            }
            *d += self.quantum;
            self.ring.rotate_left(1);
        }
        None
    }

    /// Remove a queued same-kind request for batch collection — high
    /// lane first, then bulk tenants in ring order (their deficit is
    /// not charged: riding an already-paid-for batch is free, which is
    /// exactly why coalescing is worth it).
    fn take_key(&mut self, key: (&'static str, usize)) -> Option<Pending> {
        if let Some(pos) = self.high.iter().position(|p| p.key() == key) {
            self.len -= 1;
            return self.high.remove(pos);
        }
        for i in 0..self.ring.len() {
            let t = self.ring[i];
            let q = self.bulk.get_mut(&t).expect("ring tenants have a queue");
            if let Some(pos) = q.iter().position(|p| p.key() == key) {
                let p = q.remove(pos);
                self.len -= 1;
                if q.is_empty() {
                    self.retire(t);
                }
                return p;
            }
        }
        None
    }

    /// Drop a drained tenant from the rotation; its deficit resets (a
    /// returning tenant starts from zero credit like everyone else).
    fn retire(&mut self, t: u64) {
        self.bulk.remove(&t);
        self.deficit.remove(&t);
        if let Some(pos) = self.ring.iter().position(|&x| x == t) {
            self.ring.remove(pos);
        }
    }
}

struct ServiceShared {
    queue: Mutex<LaneQueues>,
    /// Posted once per enqueued request (plus once at shutdown).
    ready: Semaphore,
    /// Admission permits — one per free queue slot.
    slots: Semaphore,
    stopping: AtomicBool,
    /// Next request id (monotonic, service-unique).
    next_req_id: AtomicU64,
    opts: ServiceOpts,
    /// Lock-free telemetry the dispatcher records into; `stats()` and
    /// the live dashboard read it without contending.
    metrics: Arc<ServiceMetrics>,
    /// The Nagle-style window controller (consulted only when
    /// [`ServiceOpts::adaptive_window`] is set).
    window: AdaptiveWindow,
    /// Per-backend throughput EWMAs (drive shard planning only when
    /// [`ServiceOpts::adaptive_shards`] is set, but always observe).
    /// Warm-started at spawn from the registry's capability cost hints.
    planner: ShardPlanner,
    /// Shard output buffers reused across batch dispatches (the
    /// dispatcher's arena — capacity survives from batch to batch).
    pool: Arc<BufferPool>,
    /// Every profiled batch's event records (service-wide aggregation).
    prof_infos: Mutex<Vec<ProfInfo>>,
}

impl ServiceShared {
    /// The dispatcher's notion of now ([`ServiceOpts::clock`] override
    /// for tests, else the real clock).
    fn now(&self) -> Instant {
        match &self.opts.clock {
            Some(c) => c(),
            None => Instant::now(),
        }
    }

    fn expired(&self, p: &Pending, now: Instant) -> bool {
        p.deadline.is_some_and(|d| now > d)
    }

    /// Answer a dequeued-but-expired request with the typed shed error
    /// and record it against its lane.
    fn shed_deadline(&self, p: &Pending) {
        self.metrics.shed_deadline[p.priority.index()].inc();
        if let Some(corr) = p.corr {
            trace::complete(
                "svc.request",
                "svc",
                Some(corr),
                None,
                p.t_submit_ns,
                trace::now_ns(),
                vec![
                    ("req", trace::Tag::from(p.req_id)),
                    ("shed", trace::Tag::from(true)),
                ],
            );
        }
        p.fulfill(Err(ServiceError::DeadlineExceeded));
    }
}

/// A persistent, thread-safe compute service — see the [module
/// docs](self).
pub struct ComputeService {
    shared: Arc<ServiceShared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl ComputeService {
    /// Start a service executing on an explicit backend registry.
    pub fn start(registry: Arc<BackendRegistry>, opts: ServiceOpts) -> Self {
        Self::spawn(Registry::Owned(registry), opts)
    }

    /// Start a service on the process-wide registry.
    pub fn start_global(opts: ServiceOpts) -> Self {
        Self::spawn(Registry::Global, opts)
    }

    fn spawn(registry: Registry, mut opts: ServiceOpts) -> Self {
        // Resolve the device selector once: the dispatcher executes
        // against a filtered registry snapshot for the service lifetime.
        let registry = match opts.selector.take() {
            Some(chain) => {
                let sub = BackendRegistry::new();
                for b in registry.get().select(&chain) {
                    sub.register(b);
                }
                Registry::Owned(Arc::new(sub))
            }
            None => registry,
        };
        let metrics = Arc::new(ServiceMetrics::new());
        let window = AdaptiveWindow::from_static(opts.batch_window);
        metrics.window_ns.set(window.window_ns() as i64);
        // Warm-start the shard planner from the registry's capability
        // cost hints: the very first proportional plan already skews
        // toward the backends their plugins declared fast, instead of
        // starting uniform and discovering the zoo's skew by stealing.
        let planner = ShardPlanner::new();
        for (b, caps) in registry.get().entries() {
            if let Some(hint) = caps.cost_hint_bytes_per_ns {
                planner.prime(&b.name(), hint);
            }
        }
        let queue = LaneQueues::new(opts.drr_quantum);
        let shared = Arc::new(ServiceShared {
            queue: Mutex::new(queue),
            ready: Semaphore::new(0),
            slots: Semaphore::new(opts.queue_cap.max(1)),
            stopping: AtomicBool::new(false),
            next_req_id: AtomicU64::new(1),
            opts,
            metrics,
            window,
            planner,
            pool: Arc::new(BufferPool::new()),
            prof_infos: Mutex::new(Vec::new()),
        });
        let sh = shared.clone();
        let dispatcher = std::thread::Builder::new()
            .name("cf4rs-service".into())
            .spawn(move || dispatcher_loop(registry, sh))
            .expect("spawn service dispatcher");
        Self { shared, dispatcher: Some(dispatcher) }
    }

    /// Submit a request, blocking while the admission queue is full
    /// (backpressure).
    pub fn submit(&self, req: WorkloadRequest) -> Result<ResponseHandle, ServiceError> {
        self.admit(req, true, None).map(|(slot, _)| ResponseHandle { slot })
    }

    /// Submit without blocking; [`ServiceError::QueueFull`] when the
    /// admission queue is at capacity.
    pub fn try_submit(
        &self,
        req: WorkloadRequest,
    ) -> Result<ResponseHandle, ServiceError> {
        self.admit(req, false, None).map(|(slot, _)| ResponseHandle { slot })
    }

    /// Submit without blocking, delivering the response to `cb` on the
    /// dispatcher thread instead of through a handle — the serving
    /// edge's path: thousands of in-flight requests with no parked
    /// waiter threads. Returns the admitted request's service id. On
    /// admission failure the callback is dropped unfired — the
    /// returned error IS the answer, and the caller replies itself.
    pub fn try_submit_with(
        &self,
        req: WorkloadRequest,
        cb: ResponseCallback,
    ) -> Result<u64, ServiceError> {
        self.admit(req, false, Some(cb)).map(|(_, req_id)| req_id)
    }

    fn admit(
        &self,
        req: WorkloadRequest,
        block: bool,
        cb: Option<ResponseCallback>,
    ) -> Result<(Arc<Slot>, u64), ServiceError> {
        let iters = req.resolved_iters();
        if req.workload.units() == 0 {
            return Err(ServiceError::Invalid("workload has zero units".into()));
        }
        if iters == 0 {
            return Err(ServiceError::Invalid("zero iterations".into()));
        }
        if self.shared.stopping.load(Ordering::SeqCst) {
            return Err(ServiceError::ShuttingDown);
        }
        let priority = req.priority.unwrap_or(self.shared.opts.default_priority);
        if block {
            self.shared.slots.wait();
        } else {
            // The high-reserve check is advisory (the count is a racy
            // snapshot), which is fine: it only has to bias rejection
            // toward bulk traffic, not enforce an exact floor.
            if priority == Priority::Bulk
                && self.shared.opts.high_reserve > 0
                && self.shared.slots.available() <= self.shared.opts.high_reserve
            {
                return Err(ServiceError::QueueFull);
            }
            if !self.shared.slots.try_wait() {
                return Err(ServiceError::QueueFull);
            }
        }
        let deadline = req
            .deadline
            .or_else(|| self.shared.opts.default_deadline.map(|d| self.shared.now() + d));
        let units = req.workload.units();
        let slot = Arc::new(Slot::new(cb));
        let req_id = self.shared.next_req_id.fetch_add(1, Ordering::SeqCst);
        // Tracing: resolve the correlation id here (adopting an
        // upstream one when the edge opened the trace) and stamp the
        // submit time — the anchor for the request's wait span. When
        // the sink is disarmed this is one relaxed load.
        let (corr, t_submit_ns) = if (req.trace || req.corr.is_some()) && trace::enabled()
        {
            let corr = req.corr.unwrap_or_else(trace::new_corr);
            let t0 = trace::now_ns();
            trace::complete(
                "svc.admit",
                "svc",
                Some(corr),
                None,
                t0,
                t0,
                vec![
                    ("req", trace::Tag::from(req_id)),
                    ("lane", trace::Tag::from(priority.label())),
                    ("tenant", trace::Tag::from(req.tenant)),
                ],
            );
            (Some(corr), t0)
        } else {
            (None, 0)
        };
        let pending = Pending {
            workload: req.workload,
            iters,
            slot: slot.clone(),
            submitted: Instant::now(),
            req_id,
            priority,
            deadline,
            tenant: req.tenant,
            units,
            corr,
            t_submit_ns,
        };
        {
            // Re-check shutdown *inside* the queue critical section:
            // the dispatcher's drain-mode exit pops this queue under the
            // same lock after observing `stopping`, so a push that wins
            // the lock race is guaranteed to be seen by the drain, and a
            // push that loses it is guaranteed to see `stopping` here —
            // no accepted request can slip past the drain un-answered.
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.stopping.load(Ordering::SeqCst) {
                drop(q);
                self.shared.slots.post();
                // The error return is this request's answer; defuse the
                // slot so neither the callback nor the Abandoned guard
                // fires when `pending` drops here.
                pending.slot.cancel();
                return Err(ServiceError::ShuttingDown);
            }
            q.push(pending);
            // Inside the critical section, so the dispatcher (which
            // decrements under the same lock) can never observe the
            // pop before the push and drive the gauge negative.
            self.shared.metrics.submitted.inc();
            self.shared.metrics.queue_depth.add(1);
        }
        self.shared.ready.post();
        Ok((slot, req_id))
    }

    /// Snapshot of the running totals — a read over the lock-free
    /// [`ServiceMetrics`] counters (never blocks the dispatcher).
    pub fn stats(&self) -> ServiceStats {
        let m = &self.shared.metrics;
        ServiceStats {
            requests: m.answered.get() as usize,
            batches: m.batches.get() as usize,
            coalesced: m.coalesced.get() as usize,
            max_batch: m.max_batch.get() as usize,
            errors: m.errors.get() as usize,
            retries: m.retries.get() as usize,
            quarantine_events: m.quarantine_events.get() as usize,
            deadline_shed: m.shed_deadline.iter().map(|c| c.get() as usize).sum(),
        }
    }

    /// The service's live metrics surface (latency histograms,
    /// trailing-window rates, queue depth, current batch window,
    /// per-backend byte shares) — what `serve --live` renders.
    pub fn metrics(&self) -> Arc<ServiceMetrics> {
        self.shared.metrics.clone()
    }

    /// Stop accepting new requests (idempotent); already-accepted
    /// requests still drain in the background. [`shutdown`] implies
    /// this.
    ///
    /// [`shutdown`]: ComputeService::shutdown
    pub fn initiate_shutdown(&self) {
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.ready.post();
    }

    /// Stop accepting requests, drain every accepted one (their handles
    /// all resolve), join the dispatcher and report.
    pub fn shutdown(mut self) -> ServiceReport {
        self.initiate_shutdown();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        let stats = self.stats();
        // Entries in `Prof::add_timeline`'s shape, grouped per queue.
        type Timeline = Vec<(String, (u64, u64, u64, u64))>;
        let infos = std::mem::take(&mut *self.shared.prof_infos.lock().unwrap());
        let (prof_summary, prof_export) = if infos.is_empty() {
            (None, None)
        } else {
            let mut by_queue: BTreeMap<String, Timeline> = BTreeMap::new();
            for i in infos {
                by_queue
                    .entry(i.queue)
                    .or_default()
                    .push((i.name, (i.t_queued, i.t_submit, i.t_start, i.t_end)));
            }
            let mut prof = Prof::new();
            for (q, entries) in by_queue {
                prof.add_timeline(q, entries);
            }
            match prof.calc() {
                Ok(()) => (Some(prof.summary_default()), prof.export_string().ok()),
                Err(_) => (None, None),
            }
        };
        ServiceReport { stats, prof_summary, prof_export }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        if let Some(h) = self.dispatcher.take() {
            self.shared.stopping.store(true, Ordering::SeqCst);
            self.shared.ready.post();
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn dispatcher_loop(registry: Registry, sh: Arc<ServiceShared>) {
    let mut batch_id = 0u64;
    loop {
        let draining = sh.stopping.load(Ordering::SeqCst);
        if !draining {
            sh.ready.wait();
            if sh.stopping.load(Ordering::SeqCst) {
                // The wake may be the shutdown post; re-enter in drain
                // mode (which no longer consumes permits).
                continue;
            }
        }
        let first = loop {
            let popped = {
                let mut q = sh.queue.lock().unwrap();
                let p = q.pop_next();
                if p.is_some() {
                    sh.metrics.queue_depth.sub(1);
                }
                p
            };
            let Some(p) = popped else { break None };
            sh.slots.post();
            if sh.expired(&p, sh.now()) {
                // Shed at the dequeue point: answer the typed error and
                // keep popping. The extra item consumed here settles
                // against its own `ready` permit; a post still in
                // flight is tolerated (it surfaces as a spurious
                // main-loop wake, which finds the queue empty).
                sh.shed_deadline(&p);
                let _ = sh.ready.try_wait();
                continue;
            }
            break Some(p);
        };
        let Some(first) = first else {
            if draining {
                return;
            }
            // Spurious wake: an item we already batch-collected posted
            // its permit late. Nothing to do.
            continue;
        };
        let batch = collect_batch(&sh, first, draining);
        execute_batch(&registry, &sh, batch, batch_id);
        batch_id += 1;
    }
}

/// Grow a batch around `first`: take queued same-kind requests, waiting
/// up to the batch window for stragglers (skipped in drain mode).
///
/// With [`ServiceOpts::adaptive_window`] the wait is Nagle-style: the
/// deadline re-arms on every straggler (stretch while requests keep
/// arriving, up to the controller's hard maximum) and the window
/// controller learns from what happened — observed inter-arrival gaps
/// shrink or stretch the next wait, and a wait that times out with no
/// straggler at all (`the queue went idle`) halves it.
fn collect_batch(sh: &ServiceShared, first: Pending, draining: bool) -> Vec<Pending> {
    let key = first.key();
    let mut batch = vec![first];
    let adaptive = sh.opts.adaptive_window;
    let window = if adaptive { sh.window.window() } else { sh.opts.batch_window };
    let start = Instant::now();
    let hard_deadline = start + if adaptive { sh.window.max() } else { window };
    let mut deadline = start + window;
    let mut last_arrival = start;
    let mut got_straggler = false;
    // `ready` permits consumed for arrivals that did NOT match the key;
    // returned when the window closes so their wakeups aren't lost.
    let mut borrowed = 0usize;
    while batch.len() < sh.opts.max_batch {
        let taken = {
            let mut q = sh.queue.lock().unwrap();
            let p = q.take_key(key);
            if p.is_some() {
                sh.metrics.queue_depth.sub(1);
            }
            p
        };
        if let Some(p) = taken {
            // Settle the taken item's `ready` permit: prefer one we
            // already borrowed; tolerate the post still being in flight
            // (it then surfaces as a spurious main-loop wake).
            if borrowed > 0 {
                borrowed -= 1;
            } else {
                let _ = sh.ready.try_wait();
            }
            sh.slots.post();
            if sh.expired(&p, sh.now()) {
                // A straggler that already blew its deadline is shed,
                // not batched (and doesn't count as an arrival for the
                // adaptive window — it never rides a batch).
                sh.shed_deadline(&p);
                continue;
            }
            if adaptive {
                let now = Instant::now();
                let gap = now.duration_since(last_arrival).as_nanos() as u64;
                sh.window.observe_gap(gap);
                last_arrival = now;
                got_straggler = true;
                // Re-arm: keep the batch open one (freshly adapted)
                // window past this arrival, bounded by the hard max.
                deadline = (now + sh.window.window()).min(hard_deadline);
            }
            batch.push(p);
            continue;
        }
        if draining || sh.stopping.load(Ordering::SeqCst) {
            break;
        }
        let Some(left) = deadline.checked_duration_since(Instant::now()) else {
            if adaptive && !got_straggler {
                sh.window.observe_idle_close();
            }
            break;
        };
        if !sh.ready.wait_timeout(left) {
            if adaptive && !got_straggler {
                sh.window.observe_idle_close();
            }
            break;
        }
        // Woken by an arrival that may be a different kind: hold the
        // permit while re-scanning so this wait can't spin on its own
        // re-post.
        borrowed += 1;
    }
    for _ in 0..borrowed {
        sh.ready.post();
    }
    sh.metrics.window_ns.set(if adaptive {
        sh.window.window_ns() as i64
    } else {
        sh.opts.batch_window.as_nanos() as i64
    });
    batch
}

fn execute_batch(
    registry: &Registry,
    sh: &ServiceShared,
    batch: Vec<Pending>,
    batch_id: u64,
) {
    let n = batch.len();
    let iters = batch[0].iters;
    let members: Vec<Arc<dyn Workload>> =
        batch.iter().map(|p| p.workload.clone()).collect();
    // Tracing: register every traced member's req→corr mapping before
    // the scheduler runs (its shard tags carry the req id, and the
    // workers resolve it back through the registry), and force
    // per-request profiling on so device events exist to graft even
    // when the service itself is not profiling.
    let traced_any = trace::enabled() && batch.iter().any(|p| p.corr.is_some());
    if traced_any {
        for p in &batch {
            if let Some(corr) = p.corr {
                trace::register_req(p.req_id, corr);
            }
        }
    }
    let profile = sh.opts.profile || traced_any;
    // Stamp the batch id into the profile queue labels (the fallback
    // for untagged spans — transfers) and each request's id onto its
    // own shards, so exported timelines attribute every span to its
    // batch and every kernel span to its exact request.
    let tag = profile.then(|| format!("svc.batch-{batch_id}."));
    let member_tags = profile.then(|| {
        batch.iter().map(|p| format!("svc.req-{}.", p.req_id)).collect::<Vec<_>>()
    });
    let t_plan0 = if traced_any { trace::now_ns() } else { 0 };
    let plan = if sh.opts.adaptive_shards {
        plan_members_proportional(
            registry.get(),
            &members,
            sh.opts.min_chunk,
            &sh.planner,
        )
    } else {
        None
    };
    let t_exec0 = if traced_any { trace::now_ns() } else { 0 };
    if traced_any {
        for p in &batch {
            if let Some(corr) = p.corr {
                // Queueing + batch-window wait, then shard planning —
                // one span each, per traced member, so every request's
                // tree explains its own latency.
                trace::complete(
                    "svc.wait",
                    "svc",
                    Some(corr),
                    None,
                    p.t_submit_ns,
                    t_plan0,
                    vec![("req", trace::Tag::from(p.req_id))],
                );
                trace::complete(
                    "svc.plan",
                    "svc",
                    Some(corr),
                    None,
                    t_plan0,
                    t_exec0,
                    vec![("adaptive", trace::Tag::from(sh.opts.adaptive_shards))],
                );
            }
        }
    }
    let result = run_members(
        registry.get(),
        members,
        iters,
        &sh.opts,
        profile,
        tag,
        member_tags,
        plan,
        Some(sh.pool.clone()),
    );
    let t_exec1 = if traced_any { trace::now_ns() } else { 0 };
    if traced_any {
        for p in &batch {
            if let Some(corr) = p.corr {
                trace::complete(
                    "svc.exec",
                    "svc",
                    Some(corr),
                    None,
                    t_exec0,
                    t_exec1,
                    vec![
                        ("batch", trace::Tag::from(batch_id)),
                        ("batch_size", trace::Tag::from(n)),
                    ],
                );
            }
        }
    }
    match result {
        Ok(mut out) => {
            // Feed the controllers and the metrics surface.
            let mut backend_bytes = Vec::with_capacity(out.per_backend.len());
            for load in &out.per_backend {
                sh.planner.observe(&load.name, load.bytes, load.busy_ns);
                backend_bytes.push((load.name.clone(), load.bytes));
            }
            sh.metrics.add_backend_bytes(&backend_bytes);
            sh.metrics.retries.add(out.retries);
            if !out.quarantined.is_empty() {
                sh.metrics.quarantine_events.inc();
            }
            let infos = out.prof_infos.take();
            // Graft each traced request's device-event slice into its
            // span tree: the `svc.req-<id>.`-prefixed queues are that
            // request's kernel spans, already on the shared clock.
            if traced_any {
                if let Some(infos) = infos.as_ref() {
                    for p in &batch {
                        if let Some(corr) = p.corr {
                            let prefix = format!("svc.req-{}.", p.req_id);
                            let slice: Vec<ProfInfo> = infos
                                .iter()
                                .filter(|i| i.queue.starts_with(&prefix))
                                .cloned()
                                .collect();
                            trace::graft_prof(&slice, Some(corr));
                        }
                    }
                }
            }
            let batch_prof = out.prof_summary.as_ref().map(|s| {
                Arc::new(BatchProf {
                    batch_id,
                    batch_size: n,
                    summary: s.clone(),
                    export: out.prof_export.clone().unwrap_or_default(),
                })
            });
            // Slice the batch profile per request: each request's
            // `svc.req-<id>.` queues render into its own BatchProf, so
            // the Prof a Response carries covers exactly that request's
            // kernel spans. Fall back to the whole-batch profile when a
            // request has no tagged span (should not happen, but a
            // blurry profile beats a missing one).
            let req_profs: Vec<Option<Arc<BatchProf>>> = batch
                .iter()
                .map(|p| {
                    let Some(infos) = infos.as_ref() else {
                        return batch_prof.clone();
                    };
                    let prefix = format!("svc.req-{}.", p.req_id);
                    let mut by_queue: BTreeMap<
                        String,
                        Vec<(String, (u64, u64, u64, u64))>,
                    > = BTreeMap::new();
                    for i in infos.iter().filter(|i| i.queue.starts_with(&prefix)) {
                        by_queue.entry(i.queue.clone()).or_default().push((
                            i.name.clone(),
                            (i.t_queued, i.t_submit, i.t_start, i.t_end),
                        ));
                    }
                    if by_queue.is_empty() {
                        return batch_prof.clone();
                    }
                    let mut prof = Prof::new();
                    for (q, entries) in by_queue {
                        prof.add_timeline(q, entries);
                    }
                    match prof.calc() {
                        Ok(()) => Some(Arc::new(BatchProf {
                            batch_id,
                            batch_size: n,
                            summary: prof.summary_default(),
                            export: prof.export_string().unwrap_or_default(),
                        })),
                        Err(_) => batch_prof.clone(),
                    }
                })
                .collect();
            if let Some(infos) = infos {
                // Service-wide aggregation only when the service itself
                // profiles — a trace-forced profile stays per-request.
                if sh.opts.profile {
                    sh.prof_infos.lock().unwrap().extend(infos);
                }
            }
            sh.metrics.batches.inc();
            if n > 1 {
                sh.metrics.coalesced.add(n as u64);
            }
            sh.metrics.max_batch.set_max(n as i64);
            // Count the whole batch before fulfilling anyone: a client
            // woken by its response must find its batch peers already
            // in `stats()` (the invariant the old batch-atomic
            // `Mutex<ServiceStats>` update provided).
            let latencies: Vec<Duration> =
                batch.iter().map(|p| p.submitted.elapsed()).collect();
            for (p, &latency) in batch.iter().zip(&latencies) {
                sh.metrics.answered.inc();
                sh.metrics.record_latency(latency, p.priority);
            }
            for (i, ((p, bytes), latency)) in
                batch.iter().zip(out.outputs).zip(latencies).enumerate()
            {
                // Close the request's root service span (submit →
                // fulfil) and hand its whole corr group back on the
                // response — assembled lazily by `Response::trace()`.
                let trace_spans = p.corr.filter(|_| trace::enabled()).map(|corr| {
                    trace::complete(
                        "svc.request",
                        "svc",
                        Some(corr),
                        None,
                        p.t_submit_ns,
                        trace::now_ns(),
                        vec![
                            ("req", trace::Tag::from(p.req_id)),
                            ("batch", trace::Tag::from(batch_id)),
                            ("batch_size", trace::Tag::from(n)),
                        ],
                    );
                    trace::unregister_req(p.req_id);
                    Arc::new(trace::collect_corr(corr))
                });
                p.fulfill(Ok(Response {
                    output: bytes,
                    latency,
                    batch_id,
                    batch_size: n,
                    req_id: p.req_id,
                    prof: req_profs[i].clone(),
                    trace: trace_spans,
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            sh.metrics.batches.inc();
            sh.metrics.errors.add(n as u64);
            for p in &batch {
                if let Some(corr) = p.corr {
                    trace::complete(
                        "svc.request",
                        "svc",
                        Some(corr),
                        None,
                        p.t_submit_ns,
                        trace::now_ns(),
                        vec![
                            ("req", trace::Tag::from(p.req_id)),
                            ("error", trace::Tag::from(true)),
                        ],
                    );
                    trace::unregister_req(p.req_id);
                }
                p.fulfill(Err(ServiceError::Execution(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{PrngWorkload, SaxpyWorkload};

    #[test]
    fn batch_shards_never_straddle_members() {
        let members: Vec<Arc<dyn Workload>> = vec![
            Arc::new(SaxpyWorkload::new(100, 2.0)),
            Arc::new(SaxpyWorkload::new(7000, 2.0)),
            Arc::new(SaxpyWorkload::new(3, 2.0)),
        ];
        let shards = plan_batch_shards(&members, 6, 64);
        // Coverage: contiguous from 0 to the total.
        let mut lo = 0usize;
        for s in &shards {
            assert_eq!(s.lo, lo);
            assert!(s.len > 0);
            lo += s.len;
        }
        assert_eq!(lo, 7103);
        // Alignment: each shard inside exactly one member range.
        let bounds = [0usize, 100, 7100, 7103];
        for s in &shards {
            assert!(
                bounds.windows(2).any(|w| w[0] <= s.lo && s.lo + s.len <= w[1]),
                "{s:?} straddles"
            );
        }
        // The big member got split, the small ones stayed whole.
        assert!(shards.len() > 3);
        assert!(shards.iter().any(|s| s.lo == 0 && s.len == 100));
        assert!(shards.iter().any(|s| s.lo == 7100 && s.len == 3));
    }

    #[test]
    fn batch_workload_delegates_bit_identically() {
        // Two PRNG members of different sizes: the batch's reference is
        // the concatenation of each member's own stream (seeded from
        // gid 0 in *member* coordinates — not batch coordinates).
        let a = PrngWorkload::new(512);
        let b = PrngWorkload::new(256);
        let members: Vec<Arc<dyn Workload>> = vec![Arc::new(a), Arc::new(b)];
        let batch = BatchWorkload::new(members);
        let mut expect = a.reference(3);
        expect.extend_from_slice(&b.reference(3));
        assert_eq!(batch.reference(3), expect);
        assert_eq!(batch.units(), 768);
        // Member mapping.
        let (m, local) = batch.member_of(Shard { lo: 600, len: 100 });
        assert_eq!((m, local), (1, Shard { lo: 88, len: 100 }));
    }

    #[test]
    fn run_batch_rejects_mismatched_iters_and_empty() {
        let reg = BackendRegistry::with_default_backends();
        let opts = ServiceOpts::default();
        assert!(run_batch(&reg, &[], &opts).is_err());
        let reqs = vec![
            WorkloadRequest::new(SaxpyWorkload::new(64, 2.0)).iters(1),
            WorkloadRequest::new(SaxpyWorkload::new(64, 2.0)).iters(2),
        ];
        assert!(run_batch(&reg, &reqs, &opts).is_err());
    }
}
