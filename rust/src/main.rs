//! `cf4rs` — command-line entry point.
//!
//! Subcommands:
//! * `devinfo`      — the paper's `ccl_devinfo` utility;
//! * `cclc`         — the paper's `ccl_c` offline compiler/analyzer;
//! * `plot-events`  — the paper's `ccl_plot_events` chart generator;
//! * `rng`          — run the §5 PRNG service (ccl or raw realisation);
//! * `bench`        — regenerate the paper's evaluation (§6): `loc`,
//!   `overhead`, `figure3`, `figure5` — plus the backend comparison
//!   (`backends`) and the workload × path matrix (`workloads`).

use cf4rs::coordinator::{
    run_ccl, run_raw, run_sharded, run_v2, RngConfig, ShardedRngConfig, Sink,
};
use cf4rs::harness;
use cf4rs::utils::{cclc, devinfo, plot_events};

fn usage() -> i32 {
    eprintln!(
        "usage: cf4rs <command> [args]\n\
         commands:\n\
         \x20 devinfo [-a] [-d N] [-c p1,p2] [--list]   query devices\n\
         \x20 cclc build|analyze|link [opts] FILE...    offline kernel tool\n\
         \x20 plot-events FILE.tsv [--svg OUT]          queue utilization chart\n\
         \x20 rng [--raw|--v2|--sharded] [--numrn N] [--iters I] [--device D]\n\
         \x20     [--no-profile] [--summary] [--export FILE] [--stdout]\n\
         \x20     (--v2 runs through the fluent ccl::v2 tier;\n\
         \x20      --sharded dispatches across ALL backends, work-stealing)\n\
         \x20 bench loc|overhead|figure3|figure5|backends|workloads [args]\n\
         \x20     regenerate paper results, backend comparison, and the\n\
         \x20     (workload x path) validation/timing matrix (--quick)"
    );
    2
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        std::process::exit(usage());
    };
    let rest = &args[1..];
    let code = match cmd.as_str() {
        "devinfo" => devinfo::main(rest),
        "cclc" => cclc::main(rest),
        "plot-events" => plot_events::main(rest),
        "rng" => rng_main(rest),
        "bench" => harness::main(rest),
        "-h" | "--help" | "help" => usage(),
        other => {
            eprintln!("unknown command {other:?}");
            usage()
        }
    };
    std::process::exit(code);
}

/// `cf4rs rng`: the §5 service from the command line.
fn rng_main(args: &[String]) -> i32 {
    let mut numrn = 1 << 16;
    let mut iters = 16usize;
    let mut device = 1u32;
    let mut raw = false;
    let mut v2 = false;
    let mut sharded = false;
    let mut profile = true;
    let mut want_summary = false;
    let mut export: Option<String> = None;
    let mut to_stdout = false;

    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut next = |what: &str| -> Result<String, String> {
            it.next().cloned().ok_or(format!("{what} needs a value"))
        };
        let r: Result<(), String> = (|| {
            match a.as_str() {
                "--raw" => raw = true,
                "--v2" => v2 = true,
                "--sharded" => sharded = true,
                "--numrn" | "-n" => numrn = next("--numrn")?.parse().map_err(|e| format!("{e}"))?,
                "--iters" | "-i" => iters = next("--iters")?.parse().map_err(|e| format!("{e}"))?,
                "--device" | "-d" => device = next("--device")?.parse().map_err(|e| format!("{e}"))?,
                "--no-profile" => profile = false,
                "--summary" => want_summary = true,
                "--export" => export = Some(next("--export")?),
                "--stdout" => to_stdout = true,
                other => return Err(format!("unknown rng option {other:?}")),
            }
            Ok(())
        })();
        if let Err(e) = r {
            eprintln!("rng: {e}");
            return 2;
        }
    }

    let mut cfg = RngConfig::new(numrn, iters);
    cfg.device_index = device;
    cfg.profile = profile;
    cfg.sink = if to_stdout {
        Sink::Writer(std::sync::Mutex::new(Box::new(std::io::stdout())))
    } else {
        Sink::Discard
    };

    let implementation = if sharded {
        "sharded (all backends)"
    } else if raw {
        "raw"
    } else if v2 {
        "cf4rs v2 (fluent tier)"
    } else {
        "cf4rs"
    };
    eprintln!(" * Implementation            : {implementation}");
    eprintln!(" * Random numbers / iteration: {numrn}");
    eprintln!(" * Iterations                : {iters}");
    if !sharded {
        eprintln!(" * Device index              : {device}");
    }

    if sharded {
        let mut scfg = ShardedRngConfig::new(numrn, iters);
        scfg.profile = profile;
        scfg.sink = if to_stdout {
            Sink::Writer(std::sync::Mutex::new(Box::new(std::io::stdout())))
        } else {
            Sink::Discard
        };
        match run_sharded(&scfg) {
            Ok(out) => {
                eprintln!(" * Total elapsed time        : {:e}s", out.wall.as_secs_f64());
                eprintln!(" * Stream chunks             : {}", out.num_chunks);
                for l in &out.per_backend {
                    eprintln!(
                        " * {:<28}: {} tasks ({} stolen), busy {:e}s",
                        l.name,
                        l.tasks,
                        l.stolen,
                        l.busy_ns as f64 * 1e-9
                    );
                }
                if want_summary {
                    if let Some(s) = &out.prof_summary {
                        eprintln!("{s}");
                    }
                }
                if let Some(path) = export {
                    if let Some(tsv) = &out.prof_export {
                        if let Err(e) = std::fs::write(&path, tsv) {
                            eprintln!("rng: writing {path}: {e}");
                            return 1;
                        }
                        eprintln!(" * Profile exported to {path}");
                    }
                }
                return 0;
            }
            Err(e) => {
                eprintln!("rng(sharded): {e}");
                return 1;
            }
        }
    }

    if raw {
        match run_raw(&cfg) {
            Ok(out) => {
                eprintln!(" * Total elapsed time        : {:e}s", out.wall.as_secs_f64());
                if let Some((tkinit, tkrng, tcomms)) = out.raw_prof {
                    eprintln!(" * Total time in 'init' kernel       : {:e}s", tkinit as f64 * 1e-9);
                    eprintln!(" * Total time in 'rng' kernel        : {:e}s", tkrng as f64 * 1e-9);
                    eprintln!(" * Total time fetching data from dev : {:e}s", tcomms as f64 * 1e-9);
                }
                0
            }
            Err(e) => {
                eprintln!("rng(raw): {e}");
                1
            }
        }
    } else {
        let (label, result) = if v2 {
            ("v2", run_v2(&cfg))
        } else {
            ("ccl", run_ccl(&cfg))
        };
        match result {
            Ok(out) => {
                eprintln!(" * Total elapsed time        : {:e}s", out.wall.as_secs_f64());
                if want_summary {
                    if let Some(s) = &out.prof_summary {
                        eprintln!("{s}");
                    }
                }
                if let Some(path) = export {
                    if let Some(tsv) = &out.prof_export {
                        if let Err(e) = std::fs::write(&path, tsv) {
                            eprintln!("rng: writing {path}: {e}");
                            return 1;
                        }
                        eprintln!(" * Profile exported to {path}");
                    }
                }
                0
            }
            Err(e) => {
                eprintln!("rng({label}): {e}");
                1
            }
        }
    }
}
