//! `cf4rs` — command-line entry point.
//!
//! Subcommands:
//! * `devinfo`      — the paper's `ccl_devinfo` utility;
//! * `cclc`         — the paper's `ccl_c` offline compiler/analyzer;
//! * `plot-events`  — the paper's `ccl_plot_events` chart generator;
//! * `rng`          — run the §5 PRNG service (ccl or raw realisation);
//! * `serve`        — run the persistent multi-client compute service:
//!   concurrent clients submit a mixed workload stream, the service
//!   micro-batches and dispatches across all backends, every response is
//!   validated bit-for-bit against the host oracle; `--live` prints a
//!   refreshing telemetry dashboard, `--adaptive` turns on the adaptive
//!   batch window and proportional shard planning, `--zoo` serves off
//!   the heterogeneous plugin device zoo (throttled + faulty +
//!   memory-capped devices) with the paranoid fault policy;
//! * `edge`         — run the TCP serving edge in front of the compute
//!   service: a length-prefixed binary protocol with priority lanes,
//!   per-tenant fairness, deadline tagging and SLO-aware overload
//!   control; announces `EDGE LISTENING <addr>` on stdout and serves
//!   until stdin closes (or `--serve-secs` elapses), then drains
//!   gracefully;
//! * `trace`        — replay one workload × path cell with the span
//!   subsystem armed and print the assembled span tree (edge →
//!   service → scheduler → device); `--json` emits Chrome trace-event
//!   JSON loadable in Perfetto/chrome://tracing, `--tsv` the flat
//!   table, `--out FILE` writes the Chrome document;
//! * `lint`         — replay workloads under the command recorder and
//!   run the happens-before static analyzer over the captured streams:
//!   data races, unwaited host reads, uninitialized reads, dependency
//!   cycles, dead writes; `--strict` turns findings into a non-zero
//!   exit, `--json` emits the machine-readable report;
//! * `bench`        — regenerate the paper's evaluation (§6): `loc`,
//!   `overhead`, `figure3`, `figure5` — plus the backend comparison
//!   (`backends`), the workload × path matrix (`workloads`), the
//!   service latency/batching cell (`service`), the adaptive-control
//!   cell (`adaptive`), the native-tier speedup gate (`native`), the
//!   plugin-ABI device-zoo cell (`zoo`), the serving-edge
//!   load-generator cell (`edge`), the static-analysis detector
//!   gate (`lint-graph`) and the tracing overhead/completeness gate
//!   (`trace`).

use cf4rs::coordinator::{
    run_ccl, run_raw, run_sharded, run_v2, RngConfig, ShardedRngConfig, Sink,
};
use cf4rs::harness;
use cf4rs::utils::{cclc, devinfo, plot_events};

fn usage() -> i32 {
    eprintln!(
        "usage: cf4rs <command> [args]\n\
         commands:\n\
         \x20 devinfo [-a] [-d N] [-c p1,p2] [--list]   query devices\n\
         \x20 cclc build|analyze|link [opts] FILE...    offline kernel tool\n\
         \x20 plot-events FILE.tsv [--svg OUT]          queue utilization chart\n\
         \x20 rng [--raw|--v2|--sharded] [--numrn N] [--iters I] [--device D]\n\
         \x20     [--no-profile] [--summary] [--export FILE] [--stdout]\n\
         \x20     (--v2 runs through the fluent ccl::v2 tier;\n\
         \x20      --sharded dispatches across ALL backends, work-stealing)\n\
         \x20 serve [--requests N] [--clients C] [--max-batch B]\n\
         \x20     [--window-us U] [--queue-cap Q] [--no-batch] [--profile]\n\
         \x20     [--live] [--adaptive] [--zoo]\n\
         \x20     persistent compute service: C concurrent clients x N\n\
         \x20     mixed requests each, micro-batched across all backends,\n\
         \x20     p50/p95 latency + req/s, oracle-validated\n\
         \x20     (--live prints the telemetry dashboard while serving;\n\
         \x20      --adaptive sizes the batch window and shard plan online;\n\
         \x20      --zoo serves off the heterogeneous plugin device zoo\n\
         \x20      with fault tolerance + adaptive control forced on)\n\
         \x20 edge [--port N] [--queue-cap Q] [--max-batch B] [--window-us U]\n\
         \x20     [--high-budget-ms H] [--bulk-budget-ms L] [--min-gate-samples S]\n\
         \x20     [--high-reserve R] [--throttle-ns NS] [--serve-secs T]\n\
         \x20     TCP serving edge (binary protocol, priority lanes,\n\
         \x20     per-tenant fairness, deadlines, overload shedding);\n\
         \x20     port 0 = ephemeral, announced as 'EDGE LISTENING addr'\n\
         \x20 trace [--workload prng|saxpy|reduce|stencil|matmul]\n\
         \x20     [--path rawcl|ccl-v1|ccl-v2|sharded|native|service]\n\
         \x20     [--iters I] [--json] [--tsv] [--out FILE] [--quick]\n\
         \x20     replay one cell with tracing armed and print the span\n\
         \x20     tree (default: human tree + completeness; --json emits\n\
         \x20     Chrome trace-event JSON for Perfetto/chrome://tracing)\n\
         \x20 lint [--workload prng|saxpy|reduce|stencil|matmul|all]\n\
         \x20     [--path rawcl|ccl-v1|ccl-v2|sharded|native|all]\n\
         \x20     [--json] [--strict] [--quick]\n\
         \x20     replay workloads under the command recorder and run the\n\
         \x20     happens-before analyzer (races, unwaited host reads,\n\
         \x20     uninitialized reads, cycles, dead writes) over the streams\n\
         \x20 bench loc|overhead|figure3|figure5|backends|workloads|service|\n\
         \x20     adaptive|native|zoo|edge|lint-graph|trace   regenerate\n\
         \x20     paper results, backend comparison, the (workload x path)\n\
         \x20     matrix, the service cell, the adaptive-control cell, the\n\
         \x20     native-vs-interpreter speedup gate, the plugin device-zoo\n\
         \x20     cell, the serving-edge open-loop load-generator cell, the\n\
         \x20     static-analysis detector gate and the tracing\n\
         \x20     overhead/completeness gate (--quick)"
    );
    2
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        std::process::exit(usage());
    };
    let rest = &args[1..];
    let code = match cmd.as_str() {
        "devinfo" => devinfo::main(rest),
        "cclc" => cclc::main(rest),
        "plot-events" => plot_events::main(rest),
        "rng" => rng_main(rest),
        "serve" => serve_main(rest),
        "edge" => edge_main(rest),
        "trace" => harness::trace::trace_main(rest),
        "lint" => harness::lint::lint_main(rest),
        "bench" => harness::main(rest),
        "-h" | "--help" | "help" => usage(),
        other => {
            eprintln!("unknown command {other:?}");
            usage()
        }
    };
    std::process::exit(code);
}

/// `cf4rs serve`: the persistent multi-client compute service.
fn serve_main(args: &[String]) -> i32 {
    use cf4rs::backend::plugin::zoo_registry;
    use cf4rs::backend::BackendRegistry;
    use cf4rs::coordinator::{FaultPolicy, ServiceOpts};
    use cf4rs::harness::service::run_session;
    use std::sync::Arc;
    use std::time::Duration;

    let mut requests = 32usize; // per client
    let mut clients = 4usize;
    let mut max_batch = 16usize;
    let mut window_us = 2000u64;
    let mut queue_cap = 64usize;
    let mut profile = false;
    let mut no_batch = false;
    let mut live = false;
    let mut adaptive = false;
    let mut zoo = false;

    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut next = |what: &str| -> Result<String, String> {
            it.next().cloned().ok_or(format!("{what} needs a value"))
        };
        let r: Result<(), String> = (|| {
            match a.as_str() {
                "--requests" | "-n" => {
                    requests = next("--requests")?.parse().map_err(|e| format!("{e}"))?
                }
                "--clients" | "-c" => {
                    clients = next("--clients")?.parse().map_err(|e| format!("{e}"))?
                }
                "--max-batch" => {
                    max_batch = next("--max-batch")?.parse().map_err(|e| format!("{e}"))?
                }
                "--window-us" => {
                    window_us = next("--window-us")?.parse().map_err(|e| format!("{e}"))?
                }
                "--queue-cap" => {
                    queue_cap = next("--queue-cap")?.parse().map_err(|e| format!("{e}"))?
                }
                "--profile" => profile = true,
                "--no-batch" => no_batch = true,
                "--live" => live = true,
                "--adaptive" => adaptive = true,
                "--zoo" => zoo = true,
                other => return Err(format!("unknown serve option {other:?}")),
            }
            Ok(())
        })();
        if let Err(e) = r {
            eprintln!("serve: {e}");
            return 2;
        }
    }
    if clients == 0 || requests == 0 {
        eprintln!("serve: --clients and --requests must be > 0");
        return 2;
    }
    if no_batch {
        max_batch = 1;
    }
    if zoo {
        // The zoo has deliberately slow, flaky and dying devices:
        // fault tolerance and adaptive planning are the point.
        adaptive = true;
    }

    let opts = ServiceOpts {
        queue_cap,
        max_batch,
        batch_window: Duration::from_micros(window_us),
        profile,
        adaptive_window: adaptive,
        adaptive_shards: adaptive,
        faults: zoo.then(FaultPolicy::paranoid),
        ..ServiceOpts::default()
    };
    eprintln!(" * Clients                   : {clients}");
    eprintln!(" * Requests per client       : {requests}");
    eprintln!(" * Micro-batching            : {}", if no_batch {
        "off".to_string()
    } else {
        format!("up to {max_batch}/batch, {window_us} us window")
    });
    eprintln!(" * Admission queue capacity  : {queue_cap}");
    eprintln!(" * Adaptive control          : {}", if adaptive {
        "window + shard plan (profile-driven)"
    } else {
        "off (static window, uniform shards)"
    });
    eprintln!(" * Backends                  : {}", if zoo {
        "plugin device zoo (paranoid fault policy)"
    } else {
        "default registry"
    });

    let registry =
        Arc::new(if zoo { zoo_registry() } else { BackendRegistry::with_default_backends() });
    let dashboard = live.then(|| Duration::from_millis(250));
    let out = run_session(registry, clients, requests, opts, false, dashboard);

    eprintln!(" * Completed requests        : {}", out.completed);
    eprintln!(" * Wall time                 : {:e}s", out.wall.as_secs_f64());
    eprintln!(" * Throughput                : {:.1} req/s", out.req_per_s());
    eprintln!(" * Latency p50 / p95         : {:.2} ms / {:.2} ms", out.p50_ms(), out.p95_ms());
    eprintln!(
        " * Batches                   : {} ({} requests coalesced, max batch {})",
        out.stats.batches, out.stats.coalesced, out.stats.max_batch
    );
    if zoo {
        eprintln!(
            " * Fault tolerance           : {} retries, {} quarantine events",
            out.stats.retries, out.stats.quarantine_events
        );
    }
    if profile {
        if let Some(s) = &out.report.prof_summary {
            eprintln!("{s}");
        }
    }
    if out.failures > 0 || out.mismatches > 0 {
        eprintln!(
            "serve: FAILED — {} submit/wait failures, {} oracle mismatches",
            out.failures, out.mismatches
        );
        return 1;
    }
    eprintln!(" * All responses validated against the host oracle");
    0
}

/// `cf4rs edge`: the TCP serving edge in front of the compute service.
fn edge_main(args: &[String]) -> i32 {
    use cf4rs::backend::{Backend, BackendRegistry, SimBackend, ThrottledBackend};
    use cf4rs::coordinator::edge::{EdgeOpts, EdgeServer};
    use cf4rs::coordinator::ServiceOpts;
    use cf4rs::rawcl::types::DeviceId;
    use std::io::{BufRead, Write};
    use std::sync::Arc;
    use std::time::Duration;

    let mut port = 0u16;
    let mut queue_cap = 64usize;
    let mut max_batch = 16usize;
    let mut window_us = 2000u64;
    let mut high_budget_ms = 2000u64;
    let mut bulk_budget_ms = 500u64;
    let mut min_gate_samples = 32u64;
    let mut high_reserve = 0usize;
    let mut throttle_ns: Option<u64> = None;
    let mut serve_secs = 0u64; // 0 = serve until stdin closes

    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut next = |what: &str| -> Result<String, String> {
            it.next().cloned().ok_or(format!("{what} needs a value"))
        };
        let r: Result<(), String> = (|| {
            match a.as_str() {
                "--port" | "-p" => {
                    port = next("--port")?.parse().map_err(|e| format!("{e}"))?
                }
                "--queue-cap" => {
                    queue_cap = next("--queue-cap")?.parse().map_err(|e| format!("{e}"))?
                }
                "--max-batch" => {
                    max_batch = next("--max-batch")?.parse().map_err(|e| format!("{e}"))?
                }
                "--window-us" => {
                    window_us = next("--window-us")?.parse().map_err(|e| format!("{e}"))?
                }
                "--high-budget-ms" => {
                    high_budget_ms =
                        next("--high-budget-ms")?.parse().map_err(|e| format!("{e}"))?
                }
                "--bulk-budget-ms" => {
                    bulk_budget_ms =
                        next("--bulk-budget-ms")?.parse().map_err(|e| format!("{e}"))?
                }
                "--min-gate-samples" => {
                    min_gate_samples =
                        next("--min-gate-samples")?.parse().map_err(|e| format!("{e}"))?
                }
                "--high-reserve" => {
                    high_reserve =
                        next("--high-reserve")?.parse().map_err(|e| format!("{e}"))?
                }
                "--throttle-ns" => {
                    throttle_ns =
                        Some(next("--throttle-ns")?.parse().map_err(|e| format!("{e}"))?)
                }
                "--serve-secs" => {
                    serve_secs = next("--serve-secs")?.parse().map_err(|e| format!("{e}"))?
                }
                other => return Err(format!("unknown edge option {other:?}")),
            }
            Ok(())
        })();
        if let Err(e) = r {
            eprintln!("edge: {e}");
            return 2;
        }
    }

    // `--throttle-ns` swaps the default registry for one deterministic
    // throttled sim device — a fixed, small capacity the load generator
    // can saturate on any CI machine.
    let registry = Arc::new(match throttle_ns {
        Some(rate) => {
            let reg = BackendRegistry::new();
            let inner: Arc<dyn Backend> =
                Arc::new(SimBackend::new(DeviceId(1)).expect("sim device 1"));
            reg.register(Arc::new(ThrottledBackend::new(inner, rate)));
            reg
        }
        None => BackendRegistry::with_default_backends(),
    });
    let opts = EdgeOpts {
        service: ServiceOpts {
            queue_cap,
            max_batch,
            batch_window: Duration::from_micros(window_us),
            high_reserve,
            ..ServiceOpts::default()
        },
        registry: Some(registry),
        high_p99_budget: Duration::from_millis(high_budget_ms),
        bulk_p99_budget: Duration::from_millis(bulk_budget_ms),
        min_gate_samples,
        ..EdgeOpts::default()
    };
    let server = match EdgeServer::start(port, opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("edge: bind failed: {e}");
            return 1;
        }
    };
    let metrics = server.metrics();

    // The machine-readable announce line a parent process parses to
    // learn the resolved port. Must be on stdout, must be flushed.
    println!("EDGE LISTENING {}", server.local_addr());
    let _ = std::io::stdout().flush();
    eprintln!(" * Listening on              : {}", server.local_addr());
    eprintln!(" * Admission queue capacity  : {queue_cap}");
    eprintln!(" * Micro-batching            : up to {max_batch}/batch, {window_us} us window");
    eprintln!(" * p99 budgets (high / bulk) : {high_budget_ms} ms / {bulk_budget_ms} ms");
    if let Some(ns) = throttle_ns {
        eprintln!(" * Backend                   : throttled sim ({ns} ns/KiB)");
    }

    if serve_secs > 0 {
        std::thread::sleep(Duration::from_secs(serve_secs));
    } else {
        // Serve until the parent drops our stdin (or a tty user sends
        // EOF) — the subprocess-friendly shutdown signal.
        let stdin = std::io::stdin();
        let mut line = String::new();
        loop {
            line.clear();
            match stdin.lock().read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }

    eprintln!("edge: draining...");
    let report = server.shutdown();
    let shed_overload: u64 = metrics.shed_overload.iter().map(|c| c.get() as u64).sum();
    eprintln!(" * Connections served        : {}", report.connections);
    eprintln!(" * Requests answered         : {}", report.service.stats.requests);
    eprintln!(
        " * Deadline / overload shed  : {} / {}",
        report.service.stats.deadline_shed, shed_overload
    );
    0
}

/// `cf4rs rng`: the §5 service from the command line.
fn rng_main(args: &[String]) -> i32 {
    let mut numrn = 1 << 16;
    let mut iters = 16usize;
    let mut device = 1u32;
    let mut raw = false;
    let mut v2 = false;
    let mut sharded = false;
    let mut profile = true;
    let mut want_summary = false;
    let mut export: Option<String> = None;
    let mut to_stdout = false;

    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut next = |what: &str| -> Result<String, String> {
            it.next().cloned().ok_or(format!("{what} needs a value"))
        };
        let r: Result<(), String> = (|| {
            match a.as_str() {
                "--raw" => raw = true,
                "--v2" => v2 = true,
                "--sharded" => sharded = true,
                "--numrn" | "-n" => numrn = next("--numrn")?.parse().map_err(|e| format!("{e}"))?,
                "--iters" | "-i" => iters = next("--iters")?.parse().map_err(|e| format!("{e}"))?,
                "--device" | "-d" => device = next("--device")?.parse().map_err(|e| format!("{e}"))?,
                "--no-profile" => profile = false,
                "--summary" => want_summary = true,
                "--export" => export = Some(next("--export")?),
                "--stdout" => to_stdout = true,
                other => return Err(format!("unknown rng option {other:?}")),
            }
            Ok(())
        })();
        if let Err(e) = r {
            eprintln!("rng: {e}");
            return 2;
        }
    }

    let mut cfg = RngConfig::new(numrn, iters);
    cfg.device_index = device;
    cfg.profile = profile;
    cfg.sink = if to_stdout {
        Sink::Writer(std::sync::Mutex::new(Box::new(std::io::stdout())))
    } else {
        Sink::Discard
    };

    let implementation = if sharded {
        "sharded (all backends)"
    } else if raw {
        "raw"
    } else if v2 {
        "cf4rs v2 (fluent tier)"
    } else {
        "cf4rs"
    };
    eprintln!(" * Implementation            : {implementation}");
    eprintln!(" * Random numbers / iteration: {numrn}");
    eprintln!(" * Iterations                : {iters}");
    if !sharded {
        eprintln!(" * Device index              : {device}");
    }

    if sharded {
        let mut scfg = ShardedRngConfig::new(numrn, iters);
        scfg.profile = profile;
        scfg.sink = if to_stdout {
            Sink::Writer(std::sync::Mutex::new(Box::new(std::io::stdout())))
        } else {
            Sink::Discard
        };
        match run_sharded(&scfg) {
            Ok(out) => {
                eprintln!(" * Total elapsed time        : {:e}s", out.wall.as_secs_f64());
                eprintln!(" * Stream chunks             : {}", out.num_chunks);
                for l in &out.per_backend {
                    eprintln!(
                        " * {:<28}: {} tasks ({} stolen), busy {:e}s",
                        l.name,
                        l.tasks,
                        l.stolen,
                        l.busy_ns as f64 * 1e-9
                    );
                }
                if want_summary {
                    if let Some(s) = &out.prof_summary {
                        eprintln!("{s}");
                    }
                }
                if let Some(path) = export {
                    if let Some(tsv) = &out.prof_export {
                        if let Err(e) = std::fs::write(&path, tsv) {
                            eprintln!("rng: writing {path}: {e}");
                            return 1;
                        }
                        eprintln!(" * Profile exported to {path}");
                    }
                }
                return 0;
            }
            Err(e) => {
                eprintln!("rng(sharded): {e}");
                return 1;
            }
        }
    }

    if raw {
        match run_raw(&cfg) {
            Ok(out) => {
                eprintln!(" * Total elapsed time        : {:e}s", out.wall.as_secs_f64());
                if let Some((tkinit, tkrng, tcomms)) = out.raw_prof {
                    eprintln!(" * Total time in 'init' kernel       : {:e}s", tkinit as f64 * 1e-9);
                    eprintln!(" * Total time in 'rng' kernel        : {:e}s", tkrng as f64 * 1e-9);
                    eprintln!(" * Total time fetching data from dev : {:e}s", tcomms as f64 * 1e-9);
                }
                0
            }
            Err(e) => {
                eprintln!("rng(raw): {e}");
                1
            }
        }
    } else {
        let (label, result) = if v2 {
            ("v2", run_v2(&cfg))
        } else {
            ("ccl", run_ccl(&cfg))
        };
        match result {
            Ok(out) => {
                eprintln!(" * Total elapsed time        : {:e}s", out.wall.as_secs_f64());
                if want_summary {
                    if let Some(s) = &out.prof_summary {
                        eprintln!("{s}");
                    }
                }
                if let Some(path) = export {
                    if let Some(tsv) = &out.prof_export {
                        if let Err(e) = std::fs::write(&path, tsv) {
                            eprintln!("rng: writing {path}: {e}");
                            return 1;
                        }
                        eprintln!(" * Profile exported to {path}");
                    }
                }
                0
            }
            Err(e) => {
                eprintln!("rng({label}): {e}");
                1
            }
        }
    }
}
