//! Happens-before graph over a recorded [`Stream`].
//!
//! Each queue is an in-order timeline; a command's vector clock is the
//! per-queue high-water mark of everything that happens before it:
//!
//! ```text
//! VC(c) = join( VC(prev cmd on queue(c)),
//!               VC(d) for d in c.deps,
//!               host clock of the enqueuing thread )
//! VC(c)[queue(c)] = position of c in its queue (1-based)
//! ```
//!
//! Host threads carry their own clocks: waiting on an event
//! (`wait_for_events`, a blocking transfer) or draining a queue (`finish`)
//! joins the awaited commands' clocks into the thread clock, and every
//! command the thread enqueues afterwards inherits it — that is how
//! host-mediated synchronisation (compute, wait, read, re-upload) orders
//! commands across queues without an explicit event edge.
//!
//! `a happens-before b  ⟺  VC(b)[queue(a)] ≥ pos(a)` — O(1) per query.
//!
//! Recorded streams are acyclic by construction (an event exists only
//! after its command is enqueued), but synthetic streams can express
//! forward/cyclic waits, so a Kahn pass runs first and reports the set of
//! commands stuck in cycles; their forward dependency edges are ignored in
//! the clock pass (conservative: fewer edges can only add findings).

use super::record::{Cmd, Record, Stream};

pub struct HbGraph {
    /// All commands, indexed by command id.
    pub cmds: Vec<Cmd>,
    /// 1-based position of each command in its queue's timeline.
    pub pos: Vec<u32>,
    /// Vector clock per command (`clocks[c][q]` = positions on queue `q`
    /// known to happen before or at `c`).
    pub clocks: Vec<Vec<u32>>,
    /// Command ids participating in dependency cycles (empty = acyclic).
    pub cycle: Vec<usize>,
}

impl HbGraph {
    /// Does `a` happen before (or equal) `b`?
    pub fn hb(&self, a: usize, b: usize) -> bool {
        if a == b {
            return true;
        }
        self.clocks[b][self.cmds[a].queue] >= self.pos[a]
    }
}

fn join(dst: &mut [u32], src: &[u32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = (*d).max(*s);
    }
}

/// Detect dependency cycles over explicit wait edges + same-queue order.
fn find_cycles(cmds: &[Cmd], n_queues: usize) -> Vec<usize> {
    let n = cmds.len();
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut indeg = vec![0usize; n];
    let mut last_on_queue = vec![usize::MAX; n_queues];
    for c in cmds {
        let prev = last_on_queue[c.queue];
        if prev != usize::MAX {
            succs[prev].push(c.id);
            indeg[c.id] += 1;
        }
        last_on_queue[c.queue] = c.id;
        for &d in &c.deps {
            if d < n && d != c.id {
                succs[d].push(c.id);
                indeg[c.id] += 1;
            }
        }
    }
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut done = 0usize;
    while let Some(i) = ready.pop() {
        done += 1;
        for &j in &succs[i] {
            indeg[j] -= 1;
            if indeg[j] == 0 {
                ready.push(j);
            }
        }
    }
    if done == n {
        Vec::new()
    } else {
        (0..n).filter(|&i| indeg[i] > 0).collect()
    }
}

/// Build the happens-before graph for a stream.
pub fn build(stream: &Stream) -> HbGraph {
    let n_queues = stream.queues.len();
    let cmds: Vec<Cmd> = stream
        .records
        .iter()
        .filter_map(|r| match r {
            Record::Cmd(c) => Some(c.clone()),
            _ => None,
        })
        .collect();
    debug_assert!(cmds.iter().enumerate().all(|(i, c)| c.id == i));
    let cycle = find_cycles(&cmds, n_queues);

    let n = cmds.len();
    let mut pos = vec![0u32; n];
    let mut clocks: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut queue_len = vec![0u32; n_queues];
    let mut last_on_queue = vec![usize::MAX; n_queues];
    // Host clocks, one per interned thread, grown on demand.
    let mut host: Vec<Vec<u32>> = Vec::new();
    let host_clock = |host: &mut Vec<Vec<u32>>, t: u32| -> &mut Vec<u32> {
        let t = t as usize;
        while host.len() <= t {
            host.push(vec![0u32; n_queues]);
        }
        &mut host[t]
    };

    for rec in &stream.records {
        match rec {
            Record::Cmd(c) => {
                let mut vc = host_clock(&mut host, c.thread).clone();
                let prev = last_on_queue[c.queue];
                if prev != usize::MAX {
                    join(&mut vc, &clocks[prev]);
                }
                for &d in &c.deps {
                    // Forward deps (only expressible synthetically) were
                    // reported by the cycle pass; their clocks do not exist
                    // yet, so skip them here.
                    if d < c.id {
                        join(&mut vc, &clocks[d]);
                    }
                }
                queue_len[c.queue] += 1;
                let p = queue_len[c.queue];
                vc[c.queue] = p;
                pos[c.id] = p;
                if c.blocking {
                    join(host_clock(&mut host, c.thread), &vc);
                }
                clocks[c.id] = vc;
                last_on_queue[c.queue] = c.id;
            }
            Record::HostWait { thread, cmds: targets } => {
                for &t in targets {
                    if !clocks.get(t).map(Vec::is_empty).unwrap_or(true) {
                        let tc = clocks[t].clone();
                        join(host_clock(&mut host, *thread), &tc);
                    }
                }
            }
            Record::HostSync { thread, queue } => {
                let last = last_on_queue[*queue];
                if last != usize::MAX {
                    let lc = clocks[last].clone();
                    join(host_clock(&mut host, *thread), &lc);
                }
            }
            Record::BufCreate { .. } | Record::BufRelease { .. } => {}
        }
    }

    HbGraph { cmds, pos, clocks, cycle }
}
