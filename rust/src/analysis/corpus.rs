//! Seeded-bug corpus: synthetic streams that each plant one known hazard
//! class, used by the CI detector gate (`bench lint-graph`, the
//! `lint_corpus` example) and the analyzer's own tests.
//!
//! The gate is two-sided: the clean workload matrix must analyze to zero
//! findings, AND every corpus case must be flagged with its expected rule.
//! A detector that goes quiet (or noisy) fails one side or the other.
//!
//! Cases mirror real mistakes the recorded tiers can make:
//!
//! * `severed-dep-edge` — a cross-queue consumer launched without the
//!   producer in its wait list (what `.independent()` does when the
//!   dependency was real);
//! * `swapped-arg-roles` — a kernel recorded with its read/write sets
//!   transposed, so it "reads" the never-written output buffer;
//! * `missing-host-wait` — a host read-back on another queue with no
//!   event dependency on the producing kernel;
//! * `cyclic-waits` — wait edges forming a cycle (deadlock at runtime,
//!   expressible synthetically via forward deps);
//! * `dead-write` — an uploaded buffer nothing ever reads;
//! * `last-reader-only` — the WAR hazard of a dependency tracker that
//!   remembers only the *most recent* reader: a later writer waits on
//!   that reader alone and races the earlier one (the pre-fix
//!   `ccl::v2::deps` regression class).

use super::lint::Rule;
use super::record::{CmdKind, Stream, StreamBuilder};

/// One corpus entry: a stream seeded with exactly one hazard class and
/// the rule the analyzer must report for it.
pub struct CorpusCase {
    pub name: &'static str,
    pub expect: Rule,
    pub stream: Stream,
}

fn severed_dep_edge() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let q1 = b.queue("Q1");
    let x = b.buffer("X", false);
    let out = b.buffer("out", false);
    b.cmd(q0, CmdKind::Kernel, "PRNG_INIT", &[], &[x], &[]);
    // Consumer on another queue, wait list severed: races the producer.
    let r = b.cmd(q1, CmdKind::Kernel, "SAXPY_KERNEL", &[x], &[out], &[]);
    b.read_back(q1, out, &[r]);
    b.build()
}

fn swapped_arg_roles() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let inp = b.buffer("in", false);
    let out = b.buffer("out", false);
    let w = b.cmd(q0, CmdKind::HostWrite, "WRITE_BUFFER", &[], &[inp], &[]);
    // Roles transposed: the kernel is recorded reading its output buffer
    // (never written) and writing its input.
    b.cmd(q0, CmdKind::Kernel, "SAXPY_KERNEL", &[out], &[inp], &[w]);
    b.build()
}

fn missing_host_wait() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let q1 = b.queue("Q1");
    let x = b.buffer("X", false);
    b.cmd(q0, CmdKind::Kernel, "RNG_KERNEL", &[], &[x], &[]);
    // Blocking read-back on another queue with no dependency on the
    // producing kernel: the host observes half-written bytes.
    b.read_back(q1, x, &[]);
    b.build()
}

fn cyclic_waits() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let q1 = b.queue("Q1");
    // Markers only — no buffer accesses, so the only possible finding is
    // the cycle itself. Command ids are assigned densely from 0, so the
    // first marker's forward dep names the second.
    b.cmd(q0, CmdKind::Marker, "MARKER", &[], &[], &[1]);
    b.cmd(q1, CmdKind::Marker, "MARKER", &[], &[], &[0]);
    b.build()
}

fn dead_write() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let x = b.buffer("X", false);
    b.cmd(q0, CmdKind::HostWrite, "WRITE_BUFFER", &[], &[x], &[]);
    b.release(x);
    b.build()
}

fn last_reader_only() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let q1 = b.queue("Q1");
    let q2 = b.queue("Q2");
    let a = b.buffer("A", false);
    let o1 = b.buffer("out1", false);
    let o2 = b.buffer("out2", false);
    let init = b.cmd(q0, CmdKind::Kernel, "PRNG_INIT", &[], &[a], &[]);
    let r1 = b.cmd(q0, CmdKind::Kernel, "REDUCE_KERNEL", &[a], &[o1], &[init]);
    let r2 = b.cmd(q1, CmdKind::Kernel, "REDUCE_KERNEL", &[a], &[o2], &[init]);
    // The buggy tracker remembered only r2; the in-place step waits on it
    // alone and overwrites A while r1 may still be reading.
    let w = b.cmd(q2, CmdKind::Kernel, "RNG_KERNEL", &[a], &[a], &[r2]);
    b.read_back(q0, o1, &[r1]);
    b.read_back(q1, o2, &[r2]);
    b.read_back(q2, a, &[w]);
    b.build()
}

/// The fixed counterpart of [`last_reader_only`] — writer waits on *both*
/// readers — which must analyze clean. Used by the regression tests to
/// pin the two-sidedness of the WAR rule.
pub fn full_reader_set() -> Stream {
    let mut b = StreamBuilder::new();
    let q0 = b.queue("Q0");
    let q1 = b.queue("Q1");
    let q2 = b.queue("Q2");
    let a = b.buffer("A", false);
    let o1 = b.buffer("out1", false);
    let o2 = b.buffer("out2", false);
    let init = b.cmd(q0, CmdKind::Kernel, "PRNG_INIT", &[], &[a], &[]);
    let r1 = b.cmd(q0, CmdKind::Kernel, "REDUCE_KERNEL", &[a], &[o1], &[init]);
    let r2 = b.cmd(q1, CmdKind::Kernel, "REDUCE_KERNEL", &[a], &[o2], &[init]);
    let w = b.cmd(q2, CmdKind::Kernel, "RNG_KERNEL", &[a], &[a], &[r1, r2]);
    b.read_back(q0, o1, &[r1]);
    b.read_back(q1, o2, &[r2]);
    b.read_back(q2, a, &[w]);
    b.build()
}

/// Every seeded-bug case. The detector gate requires `expect` to appear
/// among the findings of each case's stream — 100%, no partial credit.
pub fn seeded_bugs() -> Vec<CorpusCase> {
    vec![
        CorpusCase {
            name: "severed-dep-edge",
            expect: Rule::DataRace,
            stream: severed_dep_edge(),
        },
        CorpusCase {
            name: "swapped-arg-roles",
            expect: Rule::ReadBeforeWrite,
            stream: swapped_arg_roles(),
        },
        CorpusCase {
            name: "missing-host-wait",
            expect: Rule::UnwaitedHostRead,
            stream: missing_host_wait(),
        },
        CorpusCase {
            name: "cyclic-waits",
            expect: Rule::DependencyCycle,
            stream: cyclic_waits(),
        },
        CorpusCase {
            name: "dead-write",
            expect: Rule::DeadWrite,
            stream: dead_write(),
        },
        CorpusCase {
            name: "last-reader-only",
            expect: Rule::DataRace,
            stream: last_reader_only(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;

    #[test]
    fn every_case_is_flagged_with_its_rule() {
        for case in seeded_bugs() {
            let report = analyze(&case.stream);
            assert!(
                report.findings.iter().any(|f| f.rule == case.expect),
                "{}: expected {} among {:?}",
                case.name,
                case.expect.id(),
                report.findings.iter().map(|f| f.rule.id()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn fixed_reader_set_is_clean() {
        let report = analyze(&full_reader_set());
        assert!(report.is_clean(), "{}", report.render_human());
    }

    #[test]
    fn cyclic_case_reports_only_the_cycle() {
        let report = analyze(&cyclic_waits());
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].rule, Rule::DependencyCycle);
    }
}
