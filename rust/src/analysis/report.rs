//! Rendering for lint findings: human report, machine-readable JSON, and
//! a TSV table.
//!
//! Field escaping is shared with the profiler exporter
//! ([`crate::ccl::prof::export::escape_field`]) so queue/kernel names
//! containing tabs or newlines round-trip through both formats from one
//! implementation. The JSON renderer layers quote-escaping on top of the
//! same helper (`escape_field` handles `\\`, `\t`, `\n`, `\r`, all of
//! which are also valid JSON escapes).

use crate::ccl::prof::export::{escape_field, unescape_field};

use super::lint::{Finding, Severity};

pub const LINT_TSV_HEADER: &str = "rule\tseverity\tbuffer\tqueue\tname\tdetail";

/// JSON string contents via the shared TSV escaper plus quote escaping.
fn json_str(s: &str) -> String {
    escape_field(s).replace('"', "\\\"")
}

/// The result of analyzing one recorded stream.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub n_cmds: usize,
    pub n_queues: usize,
    pub n_buffers: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn errors(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.rule.severity() == Severity::Error)
            .count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.len() - self.errors()
    }

    /// Keep only findings that involve one of the given queues (by dense
    /// queue index). Findings with no command references (none today) are
    /// kept. Used by `Session::check` to scope a shared recording to the
    /// session's own queues.
    pub fn retain_queues(&mut self, queues: &[usize]) {
        self.findings.retain(|f| {
            f.cmds.is_empty() || f.cmds.iter().any(|c| queues.contains(&c.queue))
        });
    }

    /// Human-readable report, one block per finding.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "analyzed {} command(s) on {} queue(s), {} buffer(s): {} finding(s)\n",
            self.n_cmds,
            self.n_queues,
            self.n_buffers,
            self.findings.len()
        ));
        for f in &self.findings {
            out.push_str(&format!(
                "\n[{}] {}\n  {}\n",
                f.rule.severity().label(),
                f.rule.id(),
                f.detail
            ));
            for c in &f.cmds {
                out.push_str(&format!(
                    "  #{} {} `{}` on queue `{}`\n",
                    c.id, c.kind, c.name, c.queue_label
                ));
            }
        }
        out
    }

    /// Machine-readable JSON. `"findings"` is the total count — the CI
    /// gate greps for `"findings": 0` on the clean matrix.
    pub fn to_json(&self, meta: &[(&str, String)]) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"schema\": \"cf4rs-lint/1\",\n");
        for (k, v) in meta {
            out.push_str(&format!("  \"{}\": \"{}\",\n", json_str(k), json_str(v)));
        }
        out.push_str(&format!("  \"commands\": {},\n", self.n_cmds));
        out.push_str(&format!("  \"queues\": {},\n", self.n_queues));
        out.push_str(&format!("  \"buffers\": {},\n", self.n_buffers));
        out.push_str(&format!("  \"errors\": {},\n", self.errors()));
        out.push_str(&format!("  \"warnings\": {},\n", self.warnings()));
        out.push_str(&format!("  \"findings\": {},\n", self.findings.len()));
        out.push_str("  \"items\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"rule\": \"{}\", \"severity\": \"{}\", \"buffer\": \
                 \"{}\", \"detail\": \"{}\", \"cmds\": [",
                f.rule.id(),
                f.rule.severity().label(),
                json_str(f.buffer.as_deref().unwrap_or("")),
                json_str(&f.detail)
            ));
            for (j, c) in f.cmds.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!(
                    "{{\"id\": {}, \"kind\": \"{}\", \"name\": \"{}\", \
                     \"queue\": \"{}\"}}",
                    c.id,
                    json_str(c.kind),
                    json_str(&c.name),
                    json_str(&c.queue_label)
                ));
            }
            out.push_str("]}");
        }
        out.push_str("\n  ]\n}\n");
        out
    }

    /// TSV table, one line per finding (first involved command shown).
    /// Fields are escaped with the shared profiler-export helper so
    /// hostile names stay one line of exactly six columns.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from(LINT_TSV_HEADER);
        out.push('\n');
        for f in &self.findings {
            let (queue, name) = f
                .cmds
                .first()
                .map(|c| (c.queue_label.as_str(), c.name.as_str()))
                .unwrap_or(("", ""));
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\n",
                f.rule.id(),
                f.rule.severity().label(),
                escape_field(f.buffer.as_deref().unwrap_or("")),
                escape_field(queue),
                escape_field(name),
                escape_field(&f.detail)
            ));
        }
        out
    }
}

/// Parse a lint TSV back into its six unescaped string columns per line —
/// the round-trip counterpart of [`Report::to_tsv`], used by the escaping
/// regression tests.
pub fn parse_lint_tsv(text: &str) -> Result<Vec<[String; 6]>, String> {
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h == LINT_TSV_HEADER => {}
        other => return Err(format!("bad lint TSV header: {other:?}")),
    }
    let mut out = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 6 {
            return Err(format!(
                "lint TSV line {}: want 6 columns, got {}",
                ln + 2,
                cols.len()
            ));
        }
        let mut row: [String; 6] = Default::default();
        for (i, c) in cols.iter().enumerate() {
            row[i] =
                unescape_field(c).map_err(|e| format!("line {}: {e}", ln + 2))?;
        }
        out.push(row);
    }
    Ok(out)
}
