//! Typed findings over the happens-before graph.
//!
//! Rule catalog (also documented in the README):
//!
//! | id                  | severity | meaning                                      |
//! |---------------------|----------|----------------------------------------------|
//! | `data-race`         | error    | conflicting accesses with no HB edge         |
//! | `unwaited-host-read`| error    | host read-back racing a writer               |
//! | `read-before-write` | error    | uninitialized buffer read                    |
//! | `dependency-cycle`  | error    | wait edges form a cycle (deadlock)           |
//! | `dead-write`        | warning  | buffer written, never read (or read back)    |
//!
//! The race pass walks each buffer's accesses in record order keeping the
//! *write frontier* (maximal unordered writes) and the reads since: a new
//! access races iff some frontier element is not happens-before it — near
//! linear in practice, exact with respect to the HB relation.

use super::hb::{self, HbGraph};
use super::record::{CmdKind, Record, Stream};
use super::report::Report;

#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Rule {
    DataRace,
    UnwaitedHostRead,
    ReadBeforeWrite,
    DependencyCycle,
    DeadWrite,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::DataRace => "data-race",
            Rule::UnwaitedHostRead => "unwaited-host-read",
            Rule::ReadBeforeWrite => "read-before-write",
            Rule::DependencyCycle => "dependency-cycle",
            Rule::DeadWrite => "dead-write",
        }
    }

    pub fn severity(self) -> Severity {
        match self {
            Rule::DeadWrite => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

/// A command referenced by a finding, with enough context to act on it.
#[derive(Clone, Debug)]
pub struct CmdRef {
    pub id: usize,
    pub queue: usize,
    pub queue_label: String,
    pub name: String,
    pub kind: &'static str,
}

#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    /// Label of the buffer involved, when the rule concerns one.
    pub buffer: Option<String>,
    /// Commands involved, most significant first.
    pub cmds: Vec<CmdRef>,
    pub detail: String,
}

fn cmd_ref(g: &HbGraph, stream: &Stream, id: usize) -> CmdRef {
    let c = &g.cmds[id];
    CmdRef {
        id,
        queue: c.queue,
        queue_label: stream.queues[c.queue].label.clone(),
        name: c.name.clone(),
        kind: c.kind.label(),
    }
}

/// Per-buffer incremental race-detection state.
#[derive(Default)]
struct BufState {
    /// Maximal writes with no HB-later write (the write frontier).
    frontier: Vec<usize>,
    /// Reads since the frontier last advanced past them.
    reads_since: Vec<usize>,
    /// Writes with no read observed after them yet.
    unread_writes: Vec<usize>,
    any_write: bool,
    reported_uninit: bool,
    closed: bool,
}

/// Run every rule over a recorded stream.
pub fn analyze(stream: &Stream) -> Report {
    let g = hb::build(stream);
    let mut findings = Vec::new();

    if !g.cycle.is_empty() {
        let mut cmds: Vec<CmdRef> =
            g.cycle.iter().take(8).map(|&id| cmd_ref(&g, stream, id)).collect();
        cmds.sort_by_key(|c| c.id);
        findings.push(Finding {
            rule: Rule::DependencyCycle,
            buffer: None,
            detail: format!(
                "{} command(s) wait on each other in a cycle; none can run",
                g.cycle.len()
            ),
            cmds,
        });
    }

    let mut bufs: Vec<BufState> = (0..stream.buffers.len()).map(|_| BufState::default()).collect();
    let race = |findings: &mut Vec<Finding>, rule: Rule, buf: usize, a: usize, b: usize| {
        let meta = &stream.buffers[buf];
        let (ra, rb) = (cmd_ref(&g, stream, a), cmd_ref(&g, stream, b));
        findings.push(Finding {
            rule,
            buffer: Some(meta.label.clone()),
            detail: format!(
                "{} `{}` on {} and {} `{}` on {} both touch {} with no \
                 happens-before edge",
                ra.kind, ra.name, ra.queue_label, rb.kind, rb.name,
                rb.queue_label, meta.label
            ),
            cmds: vec![ra, rb],
        });
    };

    let close_buffer = |findings: &mut Vec<Finding>, buf: usize, st: &mut BufState| {
        if st.closed {
            return;
        }
        st.closed = true;
        if !st.unread_writes.is_empty() {
            let last = *st.unread_writes.last().unwrap();
            let meta = &stream.buffers[buf];
            findings.push(Finding {
                rule: Rule::DeadWrite,
                buffer: Some(meta.label.clone()),
                detail: format!(
                    "{} write(s) to {} were never read or read back (last by \
                     `{}`)",
                    st.unread_writes.len(),
                    meta.label,
                    g.cmds[last].name
                ),
                cmds: st
                    .unread_writes
                    .iter()
                    .map(|&id| cmd_ref(&g, stream, id))
                    .collect(),
            });
        }
    };

    for rec in &stream.records {
        match rec {
            Record::Cmd(c) => {
                for &b in &c.reads {
                    let st = &mut bufs[b];
                    if st.closed {
                        continue;
                    }
                    if !st.any_write
                        && !stream.buffers[b].initialized
                        && !st.reported_uninit
                    {
                        st.reported_uninit = true;
                        findings.push(Finding {
                            rule: Rule::ReadBeforeWrite,
                            buffer: Some(stream.buffers[b].label.clone()),
                            detail: format!(
                                "`{}` reads {} before anything wrote it \
                                 (contents undefined)",
                                c.name, stream.buffers[b].label
                            ),
                            cmds: vec![cmd_ref(&g, stream, c.id)],
                        });
                    }
                    let frontier = st.frontier.clone();
                    for w in frontier {
                        if !g.hb(w, c.id) {
                            let rule = if c.kind == CmdKind::HostRead {
                                Rule::UnwaitedHostRead
                            } else {
                                Rule::DataRace
                            };
                            race(&mut findings, rule, b, w, c.id);
                        }
                    }
                    let st = &mut bufs[b];
                    st.reads_since.push(c.id);
                    st.unread_writes.clear();
                }
                for &b in &c.writes {
                    let st = &mut bufs[b];
                    if st.closed {
                        continue;
                    }
                    let (frontier, reads) =
                        (st.frontier.clone(), st.reads_since.clone());
                    for w in frontier {
                        if !g.hb(w, c.id) {
                            race(&mut findings, Rule::DataRace, b, w, c.id);
                        }
                    }
                    for r in reads {
                        if !g.hb(r, c.id) {
                            race(&mut findings, Rule::DataRace, b, r, c.id);
                        }
                    }
                    let st = &mut bufs[b];
                    st.any_write = true;
                    st.frontier.retain(|&w| !g.hb(w, c.id));
                    st.frontier.push(c.id);
                    st.reads_since.retain(|&r| !g.hb(r, c.id));
                    st.unread_writes.push(c.id);
                }
            }
            Record::BufRelease { buf } => {
                close_buffer(&mut findings, *buf, &mut bufs[*buf]);
            }
            _ => {}
        }
    }
    for (b, st) in bufs.iter_mut().enumerate() {
        close_buffer(&mut findings, b, st);
    }

    Report {
        findings,
        n_cmds: stream.n_cmds,
        n_queues: stream.queues.len(),
        n_buffers: stream.buffers.len(),
    }
}
