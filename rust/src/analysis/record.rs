//! Command-stream recorder.
//!
//! A process-global, refcount-free recorder: [`Recording::start`] arms it,
//! dropping the guard disarms it. While armed, the rawcl enqueue paths and
//! the backend dispatch sites append [`Record`]s under a single mutex; when
//! disarmed the only cost at every hook site is one relaxed atomic load.
//!
//! Identity is interned: queues and buffers are keyed by `(space, raw
//! handle)` where the space is `"rawcl"` for the simulated-OpenCL substrate
//! and a per-backend name (`"be:<backend>"`) at the backend tier, so the
//! two tiers' handle values never alias. Buffer handles that are released
//! and re-created get a fresh dense id (generation bump) — reuse of a raw
//! handle value must not merge two unrelated lifetimes. Event handles are
//! resolved to the *producing command* at record time, which gives snapshot
//! semantics under event-handle reuse.
//!
//! Recordings are serialized process-wide (the guard holds a lock), so
//! concurrent tests cannot pollute each other's streams.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::rawcl::types::{EventH, MemH, QueueH};

/// Identity space of the simulated-OpenCL substrate.
pub const RAWCL_SPACE: &str = "rawcl";

/// What a recorded command does, for access classification and reporting.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum CmdKind {
    /// Kernel launch; reads/writes derived from `arg_roles`.
    Kernel,
    /// Device→host transfer (the host observes buffer contents).
    HostRead,
    /// Host→device transfer.
    HostWrite,
    /// Device-side buffer copy (reads src, writes dst).
    Copy,
    /// Device-side fill (writes dst).
    Fill,
    /// Synchronisation-only command (no buffer accesses).
    Marker,
}

impl CmdKind {
    pub fn label(self) -> &'static str {
        match self {
            CmdKind::Kernel => "kernel",
            CmdKind::HostRead => "read",
            CmdKind::HostWrite => "write",
            CmdKind::Copy => "copy",
            CmdKind::Fill => "fill",
            CmdKind::Marker => "marker",
        }
    }
}

/// One recorded device command.
#[derive(Clone, Debug)]
pub struct Cmd {
    /// Dense command index (== position among `Record::Cmd`s).
    pub id: usize,
    /// Interned host thread that enqueued the command.
    pub thread: u32,
    /// Index into [`Stream::queues`].
    pub queue: usize,
    pub kind: CmdKind,
    /// Kernel name, or the transfer kind's display name.
    pub name: String,
    /// Indices into [`Stream::buffers`] the command reads.
    pub reads: Vec<usize>,
    /// Indices into [`Stream::buffers`] the command writes.
    pub writes: Vec<usize>,
    /// Command ids from the declared wait list (resolved at record time).
    pub deps: Vec<usize>,
    /// The enqueuing host thread waited inline for completion.
    pub blocking: bool,
}

/// One entry in a recorded stream, in global record order.
#[derive(Clone, Debug)]
pub enum Record {
    Cmd(Cmd),
    /// Host thread blocked on these commands (`wait_for_events`).
    HostWait { thread: u32, cmds: Vec<usize> },
    /// Host thread drained a queue (`finish`).
    HostSync { thread: u32, queue: usize },
    BufCreate { buf: usize },
    BufRelease { buf: usize },
}

/// A queue as seen by the analyzer.
#[derive(Clone, Debug)]
pub struct QueueInfo {
    pub label: String,
    pub space: String,
    pub raw: u64,
}

/// A buffer lifetime as seen by the analyzer.
#[derive(Clone, Debug)]
pub struct BufMeta {
    pub label: String,
    /// Contents defined before the first recorded write (`COPY_HOST_PTR`
    /// creation, or the buffer pre-dates the recording window).
    pub initialized: bool,
    pub bytes: usize,
}

/// A recorded command stream — the analyzer's sole input. Can come from
/// the live recorder or be built synthetically with [`StreamBuilder`]
/// (seeded-bug corpus, fuzz tests).
#[derive(Clone, Debug, Default)]
pub struct Stream {
    pub queues: Vec<QueueInfo>,
    pub buffers: Vec<BufMeta>,
    pub records: Vec<Record>,
    /// Number of `Record::Cmd` entries (dense command-id upper bound).
    pub n_cmds: usize,
}

impl Stream {
    /// Dense queue index for a raw handle in a space, if recorded.
    pub fn queue_index(&self, space: &str, raw: u64) -> Option<usize> {
        self.queues.iter().position(|q| q.space == space && q.raw == raw)
    }
}

// ---------------------------------------------------------------------------
// Global recorder
// ---------------------------------------------------------------------------

struct RecState {
    stream: Stream,
    spaces: HashMap<String, u32>,
    /// (space, raw handle) → dense queue index.
    queues: HashMap<(u32, u64), usize>,
    /// (space, raw handle) → dense buffer index (current generation).
    buffers: HashMap<(u32, u64), usize>,
    /// (space, raw event handle) → producing command id.
    events: HashMap<(u32, u64), usize>,
    threads: HashMap<std::thread::ThreadId, u32>,
}

impl RecState {
    fn new() -> Self {
        Self {
            stream: Stream::default(),
            spaces: HashMap::new(),
            queues: HashMap::new(),
            buffers: HashMap::new(),
            events: HashMap::new(),
            threads: HashMap::new(),
        }
    }

    fn space(&mut self, name: &str) -> u32 {
        if let Some(&s) = self.spaces.get(name) {
            return s;
        }
        let s = self.spaces.len() as u32;
        self.spaces.insert(name.to_string(), s);
        s
    }

    fn thread(&mut self) -> u32 {
        let id = std::thread::current().id();
        if let Some(&t) = self.threads.get(&id) {
            return t;
        }
        let t = self.threads.len() as u32;
        self.threads.insert(id, t);
        t
    }

    fn queue(&mut self, space: u32, space_name: &str, raw: u64) -> usize {
        if let Some(&q) = self.queues.get(&(space, raw)) {
            return q;
        }
        let q = self.stream.queues.len();
        self.stream.queues.push(QueueInfo {
            label: format!("{space_name}-q{raw}"),
            space: space_name.to_string(),
            raw,
        });
        self.queues.insert((space, raw), q);
        q
    }

    /// Current generation of a buffer handle; handles first seen mid-use
    /// pre-date the recording window and count as initialized.
    fn buffer(&mut self, space: u32, raw: u64) -> usize {
        if let Some(&b) = self.buffers.get(&(space, raw)) {
            return b;
        }
        let b = self.stream.buffers.len();
        self.stream.buffers.push(BufMeta {
            label: format!("buf{raw}"),
            initialized: true,
            bytes: 0,
        });
        self.buffers.insert((space, raw), b);
        b
    }

    fn push_cmd(
        &mut self,
        space_name: &str,
        raw_queue: u64,
        kind: CmdKind,
        name: &str,
        reads: &[u64],
        writes: &[u64],
        wait_raw: &[u64],
        ev_raw: Option<u64>,
        blocking: bool,
    ) {
        let sp = self.space(space_name);
        let queue = self.queue(sp, space_name, raw_queue);
        let thread = self.thread();
        let reads: Vec<usize> = reads.iter().map(|&m| self.buffer(sp, m)).collect();
        let writes: Vec<usize> = writes.iter().map(|&m| self.buffer(sp, m)).collect();
        // Unresolvable wait entries (events from before the recording
        // window, user events) are dropped — conservative: missing edges
        // can only surface as extra findings, never hide one.
        let deps: Vec<usize> = wait_raw
            .iter()
            .filter_map(|&e| self.events.get(&(sp, e)).copied())
            .collect();
        let id = self.stream.n_cmds;
        self.stream.n_cmds += 1;
        if let Some(ev) = ev_raw {
            self.events.insert((sp, ev), id);
        }
        self.stream.records.push(Record::Cmd(Cmd {
            id,
            thread,
            queue,
            kind,
            name: name.to_string(),
            reads,
            writes,
            deps,
            blocking,
        }));
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<RecState>> = Mutex::new(None);
/// Serializes recording windows process-wide (parallel tests must not
/// interleave their streams).
static WINDOW: Mutex<()> = Mutex::new(());

fn lock_state() -> MutexGuard<'static, Option<RecState>> {
    match STATE.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Cheap armed-check for every hook site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII recording window. Arms the global recorder on `start`, disarms on
/// drop. Windows are exclusive: a second `start` blocks until the first
/// guard drops.
pub struct Recording {
    _window: MutexGuard<'static, ()>,
}

impl Recording {
    pub fn start() -> Recording {
        let window = match WINDOW.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *lock_state() = Some(RecState::new());
        ENABLED.store(true, Ordering::SeqCst);
        Recording { _window: window }
    }

    /// Copy of the stream recorded so far.
    pub fn snapshot(&self) -> Stream {
        lock_state().as_ref().map(|s| s.stream.clone()).unwrap_or_default()
    }

    /// Stop recording and return the stream.
    pub fn finish(self) -> Stream {
        let stream = self.snapshot();
        drop(self);
        stream
    }
}

impl Drop for Recording {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        *lock_state() = None;
    }
}

/// Snapshot of the active recording, if one is armed (for
/// `Session::check`).
pub fn snapshot_active() -> Option<Stream> {
    if !enabled() {
        return None;
    }
    lock_state().as_ref().map(|s| s.stream.clone())
}

// ---------------------------------------------------------------------------
// rawcl hook surface (called from the substrate's public API functions)
// ---------------------------------------------------------------------------

/// Helper shared by all hooks: run `f` against the armed state, if any.
fn with_state(f: impl FnOnce(&mut RecState)) {
    if !enabled() {
        return;
    }
    let mut st = lock_state();
    if let Some(s) = st.as_mut() {
        f(s);
    }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn rawcl_cmd(
    queue: QueueH,
    kind: CmdKind,
    name: &str,
    reads: &[MemH],
    writes: &[MemH],
    wait: &[EventH],
    ev: EventH,
    blocking: bool,
) {
    with_state(|s| {
        let reads: Vec<u64> = reads.iter().map(|m| m.0).collect();
        let writes: Vec<u64> = writes.iter().map(|m| m.0).collect();
        let wait: Vec<u64> = wait.iter().map(|e| e.0).collect();
        s.push_cmd(
            RAWCL_SPACE,
            queue.0,
            kind,
            name,
            &reads,
            &writes,
            &wait,
            Some(ev.0),
            blocking,
        );
    });
}

pub(crate) fn rawcl_buf_create(h: MemH, bytes: usize, initialized: bool) {
    with_state(|s| {
        let sp = s.space(RAWCL_SPACE);
        // Fresh generation even if the raw handle value is reused.
        let b = s.stream.buffers.len();
        s.stream.buffers.push(BufMeta {
            label: format!("buf{}", h.0),
            initialized,
            bytes,
        });
        s.buffers.insert((sp, h.0), b);
        s.stream.records.push(Record::BufCreate { buf: b });
    });
}

pub(crate) fn rawcl_buf_release(h: MemH) {
    with_state(|s| {
        let sp = s.space(RAWCL_SPACE);
        if let Some(b) = s.buffers.remove(&(sp, h.0)) {
            s.stream.records.push(Record::BufRelease { buf: b });
        }
    });
}

pub(crate) fn rawcl_host_wait(evs: &[EventH]) {
    with_state(|s| {
        let sp = s.space(RAWCL_SPACE);
        let cmds: Vec<usize> =
            evs.iter().filter_map(|e| s.events.get(&(sp, e.0)).copied()).collect();
        if cmds.is_empty() {
            return;
        }
        let thread = s.thread();
        s.stream.records.push(Record::HostWait { thread, cmds });
    });
}

pub(crate) fn rawcl_finish(q: QueueH) {
    with_state(|s| {
        let sp = s.space(RAWCL_SPACE);
        let queue = s.queue(sp, RAWCL_SPACE, q.0);
        let thread = s.thread();
        s.stream.records.push(Record::HostSync { thread, queue });
    });
}

pub(crate) fn rawcl_queue_label(q: QueueH, label: &str) {
    with_state(|s| {
        let sp = s.space(RAWCL_SPACE);
        let queue = s.queue(sp, RAWCL_SPACE, q.0);
        s.stream.queues[queue].label = label.to_string();
    });
}

// ---------------------------------------------------------------------------
// Backend-tier hook surface (scheduler shard dispatch, exec backend path)
// ---------------------------------------------------------------------------

/// Record a backend-tier command. Each backend instance is one in-order
/// logical queue, so `space` doubles as the queue identity.
pub(crate) fn backend_cmd(
    space: &str,
    kind: CmdKind,
    name: &str,
    reads: &[u64],
    writes: &[u64],
    ev: Option<u64>,
    blocking: bool,
) {
    with_state(|s| {
        s.push_cmd(space, 0, kind, name, reads, writes, &[], ev, blocking);
    });
}

/// `Backend::wait(ev)` — a host-side join on the producing command.
pub(crate) fn backend_host_wait(space: &str, ev: u64) {
    with_state(|s| {
        let sp = s.space(space);
        let Some(&cmd) = s.events.get(&(sp, ev)) else { return };
        let thread = s.thread();
        s.stream.records.push(Record::HostWait { thread, cmds: vec![cmd] });
    });
}

// ---------------------------------------------------------------------------
// Synthetic streams (corpus + fuzzing)
// ---------------------------------------------------------------------------

/// Builds a [`Stream`] by hand, for the seeded-bug corpus and property
/// tests. Commands reference queues/buffers/commands by the indices the
/// builder returns.
#[derive(Default)]
pub struct StreamBuilder {
    stream: Stream,
}

impl StreamBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn queue(&mut self, label: &str) -> usize {
        let q = self.stream.queues.len();
        self.stream.queues.push(QueueInfo {
            label: label.to_string(),
            space: "synthetic".to_string(),
            raw: q as u64,
        });
        q
    }

    pub fn buffer(&mut self, label: &str, initialized: bool) -> usize {
        let b = self.stream.buffers.len();
        self.stream.buffers.push(BufMeta {
            label: label.to_string(),
            initialized,
            bytes: 0,
        });
        self.stream.records.push(Record::BufCreate { buf: b });
        b
    }

    /// Append a command on host thread 0; returns its id for wait lists.
    pub fn cmd(
        &mut self,
        queue: usize,
        kind: CmdKind,
        name: &str,
        reads: &[usize],
        writes: &[usize],
        deps: &[usize],
    ) -> usize {
        let id = self.stream.n_cmds;
        self.stream.n_cmds += 1;
        self.stream.records.push(Record::Cmd(Cmd {
            id,
            thread: 0,
            queue,
            kind,
            name: name.to_string(),
            reads: reads.to_vec(),
            writes: writes.to_vec(),
            deps: deps.to_vec(),
            blocking: false,
        }));
        id
    }

    /// A blocking device→host read-back of `buf` (what `enqueue_read_buffer`
    /// with `blocking=true` records).
    pub fn read_back(&mut self, queue: usize, buf: usize, deps: &[usize]) -> usize {
        let id = self.cmd(queue, CmdKind::HostRead, "READ_BUFFER", &[buf], &[], deps);
        if let Some(Record::Cmd(c)) = self.stream.records.last_mut() {
            c.blocking = true;
        }
        id
    }

    pub fn host_wait(&mut self, cmds: &[usize]) {
        self.stream.records.push(Record::HostWait { thread: 0, cmds: cmds.to_vec() });
    }

    pub fn finish(&mut self, queue: usize) {
        self.stream.records.push(Record::HostSync { thread: 0, queue });
    }

    pub fn release(&mut self, buf: usize) {
        self.stream.records.push(Record::BufRelease { buf });
    }

    pub fn build(self) -> Stream {
        self.stream
    }
}
