//! Static analysis over recorded command graphs.
//!
//! cf4ocl's pitch is safe-by-construction event/memory management — but the
//! raw and v1 tiers have no checking at all, and the v2 tier lets callers
//! *opt out* of implicit dependency chaining (`.independent()`, `.after()`),
//! so a missing event edge silently yields nondeterministic output. This
//! module closes that gap without touching execution semantics:
//!
//! 1. [`record`] — a lightweight global recorder threaded through the rawcl
//!    enqueue paths, the ccl v1 `Queue` (labels), the `ccl::v2`
//!    launch/read/write paths, and the scheduler's per-shard backend
//!    dispatch. Each command's buffer access set is derived from the
//!    `arg_roles` ABI single source; declared event dependencies are
//!    resolved to producing commands at record time (snapshot semantics
//!    under handle reuse).
//! 2. [`hb`] — the happens-before graph: per-queue vector clocks, edges
//!    from same-queue program order, event wait lists, and host-mediated
//!    synchronisation (event waits, `finish`, blocking transfers).
//! 3. [`lint`] — typed findings over the graph: data races,
//!    read-before-write, dependency cycles, dead writes, unwaited host
//!    reads.
//! 4. [`report`] — human-readable and machine-readable (JSON/TSV)
//!    rendering, sharing the profiler exporter's field escaping so hostile
//!    queue/kernel names round-trip.
//!
//! Surfaces: [`crate::ccl::v2::Session::check`], the `cf4rs lint` CLI mode
//! (replays any workload × path cell under the recorder), and the
//! `bench lint-graph` CI gate (clean 5×5 matrix must be finding-free AND a
//! seeded-bug corpus must be flagged at 100% — see `examples/lint_corpus.rs`).

pub mod corpus;
pub mod hb;
pub mod lint;
pub mod record;
pub mod report;

pub use lint::{analyze, CmdRef, Finding, Rule, Severity};
pub use record::{BufMeta, Cmd, CmdKind, QueueInfo, Record, Recording, Stream, StreamBuilder};
pub use report::Report;
