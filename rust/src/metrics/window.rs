//! Sliding-window view over a [`Histogram`]: "req/s and p95 over the
//! last few seconds", not since process start.
//!
//! The window is a ring of time slots. A sample lands in the slot of
//! its epoch (`now / slot_ns`); a slot whose stored epoch has fallen
//! out of the ring is lazily reset by the first writer of the new
//! epoch (CAS on the slot's epoch word). Readers merge the slots whose
//! epoch is still inside the window.
//!
//! The reset race (a reader or a straggling writer touching a slot
//! mid-reset) can over- or under-count a handful of samples at slot
//! boundaries — monitoring-grade semantics, documented and accepted;
//! every structural invariant (expiry, merge) is deterministic and
//! tested through the explicit `_at` methods, which take the clock as
//! an argument.

use std::sync::atomic::{AtomicU64, Ordering};

use super::histogram::Histogram;
use crate::rawcl::clock;

struct Slot {
    /// `epoch + 1`; 0 = never written.
    epoch1: AtomicU64,
    hist: Histogram,
}

/// A histogram that only remembers the last `slots × slot_ns`
/// nanoseconds. See the [module docs](self).
pub struct WindowedHistogram {
    slot_ns: u64,
    slots: Vec<Slot>,
}

impl WindowedHistogram {
    /// `slots` ring slots of `slot_ns` each; the window spans
    /// `slots × slot_ns`.
    pub fn new(slots: usize, slot_ns: u64) -> Self {
        assert!(slots > 0 && slot_ns > 0, "window needs non-empty slots");
        Self {
            slot_ns,
            slots: (0..slots)
                .map(|_| Slot { epoch1: AtomicU64::new(0), hist: Histogram::new() })
                .collect(),
        }
    }

    /// Total window span in nanoseconds.
    pub fn span_ns(&self) -> u64 {
        self.slot_ns * self.slots.len() as u64
    }

    /// Record `v` at an explicit clock reading (tests drive this
    /// directly; [`record`](Self::record) feeds it the process clock).
    pub fn record_at(&self, now_ns: u64, v: u64) {
        let epoch = now_ns / self.slot_ns;
        let slot = &self.slots[(epoch % self.slots.len() as u64) as usize];
        loop {
            let e1 = slot.epoch1.load(Ordering::Acquire);
            if e1 == epoch + 1 {
                break;
            }
            // The slot belongs to an expired epoch: first writer of the
            // new epoch claims and resets it.
            if slot
                .epoch1
                .compare_exchange(e1, epoch + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                slot.hist.clear();
                break;
            }
        }
        slot.hist.record(v);
    }

    /// Merge the slots still inside the window ending at `now_ns` into
    /// one [`Histogram`].
    pub fn snapshot_at(&self, now_ns: u64) -> Histogram {
        let epoch = now_ns / self.slot_ns;
        let oldest = epoch.saturating_sub(self.slots.len() as u64 - 1);
        let merged = Histogram::new();
        for slot in &self.slots {
            let e1 = slot.epoch1.load(Ordering::Acquire);
            if e1 > oldest && e1 <= epoch + 1 {
                merged.merge_from(&slot.hist);
            }
        }
        merged
    }

    /// Samples inside the window ending at `now_ns`.
    pub fn count_at(&self, now_ns: u64) -> u64 {
        self.snapshot_at(now_ns).count()
    }

    /// Trailing average event rate per second over the window ending
    /// at `now_ns`. The divisor is the lesser of the window span and
    /// the time since the oldest live slot began, so a service younger
    /// than the window reports its true rate instead of diluting the
    /// count over time that has not happened yet.
    pub fn rate_per_s_at(&self, now_ns: u64) -> f64 {
        let epoch = now_ns / self.slot_ns;
        let oldest = epoch.saturating_sub(self.slots.len() as u64 - 1);
        let mut count = 0u64;
        let mut first_epoch = u64::MAX;
        for slot in &self.slots {
            let e1 = slot.epoch1.load(Ordering::Acquire);
            if e1 > oldest && e1 <= epoch + 1 {
                count += slot.hist.count();
                first_epoch = first_epoch.min(e1 - 1);
            }
        }
        if count == 0 {
            return 0.0;
        }
        let covered = now_ns
            .saturating_sub(first_epoch * self.slot_ns)
            .clamp(1, self.span_ns());
        count as f64 / (covered as f64 * 1e-9)
    }

    /// [`record_at`](Self::record_at) on the process profiling clock.
    pub fn record(&self, v: u64) {
        self.record_at(clock::now_ns(), v);
    }

    /// [`snapshot_at`](Self::snapshot_at) on the process profiling clock.
    pub fn snapshot(&self) -> Histogram {
        self.snapshot_at(clock::now_ns())
    }

    /// [`rate_per_s_at`](Self::rate_per_s_at) on the process profiling
    /// clock.
    pub fn rate_per_s(&self) -> f64 {
        self.rate_per_s_at(clock::now_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_expire_after_the_window() {
        let w = WindowedHistogram::new(4, 1_000);
        w.record_at(100, 7);
        w.record_at(1_100, 8);
        assert_eq!(w.count_at(1_100), 2);
        // 4 slots of 1000 ns: the epoch-0 sample expires once the clock
        // enters epoch 4, the epoch-1 sample at epoch 5.
        assert_eq!(w.count_at(4_000), 1);
        assert_eq!(w.count_at(5_000), 0);
    }

    #[test]
    fn slot_reuse_resets_stale_counts() {
        let w = WindowedHistogram::new(2, 100);
        w.record_at(0, 1);
        // Same ring slot (epoch 2 → slot 0), two epochs later: the old
        // epoch-0 count must not survive the reuse.
        w.record_at(200, 2);
        assert_eq!(w.count_at(200), 1);
        assert_eq!(w.snapshot_at(200).quantile(0.5), 2);
    }

    #[test]
    fn rate_covers_only_elapsed_time() {
        let w = WindowedHistogram::new(5, 200_000_000); // 1 s window
        for i in 0..50 {
            w.record_at(i * 10_000_000, 1);
        }
        // Half a second in: 50 events over 0.499 s, not over the full
        // (not yet elapsed) 1 s window.
        let r = w.rate_per_s_at(499_000_000);
        assert!((r - 50.0 / 0.499).abs() < 1e-9, "{r}");
        // After the first slot (20 events at epoch 0) expires, the 30
        // surviving events rate over the time since the oldest
        // surviving slot began.
        let r = w.rate_per_s_at(1_199_000_000);
        assert!((r - 30.0 / 0.999).abs() < 1e-9, "{r}");
        // An empty window rates 0.
        let empty = WindowedHistogram::new(4, 1_000);
        assert_eq!(empty.rate_per_s_at(10_000), 0.0);
    }
}
