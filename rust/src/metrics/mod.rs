//! # `metrics` — live telemetry for the running system
//!
//! The paper's profiler ([`crate::ccl::prof`]) is *offline*: it
//! explains a run after the fact. This subsystem is the *online*
//! complement — cheap enough to sit on the dispatcher's and
//! scheduler's hot paths, continuously queryable while the system
//! serves traffic, and the measurement source the
//! [`crate::coordinator::adaptive`] controller closes its feedback
//! loop on (the paper's closing claim — profiling "allowed for a quick
//! analysis on how to optimize the application" — turned into a
//! control input):
//!
//! * [`Counter`] / [`Gauge`] — single relaxed atomics; readers never
//!   contend with writers;
//! * [`Histogram`] — log-bucketed (HdrHistogram-style) u64 histogram:
//!   lock-free recording, bucket-wise **merge** (associative and
//!   commutative), nearest-rank **quantile** queries with relative
//!   error bounded by [`histogram::MAX_REL_ERROR`];
//! * [`WindowedHistogram`] — a ring of histogram slots giving the
//!   trailing-window view (`req/s and p95 over the last 2 s`) the
//!   `serve --live` dashboard prints.
//!
//! All instruments take `&self`; share them behind an `Arc` and record
//! from any thread.

pub mod counter;
pub mod histogram;
pub mod window;

pub use counter::{Counter, Gauge};
pub use histogram::{bucket_index, Histogram, MAX_REL_ERROR, NUM_BUCKETS};
pub use window::WindowedHistogram;
