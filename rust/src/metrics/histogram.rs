//! Log-bucketed, lock-free, mergeable latency histogram.
//!
//! The bucket scheme is HdrHistogram-style: values below
//! 2^[`SUB_BITS`] get one exact bucket each; above that, every octave
//! (power of two) splits into 2^`SUB_BITS` sub-buckets, so a bucket's
//! width over its lower bound — the worst-case *relative* quantile
//! error — is bounded by 2^-`SUB_BITS` (and the midpoint
//! representative halves it again; see [`MAX_REL_ERROR`]). With
//! `SUB_BITS = 5` the whole u64 range fits in [`NUM_BUCKETS`] = 1920
//! buckets (15 KiB of atomics), so recording is one relaxed
//! `fetch_add` with no allocation and no lock — safe on the service
//! dispatcher's and scheduler's hot paths.
//!
//! Histograms **merge** by bucket-wise addition, which is associative
//! and commutative (property-tested in `rust/tests/metrics.rs`), so
//! per-shard or per-thread histograms combine into service-wide ones
//! without coordination.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: 2^SUB_BITS sub-buckets per octave.
pub const SUB_BITS: u32 = 5;
const SUB: u64 = 1 << SUB_BITS; // 32

/// Total bucket count covering all of u64.
pub const NUM_BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB as usize;

/// Worst-case relative error of a quantile query (midpoint
/// representative of a bucket whose width/lower-bound ≤ 2^-SUB_BITS).
pub const MAX_REL_ERROR: f64 = 1.0 / (SUB as f64 * 2.0);

/// The bucket a value lands in.
pub fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // ≥ SUB_BITS
    let exp = msb - SUB_BITS;
    let mantissa = (v >> exp) & (SUB - 1);
    ((exp as usize + 1) << SUB_BITS) + mantissa as usize
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lo(i: usize) -> u64 {
    if i < SUB as usize {
        return i as u64;
    }
    let exp = (i as u64 >> SUB_BITS) - 1;
    let mantissa = i as u64 & (SUB - 1);
    (SUB + mantissa) << exp
}

/// The value a quantile query reports for bucket `i`: its midpoint,
/// which stays inside the bucket (`bucket_index(representative(i)) ==
/// i`) and bounds the relative error by [`MAX_REL_ERROR`].
pub fn representative(i: usize) -> u64 {
    if i < SUB as usize {
        return i as u64;
    }
    let exp = (i as u64 >> SUB_BITS) - 1;
    bucket_lo(i) + (1u64 << exp) / 2
}

/// A lock-free log-bucketed histogram of u64 samples (latencies in ns,
/// batch sizes, ...). See the [module docs](self) for the scheme.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free: two relaxed adds.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (wrapping at u64 scale).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Arithmetic mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`): the representative of
    /// the bucket holding the sample of rank `ceil(q·count)` (rank 1
    /// for `q = 0`). Returns 0 when the histogram is empty. The result
    /// lands in the **same bucket** as the exact order statistic, so
    /// its relative error is bounded by [`MAX_REL_ERROR`].
    pub fn quantile(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return representative(i);
            }
        }
        representative(NUM_BUCKETS - 1)
    }

    /// Bucket-wise add `other` into `self` (associative, commutative).
    pub fn merge_from(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(&other.buckets) {
            let v = src.load(Ordering::Relaxed);
            if v != 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Reset every bucket to zero.
    pub fn clear(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }

    /// An independent copy of the current state (a consistent-enough
    /// snapshot for monitoring; concurrent writers may be mid-record).
    pub fn snapshot(&self) -> Histogram {
        let h = Histogram::new();
        h.merge_from(self);
        h
    }

    /// Non-zero buckets as `(bucket index, count)` — the canonical
    /// form the merge-equality property tests compare.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c != 0).then_some((i, c))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_scheme_is_contiguous_and_monotone() {
        // Exact region.
        for v in 0..SUB {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_lo(v as usize), v);
            assert_eq!(representative(v as usize), v);
        }
        // Every bucket's lower bound maps back to that bucket, bounds
        // are strictly increasing, and the representative stays inside.
        for i in 1..NUM_BUCKETS {
            assert!(bucket_lo(i) > bucket_lo(i - 1), "bucket {i}");
            assert_eq!(bucket_index(bucket_lo(i)), i, "lo of bucket {i}");
            assert_eq!(bucket_index(representative(i)), i, "rep of bucket {i}");
        }
        // Extremes.
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_index(0), 0);
    }

    #[test]
    fn quantiles_on_small_exact_values_are_exact() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 10] {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(0.5), 5);
        assert_eq!(h.quantile(0.95), 10);
        assert_eq!(h.quantile(1.0), 10);
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum(), 55);
        assert!((h.mean() - 5.5).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single_sample_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        h.record(1_000_000);
        let p = h.quantile(0.95);
        let rel = (p as f64 - 1e6).abs() / 1e6;
        assert!(rel <= MAX_REL_ERROR, "rel error {rel}");
        assert_eq!(bucket_index(p), bucket_index(1_000_000));
    }

    #[test]
    fn merge_adds_bucket_wise() {
        let (a, b) = (Histogram::new(), Histogram::new());
        a.record(100);
        b.record(100);
        b.record(1 << 40);
        a.merge_from(&b);
        assert_eq!(a.count(), 3);
        let nz = a.nonzero_buckets();
        assert_eq!(nz.len(), 2);
        assert_eq!(nz[0], (bucket_index(100), 2));
    }
}
