//! Lock-free scalar instruments: [`Counter`] and [`Gauge`].
//!
//! Both are single atomics with relaxed ordering — readers observe a
//! recent (not necessarily instantaneous) value, which is exactly the
//! monitoring contract: a `stats()` reader must never contend with the
//! dispatcher or scheduler hot path it observes.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// A monotonically increasing event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Count one event.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Count `n` events at once.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current total.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A value that can move in both directions (queue depth, current
/// window size, ...). Also usable as a running maximum via
/// [`set_max`](Gauge::set_max).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is currently lower (running max).
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_moves_both_ways_and_tracks_max() {
        let g = Gauge::new();
        g.add(3);
        g.sub(1);
        assert_eq!(g.get(), 2);
        g.set(7);
        g.set_max(5); // lower → no-op
        assert_eq!(g.get(), 7);
        g.set_max(11);
        assert_eq!(g.get(), 11);
    }

    #[test]
    fn counter_is_consistent_under_contention() {
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}
