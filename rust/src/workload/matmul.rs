//! Row-band tiled matmul as a [`Workload`].
//!
//! `C = A · B` for fixed deterministic `d × d` operands. Shards are row
//! bands of `A`/`C` (the tile shape that needs no cross-shard reduction):
//! every band receives the whole of `B`, computes its band of `C` with a
//! fixed ascending-`k` accumulation order, and the bands concatenate.
//! The operand values are small integers in f32, so products and the
//! short dot-product sums are exact and bit-stable.

use crate::backend::CompileSpec;
use crate::rawcl::simexec;

use super::{concat_outputs, f32_bytes, IterPlan, Shard, Workload};

/// `d × d` square multiply, recomputed each iteration.
#[derive(Debug, Clone, Copy)]
pub struct MatmulWorkload {
    d: usize,
}

impl MatmulWorkload {
    pub fn new(d: usize) -> Self {
        Self { d }
    }

    fn a_at(i: usize, j: usize) -> f32 {
        (((i * 7 + j * 3) % 13) as f32) - 6.0
    }

    fn b_at(i: usize, j: usize) -> f32 {
        (((i * 5 + j * 11) % 9) as f32) - 4.0
    }

    /// Rows `[lo, lo+len)` of A, row-major.
    fn a_band(&self, shard: Shard) -> Vec<u8> {
        let mut vals = Vec::with_capacity(shard.len * self.d);
        for r in shard.lo..shard.lo + shard.len {
            for j in 0..self.d {
                vals.push(Self::a_at(r, j));
            }
        }
        f32_bytes(&vals)
    }

    fn b_full(&self) -> Vec<u8> {
        let mut vals = Vec::with_capacity(self.d * self.d);
        for i in 0..self.d {
            for j in 0..self.d {
                vals.push(Self::b_at(i, j));
            }
        }
        f32_bytes(&vals)
    }
}

impl Workload for MatmulWorkload {
    fn name(&self) -> &'static str {
        "matmul"
    }

    fn units(&self) -> usize {
        self.d
    }

    fn unit_bytes(&self) -> usize {
        self.d * 4
    }

    fn default_iters(&self) -> usize {
        2
    }

    fn kernels(&self, shard: Shard) -> Vec<CompileSpec> {
        vec![CompileSpec::matmul(shard.len, self.d)]
    }

    fn plan(&self, shard: Shard, _iter: usize, _state: &[u8]) -> IterPlan {
        IterPlan {
            kernel: 0,
            inputs: vec![self.a_band(shard), self.b_full()],
            scalars: vec![],
            out_bytes: shard.len * self.d * 4,
        }
    }

    fn global_dims(&self, shard: Shard, _iter: usize) -> Vec<usize> {
        vec![shard.len, self.d]
    }

    fn merge(&self, _shards: &[Shard], outputs: &[Vec<u8>]) -> Vec<u8> {
        concat_outputs(outputs)
    }

    fn reference(&self, _iters: usize) -> Vec<u8> {
        let shard = Shard::whole(self.d);
        let (a, b) = (self.a_band(shard), self.b_full());
        let mut out = vec![0u8; self.d * self.d * 4];
        simexec::run_matmul(&a, &b, &mut out, self.d, self.d);
        out
    }
}
