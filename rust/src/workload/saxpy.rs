//! Iterated SAXPY (`y ← a·x + y`) as a [`Workload`] — the worked
//! example of the [module docs](super).
//!
//! `x` is a fixed deterministic pattern; the state is `y`. Both the
//! multiply and the add are elementwise, so shard outputs concatenate
//! and every path is bit-identical.

use crate::backend::CompileSpec;
use crate::rawcl::simexec;

use super::{concat_outputs, f32_bytes, IterPlan, Shard, Workload};

/// `n` f32 elements, one saxpy pass per iteration.
#[derive(Debug, Clone, Copy)]
pub struct SaxpyWorkload {
    n: usize,
    a: f32,
}

impl SaxpyWorkload {
    pub fn new(n: usize, a: f32) -> Self {
        Self { n, a }
    }

    /// The fixed input `x[i]` (exactly representable small values).
    fn x_at(i: usize) -> f32 {
        ((i % 29) as f32) - 14.0
    }

    fn x_slice(&self, shard: Shard) -> Vec<u8> {
        let xs: Vec<f32> = (shard.lo..shard.lo + shard.len).map(Self::x_at).collect();
        f32_bytes(&xs)
    }
}

impl Workload for SaxpyWorkload {
    fn name(&self) -> &'static str {
        "saxpy"
    }

    fn units(&self) -> usize {
        self.n
    }

    fn unit_bytes(&self) -> usize {
        4
    }

    fn default_iters(&self) -> usize {
        4
    }

    fn init_state(&self) -> Vec<u8> {
        let ys: Vec<f32> = (0..self.n).map(|i| ((i % 17) as f32) * 0.25).collect();
        f32_bytes(&ys)
    }

    fn kernels(&self, shard: Shard) -> Vec<CompileSpec> {
        vec![CompileSpec::saxpy(shard.len)]
    }

    fn plan(&self, shard: Shard, _iter: usize, state: &[u8]) -> IterPlan {
        IterPlan {
            kernel: 0,
            inputs: vec![self.x_slice(shard), state[shard.byte_range(4)].to_vec()],
            scalars: vec![self.a],
            out_bytes: shard.len * 4,
        }
    }

    fn merge(&self, _shards: &[Shard], outputs: &[Vec<u8>]) -> Vec<u8> {
        concat_outputs(outputs)
    }

    fn reference(&self, iters: usize) -> Vec<u8> {
        let x = self.x_slice(Shard::whole(self.n));
        let mut y = self.init_state();
        let mut out = vec![0u8; self.n * 4];
        for _ in 0..iters {
            simexec::run_saxpy(self.a, &x, &y, &mut out);
            std::mem::swap(&mut y, &mut out);
        }
        y
    }
}
