//! Tree reduction as a [`Workload`].
//!
//! The input vector (a deterministic seed pattern) is the state and
//! never changes; each iteration reduces it to one 64-bit word. Shards
//! produce partial sums and [`Workload::merge`] folds them — exact for
//! any split because wrapping addition is associative.

use crate::backend::CompileSpec;
use crate::rawcl::simexec;

use super::{u64s, IterPlan, Shard, Workload};

/// Wrapping-u64 sum of `n` words.
#[derive(Debug, Clone, Copy)]
pub struct ReduceWorkload {
    n: usize,
}

impl ReduceWorkload {
    pub fn new(n: usize) -> Self {
        Self { n }
    }
}

impl Workload for ReduceWorkload {
    fn name(&self) -> &'static str {
        "reduce"
    }

    fn units(&self) -> usize {
        self.n
    }

    fn unit_bytes(&self) -> usize {
        8
    }

    fn default_iters(&self) -> usize {
        2
    }

    fn init_state(&self) -> Vec<u8> {
        // The seed hash gives well-mixed words whose sum exercises all
        // 64 bits (carries included).
        let mut state = vec![0u8; self.n * 8];
        simexec::run_init(&mut state);
        state
    }

    fn kernels(&self, shard: Shard) -> Vec<CompileSpec> {
        vec![CompileSpec::reduce(shard.len)]
    }

    fn plan(&self, shard: Shard, _iter: usize, state: &[u8]) -> IterPlan {
        IterPlan {
            kernel: 0,
            inputs: vec![state[shard.byte_range(8)].to_vec()],
            scalars: vec![],
            out_bytes: 8,
        }
    }

    fn merge(&self, _shards: &[Shard], outputs: &[Vec<u8>]) -> Vec<u8> {
        // Fold the per-shard partial sums — the tree's last level.
        let partials: Vec<u64> = outputs.iter().map(|o| u64s(o)[0]).collect();
        simexec::reduce_tree(&partials).to_le_bytes().to_vec()
    }

    /// The input is constant, so the reduced word never changes between
    /// iterations — the state must stay the input vector.
    fn next_state(&self, prev: Vec<u8>, _merged: Vec<u8>) -> Vec<u8> {
        prev
    }

    fn reference(&self, _iters: usize) -> Vec<u8> {
        let words = u64s(&self.init_state());
        simexec::reduce_tree(&words).to_le_bytes().to_vec()
    }
}
