//! Path drivers: run any [`Workload`] through each execution tier.
//!
//! All drivers follow the same shape — resolve the whole-problem shard,
//! compile the workload's kernels, then per iteration: upload the
//! plan's inputs, build the argument list from the kernel family's
//! [`arg_roles`](crate::rawcl::kernelspec::KernelKind::arg_roles),
//! launch over [`Workload::global_dims`], read the output back and fold
//! it through [`Workload::merge`]/[`Workload::next_state`]. Every driver
//! returns the final merged output bytes, which the harness compares
//! against [`Workload::reference`] and across paths — all five must be
//! bit-identical.
//!
//! * [`run_raw_path`] — the verbose substrate (listings S1-style);
//! * [`run_ccl_path`] — the `ccl` v1 wrappers (listing S2-style);
//! * [`run_v2_path`] — the fluent `ccl::v2` session tier;
//! * [`run_sharded_path`] — the multi-backend work-stealing scheduler;
//! * [`run_native_path`] — the native parallel-kernel tier
//!   ([`NativeBackend`]) driven through the uniform [`Backend`]
//!   contract ([`run_backend_path`] is the same driver over any single
//!   backend — `bench native` uses it to race the native tier against
//!   the interpreting PJRT backend on identical command streams).

use crate::analysis::record as arec;
use crate::backend::{Backend, BackendRegistry, NativeBackend};
use crate::ccl::errors::{CclError, CclResult};
use crate::ccl::v2::Session;
use crate::ccl::{self, Arg};
use crate::coordinator::scheduler::{run_sharded_workload_on, ShardedConfig};
use crate::rawcl;
use crate::rawcl::kernelspec::ArgRole;
use crate::rawcl::types::{DeviceId, MemFlags, QueueProps};
use crate::runtime::hlogen;
use crate::runtime::literal::ElemType;

use super::{f32_bytes, f32s, u64s, Shard, Workload};

/// Encode u64s little-endian (counterpart of [`super::u64s`]).
fn u64_bytes(vals: &[u64]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Run a workload on the raw substrate (manual status codes, manual
/// object lifecycle — the listing-S1 style).
pub fn run_raw_path(
    w: &dyn Workload,
    iters: usize,
    device_index: u32,
) -> Result<Vec<u8>, String> {
    macro_rules! chk {
        ($st:expr, $what:expr) => {
            if $st != rawcl::CL_SUCCESS {
                return Err(format!("{}: {}", $what, rawcl::status_name($st)));
            }
        };
    }

    let shard = Shard::whole(w.units());
    let specs = w.kernels(shard);
    let dev = DeviceId(device_index);
    let mut st = rawcl::CL_SUCCESS;
    let ctx = rawcl::create_context(&[dev], &mut st);
    chk!(st, "create context");
    let cq = rawcl::create_command_queue(ctx, dev, QueueProps::empty(), &mut st);
    chk!(st, "create queue");

    let mut sources = Vec::with_capacity(specs.len());
    for spec in &specs {
        sources.push(
            hlogen::resolve_source(&spec.gen_spec())
                .map_err(|e| format!("resolving {:?} source: {e}", spec.kind))?,
        );
    }
    let prg = rawcl::create_program_with_source(ctx, &sources, &mut st);
    chk!(st, "create program");
    let bst = rawcl::build_program(prg, None, "");
    if bst == rawcl::CL_BUILD_PROGRAM_FAILURE {
        let mut log = String::new();
        rawcl::get_program_build_log(prg, &mut log);
        return Err(format!("build failure:\n{log}"));
    }
    chk!(bst, "build program");

    let mut kernels = Vec::with_capacity(specs.len());
    for spec in &specs {
        let k = rawcl::create_kernel(prg, spec.kind.module_name(), &mut st);
        chk!(st, "create kernel");
        kernels.push(k);
    }

    let mut state = w.init_state();
    let mut last = Vec::new();
    for iter in 0..iters {
        let plan = w.plan(shard, iter, &state);
        let spec = specs[plan.kernel];
        let kern = kernels[plan.kernel];

        let mut in_bufs = Vec::with_capacity(plan.inputs.len());
        for data in &plan.inputs {
            let b = rawcl::create_buffer(
                ctx,
                MemFlags::READ_WRITE | MemFlags::COPY_HOST_PTR,
                data.len(),
                Some(data),
                &mut st,
            );
            chk!(st, "create input buffer");
            in_bufs.push(b);
        }
        let out_buf =
            rawcl::create_buffer(ctx, MemFlags::READ_WRITE, plan.out_bytes, None, &mut st);
        chk!(st, "create output buffer");

        let roles = spec.kind.arg_roles(spec.n, spec.m);
        let (mut ii, mut si) = (0usize, 0usize);
        for (slot, role) in roles.iter().enumerate() {
            let value = match role {
                ArgRole::BakedScalar { expect_u32, .. } => {
                    rawcl::ArgValue::Scalar(expect_u32.unwrap_or(0).to_le_bytes().to_vec())
                }
                ArgRole::ScalarInput { .. } => {
                    let v = plan.scalars[si];
                    si += 1;
                    rawcl::ArgValue::Scalar(v.to_le_bytes().to_vec())
                }
                ArgRole::BufferInput { .. } => {
                    let b = in_bufs[ii];
                    ii += 1;
                    rawcl::ArgValue::Buffer(b)
                }
                ArgRole::BufferOutput { .. } => rawcl::ArgValue::Buffer(out_buf),
            };
            chk!(rawcl::set_kernel_arg(kern, slot, &value), "set kernel arg");
        }

        let dims = w.global_dims(shard, iter);
        chk!(
            rawcl::enqueue_ndrange_kernel(
                cq,
                kern,
                dims.len() as u32,
                &dims,
                None,
                &[],
                None,
            ),
            "enqueue kernel"
        );
        chk!(rawcl::finish(cq), "finish");
        let mut out = vec![0u8; plan.out_bytes];
        chk!(
            rawcl::enqueue_read_buffer(cq, out_buf, true, 0, &mut out, &[], None),
            "read output"
        );
        for b in in_bufs {
            rawcl::release_mem_object(b);
        }
        rawcl::release_mem_object(out_buf);

        let merged = w.merge(&[shard], &[out]);
        if iter + 1 == iters {
            last = merged;
        } else {
            state = w.next_state(state, merged);
        }
    }

    for k in kernels {
        rawcl::release_kernel(k);
    }
    rawcl::release_program(prg);
    rawcl::release_command_queue(cq);
    rawcl::release_context(ctx);
    Ok(last)
}

/// Run a workload on the `ccl` v1 framework tier.
pub fn run_ccl_path(
    w: &dyn Workload,
    iters: usize,
    device_index: u32,
) -> CclResult<Vec<u8>> {
    let shard = Shard::whole(w.units());
    let specs = w.kernels(shard);
    let dev = ccl::Device::from_id(DeviceId(device_index))?;
    let ctx = ccl::Context::new_from_devices(&[dev])?;
    let cq = ccl::Queue::new(&ctx, dev, QueueProps::empty())?;
    let gen: Vec<hlogen::GenSpec> = specs.iter().map(|s| s.gen_spec()).collect();
    let prg = ccl::Program::new_from_specs(&ctx, &gen)?;
    prg.build()?;
    let mut kernels = Vec::with_capacity(specs.len());
    for spec in &specs {
        kernels.push(prg.kernel(spec.kind.module_name())?);
    }

    let mut state = w.init_state();
    let mut last = Vec::new();
    for iter in 0..iters {
        let plan = w.plan(shard, iter, &state);
        let spec = specs[plan.kernel];
        let kern = &kernels[plan.kernel];

        let mut in_bufs = Vec::with_capacity(plan.inputs.len());
        for data in &plan.inputs {
            in_bufs.push(ccl::Buffer::from_slice(&ctx, MemFlags::READ_WRITE, data)?);
        }
        let out_buf = ccl::Buffer::new(&ctx, MemFlags::READ_WRITE, plan.out_bytes)?;

        let roles = spec.kind.arg_roles(spec.n, spec.m);
        let (mut ii, mut si) = (0usize, 0usize);
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(roles.len());
        for role in &roles {
            args.push(match role {
                ArgRole::BakedScalar { expect_u32, .. } => {
                    Arg::priv_u32(expect_u32.unwrap_or(0))
                }
                ArgRole::ScalarInput { .. } => {
                    let v = plan.scalars[si];
                    si += 1;
                    Arg::priv_f32(v)
                }
                ArgRole::BufferInput { .. } => {
                    let b = &in_bufs[ii];
                    ii += 1;
                    Arg::buf(b)
                }
                ArgRole::BufferOutput { .. } => Arg::buf(&out_buf),
            });
        }

        let dims = w.global_dims(shard, iter);
        let (gws, lws) = kern.suggest_worksizes(dev, &dims)?;
        kern.set_args_and_enqueue_ndrange(&cq, &gws, Some(&lws), &[], &args)?;
        cq.finish()?;
        let mut out = vec![0u8; plan.out_bytes];
        out_buf.enqueue_read(&cq, 0, &mut out, &[])?;

        let merged = w.merge(&[shard], &[out]);
        if iter + 1 == iters {
            last = merged;
        } else {
            state = w.next_state(state, merged);
        }
    }
    Ok(last)
}

/// Run a workload on the fluent `ccl::v2` session tier.
pub fn run_v2_path(
    w: &dyn Workload,
    iters: usize,
    device_index: u32,
) -> CclResult<Vec<u8>> {
    /// A typed v2 buffer of whichever element type the ABI slot needs.
    enum VBuf<'s> {
        U64(crate::ccl::v2::Buffer<'s, u64>),
        F32(crate::ccl::v2::Buffer<'s, f32>),
    }

    impl<'s> VBuf<'s> {
        fn from_bytes(sess: &'s Session, dtype: ElemType, data: &[u8]) -> CclResult<Self> {
            match dtype {
                ElemType::U64 => Ok(VBuf::U64(sess.buffer_from(&u64s(data))?)),
                ElemType::F32 => Ok(VBuf::F32(sess.buffer_from(&f32s(data))?)),
                ElemType::U32 => Err(CclError::framework(
                    "u32 buffers are not used by any workload ABI",
                )),
            }
        }

        fn alloc(sess: &'s Session, dtype: ElemType, bytes: usize) -> CclResult<Self> {
            match dtype {
                ElemType::U64 => Ok(VBuf::U64(sess.buffer(bytes / 8)?)),
                ElemType::F32 => Ok(VBuf::F32(sess.buffer(bytes / 4)?)),
                ElemType::U32 => Err(CclError::framework(
                    "u32 buffers are not used by any workload ABI",
                )),
            }
        }

        fn read_bytes(&self) -> CclResult<Vec<u8>> {
            match self {
                VBuf::U64(b) => Ok(u64_bytes(&b.read_vec()?)),
                VBuf::F32(b) => Ok(f32_bytes(&b.read_vec()?)),
            }
        }
    }

    let shard = Shard::whole(w.units());
    let specs = w.kernels(shard);
    let sess = Session::builder().device_index(device_index).build()?;
    let gen: Vec<hlogen::GenSpec> = specs.iter().map(|s| s.gen_spec()).collect();
    sess.load_specs(&gen)?;

    let mut state = w.init_state();
    let mut last = Vec::new();
    for iter in 0..iters {
        let plan = w.plan(shard, iter, &state);
        let spec = specs[plan.kernel];
        let roles = spec.kind.arg_roles(spec.n, spec.m);

        // Typed buffers per ABI slot.
        let mut in_bufs: Vec<VBuf<'_>> = Vec::with_capacity(plan.inputs.len());
        let mut out_buf: Option<VBuf<'_>> = None;
        {
            let mut data_iter = plan.inputs.iter();
            for role in &roles {
                match role {
                    ArgRole::BufferInput { dtype, .. } => {
                        let data = data_iter.next().ok_or_else(|| {
                            CclError::framework("plan supplies too few input payloads")
                        })?;
                        in_bufs.push(VBuf::from_bytes(&sess, *dtype, data)?);
                    }
                    ArgRole::BufferOutput { dtype, bytes } => {
                        out_buf = Some(VBuf::alloc(&sess, *dtype, *bytes)?);
                    }
                    _ => {}
                }
            }
        }
        let out_buf = out_buf
            .ok_or_else(|| CclError::framework("kernel ABI has no output buffer"))?;

        let dims = w.global_dims(shard, iter);
        let mut launch = sess
            .kernel(spec.kind.module_name())?
            .global_nd(&dims)
            .name(spec.event_name());
        let (mut ii, mut si) = (0usize, 0usize);
        for role in &roles {
            launch = match role {
                ArgRole::BakedScalar { expect_u32, .. } => {
                    launch.arg(expect_u32.unwrap_or(0))
                }
                ArgRole::ScalarInput { .. } => {
                    let v = plan.scalars[si];
                    si += 1;
                    launch.arg(v)
                }
                ArgRole::BufferInput { .. } => {
                    let b = &in_bufs[ii];
                    ii += 1;
                    match b {
                        VBuf::U64(b) => launch.arg(b),
                        VBuf::F32(b) => launch.arg(b),
                    }
                }
                ArgRole::BufferOutput { .. } => match &out_buf {
                    VBuf::U64(b) => launch.arg(b),
                    VBuf::F32(b) => launch.arg(b),
                },
            };
        }
        launch.launch()?;
        // read_bytes is ordered after the launch by the session's
        // implicit last-writer dependency tracking.
        let out = out_buf.read_bytes()?;

        let merged = w.merge(&[shard], &[out]);
        if iter + 1 == iters {
            last = merged;
        } else {
            state = w.next_state(state, merged);
        }
    }
    sess.finish()?;
    Ok(last)
}

/// Run a workload on one explicit [`Backend`] through the uniform
/// contract (compile → alloc/write → enqueue → wait → read), unsharded.
/// This is the single-backend analogue of the other path drivers: same
/// command stream on any substrate, so outputs are directly comparable
/// across backends — `bench native` races [`NativeBackend`] against the
/// interpreting [`PjrtBackend`](crate::backend::PjrtBackend) with it.
pub fn run_backend_path(
    w: &dyn Workload,
    iters: usize,
    b: &dyn Backend,
) -> Result<Vec<u8>, String> {
    let shard = Shard::whole(w.units());
    let specs = w.kernels(shard);
    let mut kernels = Vec::with_capacity(specs.len());
    for spec in &specs {
        kernels.push(b.compile(spec).map_err(|e| e.to_string())?);
    }

    // Backend-tier command recording: each backend is one in-order
    // logical queue, identified by its name. Only built when a
    // recording window is armed (the common case pays one atomic load).
    let rec_space = if arec::enabled() { Some(format!("be:{}", b.name())) } else { None };

    let mut state = w.init_state();
    let mut last = Vec::new();
    for iter in 0..iters {
        let plan = w.plan(shard, iter, &state);
        let spec = specs[plan.kernel];
        let kernel = kernels[plan.kernel];

        let mut in_bufs = Vec::with_capacity(plan.inputs.len());
        for data in &plan.inputs {
            let buf = b.alloc(data.len()).map_err(|e| e.to_string())?;
            let wev = b.write(buf, 0, data).map_err(|e| e.to_string())?;
            if let Some(space) = &rec_space {
                arec::backend_cmd(
                    space,
                    arec::CmdKind::HostWrite,
                    "WRITE_BUFFER",
                    &[],
                    &[buf.0],
                    Some(wev.0),
                    false,
                );
            }
            in_bufs.push(buf);
        }
        let out_buf = b.alloc(plan.out_bytes).map_err(|e| e.to_string())?;
        let args = spec.launch_args(&in_bufs, out_buf, &plan.scalars);
        let ev = b.enqueue(kernel, &args, None).map_err(|e| e.to_string())?;
        if let Some(space) = &rec_space {
            let (reads, writes) = crate::backend::launch_arg_access(&args);
            arec::backend_cmd(
                space,
                arec::CmdKind::Kernel,
                spec.event_name(),
                &reads,
                &writes,
                Some(ev.0),
                false,
            );
        }
        b.wait(ev).map_err(|e| e.to_string())?;
        if let Some(space) = &rec_space {
            arec::backend_host_wait(space, ev.0);
        }
        let mut out = vec![0u8; plan.out_bytes];
        let rev = b.read(out_buf, 0, &mut out).map_err(|e| e.to_string())?;
        if let Some(space) = &rec_space {
            arec::backend_cmd(
                space,
                arec::CmdKind::HostRead,
                "READ_BUFFER",
                &[out_buf.0],
                &[],
                Some(rev.0),
                true,
            );
        }
        for buf in in_bufs {
            b.free(buf);
        }
        b.free(out_buf);

        let merged = w.merge(&[shard], &[out]);
        if iter + 1 == iters {
            last = merged;
        } else {
            state = w.next_state(state, merged);
        }
    }
    Ok(last)
}

/// Run a workload on the native parallel-kernel tier — a fresh
/// [`NativeBackend`] (worker pool and all) driven by
/// [`run_backend_path`].
pub fn run_native_path(w: &dyn Workload, iters: usize) -> Result<Vec<u8>, String> {
    let b = NativeBackend::native().map_err(|e| e.to_string())?;
    run_backend_path(w, iters, &b)
}

/// Run a workload through the multi-backend work-stealing scheduler.
pub fn run_sharded_path<W: Workload + Clone>(
    w: &W,
    iters: usize,
    registry: &BackendRegistry,
) -> CclResult<Vec<u8>> {
    let mut cfg = ShardedConfig::new(w.clone(), iters);
    cfg.min_chunk = (w.units() / 8).max(1);
    let outcome = run_sharded_workload_on(registry, &cfg)?;
    Ok(outcome.final_output)
}
