//! # `workload` — the workload-agnostic execution contract
//!
//! The paper's evaluation rests on one application (the §5 PRNG
//! service); this module decouples *what* is computed from *how* it is
//! executed, EngineCL-style. A [`Workload`] describes an iterated,
//! shardable device computation in four moves:
//!
//! * [`kernels`](Workload::kernels) — the [`CompileSpec`]s a shard of
//!   the index space needs (sharding parameters such as the PRNG
//!   `gid_offset` or a stencil band's halo geometry are baked in here);
//! * [`plan`](Workload::plan) — one iteration's launch: which kernel,
//!   the host payloads for its input buffers, its scalars, and the
//!   output size;
//! * [`merge`](Workload::merge) — how per-shard outputs combine into
//!   the global result (concatenation for elementwise workloads,
//!   partial-sum folding for reductions, halo-trimming for stencils);
//! * [`reference`](Workload::reference) — the host oracle every
//!   execution path must match **bit for bit**.
//!
//! Because the contract speaks in byte payloads and ABI argument roles
//! ([`KernelKind::arg_roles`](crate::rawcl::kernelspec::KernelKind::arg_roles)),
//! one workload definition runs unchanged through all four execution
//! paths: the raw substrate ([`exec::run_raw_path`]), the `ccl` v1
//! framework ([`exec::run_ccl_path`]), the fluent `ccl::v2` session tier
//! ([`exec::run_v2_path`]), and the multi-backend work-stealing
//! scheduler
//! ([`run_sharded_workload`](crate::coordinator::scheduler::run_sharded_workload)).
//!
//! ## Worked example: SAXPY through the trait
//!
//! The iterated SAXPY workload computes `y ← a·x + y` on the device
//! each iteration. Running it is the same three lines on every path:
//!
//! ```no_run
//! use cf4rs::workload::{exec, SaxpyWorkload, Workload};
//!
//! let w = SaxpyWorkload::new(4096, 2.5);
//! let iters = w.default_iters();
//! // Any path; all four produce bit-identical bytes.
//! let v2 = exec::run_v2_path(&w, iters, 0).unwrap();
//! let raw = exec::run_raw_path(&w, iters, 1).unwrap();
//! assert_eq!(v2, raw);
//! assert_eq!(v2, w.reference(iters));
//! ```
//!
//! Implementing a new workload means describing its launch, not its
//! execution. SAXPY's core (see `saxpy.rs`) is literally:
//!
//! * `kernels`: `vec![CompileSpec::saxpy(shard.len)]`;
//! * `plan`: inputs = the `x` slice and the current `y` slice of the
//!   shard, scalars = `[a]`, output = `len × 4` bytes;
//! * `merge`: concatenate shard outputs in order;
//! * `reference`: fold the scalar
//!   [`run_saxpy`](crate::rawcl::simexec::run_saxpy) oracle `iters`
//!   times.

pub mod exec;
mod matmul;
mod prng;
mod reduce;
mod saxpy;
mod stencil;

pub use matmul::MatmulWorkload;
pub use prng::PrngWorkload;
pub use reduce::ReduceWorkload;
pub use saxpy::SaxpyWorkload;
pub use stencil::StencilWorkload;

use crate::backend::CompileSpec;

/// One contiguous shard `[lo, lo+len)` of a workload's principal index
/// space (elements for 1-D workloads, grid/matrix rows for 2-D ones).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub lo: usize,
    pub len: usize,
}

impl Shard {
    /// The un-sharded whole index space.
    pub fn whole(units: usize) -> Self {
        Self { lo: 0, len: units }
    }

    /// Byte range of this shard in a state vector of `unit_bytes`-sized
    /// units.
    pub fn byte_range(&self, unit_bytes: usize) -> std::ops::Range<usize> {
        self.lo * unit_bytes..(self.lo + self.len) * unit_bytes
    }
}

/// One iteration's launch plan for one shard.
pub struct IterPlan {
    /// Index into [`Workload::kernels`] of the kernel to launch.
    pub kernel: usize,
    /// Host payloads for the kernel's buffer-input slots, in ABI order.
    pub inputs: Vec<Vec<u8>>,
    /// Values for the kernel's f32 `ScalarInput` slots, in ABI order.
    pub scalars: Vec<f32>,
    /// Byte size of the shard's output buffer.
    pub out_bytes: usize,
}

/// A deterministic, shardable, iterated device computation — see the
/// [module docs](self) for the contract and a worked SAXPY example.
///
/// Determinism is load-bearing: every path (and every shard split) must
/// produce the same output bits, so floating-point workloads fix their
/// per-element accumulation order and integer reductions use wrapping
/// (associative) arithmetic.
pub trait Workload: Send + Sync {
    /// Short identifier used in reports (`"prng"`, `"saxpy"`, ...).
    fn name(&self) -> &'static str;

    /// Size of the principal index space, in shardable units.
    fn units(&self) -> usize;

    /// Bytes of global state per unit (used to slice shard inputs).
    fn unit_bytes(&self) -> usize;

    /// Iteration count a standard run uses.
    fn default_iters(&self) -> usize {
        1
    }

    /// Global state before iteration 0 (empty when iteration 0 does not
    /// read state, e.g. the PRNG's device-side seeding).
    fn init_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Compile specs a shard needs, in a fixed order [`IterPlan::kernel`]
    /// indexes into.
    fn kernels(&self, shard: Shard) -> Vec<CompileSpec>;

    /// The launch plan for `shard` at `iter`, given the current global
    /// state.
    fn plan(&self, shard: Shard, iter: usize, state: &[u8]) -> IterPlan;

    /// Real (pre-rounding) global work dimensions for the shard's launch
    /// at `iter`. Defaults to 1-D over the shard length; 2-D workloads
    /// override.
    fn global_dims(&self, shard: Shard, iter: usize) -> Vec<usize> {
        let _ = iter;
        vec![shard.len]
    }

    /// Merge per-shard outputs (shard order) into the iteration's global
    /// output.
    fn merge(&self, shards: &[Shard], outputs: &[Vec<u8>]) -> Vec<u8>;

    /// Derive the next global state from the previous state and the
    /// merged output (both by value, so the common "the output *is* the
    /// state" default is a move, not a copy — this sits on the
    /// scheduler's per-iteration hot path). Constant-input workloads
    /// (reduce) keep the previous state instead.
    fn next_state(&self, prev: Vec<u8>, merged: Vec<u8>) -> Vec<u8> {
        let _ = prev;
        merged
    }

    /// Host oracle: the exact bytes every path must produce after
    /// `iters` iterations.
    fn reference(&self, iters: usize) -> Vec<u8>;
}

/// Shared-ownership workloads run anywhere a concrete one does — the
/// compute service holds its queued requests as `Arc<dyn Workload>` and
/// submits them straight into the sharded scheduler through this impl.
impl Workload for std::sync::Arc<dyn Workload> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn units(&self) -> usize {
        (**self).units()
    }

    fn unit_bytes(&self) -> usize {
        (**self).unit_bytes()
    }

    fn default_iters(&self) -> usize {
        (**self).default_iters()
    }

    fn init_state(&self) -> Vec<u8> {
        (**self).init_state()
    }

    fn kernels(&self, shard: Shard) -> Vec<CompileSpec> {
        (**self).kernels(shard)
    }

    fn plan(&self, shard: Shard, iter: usize, state: &[u8]) -> IterPlan {
        (**self).plan(shard, iter, state)
    }

    fn global_dims(&self, shard: Shard, iter: usize) -> Vec<usize> {
        (**self).global_dims(shard, iter)
    }

    fn merge(&self, shards: &[Shard], outputs: &[Vec<u8>]) -> Vec<u8> {
        (**self).merge(shards, outputs)
    }

    fn next_state(&self, prev: Vec<u8>, merged: Vec<u8>) -> Vec<u8> {
        (**self).next_state(prev, merged)
    }

    fn reference(&self, iters: usize) -> Vec<u8> {
        (**self).reference(iters)
    }
}

/// Concatenate shard outputs — the merge of every elementwise workload.
pub(crate) fn concat_outputs(outputs: &[Vec<u8>]) -> Vec<u8> {
    let mut merged = Vec::with_capacity(outputs.iter().map(Vec::len).sum());
    for o in outputs {
        merged.extend_from_slice(o);
    }
    merged
}

/// Decode little-endian f32s.
pub(crate) fn f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Encode f32s little-endian.
pub(crate) fn f32_bytes(vals: &[f32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Decode little-endian u64s.
pub(crate) fn u64s(bytes: &[u8]) -> Vec<u64> {
    bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_byte_range() {
        let s = Shard { lo: 3, len: 4 };
        assert_eq!(s.byte_range(8), 24..56);
        assert_eq!(Shard::whole(10), Shard { lo: 0, len: 10 });
    }

    #[test]
    fn every_workload_names_a_consistent_geometry() {
        let ws: Vec<Box<dyn Workload>> = vec![
            Box::new(PrngWorkload::new(256)),
            Box::new(SaxpyWorkload::new(256, 2.0)),
            Box::new(ReduceWorkload::new(256)),
            Box::new(StencilWorkload::new(16, 16)),
            Box::new(MatmulWorkload::new(16)),
        ];
        for w in &ws {
            let shard = Shard::whole(w.units());
            let specs = w.kernels(shard);
            assert!(!specs.is_empty(), "{}", w.name());
            let state = w.init_state();
            let plan = w.plan(shard, 0, &state);
            assert!(plan.kernel < specs.len(), "{}", w.name());
            let dims = w.global_dims(shard, 0);
            let spec = specs[plan.kernel];
            assert_eq!(
                dims.iter().product::<usize>(),
                spec.n,
                "{}: global dims must cover the kernel size",
                w.name()
            );
            let roles = spec.kind.arg_roles(spec.n, spec.m);
            let buffer_inputs = roles
                .iter()
                .filter(|r| {
                    matches!(r, crate::rawcl::kernelspec::ArgRole::BufferInput { .. })
                })
                .count();
            assert_eq!(plan.inputs.len(), buffer_inputs, "{}", w.name());
        }
    }
}
